"""Benchmark runner: one section per paper table/figure + framework perf.

    PYTHONPATH=src python -m benchmarks.run

Emits ``name,us_per_call,derived`` CSV lines per benchmark at the end.
"""
from __future__ import annotations

import time

import numpy as np

CSV: list[tuple[str, float, str]] = []


def _bench_kernels():
    """Micro wall-times for the Pallas kernels (interpret mode on CPU: this
    measures correctness-path overhead, not TPU perf — the roofline section
    carries the perf numbers)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.conv_fused.ops import fused_conv_block
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.ssm_scan.ops import ssm_scan

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (1, 16, 16, 8)).astype(np.int8))
    w = jnp.asarray(rng.integers(-128, 128, (3, 3, 8, 16)).astype(np.int8))
    b = jnp.asarray(rng.integers(-100, 100, 16).astype(np.int32))

    def timeit(name, fn, derived=""):
        fn()  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn()
        jax.block_until_ready(out)
        CSV.append((name, (time.perf_counter() - t0) / 3 * 1e6, derived))

    timeit("kernel.conv_fused_16x16x8",
           lambda: fused_conv_block(x, w, b, pad=(1, 1), shift=6, relu=True),
           "int8 conv+relu, interpret")
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    timeit("kernel.flash_attention_128",
           lambda: flash_attention(q, k, k, blk_q=32, blk_k=32),
           "causal GQA, interpret")
    qs = jnp.asarray(rng.standard_normal((1, 128, 2, 16)), jnp.float32)
    la = -jnp.abs(jnp.asarray(rng.standard_normal((1, 128, 2)), jnp.float32))
    timeit("kernel.ssm_scan_128",
           lambda: ssm_scan(qs, qs, qs, la, chunk=32),
           "chunked recurrence, interpret")


def main() -> None:
    print("=" * 72)
    print("## Table 3: fusion speedups + compilation cost (ZU2)")
    print("=" * 72)
    from benchmarks.table3 import main as table3_main

    t0 = time.perf_counter()
    table3_main()
    CSV.append(("table3.full", (time.perf_counter() - t0) * 1e6,
                "4 CNNs x 3 strategies, simulator-scored"))

    print("\n" + "=" * 72)
    print("## Table 4: ZU9 batch-3 throughput + energy efficiency")
    print("=" * 72)
    from benchmarks.table4 import main as table4_main

    table4_main()

    print("\n" + "=" * 72)
    print("## Fig. 8/9: micro-fusion cases")
    print("=" * 72)
    from benchmarks.micro_fusion import main as micro_main

    micro_main()

    print("\n" + "=" * 72)
    print("## Table 2: evaluation-method triad")
    print("=" * 72)
    from benchmarks.evaluators import main as eval_main

    eval_main()

    print("\n" + "=" * 72)
    print("## DNNVM planner on LM architectures (lm_bridge)")
    print("=" * 72)
    from repro import configs
    from repro.core import lm_bridge

    for name in configs.ARCHS:
        print("  " + lm_bridge.report(configs.get(name), seq_len=32768))

    print("\n" + "=" * 72)
    print("## Pallas kernel micro-times (interpret mode)")
    print("=" * 72)
    _bench_kernels()

    print("\n" + "=" * 72)
    print("## Roofline (from dry-run artifacts, single pod)")
    print("=" * 72)
    try:
        from benchmarks.roofline import load, pick_hillclimb, table

        rows = load("pod")
        ok = [r for r in rows if r.get("status") == "ok"]
        if ok:
            print(table(rows))
            print("\nhillclimb candidates:", pick_hillclimb(rows))
            CSV.append(("roofline.cells_ok", float(len(ok)),
                        "dry-run cells with receipts"))
        else:
            print("(no dry-run artifacts yet — run "
                  "`python -m repro.launch.dryrun --all` first)")
    except Exception as e:  # roofline is optional when artifacts absent
        print(f"(roofline skipped: {e})")

    print("\nname,us_per_call,derived")
    for name, us, derived in CSV:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
