"""Autotune benchmark: profile-guided search vs the analytic cost model.

For each network this benchmark closes the compiler <-> measurement loop and
reports what it bought:

1. build + quantize the net, search a strategy under the hand-written
   analytic device model (the pre-tuner compiler);
2. calibrate a :class:`~repro.tune.profile.DeviceProfile` on this machine:
   measure the fused-op candidate set through the real executor
   (``tune.MeasurementHarness``) and least-squares fit the cost model's
   coefficients (``tune.calibrate``), reporting the deviation band;
3. search again under the :class:`~repro.tune.evaluator.CalibratedEvaluator`
   and diff the two strategies;
4. when they differ, measure both end-to-end with alternating passes (clock
   drift and interference epochs hit both contenders equally) and report the
   measured delta; identical strategies are reported as a zero delta without
   re-measurement;
5. compile the calibrated strategy under the profile — the artifact records
   the profile hash (``CompiledArtifact.profile_hash``).

--smoke asserts the acceptance gates (calibration deviation within the band,
calibrated strategy measured no slower than the analytic one) and is wired
into ``make ci`` as ``make tune-smoke``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import outdir


def build_quantized(model: str, img: int):
    from repro.cnn import build, init_params
    from repro.core import executor, quantize

    g = build(model, img=img, num_classes=10) if img != 224 else build(model)
    params = init_params(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    return g, qm


def strategy_key(s) -> tuple:
    return (tuple(tuple(grp) for grp in s.groups),
            tuple(tuple(h) for h in s.horizontal))


def bench_model(model: str, img: int, *, backend: str, max_samples: int,
                repeats: int, passes: int, profile_cache=None) -> dict:
    from repro import asm
    from repro.core import pathsearch
    from repro.hw import ZU2
    from repro.tune import CalibratedEvaluator, MeasurementHarness, calibrate

    dev = ZU2
    g, qm = build_quantized(model, img)

    t0 = time.perf_counter()
    s_analytic = pathsearch.search(g, dev)
    t_search_a = time.perf_counter() - t0

    # calibrate on the candidate set PLUS the analytic strategy's own
    # segments, so the fit covers the groups the search actually compares
    from repro.tune.calibrate import default_candidate_groups
    cands = default_candidate_groups(
        g, max_samples=max_samples,
        extra=[list(grp) for grp in s_analytic.groups])
    t0 = time.perf_counter()
    res = calibrate(g, qm, dev, groups=cands, backend=backend,
                    features="kernel", repeats=repeats,
                    name=f"{dev.name}-{backend}-{model}")
    t_cal = time.perf_counter() - t0
    if profile_cache is not None:
        profile_cache.put(res.profile)

    t0 = time.perf_counter()
    ev = CalibratedEvaluator(g, dev, res.profile)
    s_cal = pathsearch.search(g, dev, evaluator=ev)
    t_search_c = time.perf_counter() - t0

    changed = strategy_key(s_analytic) != strategy_key(s_cal)
    rec = {
        "model": model, "img": img, "backend": backend,
        "deviation": res.report["deviation"],
        # stacked (horizontal) launches are measured directly during
        # calibration now; their own deviation band reports separately
        "stacked": res.report.get("stacked"),
        "deviation_by_form": res.report["deviation_by_form"],
        "within_accept_band": res.report["within_accept_band"],
        "model_refit_mape": res.report.get("model_refit_mape"),
        "n_samples": res.report["n_samples"],
        "n_trimmed": res.report["n_trimmed"],
        "combine": res.profile.combine,
        "profile_hash": res.profile.hash(),
        "effective": res.profile.effective_summary(dev),
        "search_s": {"analytic": t_search_a, "calibrated": t_search_c},
        "calibrate_s": t_cal,
        "strategy_changed": changed,
        "n_groups": {"analytic": len(s_analytic.groups),
                     "calibrated": len(s_cal.groups)},
        "n_horizontal": {"analytic": len(s_analytic.horizontal),
                         "calibrated": len(s_cal.horizontal)},
        "predicted_s": {
            "analytic_strategy": ev.strategy_cost(s_analytic),
            "calibrated_strategy": ev.strategy_cost(s_cal)},
    }

    if changed:
        harness = MeasurementHarness(g, qm, dev, backend=backend,
                                     repeats=passes)
        m_a, m_c = harness.measure_strategy_set([s_analytic, s_cal])
        rec["measured_s"] = {"analytic": m_a.seconds, "calibrated": m_c.seconds}
        rec["measured_delta"] = (m_a.seconds - m_c.seconds) / m_a.seconds
        rec["measured_spread"] = {"analytic": m_a.spread,
                                  "calibrated": m_c.spread}
    else:
        rec["measured_s"] = None
        rec["measured_delta"] = 0.0       # same plan, same launches

    # the calibrated strategy compiles under the profile; the artifact
    # records the hash (Session.from_artifact warns on mismatch)
    art, _ = asm.PlanCache().get_or_compile(g, s_cal, dev, qm=qm,
                                            profile=res.profile)
    rec["artifact_profile_hash"] = art.profile_hash
    assert art.profile_hash == res.profile.hash()
    return rec


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", action="append", dest="models",
                    choices=["vgg16", "resnet50", "googlenet"], default=None,
                    help="repeatable; default: all three benchmark nets")
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--backend", default="pallas", choices=["pallas", "ref"])
    ap.add_argument("--max-samples", type=int, default=32,
                    help="calibration candidate-set cap")
    ap.add_argument("--repeats", type=int, default=12,
                    help="measurement passes per calibration unit")
    ap.add_argument("--passes", type=int, default=16,
                    help="alternating end-to-end A/B passes")
    ap.add_argument("--save-profiles", action="store_true",
                    help="write fitted profiles into the on-disk cache "
                         "(benchmarks/out/profiles)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="bare names land in benchmarks/out/ (gitignored)")
    ap.add_argument("--smoke", action="store_true",
                    help="assert deviation band + calibrated strategy not "
                         "measured-slower")
    args = ap.parse_args(argv)
    args.json_path = outdir.resolve(args.json_path)
    models = args.models or ["vgg16", "resnet50", "googlenet"]

    profile_cache = None
    if args.save_profiles:
        from repro.tune import ProfileCache
        profile_cache = ProfileCache(outdir.out_path("profiles"))

    records = []
    for model in models:
        rec = bench_model(model, args.img, backend=args.backend,
                          max_samples=args.max_samples, repeats=args.repeats,
                          passes=args.passes, profile_cache=profile_cache)
        records.append(rec)
        eff = rec["effective"]
        print(f"{model}@{args.img} [{args.backend}] calibration deviation "
              f"{rec['deviation']:.1%} ({rec['combine']} form, "
              f"{rec['n_samples']} units, {rec['n_trimmed']} trimmed, "
              f"{rec['calibrate_s']:.0f}s)")
        stk = rec.get("stacked") or {}
        if stk.get("n_samples"):
            print(f"  stacked launches: {stk['n_samples']} units measured "
                  f"directly, deviation {stk['deviation']:.1%}")
        print(f"  effective: conv {eff['conv_macs_per_cycle'] or float('nan'):.2f} "
              f"MAC/cyc-equiv, launch {eff['launch_overhead_us']:.0f}us")
        if rec["strategy_changed"]:
            ms = rec["measured_s"]
            print(f"  strategy CHANGED ({rec['n_groups']['analytic']} -> "
                  f"{rec['n_groups']['calibrated']} groups, horizontal "
                  f"{rec['n_horizontal']['analytic']} -> "
                  f"{rec['n_horizontal']['calibrated']}); measured e2e "
                  f"{ms['analytic']*1e3:.1f} -> {ms['calibrated']*1e3:.1f} ms "
                  f"({rec['measured_delta']:+.1%} vs analytic)")
        else:
            print("  strategy unchanged (calibrated search agrees with the "
                  "analytic plan); delta 0")

    out = {"img": args.img, "backend": args.backend, "models": records}
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"wrote {args.json_path}")

    if args.smoke:
        for rec in records:
            assert rec["within_accept_band"], (
                f"{rec['model']}: calibration deviation {rec['deviation']:.1%}"
                f" outside the accept band")
            assert rec["measured_delta"] >= -0.05, (
                f"{rec['model']}: calibrated strategy measured slower than "
                f"analytic ({rec['measured_delta']:+.1%})")
        print("TUNE SMOKE OK: deviation in band, calibrated strategy not "
              "measured-slower")
    return out


if __name__ == "__main__":
    main()
