import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Hillclimb workhorse: measure one (arch x shape x mesh) cell with config
overrides and print the roofline terms + memory receipts.

    PYTHONPATH=src python -m benchmarks.perf_iter granite-8b train_4k pod \
        remat_policy=dots attn_impl=xla

Records nothing — the EXPERIMENTS.md §Perf log cites these runs; the final
optimized configuration is re-swept into benchmarks/results/dryrun.
"""
import dataclasses
import sys

import jax

import repro.configs as configs
from repro.configs.base import SHAPES
from benchmarks.roofline import PEAK, HBM, ICI, model_flops


def report(rec):
    t_c = rec["hlo_flops"] / PEAK
    t_m = rec["hlo_bytes"] / HBM
    t_x = rec["collectives"]["total_bytes"] / ICI
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (rec["n_devices"] * PEAK)
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    print(f"{rec['arch']} {rec['shape']} {rec['mesh']}  "
          f"compile={rec['compile_s']}s")
    print(f"  t_compute={t_c:.3f}s t_memory={t_m:.3f}s t_collective={t_x:.3f}s"
          f"  dominant={dom[1]}")
    print(f"  per-dev flops={rec['hlo_flops']:.4g} bytes={rec['hlo_bytes']:.4g}"
          f" coll={rec['collectives']['total_bytes']:.4g}")
    print(f"  coll by op: "
          f"{ {k: f'{v:.3g}' for k, v in rec['collectives']['bytes_by_op'].items()} }")
    print(f"  MODEL_FLOPS={mf:.3g} useful_ratio="
          f"{mf / max(rec['hlo_flops'] * rec['n_devices'], 1):.3f} "
          f"roofline_frac={useful / max(dom[0], 1e-12):.4f}")
    print(f"  mem/device: args={rec.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
          f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    return dom


def main():
    arch, shape, mesh = sys.argv[1:4]
    overrides = dict(kv.split("=", 1) for kv in sys.argv[4:])
    cfg0 = configs.get(arch)
    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg0, k)
        if isinstance(cur, bool):
            typed[k] = v.lower() in ("1", "true")
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    cfg = dataclasses.replace(cfg0, **typed)
    configs.ARCHS[arch] = cfg
    from repro.launch import dryrun

    rec = dryrun.run_cell(arch, shape, mesh)
    configs.ARCHS[arch] = cfg0
    report(rec)


if __name__ == "__main__":
    main()
