"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun) and
derives, per (arch x shape) on the single-pod mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / ICI_bw

(dry-run cost_analysis numbers are per-device SPMD-program totals — verified
against a known matmul in tests — so chip count divides out of the formulas.)

Also: dominant bottleneck, MODEL_FLOPS (6*N*D train / 2*N*D inference, active
params for MoE), useful-compute ratio, roofline fraction
(= model-useful compute time / dominant term), and a what-to-do note.

    PYTHONPATH=src python -m benchmarks.roofline [--write]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro import configs
from repro.configs.base import SHAPES

PEAK = 197e12          # bf16 FLOP/s per chip
HBM = 819e9            # B/s per chip
ICI = 50e9             # B/s per link (per-chip collective bytes / this)

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = configs.get(arch)
    sh = SHAPES[shape_name]
    n = cfg.n_params_active if cfg.moe else cfg.n_params
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * sh.global_batch  # decode: one token per sequence


def load(mesh: str = "pod", results_dir: str | None = None) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir or RESULTS,
                                              f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            rows.append(rec)
            continue
        n_dev = rec["n_devices"]
        flops_dev = rec.get("hlo_flops", rec.get("hlo_flops_body", 0.0))
        bytes_dev = rec.get("hlo_bytes", rec.get("hlo_bytes_body", 0.0))
        coll_dev = rec.get("collectives", {}).get("total_bytes", 0)
        t_c = flops_dev / PEAK
        t_m = bytes_dev / HBM
        t_x = coll_dev / ICI
        dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
        mf = model_flops(rec["arch"], rec["shape"])
        useful = mf / (n_dev * PEAK)
        rec.update({
            "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dom[1], "t_dominant": dom[0],
            "model_flops": mf,
            "useful_ratio": mf / max(flops_dev * n_dev, 1e-9),
            "roofline_fraction": useful / max(dom[0], 1e-12),
        })
        rec["note"] = _note(rec)
        rows.append(rec)
    return rows


def _note(r) -> str:
    d = r["dominant"]
    if d == "collective":
        ops = r["collectives"]["bytes_by_op"]
        top = max(ops, key=ops.get) if ops else "?"
        return (f"dominated by {top}; reduce via rs+ag instead of ar, "
                f"overlap with compute, or shard activations less")
    if d == "memory":
        if r["kind"] == "decode":
            return "HBM-bound KV/weight streaming; quantize cache or batch more"
        return "HBM-bound; better fusion / remat policy to cut re-reads"
    if r["useful_ratio"] < 0.3:
        return ("compute-bound but low useful ratio: remat recompute + "
                "quadratic attention dominate; flash kernel / selective remat")
    return "compute-bound near roofline; little headroom"


def table(rows, fmt="md") -> str:
    hdr = ("arch", "shape", "t_comp(s)", "t_mem(s)", "t_coll(s)", "dominant",
           "MODEL_FLOPS", "useful", "roofline_frac")
    lines = []
    if fmt == "md":
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    ok = [r for r in rows if r.get("status") == "ok"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        vals = (r["arch"], r["shape"], f"{r['t_compute']:.3e}",
                f"{r['t_memory']:.3e}", f"{r['t_collective']:.3e}",
                r["dominant"], f"{r['model_flops']:.2e}",
                f"{r['useful_ratio']:.3f}", f"{r['roofline_fraction']:.3f}")
        lines.append("| " + " | ".join(vals) + " |" if fmt == "md"
                     else ",".join(vals))
    bad = [r for r in rows if r.get("status") != "ok"]
    for r in bad:
        lines.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                     f"{r.get('error', '')[:60]} | | | | | | |")
    return "\n".join(lines)


def pick_hillclimb(rows) -> dict:
    ok = [r for r in rows if r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    coll = max(ok, key=lambda r: r["t_collective"] / max(r["t_dominant"], 1e-12))
    # most representative of the paper's technique: the fusion-sensitive
    # attention-heavy prefill cell with the largest (memory+useless-compute)
    # overhead that kernel fusion addresses
    rep = min((r for r in ok if r["kind"] == "prefill"),
              key=lambda r: r["useful_ratio"])
    return {"worst_fraction": (worst["arch"], worst["shape"]),
            "most_collective": (coll["arch"], coll["shape"]),
            "paper_representative": (rep["arch"], rep["shape"])}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--dir", default=None, help="alternate results dir")
    args = ap.parse_args()
    rows = load(args.mesh, args.dir)
    out = table(rows)
    print(out)
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        print("\nhillclimb candidates:", pick_hillclimb(rows))
    if args.write:
        path = os.path.join(os.path.dirname(__file__), "results",
                            f"roofline_{args.mesh}.md")
        with open(path, "w") as f:
            f.write(out + "\n")
        print("wrote", path)


if __name__ == "__main__":
    main()
