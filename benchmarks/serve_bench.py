"""Serving benchmark: dynamic batching vs sequential per-request execution.

What Table 3 is to the compiler, this is to the runtime supporter: build a
model, compile it once through the plan cache, then serve R requests two
ways —

* **sequential**: one `Session.run` per request, back to back (the naive
  host loop every toolflow starts with);
* **batched**: all requests submitted to the dynamic-batching `Server`
  (optionally at a paced offered load), which flushes them as batched
  launches — ONE executor call covers a whole batch.

Reported per mode: wall-clock images/s, p50/p99 request latency, and the
batch-size histogram.  Every served output is audited bit-exact against the
unfused int8 oracle (the validation environment's contract extends to the
serving path), and the artifact's addressed instruction stream is pipelined
across requests on the time wheel (`runtime.pipeline_report`) to report the
modeled per-engine utilization / overlap next to the measured wall clock.

--smoke asserts the acceptance criteria (batched > sequential throughput,
bit-exactness, hazard-free pipelined stream) and is wired into `make ci`.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def build_session(model: str, img: int, backend: str, use_host_partition: bool,
                  profile=None):
    from repro.cnn import build, init_params
    from repro.core import executor, partition, pathsearch, quantize
    from repro.hw import ZU2
    from repro.runtime import Session
    from repro.runtime.session import _resolve_profile

    g = build(model, img=img, num_classes=10) if img != 224 else build(model)
    params = init_params(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    dv = partition.device_of(g, "paper") if use_host_partition else None
    profile = _resolve_profile(profile)
    evaluator = None
    if profile is not None:
        from repro.tune import CalibratedEvaluator
        evaluator = CalibratedEvaluator(g, ZU2, profile)
    t0 = time.perf_counter()
    strategy = pathsearch.search(g, ZU2, evaluator=evaluator, device_of=dv)
    t_search = time.perf_counter() - t0
    t0 = time.perf_counter()
    sess = Session(g, strategy, ZU2, qm, backend=backend, profile=profile)
    t_compile = time.perf_counter() - t0
    return sess, {"search_s": t_search, "compile_s": t_compile}


def drift_summary(sess) -> dict:
    """Modeled-vs-measured drift of the served plan, when the session carries
    a device profile (see ``repro.obs.drift``); cheap to skip when it
    doesn't — serve_bench's default analytic run has nothing to drift from."""
    if sess.profile is None:
        return {"available": False, "reason": "no device profile"}
    from repro.obs import DriftProfiler

    dp = DriftProfiler.from_session(sess, every=1)
    dp.prepare()
    dp.sample()
    rep = dp.report()
    return {"available": True, **rep.to_json()}


def make_requests(sess, n: int, seed: int = 1):
    from repro.core import quantize

    g, qm = sess.graph, sess.qm
    rng = np.random.default_rng(seed)
    shape = g.shape("data")
    return [quantize.quantize_to(
        rng.standard_normal((1,) + tuple(shape[1:])).astype(np.float32),
        qm.f_a["data"]) for _ in range(n)]


def run_sequential(sess, reqs) -> dict:
    sess.run(reqs[0])                      # warm the batch-1 trace
    lat = []
    t0 = time.perf_counter()
    outs = []
    for x in reqs:
        t1 = time.perf_counter()
        outs.append(sess.run(x))
        lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    lat.sort()
    return {"outputs": outs, "wall_s": wall,
            "images_per_s": len(reqs) / wall,
            "p50_ms": lat[len(lat) // 2] * 1e3,
            "p99_ms": lat[min(len(lat) - 1, int(0.99 * (len(lat) - 1)))] * 1e3}


def run_batched(sess, reqs, *, max_batch: int, max_latency_s: float,
                offered_load: float | None = None,
                target_p99_ms: float | None = None) -> dict:
    server = sess.serve(max_batch=max_batch, max_latency_s=max_latency_s,
                        target_p99_ms=target_p99_ms)
    try:
        t0 = time.perf_counter()
        futs = []
        for i, x in enumerate(reqs):
            futs.append(server.submit(x))
            if offered_load and i + 1 < len(reqs):  # paced; None = burst
                time.sleep(1.0 / offered_load)
        outs = [f.result(timeout=120) for f in futs]
        wall = time.perf_counter() - t0
        stats = server.stats()
    finally:
        server.close()
    return {"outputs": outs, "wall_s": wall,
            "images_per_s": len(reqs) / wall,
            "p50_ms": stats["p50_ms"], "p99_ms": stats["p99_ms"],
            "batch_histogram": stats["batch_histogram"],
            "mean_batch": stats["mean_batch"],
            "target_p99_ms": stats["target_p99_ms"],
            "effective_max_batch": stats["effective_max_batch"],
            "slo_shrinks": stats["slo_shrinks"],
            "slo_grows": stats["slo_grows"]}


def audit_bit_exact(sess, reqs, *out_lists) -> list[bool]:
    """Each list of served outputs must match the unfused int8 oracle
    exactly; the oracle runs ONCE per request however many lists compare."""
    from repro.core.executor import Int8Executor

    oracle = Int8Executor(sess.graph, sess.qm, strategy=None, backend="ref")
    keys = set(sess.outputs)
    refs = [oracle(x) for x in reqs]
    return [all(np.array_equal(ref[k], got[k])
                for ref, got in zip(refs, outs) for k in keys)
            for outs in out_lists]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="vgg16",
                    choices=["vgg16", "resnet50", "googlenet"])
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-latency-ms", type=float, default=5.0)
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--loads", type=float, nargs="*", default=None,
                    help="offered loads (req/s) to sweep; always includes "
                         "an unpaced burst")
    ap.add_argument("--ddr-slots", type=int, nargs="*", default=[2, 4])
    ap.add_argument("--host-partition", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="deploy fc layers on the host (paper §6.1)")
    ap.add_argument("--target-p99-ms", type=float, default=None,
                    help="latency SLO: shrink the effective max batch while "
                         "the observed p99 exceeds this target")
    ap.add_argument("--profile", default=None,
                    help="calibrated device profile (name or JSON path) to "
                         "search/compile under; also enables the drift "
                         "summary in the JSON output")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="bare names land in benchmarks/out/ (gitignored)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="alternate sequential/batched trials this many "
                         "times and keep the best of each (controls for "
                         "clock-speed drift on throttled boxes)")
    ap.add_argument("--smoke", action="store_true",
                    help="assert batched beats sequential + bit-exactness")
    args = ap.parse_args(argv)
    import outdir
    args.json_path = outdir.resolve(args.json_path)
    if args.smoke and args.repeats < 3:
        args.repeats = 3

    sess, compile_times = build_session(
        args.model, args.img, args.backend, args.host_partition,
        profile=args.profile)
    reqs = make_requests(sess, args.requests)
    print(f"{args.model}@{args.img} backend={args.backend} "
          f"requests={args.requests} fused_coverage="
          f"{sess.artifact.fused_coverage:.2f} "
          f"(search {compile_times['search_s']:.2f}s, "
          f"compile {compile_times['compile_s']:.2f}s)")

    # alternate the two modes so slow clock drift (thermal throttling) hits
    # both equally, then keep each mode's best trial
    seq = burst = None
    for _ in range(max(1, args.repeats)):
        got = run_sequential(sess, reqs)
        if seq is None or got["images_per_s"] > seq["images_per_s"]:
            seq = got
        got = run_batched(sess, reqs, max_batch=args.max_batch,
                          max_latency_s=args.max_latency_ms * 1e-3,
                          target_p99_ms=args.target_p99_ms)
        if burst is None or got["images_per_s"] > burst["images_per_s"]:
            burst = got
    print(f"sequential : {seq['images_per_s']:8.2f} img/s  "
          f"p50={seq['p50_ms']:.2f}ms p99={seq['p99_ms']:.2f}ms")
    sweeps = [{"offered_load": None, **{k: v for k, v in burst.items()
                                        if k != "outputs"}}]
    print(f"batched    : {burst['images_per_s']:8.2f} img/s  "
          f"p50={burst['p50_ms']:.2f}ms p99={burst['p99_ms']:.2f}ms  "
          f"batches={burst['batch_histogram']} (burst)")
    for load in (args.loads or []):
        got = run_batched(sess, reqs, max_batch=args.max_batch,
                          max_latency_s=args.max_latency_ms * 1e-3,
                          offered_load=load)
        sweeps.append({"offered_load": load,
                       **{k: v for k, v in got.items() if k != "outputs"}})
        print(f"batched@{load:6.0f}/s: {got['images_per_s']:8.2f} img/s  "
              f"p50={got['p50_ms']:.2f}ms p99={got['p99_ms']:.2f}ms  "
              f"batches={got['batch_histogram']}")

    exact_seq, exact_bat = audit_bit_exact(sess, reqs, seq["outputs"],
                                           burst["outputs"])
    print(f"bit-exact vs oracle: sequential={exact_seq} batched={exact_bat}")

    # pinned-input variant of the same plan: the input's DDR region leaves
    # the reuse pool, the cross-request pre-load guard disappears
    from repro.runtime.schedule import choose_ddr_slots
    from repro.runtime.schedule import pipeline_report as _pipe_report
    pinned_art, _ = sess.cache.get_or_compile(
        sess.graph, sess.artifact, sess.device, qm=sess.qm, pin_input=True)
    auto_rep = sess.pipeline_report(min(args.requests, 8), ddr_slots=None)
    print(f"auto ddr_slots: {auto_rep.ddr_slots} "
          f"(source={auto_rep.ddr_slots_source}, DRAM/compute ratio decides "
          f"the double-buffer depth)")
    pipe = {"auto": {"ddr_slots": auto_rep.ddr_slots,
                     "ddr_slots_source": auto_rep.ddr_slots_source,
                     "modeled_speedup": auto_rep.modeled_speedup,
                     "overlap": auto_rep.overlap}}
    for slots in args.ddr_slots:
        rep = sess.pipeline_report(min(args.requests, 8), ddr_slots=slots)
        repp = _pipe_report(pinned_art, min(args.requests, 8),
                            ddr_slots=slots)
        pipe[slots] = {
            "modeled_speedup": rep.modeled_speedup,
            "overlap": rep.overlap,
            "utilization": rep.utilization(),
            "bottleneck": rep.bottleneck,
            "single_request_cycles": rep.single_request_cycles,
            "total_cycles": rep.total_cycles,
            "n_preload_guards": rep.n_preload_guards,
            "pinned": {"overlap": repp.overlap,
                       "modeled_speedup": repp.modeled_speedup,
                       "n_preload_guards": repp.n_preload_guards,
                       "peak_ddr_bytes": pinned_art.peak_ddr_bytes},
        }
        u = {k: round(v, 2) for k, v in rep.utilization().items()}
        print(f"time-wheel pipeline (ddr_slots={slots}): "
              f"modeled speedup {rep.modeled_speedup:.3f}x, "
              f"overlap {rep.overlap:.1%}, bottleneck {rep.bottleneck}, "
              f"util {u} (hazard-free)")
        print(f"  pin_input: overlap {rep.overlap:.2%} -> {repp.overlap:.2%}, "
              f"pre-load guards {rep.n_preload_guards} -> "
              f"{repp.n_preload_guards}, peak DDR "
              f"{sess.artifact.peak_ddr_bytes} -> {pinned_art.peak_ddr_bytes}B")

    # observability payload: the shared metrics registry has been counting
    # the whole run (plan cache, executor launches, serve histograms); the
    # drift summary compares measured unit times against the profile's
    # predictions when the session was compiled under one
    from repro.obs import REGISTRY
    metrics_snapshot = REGISTRY.snapshot()
    drift = drift_summary(sess)
    if drift["available"]:
        print(f"drift: aggregate={drift['aggregate_deviation']:.3f} "
              f"band={drift['band']:.3f} drifted={drift['drifted']}")

    out = {
        "model": args.model, "img": args.img, "backend": args.backend,
        "requests": args.requests, "max_batch": args.max_batch,
        "max_latency_ms": args.max_latency_ms,
        "fused_coverage": sess.artifact.fused_coverage,
        **compile_times,
        "sequential": {k: v for k, v in seq.items() if k != "outputs"},
        "batched": sweeps,
        "bit_exact": {"sequential": exact_seq, "batched": exact_bat},
        "pipeline": pipe,
        "batched_vs_sequential": burst["images_per_s"] / seq["images_per_s"],
        "metrics": metrics_snapshot,
        "drift": drift,
    }
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"wrote {args.json_path}")

    if args.smoke:
        assert exact_seq and exact_bat, "served outputs diverged from oracle"
        assert burst["images_per_s"] > seq["images_per_s"], (
            f"dynamic batching must beat sequential serving: "
            f"{burst['images_per_s']:.2f} <= {seq['images_per_s']:.2f} img/s")
        assert all(p["utilization"] for p in pipe.values()
                   if "utilization" in p)
        assert pipe["auto"]["ddr_slots"] >= 1
        for slots, p in pipe.items():
            if "pinned" not in p:
                continue
            assert p["pinned"]["n_preload_guards"] == 0, (
                "pinned input plan must carry zero pre-load guards")
            assert p["pinned"]["overlap"] >= p["overlap"] - 1e-3, (
                f"pin_input regressed modeled overlap at ddr_slots={slots}: "
                f"{p['pinned']['overlap']:.4f} < {p['overlap']:.4f}")
        print("SMOKE OK: batched > sequential, bit-exact, hazard-free "
              "pipeline, pin_input guard-free")
    return out


if __name__ == "__main__":
    main()
