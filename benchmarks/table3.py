"""Reproduce paper Table 3: fusion speedups + compilation cost on ZU2@330MHz.

Columns mirror the paper: node size, graph generation (ms), isomorphism
fusion (ms), evaluation (ms), auto-tuning / path search (ms), then simulated
throughput for baseline (no kernel fusion), greedy fusion, and optimized
(DNNVM path-searched) fusion, and the speedup.

Paper reference points (ZU2, peak 380 GOPs/s):
  VGG       32 nodes  baseline 325.5  optimized 334.0   1.03x
  ResNet50  120       baseline 195.4  optimized 228.7   1.17x
  ResNet152 358       baseline 212.5  optimized 244.1   1.15x
  GoogLeNet 137       baseline 183.1  optimized 231.5   1.26x
Throughput counts FC layers on the CPU (excluded), as deployed in §6.1.
"""
from __future__ import annotations

import time

from repro.cnn import build
from repro.core import partition, pathsearch
from repro.core.cost import AnalyticEvaluator, SimulatorEvaluator
from repro.hw import ZU2, ZU9, get_device

PAPER = {  # model -> (baseline GOPs/s, greedy, optimized)
    "vgg16": (325.5, 331.5, 334.0),
    "resnet50": (195.4, 221.9, 228.7),
    "resnet152": (212.5, 233.0, 244.1),
    "googlenet": (183.1, 204.6, 231.5),
}


def run_model(name: str, device="zu2", evaluator_kind: str = "simulator",
              verbose: bool = True) -> dict:
    dev = get_device(device)
    t0 = time.perf_counter()
    g = build(name)
    t_gen = (time.perf_counter() - t0) * 1e3

    dv = partition.device_of(g, "paper")
    acc_ops = sum(g.ops(n.name) for n in g if dv(n.name) == "acc")

    t0 = time.perf_counter()
    from repro.core import isomorphism, templates
    matches = isomorphism.find_all(g, templates.ALL_TEMPLATES)
    t_iso = (time.perf_counter() - t0) * 1e3
    n_embeddings = sum(len(v) for v in matches.values())

    sim = SimulatorEvaluator(g, dev)
    ev = sim if evaluator_kind == "simulator" else AnalyticEvaluator(g, dev)

    t0 = time.perf_counter()
    naive = pathsearch.naive(g, dev, evaluator=ev, device_of=dv)
    t_eval = (time.perf_counter() - t0) * 1e3

    greedy = pathsearch.greedy(g, dev, evaluator=ev, device_of=dv)

    t0 = time.perf_counter()
    opt = pathsearch.search(g, dev, evaluator=ev, device_of=dv)
    t_tune = (time.perf_counter() - t0) * 1e3

    # memory planning + artifact compilation (cold, then plan-cache hit) —
    # the data-layout half of the compiler the throughput columns ride on
    from repro import asm
    t0 = time.perf_counter()
    art, _ = asm.PLAN_CACHE.get_or_compile(g, opt, dev)
    t_compile_cold = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    _, cache_hit = asm.PLAN_CACHE.get_or_compile(g, opt, dev)
    t_compile_hit = (time.perf_counter() - t0) * 1e3
    assert cache_hit, "plan cache must hit on identical (graph, device, strategy)"

    # lowered-program audit: how much of the searched strategy actually runs
    # fused, and the explicit reason for every group that does not
    pm = art.program.meta

    # authoritative timing: the cycle simulator over the full strategy
    def sim_seconds(strategy):
        return sim.strategy_report(strategy).seconds(dev.freq_hz)

    res = {}
    for kind, s in (("baseline", naive), ("greedy", greedy), ("optimized", opt)):
        secs = sim_seconds(s)
        res[kind] = {
            "sim_ms": secs * 1e3,
            "gops": acc_ops / secs / 1e9,
            "n_groups": len(s.groups) + len(s.horizontal),
        }
    out = {
        "model": name, "device": device, "nodes": len(g),
        "acc_gops_workload": acc_ops / 1e9,
        "graph_gen_ms": t_gen, "isomorphism_ms": t_iso,
        "n_embeddings": n_embeddings,
        "evaluation_ms": t_eval, "autotune_ms": t_tune,
        **{f"{k}_{m}": v for k, r in res.items() for m, v in r.items()},
        "ddr_peak_mb": art.peak_ddr_bytes / 1e6,
        "ddr_no_reuse_mb": art.mem_summary["no_reuse_bytes"] / 1e6,
        "ddr_reuse_factor": art.reuse_factor,
        "compile_cold_ms": t_compile_cold,
        "compile_cached_ms": t_compile_hit,
        "fused_launches": pm["n_launches"],
        "fused_coverage": art.fused_coverage,
        "fallback_ratio": 1.0 - art.fused_coverage,
        "fallback_reasons": {k: v for k, v in pm["fallback_reasons"].items()
                             if k not in ("host_op", "folded_concat")},
        "speedup": res["baseline"]["sim_ms"] / res["optimized"]["sim_ms"],
        "greedy_speedup": res["baseline"]["sim_ms"] / res["greedy"]["sim_ms"],
        "util_baseline": res["baseline"]["gops"] * 1e9 / dev.peak_ops_per_s,
        "util_optimized": res["optimized"]["gops"] * 1e9 / dev.peak_ops_per_s,
    }
    if verbose:
        p = PAPER.get(name)
        print(f"{name:10s} nodes={out['nodes']:4d} gen={t_gen:7.2f}ms "
              f"iso={t_iso:8.2f}ms tune={t_tune:8.2f}ms | "
              f"base={out['baseline_gops']:6.1f} greedy={out['greedy_gops']:6.1f} "
              f"opt={out['optimized_gops']:6.1f} GOPs/s "
              f"speedup={out['speedup']:.3f}x (greedy {out['greedy_speedup']:.3f}x)"
              + (f" | paper: {p[0]}/{p[1]}/{p[2]} {p[2]/p[0]:.2f}x" if p else ""))
        print(f"{'':10s} ddr_peak={out['ddr_peak_mb']:.2f}MB "
              f"(no-reuse {out['ddr_no_reuse_mb']:.2f}MB, "
              f"{out['ddr_reuse_factor']:.2f}x reuse) "
              f"compile cold={out['compile_cold_ms']:.1f}ms "
              f"cached={out['compile_cached_ms']:.2f}ms")
        print(f"{'':10s} fused_launches={out['fused_launches']} "
              f"coverage={out['fused_coverage']:.3f} "
              f"fallback_ratio={out['fallback_ratio']:.3f}"
              + (f" reasons={out['fallback_reasons']}"
                 if out['fallback_reasons'] else ""))
    return out


def main() -> None:
    print(f"# Table 3 reproduction — ZU2 @330MHz, peak {ZU2.peak_ops_per_s/1e9:.0f} GOPs/s")
    rows = []
    for name in ("vgg16", "resnet50", "resnet152", "googlenet"):
        rows.append(run_model(name))
    print("\nname,nodes,gen_ms,iso_ms,tune_ms,base_gops,greedy_gops,opt_gops,speedup,"
          "ddr_peak_mb,ddr_reuse,compile_cold_ms,compile_cached_ms,"
          "fused_launches,fused_coverage,fallback_ratio")
    for r in rows:
        print(f"{r['model']},{r['nodes']},{r['graph_gen_ms']:.2f},{r['isomorphism_ms']:.2f},"
              f"{r['autotune_ms']:.2f},{r['baseline_gops']:.1f},{r['greedy_gops']:.1f},"
              f"{r['optimized_gops']:.1f},{r['speedup']:.3f},"
              f"{r['ddr_peak_mb']:.2f},{r['ddr_reuse_factor']:.2f},"
              f"{r['compile_cold_ms']:.1f},{r['compile_cached_ms']:.2f},"
              f"{r['fused_launches']},{r['fused_coverage']:.3f},"
              f"{r['fallback_ratio']:.3f}")


if __name__ == "__main__":
    main()
