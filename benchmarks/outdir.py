"""Canonical output location for benchmark artifacts: benchmarks/out/.

Benchmarks used to drop JSON files into whatever the current working
directory happened to be (``serve_bench.json`` landed in the repo root when
run through make).  Everything now funnels through :func:`resolve`: bare file
names land in the gitignored ``benchmarks/out/`` directory, explicit paths
(anything containing a directory separator) are honored as given.
"""
from __future__ import annotations

import os

OUT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "out")


def out_path(name: str) -> str:
    """benchmarks/out/<name>, creating the directory on first use."""
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, name)


def resolve(path: str | None) -> str | None:
    """Route a bare file name into benchmarks/out/; pass explicit paths (and
    None) through untouched."""
    if path is None:
        return None
    if os.path.dirname(path):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        return path
    return out_path(path)
