"""Model-zoo benchmark: multi-tenant co-resident serving vs swap-per-model.

The staged-pipeline/zoo counterpart of ``serve_bench``: compile THREE nets
once through the staged compile pipeline into a content-addressed zoo, then
serve a skewed mixed-traffic request stream (default 60/30/10) two ways —

* **swapped**: the one-model-at-a-time baseline — for each model in turn,
  open a fresh session from its zoo artifact (paying the swap-in) and run
  its requests back to back;
* **co-resident**: all models admitted to one ``MultiServer`` (per-tenant
  SLO classes gold/silver/best_effort, per-model DDR partition, labelled
  metrics), the mixed stream routed per request.

Also measured, via the stage-cache metrics counters: a warm recompile of
every model must hit all four stage caches (0 stages built), and a zoo
reopen from a COLD stage cache must build nothing past the trivial wrap
(the artifact comes off disk, search/lower/plan/compile never run).

--smoke asserts the acceptance gates (cross-model bit-exactness against the
unfused int8 oracle, co-resident > swapped throughput, warm reopen compiles
0 stages) and is wired into `make ci`.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from serve_bench import audit_bit_exact, make_requests

SLO_ORDER = ("gold", "silver", "best_effort")


def build_model(model: str, img: int):
    from repro.cnn import build, init_params
    from repro.core import executor, quantize

    g = build(model, img=img, num_classes=10) if img != 224 else build(model)
    params = init_params(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    return g, qm


def _stage_counts(reg, what: str) -> dict:
    from repro.stages import STAGE_NAMES
    return {s: (reg.get(f"stages.{s}.{what}").value
                if reg.get(f"stages.{s}.{what}") else 0.0)
            for s in STAGE_NAMES}


def _delta(after: dict, before: dict) -> dict:
    return {k: after[k] - before[k] for k in after}


def make_traffic(models: list[str], weights: list[float], n: int, seed=7):
    """Skewed mixed stream: n (model, request-index) draws, weights-shuffled
    but deterministic."""
    rng = np.random.default_rng(seed)
    w = np.asarray(weights, float)
    draws = rng.choice(len(models), size=n, p=w / w.sum())
    # every model serves at least one request, whatever the skew
    for i in range(len(models)):
        if not (draws == i).any():
            draws[i] = i
    counts = {m: int((draws == i).sum()) for i, m in enumerate(models)}
    return list(draws), counts


def run_swapped(artifacts: dict, reqs_by_model: dict, backend: str) -> dict:
    """One model at a time: swap in (fresh session from the zoo artifact),
    drain that tenant's requests sequentially, swap out."""
    from repro.runtime import Session

    outs = {m: [] for m in artifacts}
    swap_s = {}
    t0 = time.perf_counter()
    for m, art in artifacts.items():
        t1 = time.perf_counter()
        sess = Session.from_artifact(art, backend=backend)
        sess.run(reqs_by_model[m][0])          # trace, as a swap-in would
        swap_s[m] = time.perf_counter() - t1
        for x in reqs_by_model[m]:
            outs[m].append(sess.run(x))
    wall = time.perf_counter() - t0
    n = sum(len(v) for v in reqs_by_model.values())
    return {"outputs": outs, "wall_s": wall, "images_per_s": n / wall,
            "swap_s": swap_s}


def run_multiserver(sessions: dict, stream, reqs_by_model: dict, *,
                    max_batch: int, max_latency_s: float) -> dict:
    from repro.runtime import MultiServer

    names = list(sessions)
    ms = MultiServer()
    for name, slo in zip(names, SLO_ORDER):
        ms.add_model(name, sessions[name], slo=slo, max_batch=max_batch,
                     max_latency_s=max_latency_s)
    try:
        cursors = {m: 0 for m in names}
        futs = []
        t0 = time.perf_counter()
        for i in stream:
            name = names[i]
            x = reqs_by_model[name][cursors[name]]
            cursors[name] += 1
            futs.append((name, ms.submit(name, x)))
        outs = {m: [] for m in names}
        for name, f in futs:
            outs[name].append(f.result(timeout=120))
        wall = time.perf_counter() - t0
        stats = ms.stats()
    finally:
        ms.close()
    n = len(futs)
    return {"outputs": outs, "wall_s": wall, "images_per_s": n / wall,
            "stats": stats}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", nargs="*",
                    default=["vgg16", "resnet50", "googlenet"])
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--requests", type=int, default=30,
                    help="total requests across all tenants")
    ap.add_argument("--mix", type=float, nargs="*", default=[60, 30, 10],
                    help="traffic skew across --models (normalized)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-latency-ms", type=float, default=2.0)
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--zoo-dir", default=None,
                    help="zoo root (default: a fresh temp dir)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="bare names land in benchmarks/out/ (gitignored)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="alternate swapped/co-resident trials and keep the "
                         "best of each (controls for clock drift)")
    ap.add_argument("--smoke", action="store_true",
                    help="assert bit-exactness, co-resident > swapped, and "
                         "warm reopen compiles 0 stages")
    args = ap.parse_args(argv)
    import outdir
    args.json_path = outdir.resolve(args.json_path)
    if args.smoke and args.repeats < 3:
        args.repeats = 3
    assert len(args.mix) == len(args.models)

    from repro.hw import ZU2
    from repro.obs import REGISTRY
    from repro.stages import StageCache, compile_model
    from repro.zoo import ModelZoo

    zoo = ModelZoo(args.zoo_dir or tempfile.mkdtemp(prefix="dnnvm-zoo-"))
    sc = StageCache()

    # ---- phase 1: compile once into the zoo (cold) ----------------------
    built, compiled, compile_s = {}, {}, {}
    for m in args.models:
        g, qm = build_model(m, args.img)
        built[m] = (g, qm)
        t0 = time.perf_counter()
        compiled[m] = compile_model(g, qm, ZU2, zoo=zoo, name=m, cache=sc)
        compile_s[m] = time.perf_counter() - t0
        print(f"compiled {m}@{args.img}: key={compiled[m].key} "
              f"({compile_s[m]:.2f}s, fused_coverage="
              f"{compiled[m].artifact.fused_coverage:.2f})")
    assert len(zoo) == len(args.models)

    # ---- warm recompile: all four stage caches must hit -----------------
    miss0, hit0 = _stage_counts(REGISTRY, "misses"), _stage_counts(REGISTRY,
                                                                   "hits")
    t0 = time.perf_counter()
    for m in args.models:
        g, qm = built[m]
        co = compile_model(g, qm, ZU2, cache=sc)
        assert co.key == compiled[m].key
    warm_s = time.perf_counter() - t0
    warm_miss = _delta(_stage_counts(REGISTRY, "misses"), miss0)
    warm_hit = _delta(_stage_counts(REGISTRY, "hits"), hit0)
    print(f"warm recompile x{len(args.models)}: {warm_s:.3f}s, "
          f"stage hits {warm_hit}, misses {warm_miss}")

    # ---- zoo reopen from a COLD stage cache: nothing rebuilt ------------
    from repro.obs.metrics import MetricsRegistry
    reopen_reg = MetricsRegistry()
    zoo_hits0 = (REGISTRY.get("zoo.hits").value
                 if REGISTRY.get("zoo.hits") else 0.0)
    t0 = time.perf_counter()
    for m in args.models:
        g, qm = built[m]
        co = compile_model(g, qm, ZU2, zoo=zoo,
                           cache=StageCache(registry=reopen_reg))
        assert co.key == compiled[m].key
    reopen_s = time.perf_counter() - t0
    reopen_miss = _stage_counts(reopen_reg, "misses")
    zoo_hits = ((REGISTRY.get("zoo.hits").value
                 if REGISTRY.get("zoo.hits") else 0.0) - zoo_hits0)
    print(f"zoo reopen x{len(args.models)}: {reopen_s:.3f}s, "
          f"zoo hits {zoo_hits:.0f}, stages rebuilt past wrap: "
          f"{ {k: v for k, v in reopen_miss.items() if k != 'wrapped'} }")

    # ---- phase 2: mixed skewed traffic ----------------------------------
    from repro.runtime import Session
    sessions = {m: Session.from_artifact(compiled[m].artifact,
                                         backend=args.backend)
                for m in args.models}
    stream, counts = make_traffic(args.models, args.mix, args.requests)
    reqs_by_model = {m: make_requests(sessions[m], counts[m])
                     for m in args.models}
    print(f"traffic: {counts} (mix {args.mix}, {args.requests} total)")

    swapped = multi = None
    for _ in range(max(1, args.repeats)):
        got = run_swapped({m: compiled[m].artifact for m in args.models},
                          reqs_by_model, args.backend)
        if swapped is None or got["images_per_s"] > swapped["images_per_s"]:
            swapped = got
        got = run_multiserver(sessions, stream, reqs_by_model,
                              max_batch=args.max_batch,
                              max_latency_s=args.max_latency_ms * 1e-3)
        if multi is None or got["images_per_s"] > multi["images_per_s"]:
            multi = got
    print(f"swapped    : {swapped['images_per_s']:8.2f} img/s "
          f"(swap-in {sum(swapped['swap_s'].values()):.2f}s total)")
    per_tenant = {}
    for m in args.models:
        st = multi["stats"]["models"][m]
        per_tenant[m] = {"slo": multi["stats"]["slo"][m],
                         "n_served": st["n_served"],
                         "p50_ms": st["p50_ms"], "p99_ms": st["p99_ms"],
                         "mean_batch": st["mean_batch"]}
        print(f"co-resident[{m}] ({per_tenant[m]['slo']}): "
              f"{st['n_served']} reqs  p50={st['p50_ms']:.2f}ms "
              f"p99={st['p99_ms']:.2f}ms  mean_batch={st['mean_batch']:.2f}")
    print(f"co-resident: {multi['images_per_s']:8.2f} img/s  "
          f"({multi['images_per_s'] / swapped['images_per_s']:.2f}x swapped)")

    exact = {}
    for m in args.models:
        e_swap, e_multi = audit_bit_exact(
            sessions[m], reqs_by_model[m], swapped["outputs"][m],
            multi["outputs"][m])
        exact[m] = {"swapped": e_swap, "co_resident": e_multi}
    print(f"bit-exact vs oracle: {exact}")

    out = {
        "models": args.models, "img": args.img, "mix": args.mix,
        "requests": args.requests, "backend": args.backend,
        "zoo_root": zoo.root, "zoo_keys": {m: compiled[m].key
                                           for m in args.models},
        "compile_s": compile_s, "warm_recompile_s": warm_s,
        "warm_stage_hits": warm_hit, "warm_stage_misses": warm_miss,
        "zoo_reopen_s": reopen_s, "zoo_reopen_stage_misses": reopen_miss,
        "swapped": {k: v for k, v in swapped.items() if k != "outputs"},
        "co_resident": {"images_per_s": multi["images_per_s"],
                        "wall_s": multi["wall_s"],
                        "per_tenant": per_tenant,
                        "ddr_partition":
                            multi["stats"]["ddr_partition"]},
        "co_resident_vs_swapped": (multi["images_per_s"]
                                   / swapped["images_per_s"]),
        "bit_exact": exact,
        "metrics": REGISTRY.snapshot(),
    }
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"wrote {args.json_path}")

    if args.smoke:
        assert all(e["swapped"] and e["co_resident"]
                   for e in exact.values()), (
            f"served outputs diverged from the oracle: {exact}")
        assert all(v == 0 for v in warm_miss.values()), (
            f"warm recompile rebuilt stages: {warm_miss}")
        assert all(v == float(len(args.models))
                   for v in warm_hit.values()), (
            f"warm recompile must hit all four stage caches per model: "
            f"{warm_hit}")
        assert all(v == 0 for s, v in reopen_miss.items()
                   if s != "wrapped"), (
            f"zoo reopen rebuilt stages past wrap: {reopen_miss}")
        assert zoo_hits >= len(args.models), "zoo reopen missed the store"
        assert multi["images_per_s"] > swapped["images_per_s"], (
            f"co-resident serving must beat sequential swapping: "
            f"{multi['images_per_s']:.2f} <= {swapped['images_per_s']:.2f}")
        for m, t in per_tenant.items():
            assert t["n_served"] == counts[m] and t["p99_ms"] > 0
        print("SMOKE OK: bit-exact, co-resident > swapped, warm recompile "
              "0 stages, zoo reopen 0 stages past wrap")
    return out


if __name__ == "__main__":
    main()
