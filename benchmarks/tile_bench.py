"""Autotuned-tiling benchmark: searched tile shapes vs the PR-4 baseline.

For each network this benchmark makes tile shape a measured compilation
decision and reports what it bought:

1. build + quantize the net, search a strategy under the paper's ZU2 model
   (the same partition the other benchmarks plan; it lowers with 1.00 fused
   coverage on the three nets) — the group partition is held fixed, this
   benchmark isolates the *tile-shape* axis;
2. run the tile-shape search (``tune.tiles.search_tile_shapes``): enumerate
   the Eq. 6-feasible kernel-executable candidates per lowered launch,
   measure the top-K plus the kernel default in round-robin passes, keep the
   measured winners in ``strategy.meta['tile_shapes']``;
3. gate per unit: re-measure every tuned launch against the analytic
   Eq. 5/6 shape (``tiling.solve``) in the same passes — tuned shapes must
   never be measured-slower;
4. A/B the tuned program against the untuned baseline end-to-end with
   alternating passes (``measure_strategy_set``), sequentially and at a
   serving batch;
5. compile the tuned strategy — the artifact (format v4) carries the tile
   records, the memory plan charges their true bank footprints, and the
   program must stay bit-exact and hazard-free.

--smoke asserts the acceptance gates (tuned never measured-slower per unit,
e2e no worse than 2%, 1.00 fused coverage, bit-exact) and is wired into
``make ci`` as ``make tile-smoke``.

The device defaults to the TPU v5e model: tile capacity must describe the
backend that actually executes the kernels (VMEM-scale buffers), not the
FPGA targets whose BRAM budgets the strategy search also supports.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

import outdir


def build_quantized(model: str, img: int):
    from repro.cnn import build, init_params
    from repro.core import executor, quantize

    g = build(model, img=img, num_classes=10) if img != 224 else build(model)
    params = init_params(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    return g, qm, x


def measure_batched(g, qm, strategy, batch: int, repeats: int) -> float:
    """Seconds per image at a serving batch (one batched Pallas launch)."""
    from repro.core import executor
    from repro.tune.measure import time_callable

    ex = executor.Int8Executor(g, qm, strategy=strategy, backend="pallas")
    rng = np.random.default_rng(2)
    shape = next(g.shape(n.name) for n in g if n.op == "input")
    x = rng.integers(-128, 128, (batch,) + tuple(shape[1:])).astype(np.int8)
    sec, *_ = time_callable(lambda v: list(ex(v).values()), [x],
                            warmup=1, repeats=repeats, center="min")
    return sec / batch


def bench_model(model: str, img: int, *, device: str, plan_device: str,
                repeats: int, passes: int, top_k: int, batch: int) -> dict:
    from repro import asm
    from repro.core import lower, partition, pathsearch, quantize, tiling, \
        validate
    from repro.hw import get_device
    from repro.tune import MeasurementHarness, search_tile_shapes

    dev = get_device(device)
    plan_dev = get_device(plan_device)
    g, qm, x = build_quantized(model, img)
    xq = quantize.quantize_to(x, qm.f_a["data"])

    # mixed compilation: softmax & friends to the host (paper §2.3.5) — the
    # accelerator program then lowers with 1.00 fused coverage
    dv = partition.device_of(g, "paper")
    s_base = pathsearch.search(g, plan_dev, device_of=dv)
    s_tuned = pathsearch.search(g, plan_dev, device_of=dv)  # tiles go here
    harness = MeasurementHarness(g, qm, dev, repeats=repeats)

    t0 = time.perf_counter()
    rep = search_tile_shapes(g, qm, dev, s_tuned, harness=harness,
                             top_k=top_k)
    t_search = time.perf_counter() - t0

    # --- per-unit gate: tuned shape vs the analytic Eq. 5/6 shape -----------
    prog = lower.lower_strategy(g, s_tuned, qm)
    coverage = prog.meta["coverage"]
    from repro.kernels.conv_fused.ops import _resolve_tile
    from repro.tune.tiles import launch_oc

    gate_items, gate_info = [], []
    for item in prog.launches():
        if item.kind == "horizontal":
            t = tiling.solve_horizontal(g, list(item.nodes), dev)
        else:
            t = tiling.solve(g, list(item.nodes), dev)
        if not t.feasible:
            continue
        ana = (t.t_h, t.t_w, t.t_oc)
        oh, ow = item.out_hw
        has_conv = (item.kind == "horizontal"
                    or any(st[0] == "conv" for st in item.stages))
        oc = launch_oc(g, item)
        # what each side actually executes, after kernel clamping — when they
        # coincide the launches are identical and any measured difference is
        # noise by definition
        same = (_resolve_tile(ana, oh, ow, oc, has_conv)
                == _resolve_tile(tuple(item.tile), oh, ow, oc, has_conv))
        gate_items.append(dataclasses.replace(item, tile=ana))
        gate_items.append(item)             # carries the tuned tile (or none)
        gate_info.append({"nodes": list(item.nodes), "analytic": list(ana),
                          "tuned": list(item.tile) if item.tile else None,
                          "identical": same})
    gate_ms = harness.measure_item_set(gate_items)
    # units whose wall-clock is below the harness's resolution on a shared
    # box carry no ordering information (the same 0.5 ms floor calibrate
    # applies via min_measurable_s); the gate compares the resolvable ones
    # with a noise tolerance on top of the search's own recording margin —
    # wider for short launches, where back-to-back copies of the SAME launch
    # routinely differ by several percent on this box
    gate_floor = 5e-4
    n_slower = n_below_floor = 0
    for i, info in enumerate(gate_info):
        ana_m, tuned_m = gate_ms[2 * i], gate_ms[2 * i + 1]
        info["analytic_s"] = ana_m.seconds
        info["tuned_s"] = tuned_m.seconds
        info["speedup_vs_analytic"] = ana_m.seconds / max(tuned_m.seconds,
                                                          1e-12)
        gate_tol = 0.05 if ana_m.seconds >= 5e-3 else 0.12
        if info["identical"]:
            continue                        # same launch twice: noise only
        if max(ana_m.seconds, tuned_m.seconds) < gate_floor:
            info["below_floor"] = True
            n_below_floor += 1
        elif tuned_m.seconds > ana_m.seconds * (1 + gate_tol):
            n_slower += 1

    # --- e2e A/B: alternated passes, sequential and batched -----------------
    m_base, m_tuned = harness.measure_strategy_set([s_base, s_tuned],
                                                   passes=passes)
    delta = (m_base.seconds - m_tuned.seconds) / m_base.seconds
    bat_base = measure_batched(g, qm, s_base, batch, repeats)
    bat_tuned = measure_batched(g, qm, s_tuned, batch, repeats)

    # --- bit-exactness + hazard-free compile --------------------------------
    exact = bool(validate.bit_exact(g, qm, xq, strategy=s_tuned,
                                    backend="pallas"))
    art = asm.compile_strategy(g, s_tuned, dev, qm=qm)   # simulator.check gates

    return {
        "model": model, "img": img, "device": device,
        "plan_device": plan_device,
        "n_units": rep.n_units, "n_tuned": rep.n_tuned,
        "tile_shapes": rep.tile_shapes,
        "tile_search_s": t_search,
        "fused_coverage": coverage,
        "unit_gate": gate_info,
        "n_units_measured_slower": n_slower,
        "n_units_below_floor": n_below_floor,
        "seq_s": {"analytic": m_base.seconds, "tuned": m_tuned.seconds},
        "seq_spread": {"analytic": m_base.spread, "tuned": m_tuned.spread},
        "measured_delta": delta,
        "batched_s_per_img": {"analytic": bat_base, "tuned": bat_tuned,
                              "batch": batch},
        "bit_exact": exact,
        "artifact": {"tile_shapes": art.tile_shapes,
                     "sim_total_cycles": art.sim_total_cycles,
                     "peak_ddr_bytes": art.peak_ddr_bytes},
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", action="append", dest="models",
                    choices=["vgg16", "resnet50", "googlenet"], default=None)
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--device", default="tpu_v5e",
                    help="capacity model for tile enumeration + compile "
                         "(default: the device that describes this backend)")
    ap.add_argument("--plan-device", default="zu2",
                    help="device the strategy partition is searched under "
                         "(default: the paper's ZU2, as in the other benches)")
    ap.add_argument("--repeats", type=int, default=8,
                    help="round-robin passes per measured tile candidate")
    ap.add_argument("--passes", type=int, default=12,
                    help="alternating end-to-end A/B passes")
    ap.add_argument("--top-k", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="bare names land in benchmarks/out/ (gitignored)")
    ap.add_argument("--smoke", action="store_true",
                    help="assert the acceptance gates")
    args = ap.parse_args(argv)
    args.json_path = outdir.resolve(args.json_path)
    models = args.models or ["vgg16", "resnet50", "googlenet"]

    records = []
    for model in models:
        rec = bench_model(model, args.img, device=args.device,
                          plan_device=args.plan_device,
                          repeats=args.repeats, passes=args.passes,
                          top_k=args.top_k, batch=args.batch)
        records.append(rec)
        print(f"{model}@{args.img} [{args.device}] tile search: "
              f"{rec['n_tuned']}/{rec['n_units']} units tuned "
              f"({rec['tile_search_s']:.0f}s), coverage "
              f"{rec['fused_coverage']:.2f}")
        print(f"  e2e seq {rec['seq_s']['analytic'] * 1e3:.1f} -> "
              f"{rec['seq_s']['tuned'] * 1e3:.1f} ms "
              f"({rec['measured_delta']:+.1%} vs analytic tiles); "
              f"batched@{args.batch} "
              f"{rec['batched_s_per_img']['analytic'] * 1e3:.1f} -> "
              f"{rec['batched_s_per_img']['tuned'] * 1e3:.1f} ms/img")
        print(f"  unit gate: {rec['n_units_measured_slower']} of "
              f"{len(rec['unit_gate'])} launches measured slower than the "
              f"Eq. 5/6 shape ({rec['n_units_below_floor']} below the "
              f"measurement floor); bit-exact {rec['bit_exact']}")

    out = {"img": args.img, "device": args.device, "batch": args.batch,
           "models": records}
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"wrote {args.json_path}")

    if args.smoke:
        for rec in records:
            assert rec["bit_exact"], f"{rec['model']}: tuned program diverged"
            assert rec["fused_coverage"] == 1.0, (
                f"{rec['model']}: searched strategy lost fused coverage "
                f"({rec['fused_coverage']:.2f})")
            assert rec["n_units_measured_slower"] == 0, (
                f"{rec['model']}: {rec['n_units_measured_slower']} tuned "
                f"units measured slower than the analytic Eq. 5/6 shapes")
            assert rec["measured_delta"] >= -0.02, (
                f"{rec['model']}: tuned tiles measured "
                f"{rec['measured_delta']:+.1%} vs the analytic baseline")
        print("TILE SMOKE OK: tuned units never slower than Eq. 5/6 shapes, "
              "e2e within gate, 1.00 coverage, bit-exact")
    return out


if __name__ == "__main__":
    main()
