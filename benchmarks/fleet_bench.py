"""Fleet benchmark + chaos gate: replicated serving under injected faults.

Three phases, all against ONE shared ``CompiledArtifact`` (every replica's
plan cache is seeded from it — the fleet compiles nothing):

* **scaling** — the same burst served by a 1-replica and a 2-replica fleet,
  with a uniform per-launch device cost injected through the chaos hook
  (``ChaosInjector.slow`` on every replica).  The injected cost models the
  accelerator's occupancy — host CPU time is shared between forced-host
  replicas, so without it a 2-replica "speedup" would only measure BLAS
  thread contention, not fleet routing.  Gate: 2 replicas >= 1.7x one.
* **chaos kill** — a paced run during which one replica is killed outright
  mid-stream.  Gate: every submitted request completes bit-exact against
  the unfused int8 oracle (ZERO drops), the dead replica is evicted with
  ``replica.evict`` + a frozen flight dump, retries are observable, and
  after healing the replica is elastically re-admitted (``replica.admit``).
* **load shedding** — a burst into one deliberately slowed replica with a
  tiny queue bound.  Gate: some of the burst is shed via ``AdmissionError``
  (degraded, not wedged), and everything accepted completes bit-exact.

--smoke asserts the gates and is wired into ``make ci`` (`fleet-smoke`).
The JSON (+ flight dumps) land in benchmarks/out/ as CI build artifacts.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="googlenet",
                    choices=["vgg16", "resnet50", "googlenet"])
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-latency-ms", type=float, default=2.0)
    ap.add_argument("--launch-cost-ms", type=float, default=500.0,
                    help="uniform per-launch device cost injected during the "
                         "scaling phase; must dominate the host compute per "
                         "launch so the gate measures routing parallelism "
                         "(sleeps release the GIL and overlap across "
                         "replicas like real accelerators would, while the "
                         "host compute serializes — on a 1-core CI box the "
                         "ceiling is (2s+2c)/(s+2c) for sleep s, compute c)")
    ap.add_argument("--kill-after-launches", type=int, default=2,
                    help="healthy launches the victim replica serves before "
                         "the kill fault arms")
    ap.add_argument("--repeats", type=int, default=1,
                    help="scaling trials per fleet width; best-of wins")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="bare names land in benchmarks/out/ (gitignored)")
    ap.add_argument("--smoke", action="store_true",
                    help="assert the chaos/scaling/shedding gates")
    args = ap.parse_args(argv)

    # forced-host devices BEFORE jax loads: each replica gets its own device
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{max(2, args.replicas)}").strip()

    import outdir
    args.json_path = outdir.resolve(args.json_path)

    from serve_bench import audit_bit_exact, build_session, make_requests
    from repro.obs import REGISTRY
    from repro.obs.flight import FlightRecorder
    from repro.obs.metrics import MetricsRegistry
    from repro.runtime import AdmissionError, ChaosInjector, Fleet

    sess, compile_times = build_session(args.model, args.img, "ref", True)
    art = sess.artifact
    reqs = make_requests(sess, args.requests)
    import jax
    print(f"{args.model}@{args.img} requests={args.requests} "
          f"devices={[str(d) for d in jax.devices()]} "
          f"(search {compile_times['search_s']:.2f}s, "
          f"compile {compile_times['compile_s']:.2f}s)")

    server_kw = {"max_batch": args.max_batch,
                 "max_latency_s": args.max_latency_ms * 1e-3}
    # generous windows: queue waits behind slow/chaos launches must look like
    # load, not like a stuck replica — the kill gate detects via the error
    # path, not via attempt timeouts
    fleet_kw = {"attempt_timeout_s": 30.0, "request_deadline_s": 240.0}

    # ------------------------------------------------------------- scaling
    def run_width(n: int) -> dict:
        best = None
        for _ in range(max(1, args.repeats)):
            fleet = Fleet(art, n_replicas=n, server_kw=dict(server_kw),
                          registry=MetricsRegistry(), **fleet_kw)
            chaos = ChaosInjector().attach(fleet)
            for rid in fleet.replicas():
                chaos.slow(rid, args.launch_cost_ms * 1e-3)
            try:
                t0 = time.perf_counter()
                futs = [fleet.submit(x) for x in reqs]
                outs = [f.result(timeout=300) for f in futs]
                wall = time.perf_counter() - t0
                st = fleet.stats()
            finally:
                chaos.heal_all()
                fleet.close()
            got = {"replicas": n, "wall_s": wall,
                   "images_per_s": len(reqs) / wall,
                   "served_per_replica": {r: v["n_served"]
                                          for r, v in st["replicas"].items()},
                   "outputs": outs}
            if best is None or got["images_per_s"] > best["images_per_s"]:
                best = got
        return best

    one = run_width(1)
    two = run_width(args.replicas)
    scaling = two["images_per_s"] / one["images_per_s"]
    print(f"scaling    : 1 replica {one['images_per_s']:8.2f} img/s; "
          f"{args.replicas} replicas {two['images_per_s']:8.2f} img/s "
          f"({scaling:.2f}x; injected launch cost "
          f"{args.launch_cost_ms:.0f}ms; served "
          f"{two['served_per_replica']})")

    # ---------------------------------------------------------- chaos kill
    dump_dir = os.path.join(os.path.dirname(args.json_path) or ".",
                            "fleet_flight")
    reg_chaos = MetricsRegistry()        # per-phase counters, not cumulative
    flight = FlightRecorder(dump_dir=dump_dir, registry=reg_chaos)
    fleet = Fleet(art, n_replicas=args.replicas, server_kw=dict(server_kw),
                  flight=flight, registry=reg_chaos, **fleet_kw)
    chaos = ChaosInjector().attach(fleet)
    victim = f"r{args.replicas - 1}"
    chaos.kill(victim, after_launches=args.kill_after_launches)
    try:
        t0 = time.perf_counter()
        futs = []
        for x in reqs:                   # paced: the kill lands mid-stream
            futs.append(fleet.submit(x))
            time.sleep(0.002)
        chaos_outs = [f.result(timeout=300) for f in futs]
        chaos_wall = time.perf_counter() - t0
        st = fleet.stats()
        evict_events = [e.to_json() for e in
                        fleet._events.records(kind="replica.evict")]
        retry_events = fleet._events.records(kind="request.retry")
        n_dumps = len(fleet.flight.dumps())
        # heal -> the victim must pass the warmup probe and rejoin
        chaos.heal(victim)
        readmitted = fleet.wait_active(victim, timeout_s=30.0)
        admit_events = [e.to_json() for e in
                        fleet._events.records(kind="replica.admit")
                        if not e.fields.get("initial")]
        st_after = fleet.stats()
    finally:
        chaos.heal_all()
        fleet.close()
    chaos_phase = {
        "victim": victim,
        "kills_fired": chaos.fired("kill"),
        "submitted": st["submitted"], "completed": st["completed"],
        "dropped": st["submitted"] - st["completed"],
        "retries": st["retries"],
        "evictions": st["replicas"][victim]["evictions"],
        "flight_dumps": n_dumps,
        "readmitted": readmitted,
        "admissions": st_after["replicas"][victim]["admissions"],
        "wall_s": chaos_wall,
        "images_per_s": len(reqs) / chaos_wall,
        "evict_events": evict_events,
        "admit_events": admit_events,
        "n_retry_events": len(retry_events),
    }
    print(f"chaos kill : {victim} killed after "
          f"{args.kill_after_launches} launches -> "
          f"{chaos_phase['completed']}/{chaos_phase['submitted']} completed "
          f"(dropped {chaos_phase['dropped']}), "
          f"retries={chaos_phase['retries']:.0f}, "
          f"evictions={chaos_phase['evictions']}, "
          f"flight dumps={n_dumps}, re-admitted={readmitted}")

    # -------------------------------------------------------- load shedding
    fleet = Fleet(art, n_replicas=1, server_kw=dict(server_kw),
                  max_queue_per_replica=4, registry=MetricsRegistry(),
                  **fleet_kw)
    chaos = ChaosInjector().attach(fleet)
    chaos.slow("r0", 0.05)
    shed, accepted, accepted_ix = 0, [], []
    try:
        for i, x in enumerate(reqs):
            try:
                accepted.append(fleet.submit(x))
                accepted_ix.append(i)
            except AdmissionError:
                shed += 1
        shed_outs = [f.result(timeout=300) for f in accepted]
        st = fleet.stats()
    finally:
        chaos.heal_all()
        fleet.close()
    shedding = {"offered": len(reqs), "accepted": len(accepted),
                "shed": shed, "rejected_metric": st["rejected"]}
    print(f"shedding   : {shed}/{len(reqs)} shed at queue bound 4, "
          f"{len(accepted)} accepted all completed")

    # ---------------------------------------------------------- bit-exact
    exact_one, exact_two, exact_chaos = audit_bit_exact(
        sess, reqs, one["outputs"], two["outputs"], chaos_outs)
    shed_reqs = [reqs[i] for i in accepted_ix]   # capacity frees mid-burst,
    [exact_shed] = audit_bit_exact(sess, shed_reqs, shed_outs) \
        if shed_outs else [True]                 # so accepted != a prefix
    print(f"bit-exact vs oracle: 1-replica={exact_one} "
          f"{args.replicas}-replica={exact_two} chaos={exact_chaos} "
          f"shed-survivors={exact_shed}")

    out = {
        "model": args.model, "img": args.img, "requests": args.requests,
        "replicas": args.replicas, "max_batch": args.max_batch,
        "launch_cost_ms": args.launch_cost_ms,
        **compile_times,
        "scaling": {
            "one": {k: v for k, v in one.items() if k != "outputs"},
            "many": {k: v for k, v in two.items() if k != "outputs"},
            "speedup": scaling,
        },
        "chaos": chaos_phase,
        "shedding": shedding,
        "bit_exact": {"one": exact_one, "many": exact_two,
                      "chaos": exact_chaos, "shed": exact_shed},
        "metrics": REGISTRY.snapshot(),          # serve-side (shared)
        "fleet_metrics": reg_chaos.snapshot(),   # chaos-phase fleet plane
    }
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"wrote {args.json_path}")

    if args.smoke:
        assert exact_one and exact_two and exact_chaos and exact_shed, (
            "fleet-served outputs diverged from the int8 oracle")
        assert chaos_phase["dropped"] == 0, (
            f"{chaos_phase['dropped']} requests dropped during the kill")
        assert chaos_phase["kills_fired"] >= 1, "the kill fault never fired"
        assert chaos_phase["evictions"] >= 1, "victim was never evicted"
        assert chaos_phase["retries"] >= 1, "no retries observed"
        assert chaos_phase["flight_dumps"] >= 1, (
            "eviction must freeze a flight dump")
        assert chaos_phase["readmitted"] and chaos_phase["admissions"] >= 1, (
            "healed replica was not re-admitted")
        assert chaos_phase["evict_events"] and chaos_phase["admit_events"] \
            and chaos_phase["n_retry_events"] >= 1, (
            "replica.evict / replica.admit / request.retry events missing")
        assert shedding["shed"] >= 1, "queue bound never shed"
        assert shedding["accepted"] >= 1, "queue bound shed everything"
        assert scaling >= 1.7, (
            f"{args.replicas}-replica fleet must scale >= 1.7x one replica "
            f"under a uniform injected launch cost; got {scaling:.2f}x")
        print(f"SMOKE OK: zero drops bit-exact under kill, evict/retry/"
              f"re-admit observable, shedding bounded, {scaling:.2f}x "
              f"scaling")
    return out


if __name__ == "__main__":
    main()
