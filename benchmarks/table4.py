"""Reproduce the "Ours / ZU9 @330 MHz batch 3" column of paper Table 4.

Paper: VGG 2.82 TOPs/s, ResNet50 1.38 TOPs/s, GoogLeNet 1.41 TOPs/s
(ZU9, 4 MB BRAM, int8, batch 3, peak 4.05 TOPs/s) and energy efficiency
123.7 GOPs/s/W for VGG at 22.8 W.

    PYTHONPATH=src python -m benchmarks.table4
"""
from __future__ import annotations

from repro.cnn import build
from repro.core import partition, pathsearch
from repro.core.cost import SimulatorEvaluator
from repro.hw import ZU9

PAPER = {"vgg16": 2.82e12, "resnet50": 1.38e12, "googlenet": 1.41e12}
ZU9_POWER_W = 22.8


def main() -> None:
    print(f"# Table 4 reproduction — ZU9 @330MHz batch 3 "
          f"(peak {ZU9.peak_ops_per_s/1e12:.2f} TOPs/s)")
    for name in ("vgg16", "resnet50", "googlenet"):
        g = build(name, batch=3)
        dv = partition.device_of(g, "paper")
        sim = SimulatorEvaluator(g, ZU9)
        opt = pathsearch.search(g, ZU9, evaluator=sim, device_of=dv)
        secs = sim.strategy_report(opt).seconds(ZU9.freq_hz)
        acc_ops = sum(g.ops(n.name) for n in g if dv(n.name) == "acc")
        tops = acc_ops / secs / 1e12
        eff = acc_ops / secs / 1e9 / ZU9_POWER_W
        print(f"  {name:10s} {tops:5.2f} TOPs/s (paper {PAPER[name]/1e12:.2f})"
              f"  {eff:6.1f} GOPs/s/W"
              + ("  (paper 123.7)" if name == "vgg16" else ""))


if __name__ == "__main__":
    main()
