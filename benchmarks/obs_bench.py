"""Observability benchmark: one served run, fully instrumented.

Builds a model under a calibrated device profile with the span tracer enabled
from the very start — so the exported trace carries the whole compile
pipeline (frontend -> pathsearch -> tiling -> memory plan -> assemble ->
simulate -> lower) — then serves R requests through the dynamic-batching
server twice: once with the tracer disabled (baseline throughput) and once
with tracing plus the sampling drift profiler on.  The simulator's
``engine_windows`` timeline of the same plan is appended as a parallel
"modeled" Perfetto process, so one trace JSON shows compile stages, per-
request/batch serve spans, and the predicted engine overlap side by side.

The profile is calibrated against the cycle simulator (fast, deterministic)
and the drift profiler samples through the same simulator oracle — the smoke
gate checks the *machinery* (valid trace, complete metrics, finite drift
band, tracing overhead <= 10%); wall-clock drift measurement is exercised by
``serve_bench --profile`` and the unit tests.

--smoke asserts those four criteria and is wired into `make ci`
(`make obs-smoke`); the trace JSON lands in benchmarks/out/ where CI uploads
it as an artifact.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
import urllib.request

import numpy as np


def build_profiled_session(model: str, img: int, backend: str):
    """Graph + sim-calibrated profile + profile-guided compiled session."""
    from repro.cnn import build, init_params
    from repro.core import executor, pathsearch, quantize
    from repro.core.cost import SimulatorEvaluator
    from repro.hw import ZU2
    from repro.runtime import Session
    from repro.tune import CalibratedEvaluator, calibrate

    g = build(model, img=img, num_classes=10) if img != 224 else build(model)
    params = init_params(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    sim = SimulatorEvaluator(g, ZU2)
    res = calibrate(g, qm, ZU2, measure_fn=lambda grp: sim(grp),
                    features="analytic")
    p = res.profile
    s = pathsearch.search(g, ZU2, evaluator=CalibratedEvaluator(g, ZU2, p))
    sess = Session(g, s, ZU2, qm, backend=backend, profile=p)
    return sess, sim


def sim_measure_fn(sess, sim):
    """Deterministic drift oracle: each plan unit re-priced by the cycle
    simulator (the same ground truth the profile was fitted on)."""
    def fn(item):
        from repro.core import lower
        if isinstance(item, lower.FusedLaunch) and item.kind == "horizontal":
            return sim.horizontal_cost([m[0] for m in item.members])
        return sim(list(item.nodes))
    return fn


def serve_once(sess, reqs, *, max_batch: int, max_latency_s: float) -> float:
    """Serve all requests through the batching server; returns images/s."""
    srv = sess.serve(max_batch=max_batch, max_latency_s=max_latency_s,
                     warmup=False)
    try:
        t0 = time.perf_counter()
        futs = [srv.submit(x) for x in reqs]
        for f in futs:
            f.result(timeout=120)
        wall = time.perf_counter() - t0
    finally:
        srv.close()
    return len(reqs) / wall


REQUIRED_COMPILE_SPANS = {"frontend", "pathsearch", "tiling", "memory_plan",
                          "assemble", "simulate", "lower"}
REQUIRED_SERVE_SPANS = {"queue_wait", "execute", "batch_form",
                        "batch_execute", "resolve", "pad", "launch"}
REQUIRED_METRICS = {"serve.requests", "serve.batches", "serve.batch_size",
                    "serve.latency_ms", "serve.queue_wait_ms",
                    "serve.execute_ms", "serve.queue_depth",
                    "plan_cache.misses", "executor.calls",
                    "executor.fused_launches", "executor.fallback_launches",
                    "drift.samples", "drift.aggregate_deviation"}
# OpenMetrics families the mid-run scrape of the full plane must expose
# (ISSUE 8): per-tenant serve series, burn-rate + drift gauges, flight ring
# occupancy, event counters, and the scrape counter itself.
REQUIRED_PLANE_FAMILIES = {"serve_requests", "serve_batches",
                           "serve_latency_ms", "serve_queue_wait_ms",
                           "serve_execute_ms", "slo_burn_rate",
                           "drift_median_deviation", "drift_tripped",
                           "flight_records", "events_emitted", "obs_scrapes",
                           "trace_spans"}


def serve_plane_once(ms, tenant, reqs, scrape_url=None) -> tuple[float, str]:
    """Serve all requests through the multi-tenant front door with the full
    plane enabled; optionally scrape the exposition endpoint while requests
    are in flight.  Returns (images/s, scraped text or '')."""
    t0 = time.perf_counter()
    futs = [ms.submit(tenant, x) for x in reqs]
    text = ""
    if scrape_url is not None:           # mid-run: the queue is still draining
        with urllib.request.urlopen(scrape_url, timeout=30) as r:
            text = r.read().decode("utf-8")
    for f in futs:
        f.result(timeout=120)
    wall = time.perf_counter() - t0
    return len(reqs) / wall, text


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="vgg16",
                    choices=["vgg16", "resnet50", "googlenet"])
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-latency-ms", type=float, default=5.0)
    ap.add_argument("--backend", default="pallas", choices=["ref", "pallas"])
    ap.add_argument("--drift-every", type=int, default=3,
                    help="sample the drift profiler every Nth batch launch")
    ap.add_argument("--repeats", type=int, default=3,
                    help="alternate untraced/traced trials, keep best of "
                         "each (controls for clock drift)")
    ap.add_argument("--trace", dest="trace_path", default="obs_trace.json",
                    help="trace JSON output; bare names land in "
                         "benchmarks/out/")
    ap.add_argument("--json", dest="json_path", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="assert trace validity, metrics completeness, "
                         "finite drift band, and <=10%% tracing overhead")
    args = ap.parse_args(argv)
    import outdir
    args.trace_path = outdir.resolve(args.trace_path)
    args.json_path = outdir.resolve(args.json_path)

    from repro.hw import ZU2
    from repro.obs import REGISTRY, TRACER, DriftProfiler

    # tracer on from the start: the compile pipeline below lands in the trace
    TRACER.enable()
    sess, sim = build_profiled_session(args.model, args.img, args.backend)
    reqs = [np.asarray(x, np.int8) for x in
            np.random.default_rng(1).integers(
                -128, 128, (args.requests,) + tuple(
                    sess.graph.shape("data")[1:]))]
    print(f"{args.model}@{args.img} backend={args.backend} "
          f"requests={args.requests} "
          f"fused_coverage={sess.artifact.fused_coverage:.2f} "
          f"profile={sess.profile.hash()}")

    # warm every allowed batch shape outside all timed windows
    serve_once(sess, reqs[:args.max_batch], max_batch=args.max_batch,
               max_latency_s=args.max_latency_ms * 1e-3)

    dp = DriftProfiler.from_session(sess, every=args.drift_every,
                                    measure_fn=sim_measure_fn(sess, sim))

    # alternate untraced / traced+profiled trials; best-of each mode
    untraced = traced = 0.0
    for _ in range(max(1, args.repeats)):
        TRACER.disable()
        sess.attach_drift(None)
        untraced = max(untraced, serve_once(
            sess, reqs, max_batch=args.max_batch,
            max_latency_s=args.max_latency_ms * 1e-3))
        TRACER.enable()
        sess.attach_drift(dp)
        traced = max(traced, serve_once(
            sess, reqs, max_batch=args.max_batch,
            max_latency_s=args.max_latency_ms * 1e-3))
    sess.attach_drift(None)
    overhead = 1.0 - traced / untraced
    print(f"untraced   : {untraced:8.2f} img/s")
    print(f"traced     : {traced:8.2f} img/s  "
          f"(overhead {overhead:+.1%}, tracing + drift sampling)")

    # ---- full production plane (ISSUE 8): multi-tenant serving with the
    # exposition endpoint, flight recorder, event log, burn-rate trackers,
    # and drift gauges all live — scraped mid-run, best-of-N throughput
    from repro.obs.events import EVENTS
    from repro.obs.export import find_samples, parse_openmetrics
    from repro.obs.flight import FlightRecorder
    from repro.runtime import MultiServer

    flight = FlightRecorder(capacity=256, dump_dir=outdir.OUT_DIR)
    ms = MultiServer(flight=flight,
                     burn_kw=dict(fast_window_s=5.0, slow_window_s=30.0,
                                  min_samples=8, cooldown_s=1.0))
    # gold, but with an attainable target: this phase measures overhead,
    # the violation is induced separately below
    ms.add_model(args.model, sess, slo="gold", target_p99_ms=1e4,
                 warmup=False, max_batch=args.max_batch,
                 max_latency_s=args.max_latency_ms * 1e-3)
    ms.attach_drift(args.model, every=args.drift_every,
                    measure_fn=sim_measure_fn(sess, sim))
    http = ms.serve_metrics()
    plane, scraped = 0.0, ""
    for _ in range(max(1, args.repeats)):
        ips, text = serve_plane_once(ms, args.model, reqs,
                                     scrape_url=http.url("/metrics"))
        if ips > plane:
            plane, scraped = ips, text
    # the plane run still traces + drift-samples, so its incremental cost is
    # measured against the traced baseline (tracing itself is gated above)
    plane_overhead = 1.0 - plane / traced
    print(f"full plane : {plane:8.2f} img/s  "
          f"(overhead {plane_overhead:+.1%} vs traced, + exposition/flight/"
          f"events/burn, scraped mid-run)")
    families = parse_openmetrics(scraped)        # strict: mid-run document
    with urllib.request.urlopen(http.url("/metrics"), timeout=30) as r:
        final = parse_openmetrics(r.read().decode())

    # dogfood the dump CLI against the live endpoint
    from repro.obs import dump as obs_dump
    snap_path = outdir.out_path("obs_snapshot.json")
    events_path = outdir.out_path("obs_events.jsonl")
    obs_dump.main(["--url", f"http://{http.host}:{http.port}",
                   "--out", snap_path, "--events-jsonl", events_path])

    # induce one SLO violation: re-admit the tenant under an unattainable
    # gold target so every request burns budget — the burn-rate alert and
    # the SLO controller both freeze the flight ring
    ms.remove_model(args.model)
    ms2 = MultiServer(flight=flight, events=EVENTS,
                      burn_kw=dict(fast_window_s=30.0, slow_window_s=60.0,
                                   min_samples=4, cooldown_s=0.0))
    hot = f"{args.model}_hot"
    ms2.add_model(hot, sess, slo="gold", target_p99_ms=1e-6, warmup=False,
                  max_batch=args.max_batch,
                  max_latency_s=args.max_latency_ms * 1e-3)
    for f in [ms2.submit(hot, x) for x in reqs[:12]]:
        f.result(timeout=120)
    ms2.close()
    slo_dumps = [d for d in flight.dumps()
                 if d["reason"] == "slo_violation"]
    alerts = EVENTS.records(kind="slo.alert")
    EVENTS.to_jsonl(events_path)                 # refresh: includes the alert
    print(f"induced SLO violation: {len(alerts)} alert event(s), "
          f"{len(slo_dumps)} flight dump(s) "
          f"-> {slo_dumps[-1].get('path') if slo_dumps else None}")
    ms.close()

    # modeled engine timeline of the same plan, as a parallel trace process
    rep = sess.pipeline_report(min(args.requests, 4), ddr_slots=None)
    n_modeled = TRACER.add_engine_windows(rep.engine_timeline, ZU2.freq_hz)
    print(f"modeled track: {n_modeled} engine windows "
          f"(ddr_slots={rep.ddr_slots}, source={rep.ddr_slots_source})")

    TRACER.export(args.trace_path)
    print(f"wrote {args.trace_path} ({len(TRACER)} spans, "
          f"{TRACER.n_dropped} dropped)")

    drift = dp.report().to_json()
    print(f"drift: aggregate={drift['aggregate_deviation']:.3f} "
          f"band={drift['band']:.3f} drifted={drift['drifted']} "
          f"({drift['n_sampled']} sampling passes)")
    metrics = REGISTRY.snapshot()

    out = {"model": args.model, "img": args.img, "backend": args.backend,
           "requests": args.requests, "max_batch": args.max_batch,
           "untraced_images_per_s": untraced,
           "traced_images_per_s": traced,
           "tracing_overhead": overhead,
           "plane_images_per_s": plane,
           "plane_overhead": plane_overhead,
           "n_scrape_families": len(final),
           "n_slo_alerts": len(alerts),
           "n_flight_dumps": flight.n_dumps,
           "events_jsonl": events_path,
           "snapshot_json": snap_path,
           "n_spans": len(TRACER), "n_dropped": TRACER.n_dropped,
           "n_modeled_spans": n_modeled,
           "trace_path": args.trace_path,
           "drift": drift, "metrics": metrics}
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"wrote {args.json_path}")

    if args.smoke:
        doc = json.load(open(args.trace_path))       # valid JSON round trip
        xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        names = {e["name"] for e in xs}
        missing = (REQUIRED_COMPILE_SPANS | REQUIRED_SERVE_SPANS) - names
        assert not missing, f"trace is missing spans: {sorted(missing)}"
        pids = {e["pid"]: e["args"]["name"] for e in doc["traceEvents"]
                if e["ph"] == "M" and e["name"] == "process_name"}
        procs = {pids[e["pid"]] for e in xs}
        assert {"measured", "modeled"} <= procs, procs
        assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
        missing_m = REQUIRED_METRICS - set(metrics)
        assert not missing_m, f"metrics snapshot incomplete: {missing_m}"
        assert metrics["serve.requests"]["value"] >= args.requests
        agg = drift["aggregate_deviation"]
        assert agg is not None and math.isfinite(agg), agg
        assert math.isfinite(drift["band"]) and drift["band"] > 0
        assert drift["profile_match"], "artifact/profile hash mismatch"
        assert traced >= 0.9 * untraced, (
            f"tracing overhead above 10%: {untraced:.2f} -> {traced:.2f} "
            f"img/s")
        # ---- ISSUE 8 gates: the full plane costs <= 5% on top of the traced
        # baseline, and its scrape, forensics, and alerting all check out
        assert plane >= 0.95 * traced, (
            f"plane overhead above 5%: {traced:.2f} -> {plane:.2f} img/s")
        # mid-run scrape parsed strictly (parse_openmetrics raised otherwise)
        # and carries the tenant's labelled serve series
        assert find_samples(families, "serve_requests", model=args.model), \
            "mid-run scrape is missing the tenant's serve.requests"
        missing_f = REQUIRED_PLANE_FAMILIES - set(final)
        assert not missing_f, f"scrape is missing families: {missing_f}"
        assert find_samples(final, "slo_burn_rate", model=args.model,
                            window="fast"), "no per-tenant burn-rate gauge"
        assert find_samples(final, "drift_median_deviation",
                            model=args.model), "no per-model drift gauge"
        # the induced gold violation alerted and froze a forensic dump
        assert alerts, "no slo.alert event after induced violation"
        assert alerts[-1].fields.get("model") == hot
        assert slo_dumps, "no slo_violation flight dump"
        last = slo_dumps[-1]
        okr = [r for r in last["records"] if r["status"] == "ok"]
        assert okr and all(r["queue_wait_s"] >= 0 and r["execute_s"] > 0
                           and r["batch_size"] >= 1
                           and r["batch_members"] for r in okr), \
            "flight records lack queue/execute/batch forensics"
        assert last["context"][hot]["tiles"], "dump lacks tile context"
        assert os.path.exists(last["path"]), last
        with open(events_path) as f:
            kinds = [json.loads(ln)["kind"] for ln in f]
        assert "slo.alert" in kinds and "flight.dump" in kinds, kinds
        print("SMOKE OK: valid Perfetto trace (compile + serve + modeled "
              "tracks), complete metrics, finite drift band, overhead "
              "<=10%; plane scrape strict-parsed with per-tenant "
              "burn/drift gauges, induced SLO violation alerted + dumped, "
              "plane overhead <=5%")
    return out


if __name__ == "__main__":
    main()
