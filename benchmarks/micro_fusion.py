"""Reproduce the paper's micro-fusion cases (Fig. 8 / Fig. 9) on ZU2.

Fig. 8 — conv+pool in GoogLeNet: input 28x28x32, conv 5x5 s1 -> 28x28x256,
pool 3x3 s1.  Paper: conv 0.375 ms, pool 0.242 ms, fusion cuts data
transfer 64% and gives 1.67x.

Fig. 9 — conv+eltwise in ResNet50: fusing the eltwise-add into one producing
conv skips SAVE+LOAD of a whole feature map.  Paper: 2.2x on the fused pair
and -36.4% data transfer.
"""
from __future__ import annotations

from repro.core import frontend
from repro.core.cost import AnalyticEvaluator, SimulatorEvaluator
from repro.core.xgraph import XGraph
from repro.hw import ZU2


def conv_pool_case() -> dict:
    g = XGraph("fig8")
    g.input("data", (1, 28, 28, 32))
    g.add("conv", "conv", ("data",), oc=256, kernel=(5, 5), stride=(1, 1),
          pad="same")
    g.add("maxpool", "pool", ("conv",), kernel=(3, 3), stride=(1, 1), pad=(1, 1))
    frontend.lower(g)
    sim = SimulatorEvaluator(g, ZU2)
    ana = AnalyticEvaluator(g, ZU2)
    unfused = sim(["conv"]) + sim(["pool"])
    fused = sim(["conv", "pool"])
    t_sep = (ana.cost(["conv"]).tiling.dram_bytes
             + ana.cost(["pool"]).tiling.dram_bytes)
    t_fus = ana.cost(["conv", "pool"]).tiling.dram_bytes
    return {
        "case": "conv+pool (Fig.8)",
        "conv_ms": sim(["conv"]) * 1e3, "pool_ms": sim(["pool"]) * 1e3,
        "unfused_ms": unfused * 1e3, "fused_ms": fused * 1e3,
        "speedup": unfused / fused,
        "transfer_reduction": 1 - t_fus / t_sep,
        "paper": {"conv_ms": 0.375, "pool_ms": 0.242, "speedup": 1.67,
                  "transfer_reduction": 0.64},
    }


def conv_eltwise_case() -> dict:
    g = XGraph("fig9")
    g.input("data", (1, 28, 28, 128))
    g.add("conv", "conv_a", ("data",), oc=128, kernel=(3, 3), pad="same")
    g.add("conv", "conv_b", ("data",), oc=128, kernel=(3, 3), pad="same")
    g.add("eltwise_add", "add", ("conv_a", "conv_b"))
    frontend.lower(g)
    sim = SimulatorEvaluator(g, ZU2)
    ana = AnalyticEvaluator(g, ZU2)
    # paper compares (conv_b then eltwise, serial) vs (conv_b fused w/ eltwise)
    serial = sim(["conv_b"]) + sim(["add"])
    fused = sim(["conv_b", "add"])
    t_sep = (ana.cost(["conv_b"]).tiling.dram_bytes
             + ana.cost(["add"]).tiling.dram_bytes)
    t_fus = ana.cost(["conv_b", "add"]).tiling.dram_bytes
    return {
        "case": "conv+eltwise (Fig.9)",
        "conv_ms": sim(["conv_b"]) * 1e3, "eltwise_ms": sim(["add"]) * 1e3,
        "unfused_ms": serial * 1e3, "fused_ms": fused * 1e3,
        "speedup": serial / fused,
        "transfer_reduction": 1 - t_fus / t_sep,
        "paper": {"eltwise_ms": 0.833, "speedup": 2.2,
                  "transfer_reduction": 0.364},
    }


def main() -> None:
    for case in (conv_pool_case(), conv_eltwise_case()):
        p = case.pop("paper")
        print(f"## {case.pop('case')}")
        for k, v in case.items():
            ref = f"   (paper {p[k]})" if k in p else ""
            print(f"  {k:20s} {v:8.3f}{ref}")


if __name__ == "__main__":
    main()
