"""Reproduce paper Table 2: the evaluation-method triad.

| Method    | On-Board | Model  | Simulator |
| Deviation | 0%       | 5-10%  | 0%        |
| Time      | <1s      | <1min  | >10min    |

Ours: the simulator is the reference (deviation 0 by definition); the
learned cost model is least-squares-fitted on candidate groups and reports
its deviation; the on-board evaluator wall-clocks the real JAX executor
(XLA-on-CPU "board"), so we report *rank correlation* with the simulator
rather than absolute deviation — the container's CPU is not the modeled
accelerator (documented deviation source, EXPERIMENTS.md §Repro).
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.cnn import build, init_params
from repro.core import pathsearch
from repro.core.cost import AnalyticEvaluator, ModelEvaluator, OnBoardEvaluator, SimulatorEvaluator
from repro.hw import ZU2


def candidate_groups(g, dev, max_n=60):
    from repro.core import isomorphism, templates

    pairs = templates.pairwise_fusable(
        isomorphism.find_all(g, templates.KERNEL_TEMPLATES))
    singles = [[n.name] for n in g if n.op not in ("input", "softmax")]
    fused = [[a, b] for (a, b) in pairs]
    return (singles + fused)[:max_n]


def main() -> None:
    g = build("resnet50", img=64, num_classes=100)
    groups = candidate_groups(g, ZU2)

    t0 = time.perf_counter()
    sim = SimulatorEvaluator(g, ZU2)
    sim_costs = [sim(gr) for gr in groups]
    t_sim = time.perf_counter() - t0

    # held-out evaluation: fit on even-indexed groups, test on odd
    t0 = time.perf_counter()
    train = groups[0::2]
    test = groups[1::2]
    model = ModelEvaluator(g, ZU2, train)
    pred = [model(gr) for gr in test]
    t_model = time.perf_counter() - t0
    sim_test = [sim(gr) for gr in test]
    finite = [(p, s) for p, s in zip(pred, sim_test)
              if math.isfinite(p) and math.isfinite(s) and s > 0]
    mape = float(np.mean([abs(p - s) / s for p, s in finite]))

    t0 = time.perf_counter()
    params = init_params(g)
    ob = OnBoardEvaluator(g, params, repeats=2)
    sub = groups[:10]
    ob_costs = [ob(gr) for gr in sub]
    t_ob = time.perf_counter() - t0
    sim_sub = [sim(gr) for gr in sub]
    rank = float(np.corrcoef(np.argsort(np.argsort(ob_costs)),
                             np.argsort(np.argsort(sim_sub)))[0, 1])

    print("# Table 2 reproduction (evaluation-method triad)")
    print(f"simulator : deviation 0% (reference)        "
          f"time {t_sim:6.2f}s / {len(groups)} groups")
    print(f"model     : deviation {mape*100:5.1f}% (fit MAPE "
          f"{model.fit_mape*100:.1f}%)  time {t_model:6.2f}s "
          f"(paper: 5-10%)")
    print(f"on-board  : rank-corr vs simulator {rank:+.2f}  "
          f"time {t_ob:6.2f}s / {len(sub)} groups (XLA-on-CPU board)")


if __name__ == "__main__":
    main()
