"""Explain benchmark: compile-decision provenance, end to end.

One run exercises the whole PR-9 surface on a real net:

1. **search-tracing overhead gate** — the strategy search is timed with and
   without ``trace=True`` (min of alternating repeats); recording the
   decision provenance must cost <= 5% of search wall-clock, or it is not
   free enough to stay on by default;
2. **report round trip** — compile the net, read the embedded CompileReport
   back off the artifact, validate it against the stable schema
   (``explain.validate_report``), strict-parse its JSON serialization, and
   render the text document (fusion decisions with at least one recorded
   not-chosen alternative and its cost, the DDR map, the bank plan);
3. **retune + plan diff** — re-run the tile search under a synthetic
   kernel-domain profile (forcing one unit to a non-default shape if the
   profile changes nothing) and assert ``explain.diff`` names *exactly* the
   units whose tile shape changed, with each side's predicted seconds;
4. **CLI** — ``python -m repro.explain`` on the saved artifact must emit
   strict-parseable JSON and the ``--diff`` of the pre/post-retune pair;
5. **live scrape** — serve the plan and GET ``/explain/<model>`` off the
   observability endpoint mid-serve; the route must return the same
   schema-valid report.

--smoke asserts all five gates and is wired into ``make ci`` as
``make explain-smoke``; the report JSON lands in benchmarks/out/ where CI
uploads it as an artifact.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import time
import urllib.request

import numpy as np

import outdir


def build_quantized(model: str, img: int):
    from repro.cnn import build, init_params
    from repro.core import executor, quantize

    g = build(model, img=img, num_classes=10) if img != 224 else build(model)
    params = init_params(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    return g, qm, x


def _kernel_profile():
    """Synthetic kernel-domain profile dominated by per-cell overhead — a
    deterministic 'this machine prefers different tiles' world, so the
    retune changes shapes without any wall-clock measurement."""
    from repro.tune.profile import COEF_NAMES, DeviceProfile

    coef = [0.0] * len(COEF_NAMES)
    coef[COEF_NAMES.index("rd")] = 1e-12
    coef[COEF_NAMES.index("conv")] = 1e-12
    coef[COEF_NAMES.index("cells")] = 1e-4
    return DeviceProfile(name="cells", device="zu2", backend="pallas",
                         jax_version="bench", features="kernel",
                         combine="sum", coef=tuple(coef), deviation=0.0,
                         n_samples=3)


def measure_trace_overhead(g, dev, dv, repeats: int) -> dict:
    """min-of-N alternating search timings, trace on vs off."""
    from repro.core import pathsearch

    on, off = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        pathsearch.search(g, dev, device_of=dv)
        on.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        pathsearch.search(g, dev, device_of=dv, trace=False)
        off.append(time.perf_counter() - t0)
    return {"search_s": min(on), "search_untraced_s": min(off),
            "overhead": min(on) / min(off) - 1.0}


def retune(g, qm, dev, strategy) -> list:
    """Tile-shape retune under the synthetic profile; guarantees at least one
    changed unit (forcing the first alternative candidate when the profile
    alone changes nothing).  Returns the changed tile keys."""
    from repro.core import lower, tiling
    from repro.tune import search_tile_shapes

    before = dict(strategy.meta.get("tile_shapes") or {})
    search_tile_shapes(g, qm, dev, strategy, profile=_kernel_profile())
    if dict(strategy.meta.get("tile_shapes") or {}) == before:
        for grp in strategy.groups:
            key = lower.tile_key(grp)
            cands = tiling.enumerate_tilings(g, list(grp), dev)
            alts = [(t.t_h, t.t_w, t.t_oc) for t in cands
                    if list((t.t_h, t.t_w, t.t_oc)) != before.get(key)]
            if alts:
                shapes = dict(strategy.meta.get("tile_shapes") or {})
                shapes[key] = [int(v) for v in alts[0]]
                strategy.meta["tile_shapes"] = shapes
                strategy.meta["tile_source"] = "measured"
                break
    after = dict(strategy.meta.get("tile_shapes") or {})
    return sorted(k for k in set(before) | set(after)
                  if before.get(k) != after.get(k))


def run_cli(argv) -> str:
    from repro.explain.__main__ import main as explain_main

    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        rc = explain_main(argv)
    assert not rc, f"repro.explain {argv} exited {rc}"
    return buf.getvalue()


def scrape_mid_serve(g, qm, strategy, dev, model: str, x) -> dict:
    """Serve the plan and GET /explain/<model> while requests are in flight."""
    from repro import asm
    from repro.core import quantize
    from repro.explain import validate_report
    from repro.runtime import Session

    sess = Session(g, strategy, dev, qm, backend="pallas",
                   cache=asm.PlanCache())
    rng = np.random.default_rng(1)
    reqs = [quantize.quantize_to(
        rng.standard_normal((1,) + tuple(g.shape("data")[1:]))
        .astype(np.float32), qm.f_a["data"]) for _ in range(8)]
    with sess.serve(max_batch=4, labels={"model": model}) as srv:
        obs = srv.serve_metrics(port=0)
        futs = [srv.submit(r) for r in reqs]
        with urllib.request.urlopen(obs.url("/explain")) as r:
            models = json.load(r)["models"]
        with urllib.request.urlopen(obs.url(f"/explain/{model}")) as r:
            scraped = json.load(r)
        for f in futs:
            f.result(timeout=120)
    assert model in models
    return validate_report(scraped)


def bench_model(model: str, img: int, *, plan_device: str,
                search_repeats: int, json_dir) -> dict:
    import os

    from repro import asm
    from repro.core import partition, pathsearch
    from repro.explain import diff, render_diff, render_report, report_of, \
        validate_report
    from repro.hw import get_device

    dev = get_device(plan_device)
    g, qm, x = build_quantized(model, img)
    dv = partition.device_of(g, "paper")

    overhead = measure_trace_overhead(g, dev, dv, search_repeats)

    # --- compile + report round trip ---------------------------------------
    s_a = pathsearch.search(g, dev, device_of=dv)
    art_a = asm.compile_strategy(g, s_a, dev, qm=qm)
    rep = validate_report(report_of(art_a))
    assert json.loads(json.dumps(rep)) == rep, "report not strictly JSON"
    n_alternatives = sum(len(ch["alternatives"])
                         for ch in rep["fusion"]["search"]["chains"])
    assert n_alternatives >= 1, "no recorded not-chosen alternative"
    text = render_report(rep)
    for marker in ("-- fusion", "-- search", "[not chosen]", "-- tiles",
                   "-- memory", "0x", "ping/pong", "-- schedule"):
        assert marker in text, f"report rendering lost section {marker!r}"

    # --- retune + plan diff -------------------------------------------------
    s_b = pathsearch.search(g, dev, device_of=dv)
    changed = retune(g, qm, dev, s_b)
    assert changed, "retune changed nothing; diff gate would be vacuous"
    art_b = asm.compile_strategy(g, s_b, dev, qm=qm)
    d = diff(art_a, art_b)
    diff_keys = sorted(c["key"] for c in d["tiles"]["changed"])
    assert diff_keys == changed, (
        f"diff named {diff_keys}, retune changed {changed}")
    assert not d["fusion"]["only_a"] and not d["fusion"]["only_b"]
    assert not d["identical"]
    render_diff(d)

    # --- CLI ----------------------------------------------------------------
    pa = os.path.join(json_dir, f"explain_{model}_a.npz")
    pb = os.path.join(json_dir, f"explain_{model}_b.npz")
    asm.save_artifact(art_a, pa)
    asm.save_artifact(art_b, pb)
    cli_rep = json.loads(run_cli([pa, "--format", "json"]))
    validate_report(cli_rep)
    assert cli_rep == rep
    assert "== compile report" in run_cli([pa])
    cli_diff = json.loads(run_cli([pa, "--diff", pb, "--format", "json"]))
    assert sorted(c["key"] for c in cli_diff["tiles"]["changed"]) == changed
    assert f"-- tiles changed" in run_cli([pa, "--diff", pb])

    # --- live scrape --------------------------------------------------------
    scraped = scrape_mid_serve(g, qm, s_a, dev, model, x)
    assert scraped == json.loads(json.dumps(rep))

    return {
        "model": model, "img": img, "plan_device": plan_device,
        **overhead,
        "n_groups": rep["fusion"]["n_groups"],
        "n_alternatives_recorded": n_alternatives,
        "n_regions": rep["memory"]["n_regions"],
        "tiles_changed_on_retune": changed,
        "report": rep,
        "diff": {k: v for k, v in d.items() if k != "report"},
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", action="append", dest="models",
                    choices=["vgg16", "resnet50", "googlenet"], default=None)
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--plan-device", default="zu2")
    ap.add_argument("--search-repeats", type=int, default=7,
                    help="alternating traced/untraced search timings")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="bare names land in benchmarks/out/ (gitignored)")
    ap.add_argument("--smoke", action="store_true",
                    help="assert the acceptance gates")
    args = ap.parse_args(argv)
    args.json_path = outdir.resolve(args.json_path)
    import os
    json_dir = os.path.dirname(args.json_path) if args.json_path \
        else outdir.resolve("explain_bench.json").rsplit(os.sep, 1)[0]
    models = args.models or ["vgg16"]

    records = []
    for model in models:
        rec = bench_model(model, args.img, plan_device=args.plan_device,
                          search_repeats=args.search_repeats,
                          json_dir=json_dir)
        records.append(rec)
        print(f"{model}@{args.img} [{args.plan_device}] explain: "
              f"{rec['n_groups']} groups, "
              f"{rec['n_alternatives_recorded']} not-chosen alternatives, "
              f"{rec['n_regions']} DDR regions in report")
        print(f"  search {rec['search_s'] * 1e3:.1f} ms traced vs "
              f"{rec['search_untraced_s'] * 1e3:.1f} ms untraced "
              f"({rec['overhead']:+.1%} overhead)")
        print(f"  retune changed {len(rec['tiles_changed_on_retune'])} "
              f"tiles; diff named them exactly; /explain scrape OK")

    out = {"img": args.img, "plan_device": args.plan_device,
           "models": records}
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"wrote {args.json_path}")

    if args.smoke:
        for rec in records:
            assert rec["overhead"] <= 0.05, (
                f"{rec['model']}: search tracing costs "
                f"{rec['overhead']:+.1%} > 5%")
            assert rec["n_alternatives_recorded"] >= 1
            assert rec["tiles_changed_on_retune"]
        print("EXPLAIN SMOKE OK: report schema-valid + strict JSON, "
              "diff names exactly the retuned tiles, CLI + /explain route "
              "serve it, tracing overhead within 5%")
    return out


if __name__ == "__main__":
    main()
