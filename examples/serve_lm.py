"""Serve a small model with batched requests through the KV-cache decode
path (greedy sampling), including a sliding-window (mixtral-style) client.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import make_serve_step
from repro.models import api


def run(arch: str, batch=4, prompt_len=16, gen_len=48):
    cfg = configs.get(arch).smoke()
    params = api.init_params(cfg)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    cache = api.init_cache(cfg, batch, prompt_len + gen_len)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (batch, prompt_len)).astype("int32")

    t0 = time.perf_counter()
    tok = jnp.asarray(prompt[:, 0])
    for p in range(prompt_len - 1):           # teacher-forced prefill
        _, cache = serve(params, cache, jnp.asarray(prompt[:, p]), jnp.int32(p))
    outs = []
    tok = jnp.asarray(prompt[:, -1])
    for p in range(prompt_len - 1, prompt_len + gen_len - 1):
        tok, cache = serve(params, cache, tok, jnp.int32(p))
        outs.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    total = batch * (prompt_len + gen_len - 1)
    print(f"{arch:24s} {total/dt:8.1f} tok/s  sample={np.stack(outs,1)[0][:8]}")


if __name__ == "__main__":
    for arch in ("granite-8b", "mixtral-8x7b", "xlstm-1.3b", "zamba2-1.2b"):
        run(arch)
