"""The DNNVM planner applied to the LM architectures (DESIGN.md §3):
per-arch kernel-fusion decisions (flash attention / chunked scan) from the
same condition-1 capacity check + cost comparison the CNN planner uses.

    PYTHONPATH=src python examples/plan_transformer.py
"""
from repro import configs
from repro.core import lm_bridge

print("DNNVM block-level planning against the TPU v5e device model\n")
for seq in (4096, 32768):
    print(f"== seq_len {seq}")
    for name in configs.ARCHS:
        print("  " + lm_bridge.report(configs.get(name), seq_len=seq))
    print()
