"""Serving quickstart: compile a CNN once, serve it with dynamic batching.

    PYTHONPATH=src python examples/serve_cnn.py --model vgg16 --img 32
    PYTHONPATH=src python examples/serve_cnn.py --model resnet50 --requests 16
    PYTHONPATH=src python examples/serve_cnn.py --model googlenet --img 64

Walks the whole runtime-supporter path: calibrate -> path-search -> compile
through the plan cache -> open a Session -> submit requests to the
dynamic-batching Server -> print throughput, latency percentiles, the batch
histogram, and the time-wheel engine schedule (modeled cross-request overlap
and per-engine utilization).  A second Session construction demonstrates the
plan-cache hit.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="vgg16",
                    choices=["vgg16", "resnet50", "googlenet"])
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-latency-ms", type=float, default=20.0)
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    args = ap.parse_args()

    from repro.cnn import build, init_params
    from repro.core import executor, partition, pathsearch, quantize
    from repro.hw import ZU2
    from repro.runtime import Session

    print(f"== compile {args.model}@{args.img} ==")
    g = build(args.model, img=args.img, num_classes=10)
    params = init_params(g)
    rng = np.random.default_rng(0)
    calib = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, calib, executor.run_float)
    dv = partition.device_of(g, "paper")
    strategy = pathsearch.search(g, ZU2, device_of=dv)

    t0 = time.perf_counter()
    sess = Session(g, strategy, ZU2, qm, backend=args.backend)
    print(f"session (cold compile): {time.perf_counter() - t0:.2f}s, "
          f"fused coverage {sess.artifact.fused_coverage:.2f}, "
          f"peak DDR {sess.artifact.peak_ddr_bytes / 1e6:.2f} MB")
    t0 = time.perf_counter()
    Session(g, strategy, ZU2, qm, backend=args.backend)
    print(f"session (plan-cache hit): {time.perf_counter() - t0:.3f}s")

    print(f"== serve {args.requests} requests "
          f"(max_batch={args.max_batch}, "
          f"max_latency={args.max_latency_ms}ms) ==")
    reqs = [quantize.quantize_to(
        rng.standard_normal((1,) + tuple(g.shape('data')[1:])).astype(np.float32),
        qm.f_a["data"]) for _ in range(args.requests)]
    with sess.serve(max_batch=args.max_batch,
                    max_latency_s=args.max_latency_ms * 1e-3) as server:
        t0 = time.perf_counter()
        futs = [server.submit(x) for x in reqs]
        outs = [f.result(timeout=600) for f in futs]
        wall = time.perf_counter() - t0
        stats = server.stats()
    top = sess.outputs[-1]
    print(f"served {len(outs)} requests in {wall:.2f}s "
          f"({len(outs) / wall:.2f} img/s)")
    print(f"latency p50={stats['p50_ms']:.1f}ms p99={stats['p99_ms']:.1f}ms, "
          f"batches {stats['batch_histogram']} "
          f"(mean {stats['mean_batch']:.1f})")
    print(f"output {top!r} of request 0: "
          f"{np.asarray(outs[0][top]).ravel()[:4]} ...")

    print("== engine-level schedule (time wheel) ==")
    rep = sess.pipeline_report(min(args.requests, 8), ddr_slots=4)
    util = ", ".join(f"{e}={u:.0%}" for e, u in rep.utilization().items())
    print(f"modeled cross-request speedup {rep.modeled_speedup:.3f}x "
          f"(overlap {rep.overlap:.1%}), bottleneck {rep.bottleneck}")
    print(f"per-engine utilization: {util}")
    lat = rep.request_latency_cycles()
    print(f"request latency (cycles): first {lat[0]}, steady-state ~{lat[-1]}")
    # show the software pipeline directly: request 1's LOADs issued while
    # request 0's CONVs were still running
    conv0 = [w for w in rep.engine_timeline["CONV"] if w[3].startswith("r0:")]
    load1 = [w for w in rep.engine_timeline["DDR_RD"]
             if w[3].startswith("r1:")]
    overlapped = [l for l in load1
                  if any(l[0] < c[1] and c[0] < l[1] for c in conv0)]
    print(f"LOAD(r1) windows overlapping CONV(r0): "
          f"{len(overlapped)}/{len(load1)}, e.g. "
          + "; ".join(f"{t}@[{s},{e})" for s, e, _, t in overlapped[:2]))


if __name__ == "__main__":
    main()
