"""Quickstart: the full DNNVM pipeline on a small CNN, end to end.

    PYTHONPATH=src python examples/quickstart.py

1. build a framework-style graph and lower it to XGraph (intrinsic +
   point-wise fusion, layout pruning);
2. enumerate kernel-fusion opportunities (subgraph isomorphism) and pick the
   best execution strategy (Floyd path search between barriers);
3. quantize to int8 (per-layer radix calibration);
4. execute the strategy — fused groups run as single Pallas kernels
   (interpret mode on CPU) — and verify bit-exactness vs the unfused oracle.
"""
import numpy as np

from repro.core import executor, pathsearch, quantize, validate
from repro.core.cost import SimulatorEvaluator
from repro.core.xgraph import XGraph
from repro.core import frontend
from repro.hw import ZU2

# 1. ---- a small ResNet-flavoured graph -------------------------------------
g = XGraph("quickstart")
g.input("data", (1, 16, 16, 8))
g.add("conv", "stem", ("data",), oc=16, kernel=(3, 3), pad="same")
g.add("bn", "stem/bn", ("stem",), gamma=1.0, beta=0.0, mean=0.0, var=1.0)
g.add("relu", "stem/relu", ("stem/bn",))
g.add("conv", "a", ("stem/relu",), oc=16, kernel=(3, 3), pad="same")
g.add("relu", "a/relu", ("a",))
g.add("conv", "b", ("a/relu",), oc=16, kernel=(3, 3), pad="same")
g.add("eltwise_add", "add", ("b", "stem/relu"))
g.add("relu", "add/relu", ("add",))
g.add("maxpool", "pool", ("add/relu",), kernel=(2, 2), stride=(2, 2))
g.add("fc", "head", ("pool",), oc=10)
frontend.lower(g)
print(g.summary(), "\n")

# 2. ---- plan ----------------------------------------------------------------
sim = SimulatorEvaluator(g, ZU2)
naive = pathsearch.naive(g, ZU2, evaluator=sim)
opt = pathsearch.search(g, ZU2, evaluator=sim)
print(f"naive strategy:     {naive.cost*1e3:8.4f} ms  "
      f"({len(naive.groups)} groups)")
print(f"optimized strategy: {opt.cost*1e3:8.4f} ms  "
      f"groups={opt.groups} horizontal={opt.horizontal}\n")

# 3. ---- quantize ------------------------------------------------------------
rng = np.random.default_rng(0)
from repro.cnn import init_params

params = init_params(g)
x = rng.standard_normal((1, 16, 16, 8)).astype(np.float32)
qm = quantize.calibrate(g, params, x, executor.run_float)
print("activation radix positions:",
      {k: v for k, v in list(qm.f_a.items())[:6]}, "...\n")

# 4. ---- execute + validate --------------------------------------------------
xq = quantize.quantize_to(x, qm.f_a["data"])
rep = validate.bit_exact(g, qm, xq, strategy=opt, backend="pallas",
                         float_params=params)
print(f"bit-exact vs unfused oracle: {rep.bit_exact} "
      f"(outputs={rep.n_outputs}, max_diff={rep.max_abs_diff})")
print(f"SQNR vs float reference (dB): "
      f"{ {k: round(v, 1) for k, v in rep.sqnr_db.items()} }")
assert rep.bit_exact
print("\nOK — fused execution is bit-identical to the oracle.")
