"""Compile ResNet-50 with DNNVM for the ZU2-class device model and report
the Table-3-style breakdown; then execute a reduced-resolution variant int8
bit-exact.

    PYTHONPATH=src python examples/compile_resnet.py
"""
import time

import numpy as np

from repro.cnn import build, init_params
from repro.core import executor, partition, pathsearch, quantize, validate
from repro.core.cost import SimulatorEvaluator
from repro.hw import ZU2

# ---- full-size planning (the compiler's job; fast) --------------------------
g = build("resnet50")
dv = partition.device_of(g, "paper")
sim = SimulatorEvaluator(g, ZU2)
t0 = time.perf_counter()
naive = pathsearch.naive(g, ZU2, evaluator=sim, device_of=dv)
greedy = pathsearch.greedy(g, ZU2, evaluator=sim, device_of=dv)
opt = pathsearch.search(g, ZU2, evaluator=sim, device_of=dv)
t_plan = time.perf_counter() - t0

acc_ops = sum(g.ops(n.name) for n in g if dv(n.name) == "acc")
for name, s in (("naive", naive), ("greedy", greedy), ("optimized", opt)):
    rep = sim.strategy_report(s)
    secs = rep.seconds(ZU2.freq_hz)
    print(f"{name:10s} {secs*1e3:8.2f} ms  {acc_ops/secs/1e9:6.1f} GOPs/s  "
          f"CONV util {rep.utilization('CONV')*100:5.1f}%")
print(f"planning took {t_plan:.2f}s for {len(g)} nodes; "
      f"speedup {naive.cost/opt.cost:.3f}x (paper: 1.17x)\n")

fused_pairs = [grp for grp in opt.groups if len(grp) > 1]
print(f"{len(fused_pairs)} fused groups, e.g.: {fused_pairs[:4]}")
print(f"horizontal groups: {opt.horizontal[:3]}\n")

# ---- reduced-resolution execution (bit-exact check) -------------------------
g32 = build("resnet50", img=32, num_classes=10)
params = init_params(g32)
x = np.random.default_rng(0).standard_normal((1, 32, 32, 3)).astype(np.float32)
qm = quantize.calibrate(g32, params, x, executor.run_float)
xq = quantize.quantize_to(x, qm.f_a["data"])
s32 = pathsearch.search(g32, ZU2)
rep = validate.bit_exact(g32, qm, xq, strategy=s32, backend="pallas")
print(f"img=32 execution bit-exact: {rep.bit_exact}")
assert rep.bit_exact
