"""End-to-end training driver: a ~100M-class LM for a few hundred steps on
the synthetic pipeline, with checkpoint/restart exercised mid-run.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(On this CPU container we train the smollm reduced config; the full-size
path is identical — swap --smoke off on a real pod.)
"""
import argparse
import dataclasses
import shutil
import time

import jax

from repro import configs
from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import SyntheticLM
from repro.launch.train import init_state, make_train_step
from repro.optim.adamw import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = dataclasses.replace(configs.get("smollm-360m").smoke(), n_layers=4)
    data = SyntheticLM(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20)
    state = init_state(cfg, opt)
    step_fn = jax.jit(make_train_step(cfg, opt, grad_accum=2))

    ckpt_dir = "/tmp/repro_train_lm_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    store = CheckpointStore(ckpt_dir)

    t0 = time.perf_counter()
    half = args.steps // 2
    for i in range(half):
        state, m = step_fn(state, data.next())
        if (i + 1) % 20 == 0:
            print(f"step {i+1:4d} loss {float(m['loss']):.4f}")
    store.save(state, step=half, extra={"data_step": data.state()["step"]})
    print(f"--- checkpoint at step {half}; simulating restart ---")

    # restart: fresh state objects, restore, resume identically
    state2 = jax.eval_shape(lambda: init_state(cfg, opt))
    state, start = store.restore(half, state2)
    data = SyntheticLM(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    data.seek(start)
    for i in range(start, args.steps):
        state, m = step_fn(state, data.next())
        if (i + 1) % 20 == 0:
            print(f"step {i+1:4d} loss {float(m['loss']):.4f}")
    dt = time.perf_counter() - t0
    print(f"done: final loss {float(m['loss']):.4f} "
          f"({args.steps} steps, {dt:.1f}s, {dt/args.steps*1e3:.0f} ms/step)")


if __name__ == "__main__":
    main()
