"""A YOLO-style straight-line detector neck — exercises the no-branch Floyd
path (paper §5.2: "CNNs with no branch like VGG and YOLO") plus the reorg op."""
from __future__ import annotations

from repro.core import frontend
from repro.core.xgraph import XGraph


def yolo_lite(img: int = 224, num_anchors: int = 5, num_classes: int = 20) -> XGraph:
    g = XGraph("yolo_lite")
    last = g.input("data", (1, img, img, 3))
    oc = 16
    for i in range(5):
        g.add("conv", f"conv{i}", (last,), oc=oc, kernel=(3, 3), pad="same")
        g.add("relu", f"relu{i}", (f"conv{i}",))
        g.add("maxpool", f"pool{i}", (f"relu{i}",), kernel=(2, 2), stride=(2, 2))
        last = f"pool{i}"
        oc = min(oc * 2, 512)
    g.add("reorg", "reorg", (last,), stride=2)
    g.add("conv", "head1", ("reorg",), oc=512, kernel=(3, 3), pad="same")
    g.add("relu", "head1/r", ("head1",))
    out_c = num_anchors * (5 + num_classes)
    g.add("conv", "head2", ("head1/r",), oc=out_c, kernel=(1, 1), pad="same")
    return frontend.lower(g)
