"""ResNet-50 / ResNet-152 (He et al. 2016) — eltwise-add fusion benchmark.

BN is emitted as explicit nodes so the intrinsic-fusion pass exercises the
paper's conv+BN folding path on a real network."""
from __future__ import annotations

import numpy as np

from repro.core import frontend
from repro.core.xgraph import XGraph


def _conv_bn(g: XGraph, name: str, bottom: str, oc: int, kernel, stride=(1, 1),
             relu: bool = True) -> str:
    g.add("conv", name, (bottom,), oc=oc, kernel=kernel, stride=stride, pad="same")
    g.add("bn", f"{name}/bn", (name,), gamma=1.0, beta=0.0,
          mean=0.0, var=1.0, eps=1e-5)
    last = f"{name}/bn"
    if relu:
        g.add("relu", f"{name}/relu", (last,))
        last = f"{name}/relu"
    return last


def _bottleneck(g: XGraph, name: str, bottom: str, mid: int, out: int,
                stride=(1, 1), project: bool = False) -> str:
    a = _conv_bn(g, f"{name}/c1", bottom, mid, (1, 1))
    b = _conv_bn(g, f"{name}/c2", a, mid, (3, 3), stride=stride)
    c = _conv_bn(g, f"{name}/c3", b, out, (1, 1), relu=False)
    if project:
        s = _conv_bn(g, f"{name}/sc", bottom, out, (1, 1), stride=stride,
                     relu=False)
    else:
        s = bottom
    g.add("eltwise_add", f"{name}/add", (c, s))
    g.add("relu", f"{name}/out", (f"{name}/add",))
    return f"{name}/out"


def _resnet(name: str, blocks: list[int], img: int, num_classes: int, batch: int = 1) -> XGraph:
    g = XGraph(name)
    last = g.input("data", (batch, img, img, 3))
    last = _conv_bn(g, "conv1", last, 64, (7, 7), stride=(2, 2))
    g.add("maxpool", "pool1", (last,), kernel=(3, 3), stride=(2, 2), pad=(0, 0))
    last = "pool1"
    widths = [(64, 256), (128, 512), (256, 1024), (512, 2048)]
    for si, (nb, (mid, out)) in enumerate(zip(blocks, widths)):
        for bi in range(nb):
            stride = (2, 2) if (bi == 0 and si > 0) else (1, 1)
            last = _bottleneck(g, f"s{si}b{bi}", last, mid, out,
                               stride=stride, project=(bi == 0))
    g.add("global_avgpool", "gap", (last,))
    g.add("fc", "fc", ("gap",), oc=num_classes)
    g.add("softmax", "prob", ("fc",))
    return frontend.lower(g)


def resnet50(img: int = 224, num_classes: int = 1000, batch: int = 1) -> XGraph:
    return _resnet("resnet50", [3, 4, 6, 3], img, num_classes, batch)


def resnet152(img: int = 224, num_classes: int = 1000, batch: int = 1) -> XGraph:
    return _resnet("resnet152", [3, 8, 36, 3], img, num_classes, batch)
