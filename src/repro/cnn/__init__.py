"""CNN model zoo — the paper's benchmarks as XGraph builders.

All builders return a *lowered* XGraph (front-end passes applied) plus a
float parameter initializer.  Input is ImageNet-style (1, 224, 224, 3) NHWC
unless overridden (tests use smaller resolutions)."""
from repro.cnn.vgg import vgg16
from repro.cnn.resnet import resnet50, resnet152
from repro.cnn.googlenet import googlenet
from repro.cnn.yolo import yolo_lite

REGISTRY = {
    "vgg16": vgg16,
    "resnet50": resnet50,
    "resnet152": resnet152,
    "googlenet": googlenet,
    "yolo_lite": yolo_lite,
}


def build(name: str, **kw):
    return REGISTRY[name](**kw)


def init_params(g, seed: int = 0, scale: float = 0.1):
    """He-ish random float params for every conv/fc node (pretrained weights
    are unavailable offline; throughput and bit-exactness are weight-agnostic,
    documented in EXPERIMENTS.md)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    params = {}
    for n in g:
        if n.op in ("conv", "dilated_conv", "deconv"):
            ic = g.shape(n.inputs[0])[3]
            kh, kw = n.attrs["kernel"]
            oc = n.attrs["oc"]
            std = scale / max(1.0, (kh * kw * ic) ** 0.5) * 4
            params[n.name] = {
                "w": rng.standard_normal((kh, kw, ic, oc)).astype("float32") * std,
                "b": rng.standard_normal(oc).astype("float32") * 0.05}
        elif n.op == "depthwise_conv":
            c = g.shape(n.inputs[0])[3]
            kh, kw = n.attrs["kernel"]
            params[n.name] = {
                "w": rng.standard_normal((kh, kw, 1, c)).astype("float32") * scale,
                "b": rng.standard_normal(c).astype("float32") * 0.05}
        elif n.op == "fc":
            ish = g.shape(n.inputs[0])
            d = ish[1] * ish[2] * ish[3]
            oc = n.attrs["oc"]
            params[n.name] = {
                "w": rng.standard_normal((d, oc)).astype("float32") * (scale / d ** 0.5 * 4),
                "b": rng.standard_normal(oc).astype("float32") * 0.05}
    return params
