"""GoogLeNet v1 (Szegedy et al. 2015) — Inception: the horizontal-fusion and
folded-concat benchmark (paper §5.2, Fig. 4)."""
from __future__ import annotations

from repro.core import frontend
from repro.core.xgraph import XGraph

# (1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj) per inception module
_INCEPTION = {
    "3a": (64, 96, 128, 16, 32, 32),
    "3b": (128, 128, 192, 32, 96, 64),
    "4a": (192, 96, 208, 16, 48, 64),
    "4b": (160, 112, 224, 24, 64, 64),
    "4c": (128, 128, 256, 24, 64, 64),
    "4d": (112, 144, 288, 32, 64, 64),
    "4e": (256, 160, 320, 32, 128, 128),
    "5a": (256, 160, 320, 32, 128, 128),
    "5b": (384, 192, 384, 48, 128, 128),
}


def _conv(g, name, bottom, oc, k, stride=(1, 1)) -> str:
    g.add("conv", name, (bottom,), oc=oc, kernel=(k, k), stride=stride, pad="same")
    g.add("relu", f"{name}/r", (name,))
    return f"{name}/r"


def _inception(g: XGraph, name: str, bottom: str, cfg) -> str:
    c1, r3, c3, r5, c5, pp = cfg
    b1 = _conv(g, f"{name}/1x1", bottom, c1, 1)
    b2 = _conv(g, f"{name}/3x3r", bottom, r3, 1)
    b2 = _conv(g, f"{name}/3x3", b2, c3, 3)
    b3 = _conv(g, f"{name}/5x5r", bottom, r5, 1)
    b3 = _conv(g, f"{name}/5x5", b3, c5, 5)
    g.add("maxpool", f"{name}/pool", (bottom,), kernel=(3, 3), stride=(1, 1),
          pad=(1, 1))
    b4 = _conv(g, f"{name}/poolp", f"{name}/pool", pp, 1)
    g.add("concat", f"{name}/out", (b1, b2, b3, b4))
    return f"{name}/out"


def googlenet(img: int = 224, num_classes: int = 1000, batch: int = 1) -> XGraph:
    g = XGraph("googlenet")
    last = g.input("data", (batch, img, img, 3))
    last = _conv(g, "conv1", last, 64, 7, stride=(2, 2))
    g.add("maxpool", "pool1", (last,), kernel=(3, 3), stride=(2, 2), pad=(0, 0))
    last = _conv(g, "conv2r", "pool1", 64, 1)
    last = _conv(g, "conv2", last, 192, 3)
    g.add("maxpool", "pool2", (last,), kernel=(3, 3), stride=(2, 2), pad=(0, 0))
    last = "pool2"
    for mod in ("3a", "3b"):
        last = _inception(g, f"inc{mod}", last, _INCEPTION[mod])
    g.add("maxpool", "pool3", (last,), kernel=(3, 3), stride=(2, 2), pad=(0, 0))
    last = "pool3"
    for mod in ("4a", "4b", "4c", "4d", "4e"):
        last = _inception(g, f"inc{mod}", last, _INCEPTION[mod])
    g.add("maxpool", "pool4", (last,), kernel=(3, 3), stride=(2, 2), pad=(0, 0))
    last = "pool4"
    for mod in ("5a", "5b"):
        last = _inception(g, f"inc{mod}", last, _INCEPTION[mod])
    g.add("global_avgpool", "gap", (last,))
    g.add("fc", "fc", ("gap",), oc=num_classes)
    g.add("softmax", "prob", ("fc",))
    return frontend.lower(g)
