"""VGG-16 (Simonyan & Zisserman 2014) — the paper's compute-bound benchmark."""
from __future__ import annotations

from repro.core import frontend
from repro.core.xgraph import XGraph

_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
        512, 512, 512, "M", 512, 512, 512, "M"]


def vgg16(img: int = 224, num_classes: int = 1000, batch: int = 1) -> XGraph:
    g = XGraph("vgg16")
    last = g.input("data", (batch, img, img, 3))
    ci = 0
    for v in _CFG:
        if v == "M":
            g.add("maxpool", f"pool{ci}", (last,), kernel=(2, 2), stride=(2, 2))
            last = f"pool{ci}"
        else:
            ci += 1
            g.add("conv", f"conv{ci}", (last,), oc=v, kernel=(3, 3),
                  stride=(1, 1), pad="same")
            g.add("relu", f"relu{ci}", (f"conv{ci}",))
            last = f"relu{ci}"
    g.add("flatten", "flat", (last,))
    g.add("fc", "fc6", ("flat",), oc=4096)
    g.add("relu", "relu_fc6", ("fc6",))
    g.add("fc", "fc7", ("relu_fc6",), oc=4096)
    g.add("relu", "relu_fc7", ("fc7",))
    g.add("fc", "fc8", ("relu_fc7",), oc=num_classes)
    g.add("softmax", "prob", ("fc8",))
    return frontend.lower(g)
