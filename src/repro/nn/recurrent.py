"""Chunked linear recurrences — the shared machinery for mLSTM (xLSTM) and
Mamba2 (SSD), plus the sequential sLSTM cell.

The recurrence  S_t = a_t * S_{t-1} + k_t v_t^T ,  y_t = S_t^T q_t  (with
per-(step, head) scalar decay a_t) is evaluated in the chunk-parallel form:
within a chunk of length L the contribution is a masked (decay-weighted)
attention-like contraction, across chunks the state S (K x V per head) is
carried by a scan.  Chunk length is a capacity decision: the (L x L) decay
mask plus the (K x V) state tile must fit VMEM — the same "fusion condition
1" the DNNVM tiling solver checks for conv chains (DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def chunked_linear_scan(q, k, v, log_a, *, chunk: int = 128, state0=None,
                        unroll: bool = False):
    """q,k: (B,S,H,K); v: (B,S,H,V); log_a: (B,S,H) <= 0 (log decay).

    Returns y (B,S,H,V), final state (B,H,K,V)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, s)
    assert s % L == 0, f"seq {s} not divisible by chunk {L}"
    n = s // L

    qc = q.reshape(b, n, L, h, dk).transpose(1, 0, 3, 2, 4)   # (n,B,H,L,K)
    kc = k.reshape(b, n, L, h, dk).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n, L, h, dv).transpose(1, 0, 3, 2, 4)
    lac = log_a.reshape(b, n, L, h).transpose(1, 0, 3, 2)     # (n,B,H,L)

    def body(S, xs):
        qb, kb, vb, lab = xs                                   # per chunk
        cum = jnp.cumsum(lab, axis=-1)                         # (B,H,L)
        # within-chunk decay-masked "attention":  A[i,j] = exp(cum_i - cum_j)
        # for j <= i (contribution of step j's kv to step i's output)
        diff = cum[..., :, None] - cum[..., None, :]           # (B,H,L,L)
        tri = jnp.tril(jnp.ones((L, L), bool))
        A = jnp.where(tri, jnp.exp(diff), 0.0).astype(qb.dtype)
        scores = jnp.einsum("bhik,bhjk->bhij", qb, kb) * A
        intra = jnp.einsum("bhij,bhjv->bhiv", scores, vb)
        # inter-chunk: state carried in, decayed per step
        decay_in = jnp.exp(cum)[..., None].astype(qb.dtype)    # (B,H,L,1)
        inter = jnp.einsum("bhik,bhkv->bhiv", qb * decay_in, S.astype(qb.dtype))
        # state update: S' = a_total * S + sum_j exp(cum_L - cum_j) k_j v_j^T
        total = cum[..., -1:]                                  # (B,H,1)
        w = jnp.exp(total - cum)[..., None]                    # (B,H,L,1)
        S = (jnp.exp(total)[..., None] * S.astype(jnp.float32)
             + jnp.einsum("bhjk,bhjv->bhkv",
                          kb.astype(jnp.float32) * w,
                          vb.astype(jnp.float32)))
        return S, (intra + inter).astype(vb.dtype)

    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    S, yc = jax.lax.scan(body, state0, (qc, kc, vc, lac),
                         unroll=n if unroll else 1)
    y = yc.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dv)
    return y, S


def linear_step(q, k, v, log_a, state):
    """One decode step.  q,k (B,H,K); v (B,H,V); log_a (B,H); state (B,H,K,V).

    Returns y (B,H,V), new state."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    S = a * state + jnp.einsum("bhk,bhv->bhkv",
                               k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), S)
    return y.astype(q.dtype), S


# ------------------------------------------------------------------- sLSTM
def slstm_scan(x, p, state0=None):
    """Sequential sLSTM block core: x (B,S,D) -> (B,S,D), state.

    True recurrence (non-linear state dependence) => lax.scan over time;
    this is the one layer family that cannot use the chunked form, noted in
    DESIGN.md §5."""
    b, s, d = x.shape
    gates = x @ p["w_gates"] + p["b_gates"]                   # (B,S,4D)
    if state0 is None:
        state0 = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32))

    def step(carry, g):
        h, c = carry
        gi, gf, gz, go = jnp.split(g.astype(jnp.float32)
                                   + (h @ p["r_gates"].astype(jnp.float32)), 4, -1)
        i, f = jax.nn.sigmoid(gi), jax.nn.sigmoid(gf)
        z, o = jnp.tanh(gz), jax.nn.sigmoid(go)
        c = f * c + i * z
        h = o * jnp.tanh(c)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, state0, gates.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2).astype(x.dtype), state0


def slstm_step(x, p, state):
    """One decode step: x (B,D), state (h, c)."""
    h, c = state
    g = x @ p["w_gates"] + p["b_gates"]
    gi, gf, gz, go = jnp.split(g.astype(jnp.float32)
                               + (h @ p["r_gates"].astype(jnp.float32)), 4, -1)
    i, f = jax.nn.sigmoid(gi), jax.nn.sigmoid(gf)
    z, o = jnp.tanh(gz), jax.nn.sigmoid(go)
    c = f * c + i * z
    h = o * jnp.tanh(c)
    return h.astype(x.dtype), (h, c)
