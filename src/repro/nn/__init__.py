"""Pure-JAX NN substrate for the LM-family architectures.

Models are pytrees of arrays + pure apply functions (no framework deps).
``init_params(cfg, rng)`` builds real arrays for smoke tests / training;
``abstract_params(cfg)`` builds ShapeDtypeStructs for the multi-pod dry-run
(never allocates).
"""
