"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder input is the modality stub: precomputed speech-frame embeddings
(B, S_enc, D) from ``input_specs`` (per assignment, the conformer frontend is
not modeled).  The decoder is a standard causal stack with cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import attention as attn
from repro.nn import layers as nnl


def init_params(cfg: ArchConfig, rng: jax.Array):
    dt = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.head_dim
    h, kv, f, V = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab
    Le, Ld = cfg.enc_layers, cfg.n_layers
    ks = jax.random.split(rng, 24)

    def norm(key, *shape):
        return jax.random.normal(key, shape, dt) * 0.02

    def stack(base, L, extra_cross: bool):
        p = {
            "ln1": jnp.ones((L, d), jnp.float32),
            "wq": norm(ks[base], L, d, h * hd),
            "wk": norm(ks[base + 1], L, d, kv * hd),
            "wv": norm(ks[base + 2], L, d, kv * hd),
            "wo": norm(ks[base + 3], L, h * hd, d),
            "ln2": jnp.ones((L, d), jnp.float32),
            "w1": norm(ks[base + 4], L, d, f),
            "w2": norm(ks[base + 5], L, f, d),
        }
        if extra_cross:
            p.update({
                "lnx": jnp.ones((L, d), jnp.float32),
                "xwq": norm(ks[base + 6], L, d, h * hd),
                "xwk": norm(ks[base + 7], L, d, kv * hd),
                "xwv": norm(ks[base + 8], L, d, kv * hd),
                "xwo": norm(ks[base + 9], L, h * hd, d),
            })
        return p

    return {
        "embed": norm(ks[20], V, d),
        "enc": stack(0, Le, False),
        "dec": stack(10, Ld, True),
        "ln_enc": jnp.ones((d,), jnp.float32),
        "ln_f": jnp.ones((d,), jnp.float32),
    }


def _self_block(cfg, x, lp, pos, causal):
    h = nnl.rms_norm(x, lp["ln1"])
    q, k, v = attn.qkv(h, lp, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    q = nnl.apply_rope(q, pos, cfg.rope_theta)
    k = nnl.apply_rope(k, pos, cfg.rope_theta)
    o = attn.sdpa(q, k, v, causal=causal)
    return x + attn.attn_out(o, lp)


def _cross(cfg, x, lp, enc_kv):
    h = nnl.rms_norm(x, lp["lnx"])
    b, s, _ = h.shape
    q = (h @ lp["xwq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k, v = enc_kv
    o = attn.sdpa(q, k, v, causal=False)
    b, s2, hh, dd = o.shape
    return x + o.reshape(b, s2, hh * dd) @ lp["xwo"]


def _mlp(cfg, x, lp):
    h = nnl.rms_norm(x, lp["ln2"])
    return x + nnl.mlp(h, lp, cfg.act)


def encode(cfg: ArchConfig, params, frames):
    x = frames.astype(jnp.dtype(cfg.dtype))
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, lp):
        x = _self_block(cfg, x, lp, pos, causal=False)
        return _mlp(cfg, x, lp), None

    from repro.nn import flags
    bfn = jax.remat(body) if cfg.remat else body
    x, _ = jax.lax.scan(bfn, x, params["enc"],
                        unroll=flags.unroll_for(cfg.enc_layers))
    return nnl.rms_norm(x, params["ln_enc"])


def decode_train(cfg: ArchConfig, params, enc_out, tokens):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, lp):
        x = _self_block(cfg, x, lp, pos, causal=True)
        be, se, _ = enc_out.shape
        k = (enc_out @ lp["xwk"]).reshape(be, se, cfg.n_kv_heads, cfg.head_dim)
        v = (enc_out @ lp["xwv"]).reshape(be, se, cfg.n_kv_heads, cfg.head_dim)
        x = _cross(cfg, x, lp, (k, v))
        return _mlp(cfg, x, lp), None

    from repro.nn import flags
    bfn = jax.remat(body) if cfg.remat else body
    x, _ = jax.lax.scan(bfn, x, params["dec"],
                        unroll=flags.unroll_for(cfg.n_layers))
    x = nnl.rms_norm(x, params["ln_f"])
    return x @ params["embed"].T.astype(x.dtype)


def loss_fn(cfg: ArchConfig, params, batch):
    enc_out = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, enc_out, batch["tokens"])
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32),
                             labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


# --------------------------------------------------------------------- decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int):
    dt = jnp.dtype(cfg.dtype)
    Ld = cfg.n_layers
    return {
        "k": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
        # cross-attention K/V precomputed from the encoder output at prefill
        "xk": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dt),
        "xv": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dt),
    }


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens][:, None, :].astype(dt)
    b = x.shape[0]
    p = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b, 1))

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        h = nnl.rms_norm(x, lp["ln1"])
        q, k, v = attn.qkv(h, lp, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
        q = nnl.apply_rope(q, p, cfg.rope_theta)
        k = nnl.apply_rope(k, p, cfg.rope_theta)
        lc = attn.cache_update({"k": ck, "v": cv}, k, v, pos)
        o = attn.decode_attend(q, lc, pos)
        x = x + attn.attn_out(o, lp)
        x = _cross(cfg, x, lp, (xk, xv))
        x = _mlp(cfg, x, lp)
        return x, (lc["k"], lc["v"])

    from repro.nn import flags
    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]),
        unroll=flags.unroll_for(cfg.n_layers))
    x = nnl.rms_norm(x, params["ln_f"])
    logits = (x @ params["embed"].T.astype(x.dtype))[:, 0]
    return logits, {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"]}
