"""Common layers: norms, rotary embeddings (incl. M-RoPE), MLPs, MoE."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _mesh_dims():
    """{axis name: size} of the mesh in effect, or None.

    Version-portable: newer JAX exposes ``jax.sharding.get_abstract_mesh``;
    on 0.4.x the ``with mesh:`` context manager sets the thread-local
    physical mesh reachable through ``jax.interpreters.pxla`` (public
    re-export, no ``jax._src`` reach-in)."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        mesh = get_abstract()
        if mesh is None or not mesh.axis_names:
            return None
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    from jax.interpreters import pxla

    env = getattr(getattr(pxla, "thread_resources", None), "env", None)
    mesh = getattr(env, "physical_mesh", None)
    if mesh is None or mesh.empty or not mesh.axis_names:
        return None
    return dict(mesh.shape)


def constrain(x, *logical):
    """Megatron-style activation sharding constraint.

    ``logical`` entries: "dp" (batch over pod+data axes), "tp" (the model
    axis), None.  No-op outside a mesh context or when a dim is not
    divisible — so the same model code runs in smoke tests (1 device) and on
    the production mesh.  Added in §Perf iteration 1: without these, XLA's
    propagation all-gathers full fp32 FFN hiddens every layer
    (EXPERIMENTS.md §Perf).
    """
    import os

    if os.environ.get("REPRO_NO_CONSTRAIN"):  # baseline-measurement switch
        return x
    dims = _mesh_dims()
    if dims is None:
        return x
    spec = []
    for d, s in zip(x.shape, logical):
        if s == "dp":
            axes = tuple(a for a in ("pod", "data") if a in dims)
            size = 1
            for a in axes:
                size *= dims[a]
            spec.append(axes if axes and d % size == 0 and d >= size else None)
        elif s == "tp":
            ok = "model" in dims and d % dims["model"] == 0 and d >= dims["model"]
            spec.append("model" if ok else None)
        else:
            spec.append(None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * gamma.astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype) * gamma.astype(x.dtype)) + beta.astype(x.dtype)


# ---------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e6):
    """x (..., S, H, D); positions (..., S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                     # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]               # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 1e6, sections=(1, 1, 2)):
    """M-RoPE (Qwen2-VL): the head_dim/2 frequency bands are split into
    temporal/height/width sections, each rotated by its own position id.

    x (..., S, H, D); positions3 (3, ..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                     # (D/2,)
    n = inv.shape[0]
    w = jnp.array(sections, jnp.float32)
    bounds = jnp.cumsum(w) / jnp.sum(w) * n
    idx = jnp.arange(n)
    sec = (idx[None, :] < bounds[:, None]).astype(jnp.float32)
    sec = sec.at[1:].set(sec[1:] - sec[:-1])       # one-hot per section (3, D/2)
    pos = positions3[..., None].astype(jnp.float32)        # (3, ..., S, 1)
    ang = jnp.einsum("k...sf,kf->...sf", pos * inv, sec)   # mix per section
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLPs
def mlp(x, p, act: str):
    if act == "silu_gated":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(x @ p["w1"])
    h = constrain(h, "dp", None, "tp")      # keep hidden model-sharded
    return constrain(h @ p["w2"], "dp", None, None)


def moe_mlp(x, p, act: str, top_k: int = 2):
    """Dense-dispatch top-k MoE: every expert sees every token, weighted by
    the (zeroed for non-selected) router probabilities.

    On a 16-way model axis with 8 experts, expert-parallel sharding would
    idle half the axis; instead experts stay local and each expert's d_ff is
    TP-sharded ("horizontal fusion" of experts sharing the same input — the
    paper's §4.1.3 template in transformer clothing; DESIGN.md §5)."""
    b, s, d = x.shape
    e = p["w1"].shape[0]
    logits = x @ p["router"]                                # (B,S,E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idxs = jax.lax.top_k(probs, top_k)                # (B,S,k)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)
    gate = jnp.zeros_like(probs).astype(x.dtype)
    gate = jax.vmap(lambda g, i, v: g.at[i].set(v), in_axes=(0, 0, 0))(
        gate.reshape(b * s, e), idxs.reshape(b * s, top_k),
        vals.astype(x.dtype).reshape(b * s, top_k)).reshape(b, s, e)
    h1 = jnp.einsum("bsd,edf->bsef", x, p["w1"])
    if act == "silu_gated":
        h = jax.nn.silu(h1) * jnp.einsum("bsd,edf->bsef", x, p["w3"])
    else:
        h = jax.nn.gelu(h1)
    h = constrain(h, "dp", None, None, "tp")
    y = jnp.einsum("bsef,efd->bsed", h, p["w2"])
    out = constrain(jnp.einsum("bsed,bse->bsd", y, gate), "dp", None, None)
    aux = _load_balance_loss(probs, idxs, e)
    return out, aux


def _load_balance_loss(probs, idxs, n_experts: int):
    """Switch-style auxiliary load-balancing loss."""
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean(jax.nn.one_hot(idxs[..., 0], n_experts), axis=(0, 1))
    return n_experts * jnp.sum(me * ce)
