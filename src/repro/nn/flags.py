"""Runtime flags for the measurement harness.

``MEASURE`` is set by the dry-run's roofline-measurement compiles only: it
makes inner chunk scans unroll (so XLA cost_analysis counts every chunk —
while bodies are otherwise counted once) and caps the chunk count.  Never on
for real runs.
"""
MEASURE = False
MEASURE_MAX_CHUNKS = 8


def unroll_for(length: int) -> int:
    """Layer-scan unroll factor: XLA cost_analysis counts a while body once,
    so measurement compiles unroll their (1-2 unit deep) stacks."""
    return max(int(length), 1) if MEASURE else 1


def chunk_for(seq: int, default: int = 128) -> tuple[int, bool]:
    """(chunk_len, unroll) for a sequence under current flags."""
    if not MEASURE:
        return (default if seq % default == 0 else seq), False
    chunk = max(default, -(-seq // MEASURE_MAX_CHUNKS))
    while seq % chunk != 0:  # grow to a divisor
        chunk += default
        if chunk >= seq:
            return seq, True
    return chunk, True
