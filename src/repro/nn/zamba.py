"""Zamba2-style hybrid: a Mamba2 backbone with ONE shared-weight
attention+MLP block applied every ``cfg.shared_attn_every`` layers.

Mamba2 (SSD form) reuses the chunked linear recurrence: k ~ B-projection
(ssm_state dim), v ~ x heads (head_dim), q ~ C-projection, per-head scalar
decay from the dt/A gate.  The shared block has distinct per-application
LayerNorms and rank-r LoRA adapters on its projections (Zamba2's design);
its input is [hidden, original embedding] concatenated, as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import attention as attn
from repro.nn import layers as nnl
from repro.nn import recurrent as rec


def _dims(cfg: ArchConfig):
    inner = 2 * cfg.d_model
    h = cfg.n_heads
    return inner, h, inner // h, cfg.ssm_state


def _napp(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every if cfg.shared_attn_every else 0


def init_params(cfg: ArchConfig, rng: jax.Array):
    dt = jnp.dtype(cfg.dtype)
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    inner, h, hd, N = _dims(cfg)
    napp = _napp(cfg)
    r = cfg.shared_attn_lora_rank
    ks = jax.random.split(rng, 20)

    def norm(key, *shape):
        return jax.random.normal(key, shape, dt) * 0.02

    mamba = {
        "ln": jnp.ones((L, d), jnp.float32),
        "w_in": norm(ks[0], L, d, 2 * inner),           # x path + gate path
        "w_bcdt": norm(ks[1], L, inner, 2 * N + h),     # B, C, dt per head
        "a_log": jnp.zeros((L, h), jnp.float32),        # per-head decay bias
        "w_out": norm(ks[2], L, inner, d),
    }
    hq, hkv = cfg.n_heads * cfg.head_dim, cfg.n_kv_heads * cfg.head_dim
    shared = {
        "ln1": jnp.ones((2 * d,), jnp.float32),
        "wq": norm(ks[3], 2 * d, hq), "wk": norm(ks[4], 2 * d, hkv),
        "wv": norm(ks[5], 2 * d, hkv), "wo": norm(ks[6], hq, d),
        "ln2": jnp.ones((d,), jnp.float32),
        "w1": norm(ks[7], d, cfg.d_ff), "w3": norm(ks[8], d, cfg.d_ff),
        "w2": norm(ks[9], cfg.d_ff, d),
    }
    lora = {  # per-application rank-r adapters on q and w1
        "qa": norm(ks[10], napp, 2 * d, r), "qb": norm(ks[11], napp, r, hq),
        "m1a": norm(ks[12], napp, d, r), "m1b": norm(ks[13], napp, r, cfg.d_ff),
        "ln1": jnp.ones((napp, 2 * d), jnp.float32),
        "ln2": jnp.ones((napp, d), jnp.float32),
    }
    return {
        "embed": norm(ks[14], V, d),
        "mamba": mamba,
        "shared": shared,
        "lora": lora,
        "ln_f": jnp.ones((d,), jnp.float32),
    }


def _mamba_qkvg(cfg, hin, lp):
    inner, h, hd, N = _dims(cfg)
    b, s, _ = hin.shape
    up = hin @ lp["w_in"]
    xpath, gate = jnp.split(up, 2, axis=-1)
    bcdt = xpath @ lp["w_bcdt"]
    Bm, Cm, dt_ = jnp.split(bcdt, [N, 2 * N], axis=-1)
    # per-head decay: a = -softplus(dt + a_log); k=B (shared across heads),
    # v=x heads, q=C
    log_a = -jax.nn.softplus(dt_.astype(jnp.float32)
                             + lp["a_log"][None, None, :])          # (B,S,H)
    dt_g = jax.nn.softplus(dt_.astype(jnp.float32))                 # input gate
    k = jnp.broadcast_to(Bm[:, :, None, :], (b, s, h, N))
    q = jnp.broadcast_to(Cm[:, :, None, :], (b, s, h, N))
    v = xpath.reshape(b, s, h, hd) * dt_g[..., None].astype(xpath.dtype)
    return q, k, v, log_a, gate


def _mamba_block(cfg, x, lp, chunk, unroll=False):
    inner, h, hd, N = _dims(cfg)
    hin = nnl.rms_norm(x, lp["ln"])
    q, k, v, log_a, gate = _mamba_qkvg(cfg, hin, lp)
    y, _ = rec.chunked_linear_scan(q, k, v, log_a, chunk=chunk, unroll=unroll)
    b, s = x.shape[:2]
    y = y.reshape(b, s, inner) * jax.nn.silu(gate)
    return x + y @ lp["w_out"]


def _shared_block(cfg, x, x0, sp, la):
    """Shared attention+MLP; input = concat(hidden, embedding residual)."""
    b, s, d = x.shape
    cat = jnp.concatenate([x, x0], axis=-1)
    h = nnl.rms_norm(cat, la["ln1"] * sp["ln1"])
    wq = sp["wq"] + la["qa"] @ la["qb"]
    q = h @ wq
    k, v = h @ sp["wk"], h @ sp["wv"]
    hd = cfg.head_dim
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q, k = nnl.apply_rope(q, pos, cfg.rope_theta), nnl.apply_rope(k, pos, cfg.rope_theta)
    o = attn.sdpa(q, k, v, causal=True)
    x = x + o.reshape(b, s, -1) @ sp["wo"]
    h2 = nnl.rms_norm(x, la["ln2"] * sp["ln2"])
    w1 = sp["w1"] + la["m1a"] @ la["m1b"]
    y = jax.nn.silu(h2 @ w1) * (h2 @ sp["w3"])
    return x + y @ sp["w2"]


def forward(cfg: ArchConfig, params, tokens, patch_embeds=None):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x0 = x
    b, s, d = x.shape
    from repro.nn import flags
    chunk, unroll = flags.chunk_for(s)
    k = cfg.shared_attn_every
    napp = _napp(cfg)
    mp = params["mamba"]

    def mbody(x, lp):
        return _mamba_block(cfg, x, lp, chunk, unroll), None

    body = jax.remat(mbody) if cfg.remat else mbody
    off = 0
    for gi in range(napp):
        sl = jax.tree.map(lambda a: a[off:off + k], mp)
        x, _ = jax.lax.scan(body, x, sl, unroll=flags.unroll_for(k))
        off += k
        la = jax.tree.map(lambda a: a[gi], params["lora"])
        x = _shared_block(cfg, x, x0, params["shared"], la)
    if cfg.n_layers - off > 0:
        sl = jax.tree.map(lambda a: a[off:], mp)
        x, _ = jax.lax.scan(body, x, sl,
                            unroll=flags.unroll_for(cfg.n_layers - off))
    x = nnl.rms_norm(x, params["ln_f"])
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits, 0.0


def loss_fn(cfg: ArchConfig, params, batch):
    logits, _ = forward(cfg, params, batch["tokens"])
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32),
                             labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


# --------------------------------------------------------------------- decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    inner, h, hd, N = _dims(cfg)
    napp = _napp(cfg)
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, h, N, hd), jnp.float32),
        "k": jnp.zeros((max(napp, 1), batch, max_len, cfg.n_kv_heads,
                        cfg.head_dim), jnp.dtype(cfg.dtype)),
        "v": jnp.zeros((max(napp, 1), batch, max_len, cfg.n_kv_heads,
                        cfg.head_dim), jnp.dtype(cfg.dtype)),
    }


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens][:, None, :].astype(dt)
    x0 = x
    inner, h, hd, N = _dims(cfg)
    k_every = cfg.shared_attn_every
    napp = _napp(cfg)
    mp = params["mamba"]
    b = x.shape[0]

    def mstep_scan(x, sl, states):
        from repro.nn import flags

        def body(x, xs):
            lp, S = xs
            hin = nnl.rms_norm(x, lp["ln"])
            q, kk, v, log_a, gate = _mamba_qkvg(cfg, hin, lp)
            y, S = rec.linear_step(q[:, 0], kk[:, 0], v[:, 0], log_a[:, 0], S)
            y = y.reshape(b, 1, inner) * jax.nn.silu(gate)
            return x + y @ lp["w_out"], S
        n = jax.tree.leaves(sl)[0].shape[0]
        return jax.lax.scan(body, x, (sl, states),
                            unroll=flags.unroll_for(max(n, 1)))

    new_ssm, new_k, new_v = [], [], []
    off = 0
    for gi in range(napp):
        sl = jax.tree.map(lambda a: a[off:off + k_every], mp)
        x, S = mstep_scan(x, sl, cache["ssm"][off:off + k_every])
        new_ssm.append(S)
        off += k_every
        la = jax.tree.map(lambda a: a[gi], params["lora"])
        sp = params["shared"]
        cat = jnp.concatenate([x, x0], axis=-1)
        hin = nnl.rms_norm(cat, la["ln1"] * sp["ln1"])
        wq = sp["wq"] + la["qa"] @ la["qb"]
        q = (hin @ wq).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        kk = (hin @ sp["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        vv = (hin @ sp["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        p = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b, 1))
        q = nnl.apply_rope(q, p, cfg.rope_theta)
        kk = nnl.apply_rope(kk, p, cfg.rope_theta)
        lc = attn.cache_update({"k": cache["k"][gi], "v": cache["v"][gi]},
                               kk, vv, pos)
        o = attn.decode_attend(q, lc, pos)
        x = x + o.reshape(b, 1, -1) @ sp["wo"]
        h2 = nnl.rms_norm(x, la["ln2"] * sp["ln2"])
        w1 = sp["w1"] + la["m1a"] @ la["m1b"]
        y = jax.nn.silu(h2 @ w1) * (h2 @ sp["w3"])
        x = x + y @ sp["w2"]
        new_k.append(lc["k"])
        new_v.append(lc["v"])
    if cfg.n_layers - off > 0:
        sl = jax.tree.map(lambda a: a[off:], mp)
        x, S = mstep_scan(x, sl, cache["ssm"][off:])
        new_ssm.append(S)
    x = nnl.rms_norm(x, params["ln_f"])
    logits = (x @ params["embed"].T.astype(x.dtype))[:, 0]
    return logits, {
        "ssm": jnp.concatenate(new_ssm) if new_ssm else cache["ssm"],
        "k": jnp.stack(new_k) if new_k else cache["k"],
        "v": jnp.stack(new_v) if new_v else cache["v"],
    }
