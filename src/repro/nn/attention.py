"""GQA attention: full / causal / sliding-window, prefill and single-token
decode with a KV cache, optional Pallas flash kernel for the score+softmax+
value contraction (the DNNVM-planned fused group; DESIGN.md §3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def _split_heads(x, n_heads, d_head):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, d_head)


def qkv(x, p, n_heads, n_kv, d_head):
    q = _split_heads(x @ p["wq"], n_heads, d_head)
    k = _split_heads(x @ p["wk"], n_kv, d_head)
    v = _split_heads(x @ p["wv"], n_kv, d_head)
    return q, k, v


def sdpa(q, k, v, *, causal: bool = True, window: int = 0,
         q_offset: int = 0, impl: str = "xla", kv_len_mask=None):
    """q (B,Sq,H,D), k/v (B,Sk,KV,D) with H % KV == 0.  Returns (B,Sq,H,D).

    ``q_offset``: absolute position of q[0] (decode: Sk-1 or cache length).
    ``kv_len_mask``: optional (B, Sk) validity mask (ragged decode caches).
    """
    if impl == "flash" and causal and window == 0 and kv_len_mask is None:
        from repro.kernels.flash_attention import ops as flash

        return flash.flash_attention(q, k, v, q_offset=q_offset)
    if impl == "xla_chunked" and kv_len_mask is None:
        return sdpa_chunked(q, k, v, causal=causal, window=window,
                            q_offset=q_offset)
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits *= (1.0 / d ** 0.5)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, NEG)
    if kv_len_mask is not None:
        logits = jnp.where(kv_len_mask[:, None, None, None, :], logits, NEG)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, sq, h, d)


def sdpa_chunked(q, k, v, *, causal=True, window=0, q_offset=0,
                 blk: int = 1024):
    """Flash-style attention in plain XLA ops: scan over KV blocks with
    online max/sum renormalization — the S x S score matrix never exists as
    a whole tensor (DNNVM kernel fusion, condition 1, realized without
    Pallas so the multi-pod dry-run can lower it on any backend; the Pallas
    kernel is the TPU-native twin).  §Perf iteration: smollm prefill_32k."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    if sk % blk or (causal and sq != sk) or window:
        return sdpa(q, k, v, causal=causal, window=window, q_offset=q_offset,
                    impl="xla")
    g = h // kv
    n = sk // blk
    qg = (q.reshape(b, sq, kv, g, d) * (1.0 / d ** 0.5)).astype(q.dtype)
    kc = k.reshape(b, n, blk, kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n, blk, kv, d).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(sq) + q_offset

    def body(carry, xs):
        m, l, acc = carry
        ki, kb, vb = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb).astype(jnp.float32)
        if causal:
            kpos = ki * blk + jnp.arange(blk)
            s = jnp.where((kpos[None, :] <= qpos[:, None])[None, None, None],
                          s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = (acc * alpha[..., None]
                   + jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype),
                                vb).astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, sq), NEG, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, d), jnp.float32)
    from repro.nn import flags

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(n), kc, vc),
                                  unroll=flags.unroll_for(n))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def attn_out(o, p):
    b, s, h, d = o.shape
    return o.reshape(b, s, h * d) @ p["wo"]


# ----------------------------------------------------------------- KV cache
def cache_init(batch, max_len, n_kv, d_head, dtype):
    return {
        "k": jnp.zeros((batch, max_len, n_kv, d_head), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, d_head), dtype),
    }


def cache_update(cache, k_new, v_new, pos, window: int = 0):
    """Insert one decode step at absolute position ``pos``.  With SWA the
    cache is a rolling buffer of size ``window`` (slot = pos % window)."""
    slot = (pos % window) if window else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    return {"k": k, "v": v}


def decode_attend(q, cache, pos, *, window: int = 0):
    """Single-token decode: q (B,1,H,D) against the cache.

    Full attention: attends to cache[:pos+1].  SWA: rolling buffer masked to
    the last ``window`` positions (no re-ordering needed: softmax is
    permutation-invariant over keys)."""
    b, _, h, d = q.shape
    k, v = cache["k"], cache["v"]
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits *= (1.0 / d ** 0.5)
    slots = jnp.arange(sk)
    if window:
        valid = slots < jnp.minimum(pos + 1, window)   # rolling occupancy
    else:
        valid = slots <= pos
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, 1, h, d)
