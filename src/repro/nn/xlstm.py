"""xLSTM stack: chunked-parallel mLSTM blocks with an sLSTM block every
``cfg.slstm_every`` layers (the [7:1] flavor).

mLSTM block: x -> norm -> up-projection to 2*d (value path + gate path);
q/k from the value path, per-head matrix memory via the shared chunked
linear recurrence; sigmoid input/forget gating (stabilized exponential
gating omitted — DESIGN.md §5); gated down-projection back to d.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import layers as nnl
from repro.nn import recurrent as rec


def _dims(cfg: ArchConfig):
    inner = 2 * cfg.d_model
    h = cfg.n_heads
    return inner, h, inner // h       # inner, heads, head_dim


def init_params(cfg: ArchConfig, rng: jax.Array):
    dt = jnp.dtype(cfg.dtype)
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    inner, h, hd = _dims(cfg)
    k = cfg.slstm_every
    n_s = L // k if k else 0          # sLSTM count
    n_m = L - n_s
    ks = jax.random.split(rng, 12)

    def norm(key, *shape):
        return jax.random.normal(key, shape, dt) * 0.02

    mlstm = {
        "ln": jnp.ones((n_m, d), jnp.float32),
        "w_up": norm(ks[0], n_m, d, 2 * inner),     # value + gate paths
        # q, k and the i/f gates come from per-head block-diagonal
        # projections (the real mLSTM's blocked q/k — keeps the layer at
        # ~27M params for the 1.3b config instead of a dense inner x inner)
        "w_qkg": norm(ks[1], n_m, h, hd, 2 * hd + 2),
        "w_down": norm(ks[2], n_m, inner, d),
    }
    slstm = {
        "ln": jnp.ones((max(n_s, 1), d), jnp.float32),
        "w_gates": norm(ks[3], max(n_s, 1), d, 4 * d),
        "r_gates": norm(ks[4], max(n_s, 1), d, 4 * d),
        "b_gates": jnp.zeros((max(n_s, 1), 4 * d), dt),
        "w_out": norm(ks[5], max(n_s, 1), d, d),
    }
    return {
        "embed": norm(ks[6], V, d),
        "mlstm": mlstm,
        "slstm": slstm,
        "ln_f": jnp.ones((d,), jnp.float32),
    }


def _mlstm_qkvg(cfg, x, lp):
    inner, h, hd = _dims(cfg)
    b, s, _ = x.shape
    up = x @ lp["w_up"]
    val, gate = jnp.split(up, 2, axis=-1)                    # (B,S,inner) each
    valh = val.reshape(b, s, h, hd)
    qkg = jnp.einsum("bshd,hde->bshe", valh, lp["w_qkg"])    # block-diagonal
    q = qkg[..., :hd] / hd ** 0.5
    k = qkg[..., hd:2 * hd] / hd ** 0.5
    gi = qkg[..., 2 * hd]                                    # (B,S,H)
    gf = qkg[..., 2 * hd + 1]
    v = valh
    log_a = jax.nn.log_sigmoid(gf.astype(jnp.float32))       # decay in (0,1)
    i_gate = jax.nn.sigmoid(gi.astype(jnp.float32))
    return q, k, v, log_a, i_gate, gate


def _mlstm_block(cfg, x, lp, chunk, unroll=False):
    inner, h, hd = _dims(cfg)
    hin = nnl.rms_norm(x, lp["ln"])
    q, k, v, log_a, i_gate, gate = _mlstm_qkvg(cfg, hin, lp)
    k = k * i_gate[..., None].astype(k.dtype)                # input gating
    y, _ = rec.chunked_linear_scan(q, k, v, log_a, chunk=chunk, unroll=unroll)
    b, s, _, _ = y.shape
    y = y.reshape(b, s, inner) * jax.nn.silu(gate)
    return x + y @ lp["w_down"]


def _slstm_block(cfg, x, lp):
    h = nnl.rms_norm(x, lp["ln"])
    y, _ = rec.slstm_scan(h, lp)
    return x + y @ lp["w_out"]


def forward(cfg: ArchConfig, params, tokens, patch_embeds=None):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    b, s, d = x.shape
    from repro.nn import flags
    chunk, unroll = flags.chunk_for(s)
    k = cfg.slstm_every
    n_groups = cfg.n_layers // k if k else 0
    per_group = k - 1 if k else 0
    mp = params["mlstm"]

    def mbody(x, lp):
        return _mlstm_block(cfg, x, lp, chunk, unroll), None

    body = jax.remat(mbody) if cfg.remat else mbody
    off = 0
    for gi in range(n_groups):
        sl = jax.tree.map(lambda a: a[off:off + per_group], mp)
        x, _ = jax.lax.scan(body, x, sl, unroll=flags.unroll_for(per_group))
        off += per_group
        sp = jax.tree.map(lambda a: a[gi], params["slstm"])
        x = _slstm_block(cfg, x, sp)
    rem = jax.tree.map(lambda a: a[off:], mp)
    n_rem = cfg.n_layers - n_groups * k if k else cfg.n_layers
    if n_rem > 0 or n_groups == 0:
        x, _ = jax.lax.scan(body, x, rem, unroll=flags.unroll_for(max(n_rem, 1)))
    x = nnl.rms_norm(x, params["ln_f"])
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits, 0.0


def loss_fn(cfg: ArchConfig, params, batch):
    logits, _ = forward(cfg, params, batch["tokens"])
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32),
                             labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


# --------------------------------------------------------------------- decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Constant-size recurrent state — the sub-quadratic long_500k story."""
    inner, h, hd = _dims(cfg)
    d = cfg.d_model
    k = cfg.slstm_every
    n_s = cfg.n_layers // k if k else 0
    n_m = cfg.n_layers - n_s
    return {
        "m_state": jnp.zeros((n_m, batch, h, hd, hd), jnp.float32),
        "s_h": jnp.zeros((max(n_s, 1), batch, d), jnp.float32),
        "s_c": jnp.zeros((max(n_s, 1), batch, d), jnp.float32),
    }


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens][:, None, :].astype(dt)       # (B,1,D)
    inner, h, hd = _dims(cfg)
    k = cfg.slstm_every
    n_groups = cfg.n_layers // k if k else 0
    per_group = k - 1 if k else 0
    mp = params["mlstm"]

    def mstep(x, lp, S):
        hin = nnl.rms_norm(x, lp["ln"])
        q, kk, v, log_a, i_gate, gate = _mlstm_qkvg(cfg, hin, lp)
        kk = kk * i_gate[..., None].astype(kk.dtype)
        y, S = rec.linear_step(q[:, 0], kk[:, 0], v[:, 0], log_a[:, 0], S)
        b = x.shape[0]
        y = y.reshape(b, 1, inner) * jax.nn.silu(gate)
        return x + y @ lp["w_down"], S

    def scan_m(x, sl, states):
        from repro.nn import flags

        def body(x, xs):
            lp, S = xs
            x, S = mstep(x, lp, S)
            return x, S
        n = jax.tree.leaves(sl)[0].shape[0]
        return jax.lax.scan(body, x, (sl, states),
                            unroll=flags.unroll_for(max(n, 1)))

    new_m, new_h, new_c = [], [], []
    off = 0
    for gi in range(n_groups):
        sl = jax.tree.map(lambda a: a[off:off + per_group], mp)
        x, S = scan_m(x, sl, cache["m_state"][off:off + per_group])
        new_m.append(S)
        off += per_group
        sp = jax.tree.map(lambda a: a[gi], params["slstm"])
        hin = nnl.rms_norm(x, sp["ln"])
        y, (sh, sc) = rec.slstm_step(hin[:, 0], sp,
                                     (cache["s_h"][gi], cache["s_c"][gi]))
        x = x + (y @ sp["w_out"])[:, None]
        new_h.append(sh)
        new_c.append(sc)
    if cfg.n_layers - n_groups * k > 0 or n_groups == 0:
        sl = jax.tree.map(lambda a: a[off:], mp)
        x, S = scan_m(x, sl, cache["m_state"][off:])
        new_m.append(S)
    x = nnl.rms_norm(x, params["ln_f"])
    logits = (x @ params["embed"].T.astype(x.dtype))[:, 0]
    new_cache = {
        "m_state": jnp.concatenate(new_m) if new_m else cache["m_state"],
        "s_h": jnp.stack(new_h) if new_h else cache["s_h"],
        "s_c": jnp.stack(new_c) if new_c else cache["s_c"],
    }
    return logits, new_cache
