"""Universal causal transformer LM: dense / MoE / SWA / VLM backbone.

Layers are stacked (leading L axis) and executed with ``lax.scan`` — the HLO
stays O(1) in depth, which keeps 512-device dry-run compiles fast and is the
remat-friendly layout for training.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import attention as attn
from repro.nn import layers as nnl


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _remat(cfg: ArchConfig, body):
    """Layer remat policy (§Perf iteration 2): "full" recomputes the whole
    block in backward; "dots" saves matmul outputs and recomputes only the
    cheap elementwise chains — fewer recompute FLOPs for more saved bytes."""
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        return jax.remat(body, policy=jax.checkpoint_policies.dots_saveable)
    return jax.remat(body)


def init_params(cfg: ArchConfig, rng: jax.Array):
    dt = _dtype(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    h, kv, f, L, V = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.n_layers, cfg.vocab
    ks = jax.random.split(rng, 16)

    def norm(k, *shape):
        return jax.random.normal(k, shape, dt) * 0.02

    layers = {
        "ln1": jnp.ones((L, d), jnp.float32),
        "ln2": jnp.ones((L, d), jnp.float32),
        "wq": norm(ks[0], L, d, h * hd),
        "wk": norm(ks[1], L, d, kv * hd),
        "wv": norm(ks[2], L, d, kv * hd),
        "wo": norm(ks[3], L, h * hd, d),
    }
    if cfg.moe:
        e = cfg.moe.n_experts
        layers["router"] = norm(ks[4], L, d, e)
        layers["w1"] = norm(ks[5], L, e, d, f)
        layers["w2"] = norm(ks[6], L, e, f, d)
        if cfg.act == "silu_gated":
            layers["w3"] = norm(ks[7], L, e, d, f)
    else:
        layers["w1"] = norm(ks[5], L, d, f)
        layers["w2"] = norm(ks[6], L, f, d)
        if cfg.act == "silu_gated":
            layers["w3"] = norm(ks[7], L, d, f)
    params = {
        "embed": norm(ks[8], V, d),
        "layers": layers,
        "ln_f": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = norm(ks[9], V, d)
    return params


# ------------------------------------------------------------------ positions
def positions_for(cfg: ArchConfig, batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if not cfg.mrope:
        return pos
    # M-RoPE stub grid: the first n_patches positions are image patches on a
    # (g x g) grid at t=0; text follows temporally.
    npat = min(cfg.n_patches, seq)
    g = max(1, int(npat ** 0.5))
    idx = jnp.arange(seq)
    is_img = idx < npat
    t = jnp.where(is_img, 0, idx - npat + 1)
    hh = jnp.where(is_img, idx // g, idx - npat + 1)
    ww = jnp.where(is_img, idx % g, idx - npat + 1)
    p3 = jnp.stack([t, hh, ww]).astype(jnp.int32)[:, None, :] + offset
    return jnp.broadcast_to(p3, (3, batch, seq))


def _rope(cfg: ArchConfig, x, pos):
    if cfg.mrope:
        return nnl.apply_mrope(x, pos, cfg.rope_theta)
    return nnl.apply_rope(x, pos, cfg.rope_theta)


# -------------------------------------------------------------------- forward
def _layer(cfg: ArchConfig, x, lp, pos, impl):
    h = nnl.rms_norm(x, lp["ln1"])
    q, k, v = attn.qkv(h, lp, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    q = nnl.constrain(_rope(cfg, q, pos), "dp", None, "tp", None)
    k = nnl.constrain(_rope(cfg, k, pos), "dp", None, "tp", None)
    v = nnl.constrain(v, "dp", None, "tp", None)
    o = attn.sdpa(q, k, v, causal=True, window=cfg.window, impl=impl)
    o = nnl.constrain(o, "dp", None, "tp", None)
    x = x + nnl.constrain(attn.attn_out(o, lp), "dp", None, None)
    h = nnl.rms_norm(x, lp["ln2"])
    if cfg.moe:
        y, aux = nnl.moe_mlp(h, lp, cfg.act, cfg.moe.top_k)
    else:
        y, aux = nnl.mlp(h, lp, cfg.act), 0.0
    return x + y, aux


def forward(cfg: ArchConfig, params, tokens, patch_embeds=None):
    """tokens (B, S_text); patch_embeds (B, n_patches, D) for VLM.

    Returns (logits (B,S,V), aux_loss)."""
    x = params["embed"][tokens].astype(_dtype(cfg))
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    pos = positions_for(cfg, b, s)

    def body(carry, lp):
        x, aux = carry
        x, a = _layer(cfg, x, lp, pos, cfg.attn_impl)
        return (x, aux + a), None

    from repro.nn import flags
    body_fn = _remat(cfg, body)
    (x, aux), _ = jax.lax.scan(body_fn, (x, 0.0), params["layers"],
                               unroll=flags.unroll_for(cfg.n_layers))
    x = nnl.rms_norm(x, params["ln_f"])
    w_out = params.get("unembed", params["embed"])
    logits = nnl.constrain(x @ w_out.T.astype(x.dtype), "dp", None, "tp")
    return logits, aux


def loss_fn(cfg: ArchConfig, params, batch):
    logits, aux = forward(cfg, params, batch["tokens"],
                          batch.get("patch_embeds"))
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:          # VLM: loss on text only
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32),
                             labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll) + 0.01 * aux


# --------------------------------------------------------------------- decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    size = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((cfg.n_layers, batch, size, cfg.n_kv_heads,
                        cfg.head_dim), _dtype(cfg)),
        "v": jnp.zeros((cfg.n_layers, batch, size, cfg.n_kv_heads,
                        cfg.head_dim), _dtype(cfg)),
    }


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    """One token: tokens (B,), pos scalar int32 (absolute position).

    Returns (logits (B,V), new cache)."""
    x = params["embed"][tokens][:, None, :].astype(_dtype(cfg))
    b = x.shape[0]
    if cfg.mrope:
        p = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (3, b, 1))
    else:
        p = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b, 1))

    def body(x, xs):
        lp, ck, cv = xs
        h = nnl.rms_norm(x, lp["ln1"])
        q, k, v = attn.qkv(h, lp, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(cfg, q, p)
        k = _rope(cfg, k, p)
        layer_cache = attn.cache_update({"k": ck, "v": cv}, k, v, pos,
                                        window=cfg.window)
        o = attn.decode_attend(q, layer_cache, pos, window=cfg.window)
        x = x + attn.attn_out(o, lp)
        h = nnl.rms_norm(x, lp["ln2"])
        if cfg.moe:
            y, _ = nnl.moe_mlp(h, lp, cfg.act, cfg.moe.top_k)
        else:
            y = nnl.mlp(h, lp, cfg.act)
        return x + y, (layer_cache["k"], layer_cache["v"])

    from repro.nn import flags
    x, (nk, nv) = jax.lax.scan(body, x,
                               (params["layers"], cache["k"], cache["v"]),
                               unroll=flags.unroll_for(cfg.n_layers))
    x = nnl.rms_norm(x, params["ln_f"])
    w_out = params.get("unembed", params["embed"])
    logits = (x @ w_out.T.astype(x.dtype))[:, 0]
    return logits, {"k": nk, "v": nv}
