"""Profile-guided cost evaluation: features + the CalibratedEvaluator.

Two feature domains turn a candidate group into the work-unit vector a
:class:`~repro.tune.profile.DeviceProfile` prices (order =
``profile.COEF_NAMES``):

* ``"analytic"`` — the analytic pipeline model's own stage quantities from
  the tiling solution (DRAM bytes, padded MACs, pool/misc elements, spatial
  tiles).  This is the domain calibration uses when the ground truth *is* the
  modeled accelerator (e.g. fitting against the cycle simulator).
* ``"kernel"``  — the work the lowered Pallas launch actually performs,
  derived from ``core.lower`` descriptors + ``chain_geometry``: per-grid-cell
  block bytes, conv MACs *including the recompute of upstream full-channel
  stages once per final-OC tile*, and the grid-cell count (interpret-mode
  dispatch overhead is per cell).  This is the domain for wall-clock
  calibration of the XLA/Pallas backend, where the abstract tiling's traffic
  numbers do not describe what runs.

:class:`CalibratedEvaluator` prices groups with a fitted profile and is a
drop-in for ``AnalyticEvaluator`` inside ``pathsearch.search(evaluator=...)``:
same call protocol (``__call__`` + ``horizontal_cost``), same INFEASIBLE
semantics (fusion condition 1 still comes from the tiling solver — a profile
never makes an unplaceable group placeable).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import lower, tiling
from repro.core.cost import INFEASIBLE, AnalyticEvaluator
from repro.core.xgraph import XGraph
from repro.hw import DeviceModel
from repro.tune.profile import COEF_NAMES, DeviceProfile

(_RD, _WR, _CONV, _POOL, _MISC,
 _CONV_STEPS, _POOL_STEPS, _MISC_STEPS, _CELLS, _LAUNCH) = range(len(COEF_NAMES))
_STAGE_IDX = (_RD, _WR, _CONV, _POOL, _MISC)
_OVERHEAD_IDX = (_CONV_STEPS, _POOL_STEPS, _MISC_STEPS, _CELLS, _LAUNCH)


# ------------------------------------------------------------------ features
def _analytic_vec(t: tiling.GroupTiling, dev: DeviceModel):
    f = np.zeros(len(COEF_NAMES))
    f[_RD] = t.load_bytes + t.weight_bytes
    f[_WR] = t.save_bytes
    f[_CONV] = t.conv_cycles * dev.macs_per_cycle_eff
    f[_POOL] = t.pool_cycles * dev.pool_elems_per_cycle
    f[_MISC] = t.misc_cycles * dev.misc_elems_per_cycle
    f[_CELLS] = t.n_spatial_tiles * max(1, t.n_oc_passes)
    f[_LAUNCH] = 1.0
    return f, max(1, t.n_spatial_tiles)


def _chain_vec(g: XGraph, launch: lower.FusedLaunch):
    """Work one chain launch performs, from the same static geometry the
    kernel itself uses (``chain_geometry``), honoring the launch's searched
    tile shape when one is set (``ops._resolve_tile`` is the single source of
    truth for what the kernel will actually run)."""
    from repro.kernels.conv_fused.conv_fused import chain_geometry
    from repro.kernels.conv_fused.ops import _resolve_tile

    stages = launch.stages
    names = [st[1] for st in stages]
    oh, ow = launch.out_hw
    conv_pos = [i for i, st in enumerate(stages) if st[0] == "conv"]
    last_conv = conv_pos[-1] if conv_pos else -1
    oc = (g.shape(names[last_conv])[3] if conv_pos
          else g.shape(launch.in_name)[3])
    th, tw, toc = _resolve_tile(tuple(launch.tile), oh, ow, oc,
                                bool(conv_pos))
    geom = chain_geometry(stages, th, oh, ow, tw)
    n = max(1, g.shape(names[-1])[0])

    in_shape = g.shape(launch.in_name)
    ic_in = (in_shape[1] * in_shape[2] * in_shape[3] if launch.fc_reshape
             else in_shape[3])

    row_cells = n * geom["n_h"] * geom["n_w"]
    oc_cells = max(1, oc // toc)

    def out_depth(i: int) -> int:
        full = g.shape(names[i])[3]
        return min(full, toc) if (last_conv >= 0 and i >= last_conv) else full

    def mult(i: int) -> int:
        """How many grid cells actually execute stage ``i``.  Stages strictly
        upstream of the final conv are invariant along the OC-tile grid axis
        (same x block, full weight panel), and XLA hoists loop-invariant work
        out of the interpret-mode grid loop — measured chains confirm the
        upstream stage is NOT re-executed per OC tile."""
        return row_cells * (oc_cells if i >= last_conv else 1)

    f = np.zeros(len(COEF_NAMES))
    # rd = ACTIVATION staging only: the padded image (and eltwise sides) is
    # sliced/masked per executing grid step.  Weight panels are deliberately
    # NOT here — they are grid-invariant, converted once per launch, and
    # priced inside conv_steps; folding them into rd couples the per-cell
    # staging rate to multi-MB panels and wrecks the fit for cheap launches.
    rd = geom["in_rows"] * geom["in_cols"] * ic_in * row_cells
    wr = th * tw * out_depth(len(stages) - 1) * row_cells * oc_cells
    conv = pool = misc = 0.0
    conv_steps = pool_steps = misc_steps = 0.0
    prev_depth = ic_in
    si = 0
    for i, st in enumerate(stages):
        out_r, out_c = geom["rows"][i], geom["cols"][i]
        depth = out_depth(i)
        if st[0] == "conv":
            kh, kw = st[2], st[3]
            m_pos = out_r * out_c
            full_oc = g.shape(names[i])[3]
            conv += m_pos * prev_depth * kh * kw * depth * mult(i)
            # per-tap patch-matmul operand traffic: the x-dependent operands
            # (M*K in, M*N out) stream per executing cell, while the weight
            # panel (K*N_full) is grid-invariant and converts once per launch
            conv_steps += (kh * kw * (m_pos * prev_depth + m_pos * depth)
                           * mult(i) + kh * kw * prev_depth * full_oc)
        elif st[0] == "pool":
            kph, kpw = st[3], st[4]
            pool += out_r * out_c * kph * kpw * depth * mult(i)
            pool_steps += (1 if st[2] == "gap" else kph * kpw) * mult(i)
        else:                                          # eltwise
            sg = geom["sides"][si]
            rd += sg["h_req"] * sg["w_req"] * depth * mult(i)
            misc += out_r * out_c * depth * mult(i)
            misc_steps += mult(i)
            si += 1
        prev_depth = depth
    f[_RD] = rd
    f[_WR] = wr
    f[_CONV] = conv
    f[_POOL] = pool
    f[_MISC] = misc
    f[_CONV_STEPS] = conv_steps
    f[_POOL_STEPS] = pool_steps
    f[_MISC_STEPS] = misc_steps
    f[_CELLS] = row_cells * oc_cells
    f[_LAUNCH] = 1.0
    return f


def _horizontal_vec(g: XGraph, launch: lower.FusedLaunch):
    from repro.kernels.conv_fused.ops import _resolve_tile

    oh, ow = launch.out_hw
    kh, kw = launch.kernel
    sh, sw = launch.stride
    oc = sum(oc_m for _, oc_m, _, _ in launch.members)
    ic = g.shape(launch.in_name)[3]
    n = max(1, g.shape(launch.members[0][0])[0])
    th, tw, toc = _resolve_tile(tuple(launch.tile), oh, ow, oc, True)
    n_h = -(-oh // th)
    n_w = -(-ow // tw)
    cells = n * n_h * n_w * max(1, oc // toc)
    hp = (th - 1) * sh + kh          # per-cell staged input extents
    wp = (tw - 1) * sw + kw
    f = np.zeros(len(COEF_NAMES))
    f[_RD] = hp * wp * ic * cells          # activation staging (see _chain_vec)
    f[_WR] = th * tw * toc * cells
    f[_CONV] = th * tw * ic * kh * kw * toc * cells
    f[_CONV_STEPS] = (kh * kw * (th * tw * ic + th * tw * toc) * cells
                      + kh * kw * ic * oc)
    f[_CELLS] = cells
    f[_LAUNCH] = 1.0
    return f


def group_features(g: XGraph, dev: DeviceModel, group: list, *,
                   domain: str = "kernel",
                   analytic: AnalyticEvaluator | None = None):
    """Feature vector + fill divisor for one chain group, or ``None`` when the
    group is infeasible on ``dev`` (tiling condition 1)."""
    analytic = analytic or AnalyticEvaluator(g, dev)
    gc = analytic.cost(group)
    if not gc.feasible:
        return None
    t = gc.tiling
    fa, n_fill = _analytic_vec(t, dev)
    if domain == "analytic":
        return fa, n_fill
    item = lower.lower_group(g, None, list(group))
    if isinstance(item, lower.FusedLaunch):
        return _chain_vec(g, item), n_fill
    # ref fallback executes the per-node jnp path: analytic work quantities,
    # one launch, per-node op dispatch
    fa[_CELLS] = len(group)
    fa[_MISC_STEPS] = len(group)
    return fa, n_fill


def horizontal_features(g: XGraph, dev: DeviceModel, heads: list, *,
                        domain: str = "kernel"):
    t = tiling.solve_horizontal(g, heads, dev)
    if not t.feasible:
        return None
    fa, n_fill = _analytic_vec(t, dev)
    if domain == "analytic":
        return [(fa, n_fill)]
    out = []
    for item in lower.lower_horizontal(g, None, list(heads)):
        if isinstance(item, lower.FusedLaunch) and item.kind == "horizontal":
            out.append((_horizontal_vec(g, item), n_fill))
        elif isinstance(item, lower.FusedLaunch):
            out.append((_chain_vec(g, item), n_fill))
        else:
            part = group_features(g, dev, list(item.nodes), domain=domain)
            if part is None:
                return None
            out.append(part)
    return out


# ----------------------------------------------------------------- evaluator
def predict_seconds(profile: DeviceProfile, f, n_fill: int) -> float:
    """Price one feature vector under a fitted profile.  Dispatch overheads
    (steps / cells / launch) are additive in both forms — they are serial
    issue cost, never hidden by the engine pipeline."""
    c = np.asarray(profile.coef)
    f = np.asarray(f)
    stage = c[list(_STAGE_IDX)] * f[list(_STAGE_IDX)]
    fixed = float((c[list(_OVERHEAD_IDX)] * f[list(_OVERHEAD_IDX)]).sum())
    if profile.combine == "sum":
        return float(stage.sum() + fixed)
    steady = float(stage.max())
    return float(steady + (stage.sum() - steady) / max(1, n_fill) + fixed)


def predict_item_seconds(profile: DeviceProfile, g: XGraph, dev: DeviceModel,
                         item) -> float | None:
    """Predicted seconds for one lowered ``GroupProgram`` item under a fitted
    profile, or ``None`` when the item has no finite prediction (host-op
    fallbacks, infeasible tilings, layout-pruned concats).

    Unlike :meth:`CalibratedEvaluator.__call__`, which prices a *candidate
    group* by re-lowering it with default tiles, this prices the item the
    artifact actually carries — honoring its searched ``tile`` — so the drift
    profiler compares measurement against the same prediction the plan was
    built on."""
    if isinstance(item, lower.RefFallback):
        if all(g.nodes[nm].op == "concat" and g.nodes[nm].attrs.get("folded")
               for nm in item.nodes):
            return None                      # pruned at emit; nothing runs
        got = group_features(g, dev, list(item.nodes),
                             domain=profile.features)
        return None if got is None else predict_seconds(profile, *got)
    if item.kind == "horizontal":
        heads = [m[0] for m in item.members]
        t = tiling.solve_horizontal(g, heads, dev)
        if not t.feasible:
            return None
        fa, n_fill = _analytic_vec(t, dev)
        f = _horizontal_vec(g, item) if profile.features == "kernel" else fa
        return predict_seconds(profile, f, n_fill)
    gc = AnalyticEvaluator(g, dev).cost(list(item.nodes))
    if not gc.feasible:
        return None
    fa, n_fill = _analytic_vec(gc.tiling, dev)
    f = _chain_vec(g, item) if profile.features == "kernel" else fa
    return predict_seconds(profile, f, n_fill)


class CalibratedEvaluator:
    """Group cost = profile-priced measured-world work (drop-in for
    ``AnalyticEvaluator`` inside ``pathsearch.search``)."""

    def __init__(self, g: XGraph, dev: DeviceModel, profile: DeviceProfile):
        self.g, self.dev, self.profile = g, dev, profile
        self._analytic = AnalyticEvaluator(g, dev)
        self._cache: dict[tuple, float] = {}

    def __call__(self, group: list) -> float:
        key = ("c", tuple(group))
        if key in self._cache:
            return self._cache[key]
        if all(self.g.nodes[nm].op == "concat" and
               self.g.nodes[nm].attrs.get("folded") for nm in group):
            cost = 0.0                      # layout-pruned, like the analytic
        else:
            got = group_features(self.g, self.dev, group,
                                 domain=self.profile.features,
                                 analytic=self._analytic)
            cost = (INFEASIBLE if got is None
                    else predict_seconds(self.profile, *got))
        self._cache[key] = cost
        return cost

    def horizontal_cost(self, heads: list) -> float:
        key = ("h", tuple(heads))
        if key in self._cache:
            return self._cache[key]
        got = horizontal_features(self.g, self.dev, heads,
                                  domain=self.profile.features)
        cost = (INFEASIBLE if got is None else
                sum(predict_seconds(self.profile, f, n) for f, n in got))
        self._cache[key] = cost
        return cost

    def strategy_cost(self, strategy) -> float:
        """Predicted end-to-end seconds of a whole strategy (sum of groups)."""
        total = sum(self(list(grp)) for grp in strategy.groups)
        total += sum(self.horizontal_cost(list(h)) for h in strategy.horizontal)
        return total if math.isfinite(total) else INFEASIBLE

    # ------------------------------------------------------------ tile shapes
    def tile_for(self, group: list) -> tuple | None:
        """Profile-predicted best kernel tile shape for ``group``, or ``None``
        when the kernel-default heuristics win.  ``pathsearch.search`` calls
        this on every searched group, so strategies picked under a calibrated
        profile carry predicted shapes even before anything is measured.
        Only meaningful in the "kernel" feature domain — an "analytic"
        profile prices the abstract tiling, not what the launch executes."""
        if self.profile.features != "kernel":
            return None
        key = ("tile", tuple(group))
        if key in self._cache:
            return self._cache[key]
        from repro.tune import tiles
        item = lower.lower_group(self.g, None, list(group))
        shape = None
        if isinstance(item, lower.FusedLaunch):
            shape = tiles.predict_best_shape(self.profile, self.g, self.dev,
                                             item)
        self._cache[key] = shape
        return shape

    def tile_for_horizontal(self, heads: list) -> dict:
        """Predicted shapes for a horizontal group's lowered launches, keyed
        by ``lower.tile_key`` of each launch's node cover ({} = defaults)."""
        if self.profile.features != "kernel":
            return {}
        key = ("tile-h", tuple(heads))
        if key in self._cache:
            return self._cache[key]
        from repro.tune import tiles
        out = {}
        for item in lower.lower_horizontal(self.g, None, list(heads)):
            if isinstance(item, lower.FusedLaunch):
                shape = tiles.predict_best_shape(self.profile, self.g,
                                                 self.dev, item)
                if shape:
                    out[lower.tile_key(item.nodes)] = shape
        self._cache[key] = out
        return out
