"""Calibrated device profiles: fitted effective coefficients + on-disk cache.

A :class:`DeviceProfile` is what calibration produces and what the
:class:`~repro.tune.evaluator.CalibratedEvaluator` consumes: a small vector of
*measured-world* rates — how fast this (device, backend, jax version) actually
retires DRAM bytes, conv MACs and pool/misc elements, plus the fixed per-grid-
cell and per-launch overheads that dominate short launches.  The coefficients
are seconds-per-work-unit (see :data:`COEF_NAMES`); their reciprocals are the
effective rates expressed in the ``DeviceModel`` vocabulary (bandwidth,
MACs/cycle, lanes), so a profile can also be projected back onto a
``DeviceModel`` for every consumer of the analytic pipeline model.

Profiles serialize to versioned JSON (:func:`save_profile` /
:func:`load_profile`) and live in an on-disk :class:`ProfileCache` keyed by
(device model, backend, jax version) — calibrate once per toolchain, reuse
across sessions.  ``DeviceProfile.hash()`` is the stable fingerprint the
compiler records into every ``CompiledArtifact`` planned under the profile.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re

from repro.hw import DeviceModel

PROFILE_SCHEMA_VERSION = 1

# Work-unit vocabulary of the cost model.  A feature vector is aligned with
# this tuple; a profile's ``coef`` holds seconds per unit of each:
#   rd         — DRAM/host bytes read (ifmaps + weights + side inputs)
#   wr         — bytes written (ofmaps)
#   conv       — padded conv MACs
#   pool       — pooling window elements
#   misc       — eltwise/misc elements
#   conv_steps — conv patch-matmul operand traffic (sum of M*K + K*N + M*N
#                over the kh*kw taps of every grid cell): XLA pays per-op
#                operand conversion/streaming on top of the MACs, which
#                dominates small-M / big-K taps
#   pool_steps — pool window-op dispatches
#   misc_steps — eltwise/requant op dispatches
#   cells      — grid cells (per-tile block staging overhead)
#   launch     — kernel launches (fixed dispatch cost)
COEF_NAMES = ("rd", "wr", "conv", "pool", "misc",
              "conv_steps", "pool_steps", "misc_steps", "cells", "launch")
FEATURE_DOMAINS = ("analytic", "kernel")
COMBINE_FORMS = ("max", "sum")


def _jax_version() -> str:
    import jax
    return jax.__version__


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Fitted effective coefficients for one (device, backend, jax) triple."""
    name: str
    device: str                     # base DeviceModel name
    backend: str                    # executor backend measured ("pallas"/"ref")
    jax_version: str
    features: str                   # feature domain: "analytic" | "kernel"
    combine: str                    # stage combination fitted: "max" | "sum"
    coef: tuple                     # seconds per unit, aligned with COEF_NAMES
    deviation: float                # median |pred-meas|/meas of the fit
    n_samples: int
    schema: int = PROFILE_SCHEMA_VERSION
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.features not in FEATURE_DOMAINS:
            raise ValueError(f"unknown feature domain {self.features!r}")
        if self.combine not in COMBINE_FORMS:
            raise ValueError(f"unknown combine form {self.combine!r}")
        if len(self.coef) != len(COEF_NAMES):
            raise ValueError(f"coef must have {len(COEF_NAMES)} entries")
        object.__setattr__(self, "coef", tuple(float(c) for c in self.coef))

    # ------------------------------------------------------------ identity
    def hash(self) -> str:
        """Stable fingerprint of everything that affects predictions."""
        return _sha({"schema": self.schema, "device": self.device,
                     "backend": self.backend, "features": self.features,
                     "combine": self.combine, "coef": list(self.coef)})

    # ------------------------------------- effective rates (DeviceModel talk)
    def _rate(self, name: str) -> float:
        c = self.coef[COEF_NAMES.index(name)]
        return (1.0 / c) if c > 0 else float("inf")

    @property
    def dram_rd_bytes_per_s(self) -> float:
        return self._rate("rd")

    @property
    def dram_wr_bytes_per_s(self) -> float:
        return self._rate("wr")

    @property
    def conv_macs_per_s(self) -> float:
        return self._rate("conv")

    @property
    def pool_elems_per_s(self) -> float:
        return self._rate("pool")

    @property
    def misc_elems_per_s(self) -> float:
        return self._rate("misc")

    @property
    def launch_overhead_s(self) -> float:
        return self.coef[COEF_NAMES.index("launch")]

    @property
    def cell_overhead_s(self) -> float:
        return self.coef[COEF_NAMES.index("cells")]

    def step_overhead_s(self, engine: str) -> float:
        return self.coef[COEF_NAMES.index(f"{engine}_steps")]

    def effective_summary(self, dev: DeviceModel) -> dict:
        """The fitted coefficients in the device-model vocabulary."""
        f = dev.freq_hz
        fin = (lambda v: v if v != float("inf") else None)
        return {
            "dram_rd_bytes_per_s": fin(self.dram_rd_bytes_per_s),
            "dram_wr_bytes_per_s": fin(self.dram_wr_bytes_per_s),
            "conv_macs_per_cycle": fin(self.conv_macs_per_s / f),
            "pool_lanes": fin(self.pool_elems_per_s / f),
            "misc_lanes": fin(self.misc_elems_per_s / f),
            "conv_step_overhead_us": self.step_overhead_s("conv") * 1e6,
            "pool_step_overhead_us": self.step_overhead_s("pool") * 1e6,
            "misc_step_overhead_us": self.step_overhead_s("misc") * 1e6,
            "launch_overhead_us": self.launch_overhead_s * 1e6,
            "cell_overhead_us": self.cell_overhead_s * 1e6,
        }

    def to_device_model(self, base: DeviceModel) -> DeviceModel:
        """Project the fitted rates onto a ``DeviceModel`` (unfitted or
        unidentifiable coefficients keep the base device's values)."""
        kw = {"name": f"{base.name}+{self.name}"}
        if self.coef[0] > 0:
            kw["dram_bw_bytes_per_s"] = self.dram_rd_bytes_per_s
        if self.coef[2] > 0:
            kw["peak_ops_override"] = 2.0 * self.conv_macs_per_s
        if self.coef[3] > 0:
            kw["pool_lanes"] = max(1, int(self.pool_elems_per_s / base.freq_hz))
        if self.coef[4] > 0:
            kw["misc_lanes"] = max(1, int(self.misc_elems_per_s / base.freq_hz))
        return base.replace(**kw)

    # -------------------------------------------------------- serialization
    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["coef"] = list(self.coef)
        d["hash"] = self.hash()
        return d

    @classmethod
    def from_json(cls, payload: dict) -> "DeviceProfile":
        d = dict(payload)
        recorded = d.pop("hash", None)
        if d.get("schema") != PROFILE_SCHEMA_VERSION:
            raise ValueError(f"profile schema {d.get('schema')} != "
                             f"{PROFILE_SCHEMA_VERSION}")
        p = cls(**d)
        if recorded is not None and recorded != p.hash():
            raise ValueError("profile hash mismatch — corrupted profile JSON")
        return p


def _sha(obj) -> str:
    return hashlib.sha256(
        json.dumps(obj, sort_keys=True).encode()).hexdigest()[:16]


def save_profile(profile: DeviceProfile, path: str) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(profile.to_json(), f, indent=2, sort_keys=True)


def load_profile(path: str) -> DeviceProfile:
    with open(path) as f:
        return DeviceProfile.from_json(json.load(f))


# ---------------------------------------------------------------- disk cache
class ProfileCache:
    """On-disk profile store keyed by (device model, backend, jax version).

    Default root is ``$DNNVM_PROFILE_CACHE`` or ``~/.cache/dnnvm/profiles``;
    one JSON file per key.  Calibration writes with :meth:`put`; sessions and
    benchmarks read with :meth:`get` (returns ``None`` on a miss — callers
    decide whether to calibrate or fall back to the analytic model).
    """

    def __init__(self, root: str | None = None):
        self.root = root or os.environ.get("DNNVM_PROFILE_CACHE") or \
            os.path.join(os.path.expanduser("~"), ".cache", "dnnvm", "profiles")

    def key(self, device: str, backend: str,
            jax_version: str | None = None) -> str:
        raw = f"{device}--{backend}--jax{jax_version or _jax_version()}"
        return re.sub(r"[^A-Za-z0-9._-]", "_", raw)

    def path_for(self, key: str) -> str:
        return os.path.join(self.root, key + ".json")

    def get(self, device: str, backend: str,
            jax_version: str | None = None) -> DeviceProfile | None:
        path = self.path_for(self.key(device, backend, jax_version))
        if not os.path.exists(path):
            return None
        return load_profile(path)

    def put(self, profile: DeviceProfile) -> str:
        path = self.path_for(self.key(profile.device, profile.backend,
                                      profile.jax_version))
        save_profile(profile, path)
        return path

    def get_by_name(self, name: str) -> DeviceProfile | None:
        if not os.path.isdir(self.root):
            return None
        for fn in sorted(os.listdir(self.root)):
            if not fn.endswith(".json"):
                continue
            try:
                p = load_profile(os.path.join(self.root, fn))
            except (ValueError, json.JSONDecodeError, OSError):
                continue
            if p.name == name:
                return p
        return None


def resolve_profile(profile, cache: ProfileCache | None = None):
    """None | DeviceProfile | name | path -> DeviceProfile | None.

    Strings resolve as a path to a profile JSON when one exists, otherwise as
    a named profile in the (default) on-disk cache."""
    if profile is None or isinstance(profile, DeviceProfile):
        return profile
    if isinstance(profile, str):
        if os.path.exists(profile):
            return load_profile(profile)
        got = (cache or ProfileCache()).get_by_name(profile)
        if got is None:
            raise KeyError(f"no profile named {profile!r} in the cache "
                           f"(root {(cache or ProfileCache()).root!r})")
        return got
    raise TypeError(f"cannot resolve profile from {type(profile).__name__}")
