"""Profile-guided autotuning: close the compiler <-> measurement loop.

The paper (§5.1, Table 2) grounds fused-op costs in on-board measurement and
uses the learned model / simulator as cheaper proxies.  This package does the
same for the actual XLA/Pallas backend the repo runs on:

* :mod:`repro.tune.measure`   — wall-clock harness over lowered
  ``GroupProgram`` entries (warmup / repeat / median-of-k, outlier rejection);
* :mod:`repro.tune.profile`   — :class:`DeviceProfile`: fitted effective
  coefficients (DRAM bandwidth, conv MACs/cycle, pool/misc lanes, per-launch
  overhead) with versioned JSON serialization and an on-disk cache keyed by
  (device model, backend, jax version);
* :mod:`repro.tune.calibrate` — least-squares fit of the analytic pipeline
  model's coefficients against harness measurements (and a measurement-refit
  ``ModelEvaluator``), reporting the paper's 5-10% deviation band;
* :mod:`repro.tune.evaluator` — :class:`CalibratedEvaluator`, pluggable into
  ``pathsearch.search(evaluator=...)`` so the strategy search optimizes
  *measured* time instead of modeled time;
* :mod:`repro.tune.tiles`     — tile-shape search: enumerate the Eq. 6
  feasible (T_h, T_w, T_oc) candidates per lowered launch, rank them with
  the profile, measure the top-K, and serialize the winners into the
  strategy/artifact (``search_tile_shapes``).
"""
from repro.tune.calibrate import CalibrationResult, calibrate, fit_profile
from repro.tune.evaluator import CalibratedEvaluator, group_features
from repro.tune.measure import Measurement, MeasurementHarness, time_callable
from repro.tune.profile import (DeviceProfile, ProfileCache, load_profile,
                                resolve_profile, save_profile)
from repro.tune.tiles import (TileSearchReport, predict_best_shape,
                              search_tile_shapes, shape_candidates,
                              tune_lowered)

__all__ = [
    "CalibrationResult", "calibrate", "fit_profile",
    "CalibratedEvaluator", "group_features",
    "Measurement", "MeasurementHarness", "time_callable",
    "DeviceProfile", "ProfileCache", "load_profile", "save_profile",
    "resolve_profile",
    "TileSearchReport", "predict_best_shape", "search_tile_shapes",
    "shape_candidates", "tune_lowered",
]
