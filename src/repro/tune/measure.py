"""On-device measurement harness (paper Table 2, method 1: "<1 s, 0%").

Runs individual ``GroupProgram`` entries — ``FusedLaunch`` chains, horizontal
stacks and ``RefFallback`` groups — through the *real* executor path in
isolation and wall-clocks them with warmup / repeat / median-of-k timing and
MAD-based outlier rejection.  Because measurement reuses the ``core.lower``
descriptors, every candidate group the path search can enumerate is also a
measurable unit: lower the group once, build a standalone jitted callable
around its launch, time it.

The harness is the ground-truth source for :mod:`repro.tune.calibrate`; it is
also usable directly (``measure_strategy`` times a whole compiled strategy
end-to-end for the tune benchmark's A/B comparison).
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from repro.core import executor as core_executor
from repro.core import lower
from repro.core.xgraph import XGraph
from repro.hw import DeviceModel


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Robust wall-clock of one measurable unit."""
    nodes: tuple
    kind: str                  # "chain" | "horizontal" | "fallback" | "e2e"
    seconds: float             # median of accepted samples
    spread: float              # MAD / median of accepted samples (rel. jitter)
    n_samples: int             # accepted sample count
    n_rejected: int            # outliers dropped by the MAD filter
    samples: tuple = ()        # raw samples (accepted + rejected), seconds

    def to_json(self) -> dict:
        return {"nodes": list(self.nodes), "kind": self.kind,
                "seconds": self.seconds, "spread": self.spread,
                "n_samples": self.n_samples, "n_rejected": self.n_rejected}


def _robust_center(samples: list, reject_nmad: float,
                   center: str = "median") -> tuple:
    """(center, relative spread, n_accepted, n_rejected) with MAD rejection.

    ``center="median"`` is the classic median-of-k after rejecting samples
    more than ``reject_nmad`` MADs out.  ``center="min"`` takes the fastest
    sample: on shared boxes interference is strictly additive and swings at
    second granularity, so the minimum over many short samples converges to
    the uncontended time — the quantity cross-group ratios must be built on.
    """
    s = np.asarray(samples, dtype=float)
    med = float(np.median(s))
    mad = float(np.median(np.abs(s - med)))
    tol = reject_nmad * max(mad, 1e-12)
    keep = s[np.abs(s - med) <= tol]
    if keep.size == 0:                     # pathological: keep everything
        keep = s
    med = float(np.median(keep))
    spread = float(np.median(np.abs(keep - med)) / max(med, 1e-12))
    loc = float(s.min()) if center == "min" else med
    return loc, spread, int(keep.size), int(s.size - keep.size)


def time_callable(fn, ins, *, warmup: int = 1, repeats: int = 5,
                  reject_nmad: float = 3.5, min_sample_s: float = 0.0,
                  max_calls: int = 512, center: str = "median") -> tuple:
    """Time ``fn(*ins)`` with warmup + per-call block_until_ready.

    With ``min_sample_s > 0`` each timed sample loops the callable until it
    spans that much wall clock (per-sample seconds = loop time / calls) —
    amortizes cgroup throttle bursts at the price of averaging interference
    in.  With the default 0 every sample is a single call, which suits the
    ``center="min"`` estimator (see :func:`_robust_center`).

    Returns (seconds, spread, n_accepted, n_rejected, samples)."""
    import jax

    for _ in range(max(1, warmup)):        # compile + cache warm
        jax.block_until_ready(fn(*ins))
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*ins))        # probe sizes the sample loop
    probe = max(time.perf_counter() - t0, 1e-9)
    calls = int(min(max_calls, max(1, math.ceil(min_sample_s / probe))))
    samples = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        for _ in range(calls):
            out = fn(*ins)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / calls)
    loc, spread, n_ok, n_rej = _robust_center(samples, reject_nmad, center)
    return loc, spread, n_ok, n_rej, tuple(samples)


# ------------------------------------------------------------- unit builders
def _rand_int8(rng, shape):
    import jax.numpy as jnp
    # full-range int8 activations (see executor.build_group_callable: near-zero
    # data constant-folds saturation work away and skews timings)
    return jnp.asarray(rng.integers(-128, 128, shape), jnp.int8)


def build_item_callable(g: XGraph, qm, item, *, interpret: bool = True):
    """One ``GroupProgram`` item as a standalone jitted callable + inputs.

    ``FusedLaunch`` entries run the actual Pallas chain/horizontal kernel;
    ``RefFallback`` entries run their nodes through the int8 ref ops — the
    exact per-item execution path of ``Int8Executor(backend="pallas")``.
    """
    import jax

    rng = np.random.default_rng(0)
    if isinstance(item, lower.RefFallback):
        return core_executor.build_group_callable(g, list(item.nodes), qm)

    from repro.kernels.conv_fused import ops as fused_ops

    in_names = list(dict.fromkeys((item.in_name,) + tuple(item.sides)))
    ins = [_rand_int8(rng, g.shape(nm)) for nm in in_names]

    @jax.jit
    def fn(*xs):
        env = dict(zip(in_names, xs))
        out = fused_ops.run_launch(item, env, qm, interpret=interpret)
        return tuple(out[k] for k in sorted(out))

    return fn, ins


# ---------------------------------------------------------------- the harness
class MeasurementHarness:
    """Measure groups / program items / whole strategies on this machine.

    ``backend="pallas"`` lowers each group through ``core.lower`` and times
    the fused kernel launch (ref ops only where lowering decides to fall
    back); ``backend="ref"`` times the per-node int8 reference path.  Results
    are memoized per group — the path search revisits segments freely.
    """

    def __init__(self, g: XGraph, qm, dev: DeviceModel | None = None, *,
                 backend: str = "pallas", interpret: bool = True,
                 warmup: int = 1, repeats: int = 12,
                 reject_nmad: float = 3.5, min_sample_s: float = 0.0,
                 center: str = "min"):
        if backend not in ("pallas", "ref"):
            raise ValueError(f"unknown backend {backend!r}")
        if center not in ("median", "min"):
            raise ValueError(f"unknown center {center!r}")
        self.g, self.qm, self.dev = g, qm, dev
        self.backend = backend
        self.interpret = interpret
        self.warmup, self.repeats = warmup, repeats
        self.reject_nmad = reject_nmad
        self.min_sample_s = min_sample_s
        self.center = center
        self._cache: dict[tuple, Measurement] = {}

    # ------------------------------------------------------------ internals
    def _time(self, fn, ins, nodes, kind) -> Measurement:
        med, spread, n_ok, n_rej, samples = time_callable(
            fn, ins, warmup=self.warmup, repeats=self.repeats,
            reject_nmad=self.reject_nmad, min_sample_s=self.min_sample_s,
            center=self.center)
        return Measurement(nodes=tuple(nodes), kind=kind, seconds=med,
                           spread=spread, n_samples=n_ok, n_rejected=n_rej,
                           samples=samples)

    def _lower_chain(self, group: list):
        return lower.lower_group(self.g, self.qm, list(group))

    def _group_callable(self, group: list) -> tuple:
        if self.backend == "pallas":
            item = self._lower_chain(group)
            kind = (item.kind if isinstance(item, lower.FusedLaunch)
                    else "fallback")
            fn, ins = build_item_callable(self.g, self.qm, item,
                                          interpret=self.interpret)
        else:
            kind = "fallback"
            fn, ins = core_executor.build_group_callable(
                self.g, list(group), self.qm)
        return fn, ins, kind

    # -------------------------------------------------------------- units
    def measure_item(self, item) -> Measurement:
        kind = (item.kind if isinstance(item, lower.FusedLaunch)
                else "fallback")
        fn, ins = build_item_callable(self.g, self.qm, item,
                                      interpret=self.interpret)
        return self._time(fn, ins, item.nodes, kind)

    def measure_group(self, group: list) -> Measurement:
        """Measure one chain group through this harness's backend."""
        key = ("chain", tuple(group))
        if key in self._cache:
            return self._cache[key]
        fn, ins, kind = self._group_callable(group)
        m = self._time(fn, ins, group, kind)
        self._cache[key] = m
        return m

    def _round_robin(self, units: list, passes: int | None) -> list:
        """The shared epoch-robust timing core: warm + probe every callable
        first, then each pass times every unit once — a shared-box
        interference epoch inflates whole passes (which MAD rejection
        discards), never one unit's samples relative to another's.

        ``units``: (nodes, kind, fn, ins) per measurable; returns one
        :class:`Measurement` per unit, in order."""
        import jax

        passes = passes if passes is not None else self.repeats
        prepped = []
        for nodes, kind, fn, ins in units:
            for _ in range(max(1, self.warmup)):
                jax.block_until_ready(fn(*ins))
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*ins))
            probe = max(time.perf_counter() - t0, 1e-9)
            calls = int(min(512, max(1, math.ceil(self.min_sample_s / probe))))
            prepped.append((nodes, kind, fn, ins, calls, []))
        for _ in range(max(1, passes)):
            for nodes, kind, fn, ins, calls, samples in prepped:
                t0 = time.perf_counter()
                for _ in range(calls):
                    out = fn(*ins)
                jax.block_until_ready(out)
                samples.append((time.perf_counter() - t0) / calls)
        out_ms = []
        for nodes, kind, fn, ins, calls, samples in prepped:
            loc, spread, n_ok, n_rej = _robust_center(
                samples, self.reject_nmad, self.center)
            out_ms.append(Measurement(
                nodes=tuple(nodes), kind=kind, seconds=loc, spread=spread,
                n_samples=n_ok, n_rejected=n_rej, samples=tuple(samples)))
        return out_ms

    def measure_set(self, groups: list, passes: int | None = None) -> list:
        """Measure many groups in round-robin passes (see
        :meth:`_round_robin` for why cross-group ratios need this)."""
        todo = []
        for grp in groups:
            key = ("chain", tuple(grp))
            if key in self._cache:
                continue
            fn, ins, kind = self._group_callable(grp)
            todo.append((key, (grp, "chain", fn, ins)))
        for (key, _), m in zip(todo,
                               self._round_robin([u for _, u in todo],
                                                 passes)):
            self._cache[key] = m
        return [self._cache[("chain", tuple(grp))] for grp in groups]

    def measure_item_set(self, items: list, passes: int | None = None
                         ) -> list[Measurement]:
        """Measure arbitrary program items in round-robin passes — the same
        epoch-robust machinery as :meth:`measure_set`, but over prebuilt
        ``FusedLaunch`` / ``RefFallback`` descriptors.  This is how the
        tile-shape search times the top-K tile candidates of every lowered
        unit: a tile variant is just another measurable item, and measuring
        all variants of all units in the same passes means interference
        epochs inflate whole passes instead of biasing one candidate.

        Results are NOT memoized: tile variants of one launch share the same
        node cover, so the per-group cache key would collide."""
        units = []
        for item in items:
            kind = (item.kind if isinstance(item, lower.FusedLaunch)
                    else "fallback")
            fn, ins = build_item_callable(self.g, self.qm, item,
                                          interpret=self.interpret)
            units.append((item.nodes, kind, fn, ins))
        return self._round_robin(units, passes)

    def measure_horizontal(self, heads: list) -> Measurement:
        """Measure a horizontal (shared-input) group: the sum of its lowered
        items (one stacked launch + any individually-lowered leftovers)."""
        key = ("horizontal", tuple(heads))
        if key in self._cache:
            return self._cache[key]
        if self.backend == "pallas":
            items = lower.lower_horizontal(self.g, self.qm, list(heads))
            parts = [self.measure_item(it) for it in items]
        else:
            parts = [self.measure_group([h]) for h in heads]
        m = Measurement(
            nodes=tuple(heads), kind="horizontal",
            seconds=sum(p.seconds for p in parts),
            spread=max((p.spread for p in parts), default=0.0),
            n_samples=min((p.n_samples for p in parts), default=0),
            n_rejected=sum(p.n_rejected for p in parts))
        self._cache[key] = m
        return m

    def measure_program(self, program: lower.GroupProgram) -> list:
        return [self.measure_item(item) for item in program.items]

    # ---------------------------------------------------------- end to end
    def measure_strategy(self, strategy, *, repeats: int | None = None,
                         seed: int = 1) -> Measurement:
        """Wall-clock one full strategy through ``Int8Executor`` (the e2e
        number the tune benchmark compares across search evaluators)."""
        ex = core_executor.Int8Executor(self.g, self.qm, strategy=strategy,
                                        backend=self.backend,
                                        interpret=self.interpret)
        rng = np.random.default_rng(seed)
        shape = next(self.g.shape(n.name) for n in self.g if n.op == "input")
        x = rng.integers(-128, 128, shape).astype(np.int8)
        med, spread, n_ok, n_rej, samples = time_callable(
            lambda v: _run(ex, v), [x],
            warmup=self.warmup,
            repeats=repeats if repeats is not None else self.repeats,
            reject_nmad=self.reject_nmad, min_sample_s=self.min_sample_s,
            center=self.center)
        nodes = tuple(nm for grp in strategy.groups for nm in grp)
        return Measurement(nodes=nodes, kind="e2e", seconds=med,
                           spread=spread, n_samples=n_ok, n_rejected=n_rej,
                           samples=samples)

    def measure_strategy_set(self, strategies: list, *,
                             passes: int | None = None,
                             seed: int = 1) -> list:
        """Alternate end-to-end passes across ``strategies`` so clock drift
        and interference epochs hit every contender equally (the A/B the tune
        benchmark reports).  Same robust center as ``measure_set``."""
        import jax

        passes = passes if passes is not None else self.repeats
        rng = np.random.default_rng(seed)
        shape = next(self.g.shape(n.name) for n in self.g if n.op == "input")
        x = rng.integers(-128, 128, shape).astype(np.int8)
        units = []
        for s in strategies:
            ex = core_executor.Int8Executor(self.g, self.qm, strategy=s,
                                            backend=self.backend,
                                            interpret=self.interpret)
            for _ in range(max(1, self.warmup)):
                _run(ex, x)
            t0 = time.perf_counter()
            _run(ex, x)
            probe = max(time.perf_counter() - t0, 1e-9)
            calls = int(min(512, max(1, math.ceil(self.min_sample_s / probe))))
            units.append((s, ex, calls, []))
        for _ in range(max(1, passes)):
            for s, ex, calls, samples in units:
                t0 = time.perf_counter()
                for _ in range(calls):
                    out = _run(ex, x)
                jax.block_until_ready(out)
                samples.append((time.perf_counter() - t0) / calls)
        out_ms = []
        for s, ex, calls, samples in units:
            loc, spread, n_ok, n_rej = _robust_center(
                samples, self.reject_nmad, self.center)
            nodes = tuple(nm for grp in s.groups for nm in grp)
            out_ms.append(Measurement(
                nodes=nodes, kind="e2e", seconds=loc, spread=spread,
                n_samples=n_ok, n_rejected=n_rej, samples=tuple(samples)))
        return out_ms


def _run(ex, x):
    # Int8Executor returns numpy dicts (already device-synced); wrap so
    # time_callable's block_until_ready has something array-like to touch.
    out = ex(x)
    return list(out.values())
