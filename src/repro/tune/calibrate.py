"""Calibration: fit the cost model's coefficients to measured wall-clock.

The analytic pipeline model prices a group as ``max(stage times) + fill``
with stage rates taken from the hand-written ``DeviceModel``.  On the actual
XLA/Pallas backend those rates are wrong by construction — they describe a
ZU-series FPGA, not this machine.  Calibration closes the loop:

1. measure a candidate fused-op set through the
   :class:`~repro.tune.measure.MeasurementHarness` (or any injected
   ``measure_fn`` — the tests fit against simulator-generated ground truth);
2. extract each group's work-unit feature vector
   (:func:`repro.tune.evaluator.group_features`);
3. least-squares fit the per-unit rates.  Both combination forms are fitted —
   the pipeline ``max + fill`` form (stage-dominance is re-assigned and the
   then-linear system re-solved until the assignment fixes) and the
   sequential ``sum`` form (an XLA CPU runs a fused kernel's stages
   back-to-back, not overlapped) — and the better-fitting form wins;
4. report the deviation band next to the paper's learned-model band (5-10%),
   and refit :class:`~repro.core.cost.ModelEvaluator` against the same
   measurements.

Coefficients are constrained nonnegative (an active-set NNLS: a negative rate
is always a collinearity artifact, never physics); features with no support in
the sample set are left at zero and recorded as unfitted.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import lower
from repro.core.cost import AnalyticEvaluator, ModelEvaluator
from repro.core.xgraph import XGraph
from repro.hw import DeviceModel
from repro.tune.evaluator import (_STAGE_IDX, CalibratedEvaluator,
                                  _horizontal_vec, group_features,
                                  predict_seconds)
from repro.tune.measure import Measurement, MeasurementHarness
from repro.tune.profile import COEF_NAMES, DeviceProfile, _jax_version

PAPER_MODEL_BAND = (0.05, 0.10)     # Table 2's learned-model deviation band
ACCEPT_BAND = 0.15                  # our acceptance ceiling (median abs dev)


# ----------------------------------------------------------------- NNLS fit
def _nnls(X: np.ndarray, y: np.ndarray, max_iter: int | None = None
          ) -> np.ndarray:
    """Nonnegative least squares (Lawson-Hanson active set): greedily admit
    the variable with the largest positive gradient, back off along the line
    segment when a candidate solution leaves the feasible orthant."""
    n = X.shape[1]
    max_iter = max_iter or 3 * n
    x = np.zeros(n)
    passive = np.zeros(n, dtype=bool)
    scale = np.linalg.norm(X, axis=0)
    usable = scale > 0
    tol = 1e-12 * max(1.0, float(scale.max(initial=0.0)))
    for _ in range(max_iter):
        w = X.T @ (y - X @ x)
        w[~usable | passive] = -np.inf
        if not (w > tol).any():
            break
        passive[int(np.argmax(w))] = True
        while True:
            s = np.zeros(n)
            sol, *_ = np.linalg.lstsq(X[:, passive], y, rcond=None)
            s[passive] = sol
            if (s[passive] >= 0).all():
                break
            bad = passive & (s <= 0)
            ratio = x[bad] / np.maximum(x[bad] - s[bad], 1e-30)
            alpha = float(ratio.min(initial=1.0))
            x = x + alpha * (s - x)
            passive &= x > 1e-30
        x = s
    return np.maximum(x, 0.0)


def _max_design(F: np.ndarray, n_fill: np.ndarray,
                assign: np.ndarray) -> np.ndarray:
    """Linearized pipeline form: the dominant stage contributes fully, the
    rest amortize over the tile count (the analytic model's fill term)."""
    X = F.copy()
    for i in range(F.shape[0]):
        for j in _STAGE_IDX:
            if j != assign[i]:
                X[i, j] = F[i, j] / n_fill[i]
    return X


def _assign(F: np.ndarray, coef: np.ndarray) -> np.ndarray:
    stage = F[:, list(_STAGE_IDX)] * coef[list(_STAGE_IDX)]
    return np.asarray([_STAGE_IDX[int(np.argmax(row))] for row in stage])


def _deviation(pred: np.ndarray, y: np.ndarray) -> float:
    return float(np.median(np.abs(pred - y) / np.maximum(y, 1e-12)))


def _fit_form(F, n_fill, y, w, combine: str, max_iters: int) -> tuple:
    """Weighted NNLS fit of one combine form; returns (coef, deviation).

    Rows are scaled by ``w`` (1/y): the objective is squared *relative*
    error, matching the reported median-relative-deviation metric — without
    it a single slow op (a 100x outlier like an int8 GEMV that falls off
    XLA's fast path) owns the whole fit."""
    if combine == "sum":
        coef = _nnls(F * w[:, None], y * w)
        return coef, _deviation(F @ coef, y)
    coef = _nnls(F * w[:, None], y * w)     # sum fit seeds the assignment
    assign = _assign(F, np.where(coef > 0, coef, 1e-30))
    deviation = math.inf
    for _ in range(max_iters):
        X = _max_design(F, n_fill, assign)
        coef = _nnls(X * w[:, None], y * w)
        deviation = _deviation(X @ coef, y)
        new_assign = _assign(F, np.where(coef > 0, coef, 1e-30))
        if (new_assign == assign).all():
            break
        assign = new_assign
    return coef, deviation


def fit_profile(F: np.ndarray, n_fill: np.ndarray, y: np.ndarray, *,
                combine: str | None = None, max_iters: int = 10,
                trim_nmedian: float = 3.0) -> dict:
    """Fit coefficients for both combine forms; return the winner + details.

    ``F``: (n, len(COEF_NAMES)) work units; ``n_fill``: fill divisor per
    sample; ``y``: measured seconds.  After the first pass, samples whose
    relative error exceeds ``trim_nmedian`` x the median are dropped and the
    winner refitted (backend pathologies must not warp every other rate);
    the reported deviation is still computed over ALL samples.
    """
    F = np.asarray(F, dtype=float)
    y = np.asarray(y, dtype=float)
    n_fill = np.maximum(1, np.asarray(n_fill, dtype=float))
    if F.ndim != 2 or F.shape[1] != len(COEF_NAMES):
        raise ValueError(f"feature matrix must be (n, {len(COEF_NAMES)})")
    if len(y) < 3:
        raise ValueError("need at least 3 measurements to fit a profile")
    w = 1.0 / np.maximum(y, 1e-12)

    forms = {f: _fit_form(F, n_fill, y, w, f, max_iters)
             for f in ("sum", "max")}
    pick = combine or min(forms, key=lambda f: forms[f][1])
    coef, deviation = forms[pick]

    # trimmed refit of the winning form
    pred = _predict_rows(F, n_fill, coef, pick)
    rel = np.abs(pred - y) / np.maximum(y, 1e-12)
    keep = rel <= trim_nmedian * max(float(np.median(rel)), 1e-6)
    n_trimmed = int((~keep).sum())
    if 0 < n_trimmed <= len(y) - max(3, len(COEF_NAMES) // 2):
        coef2, _ = _fit_form(F[keep], n_fill[keep], y[keep], w[keep],
                             pick, max_iters)
        dev2 = _deviation(_predict_rows(F, n_fill, coef2, pick), y)
        if dev2 <= deviation:
            coef, deviation = coef2, dev2
    return {
        "coef": tuple(float(c) for c in coef),
        "combine": pick,
        "deviation": deviation,
        "deviation_by_form": {k: float(v[1]) for k, v in forms.items()},
        "n_trimmed": n_trimmed,
        "fitted": [COEF_NAMES[j] for j in range(len(COEF_NAMES))
                   if np.linalg.norm(F[:, j]) > 0],
    }


def _predict_rows(F, n_fill, coef, combine) -> np.ndarray:
    from repro.tune.evaluator import _OVERHEAD_IDX
    stage = F[:, list(_STAGE_IDX)] * coef[list(_STAGE_IDX)]
    fixed = F[:, list(_OVERHEAD_IDX)] @ coef[list(_OVERHEAD_IDX)]
    if combine == "sum":
        return stage.sum(axis=1) + fixed
    steady = stage.max(axis=1)
    return steady + (stage.sum(axis=1) - steady) / n_fill + fixed


# ------------------------------------------------------------ candidate sets
def default_candidate_groups(g: XGraph, max_samples: int = 48,
                             extra: list | None = None) -> list:
    """The measurable fused-op set: singles + template-fusable pairs (+ any
    caller-supplied groups, e.g. a searched strategy's segments), stride-
    sampled down to ``max_samples`` so calibration cost stays bounded."""
    from repro.core import isomorphism, templates

    pairs = templates.pairwise_fusable(
        isomorphism.find_all(g, templates.KERNEL_TEMPLATES))
    singles = [[n.name] for n in g
               if n.op not in ("input", "softmax", "concat")]
    fused = [list(p) for p in sorted(pairs)]
    seen, cands = set(), []
    for grp in (extra or []) + singles + fused:
        key = tuple(grp)
        if key not in seen:
            seen.add(key)
            cands.append(list(grp))
    if len(cands) > max_samples:
        idx = np.linspace(0, len(cands) - 1, max_samples).astype(int)
        cands = [cands[i] for i in sorted(set(idx.tolist()))]
    return cands


def default_horizontal_candidates(g: XGraph, max_sets: int = 6) -> list:
    """Fork points with >= 2 *stackable* conv consumers sharing one input —
    the sibling sets ``lower_horizontal`` turns into ONE OC-stacked launch,
    and therefore the launches calibration must measure directly
    (extrapolating their cost from chain coefficients misses the per-channel
    requant vectors and the wider stacked OC panel the launch actually runs).
    Compatibility mirrors ``lower_horizontal``'s classes: same kernel,
    stride and pad, dilation 1."""
    out = []
    for node in g:
        classes: dict = {}
        for c in g.consumers(node.name):
            nd = g.nodes[c]
            a = nd.attrs
            if nd.op != "conv" or tuple(a.get("dilation", (1, 1))) != (1, 1):
                continue
            kh, kw = a["kernel"]
            key = (kh, kw, tuple(a.get("stride", (1, 1))),
                   str(a.get("pad", "same")))
            classes.setdefault(key, []).append(c)
        for ms in classes.values():
            if len(ms) >= 2 and len(out) < max_sets:
                out.append(ms)
    return out


# -------------------------------------------------------------- calibration
@dataclasses.dataclass
class CalibrationResult:
    profile: DeviceProfile
    measurements: list              # list[Measurement], fit set order
    report: dict                    # deviations, band checks, skip reasons
    model: ModelEvaluator | None = None   # measurement-refit learned model

    def evaluator(self, g: XGraph, dev: DeviceModel) -> CalibratedEvaluator:
        return CalibratedEvaluator(g, dev, self.profile)


def calibrate(g: XGraph, qm, dev: DeviceModel, *,
              groups: list | None = None, harness=None, measure_fn=None,
              backend: str = "pallas", features: str = "kernel",
              interpret: bool = True, warmup: int = 1, repeats: int = 7,
              max_samples: int = 48, combine: str | None = None,
              name: str | None = None, min_measurable_s: float = 5e-4,
              refit_model: bool = True,
              horizontal: list | None = None) -> CalibrationResult:
    """Measure a fused-op candidate set and fit a :class:`DeviceProfile`.

    ``measure_fn(group) -> seconds`` overrides the harness (simulator ground
    truth in tests); otherwise a :class:`MeasurementHarness` on ``backend``
    does the timing.  Only groups that are feasible on ``dev`` *and* lower to
    a fused launch (or are deliberately measurable fallbacks) enter the fit;
    skipped groups are reported, never silently dropped.

    ``horizontal`` lists sibling-head sets whose OC-stacked launches are
    measured DIRECTLY and added to the fit as stacked-launch rows (``None``:
    auto-discover fork points via :func:`default_horizontal_candidates`;
    ``[]``: disable).  Before this, a stacked launch's cost was extrapolated
    from chain coefficients alone — the per-channel requant vectors and the
    stacked OC panel never constrained the fit.  The stacked rows' own
    deviation band is reported separately (``report["stacked"]``).  Requires
    the harness path (injected ``measure_fn`` ground truth measures chain
    groups only).
    """
    analytic = AnalyticEvaluator(g, dev)
    cands = groups if groups is not None else default_candidate_groups(
        g, max_samples=max_samples)
    if measure_fn is None and harness is None:
        harness = MeasurementHarness(g, qm, dev, backend=backend,
                                     interpret=interpret, warmup=warmup,
                                     repeats=repeats)

    measurable, feats, skipped = [], [], []
    for grp in cands:
        got = group_features(g, dev, grp, domain=features, analytic=analytic)
        if got is None:
            skipped.append({"group": list(grp), "reason": "infeasible"})
            continue
        item = lower.lower_group(g, None, list(grp))
        if isinstance(item, lower.RefFallback) and \
                item.reason in ("folded_concat", "host_op"):
            skipped.append({"group": list(grp), "reason": item.reason})
            continue
        measurable.append(list(grp))
        feats.append(got)

    if measure_fn is not None:
        got_ms = []
        for grp in measurable:
            sec = measure_fn(grp)
            got_ms.append(None if sec is None else Measurement(
                nodes=tuple(grp), kind="injected", seconds=float(sec),
                spread=0.0, n_samples=1, n_rejected=0))
    else:
        # round-robin passes over the whole set: interference epochs hit
        # passes, not groups (see MeasurementHarness.measure_set)
        got_ms = harness.measure_set(measurable)

    # measurement floor: wall-clock units below ~0.5 ms are dominated by
    # dispatch jitter on a shared box — below the harness's resolution, they
    # carry no rate information and only poison the relative-error fit.  The
    # floor never applies to injected ground truth (simulator seconds are
    # exact), and is dropped entirely when it would starve the fit.
    floor = min_measurable_s if measure_fn is None else 0.0
    if sum(1 for m in got_ms
           if m is not None and m.seconds >= floor) < 8:
        floor = 0.0

    rows, fills, ys, fit_groups, measurements = [], [], [], [], []
    for grp, (f, n_fill), m in zip(measurable, feats, got_ms):
        if m is None or not math.isfinite(m.seconds) or m.seconds <= 0:
            skipped.append({"group": list(grp), "reason": "unmeasured"})
            continue
        if m.seconds < floor:
            skipped.append({"group": list(grp), "reason": "below_floor",
                            "seconds": m.seconds})
            continue
        rows.append(f)
        fills.append(n_fill)
        ys.append(m.seconds)
        fit_groups.append(list(grp))
        measurements.append(m)
    n_chain_rows = len(rows)

    # --- stacked (horizontal) launch rows, measured directly ----------------
    stacked_idx: list[int] = []
    if measure_fn is None and features == "kernel" and \
            hasattr(harness, "measure_item_set"):
        from repro.core import tiling

        h_sets, h_seen = [], set()
        for heads in (default_horizontal_candidates(g) if horizontal is None
                      else horizontal):
            key = tuple(heads)
            if key not in h_seen:
                h_seen.add(key)
                h_sets.append(list(heads))
        s_items, s_feats, s_fills = [], [], []
        for heads in h_sets:
            t = tiling.solve_horizontal(g, heads, dev)
            if not t.feasible:
                skipped.append({"group": list(heads),
                                "reason": "infeasible_horizontal"})
                continue
            for item in lower.lower_horizontal(g, qm, heads):
                if isinstance(item, lower.FusedLaunch) and \
                        item.kind == "horizontal":
                    s_items.append(item)
                    s_feats.append(_horizontal_vec(g, item))
                    s_fills.append(max(1, t.n_spatial_tiles))
        if s_items:
            for item, f, n_fill, m in zip(
                    s_items, s_feats, s_fills,
                    harness.measure_item_set(s_items)):
                if not math.isfinite(m.seconds) or m.seconds <= 0 or \
                        m.seconds < floor:
                    skipped.append({"group": list(item.nodes),
                                    "reason": "stacked_below_floor",
                                    "seconds": m.seconds})
                    continue
                stacked_idx.append(len(rows))
                rows.append(f)
                fills.append(n_fill)
                ys.append(m.seconds)
                fit_groups.append(list(item.nodes))
                measurements.append(m)

    fit = fit_profile(np.asarray(rows), np.asarray(fills), np.asarray(ys),
                      combine=combine)
    backend_name = backend if measure_fn is None else "injected"
    profile = DeviceProfile(
        name=name or f"{dev.name}-{backend_name}-cal",
        device=dev.name,
        backend=backend_name,
        jax_version=_jax_version(),
        features=features,
        combine=fit["combine"],
        coef=fit["coef"],
        deviation=fit["deviation"],
        n_samples=len(ys),
        meta={"fitted": fit["fitted"],
              "deviation_by_form": fit["deviation_by_form"]})

    # deviation of the exact prediction path the search evaluator uses
    pred = np.asarray([predict_seconds(profile, f, n)
                       for f, n in zip(rows, fills)])
    rel = np.abs(pred - np.asarray(ys)) / np.maximum(ys, 1e-12)
    report = {
        # stacked-launch rows report their own band: the paper-band headline
        # number must not hide a systematically worse horizontal fit
        "stacked": {
            "n_samples": len(stacked_idx),
            "deviation": (float(np.median(rel[stacked_idx]))
                          if stacked_idx else None),
        },
        "deviation": fit["deviation"],
        "deviation_by_form": fit["deviation_by_form"],
        "mean_abs_deviation": float(np.mean(
            np.abs(pred - np.asarray(ys)) / np.maximum(ys, 1e-12))),
        "paper_model_band": list(PAPER_MODEL_BAND),
        "accept_band": ACCEPT_BAND,
        "within_accept_band": fit["deviation"] <= ACCEPT_BAND,
        "n_samples": len(ys),
        "n_trimmed": fit["n_trimmed"],
        "n_skipped": len(skipped),
        "skipped": skipped,
        "fitted": fit["fitted"],
        "profile_hash": profile.hash(),
        "samples": [
            {**m.to_json(), "predicted": float(p),
             "rel_err": float(abs(p - m.seconds) / max(m.seconds, 1e-12))}
            for m, p in zip(measurements, pred)],
    }

    model = None
    # the learned-model refit prices groups through the chain tiling solver,
    # so it trains on the chain rows only (stacked rows would be mis-featured)
    if refit_model and n_chain_rows >= len(ModelEvaluator.FEATURES):
        model = ModelEvaluator(g, dev, fit_groups[:n_chain_rows],
                               targets=list(ys[:n_chain_rows]))
        report["model_refit_mape"] = model.fit_mape
        report["model_within_paper_band"] = model.fit_mape <= PAPER_MODEL_BAND[1]

    return CalibrationResult(profile=profile, measurements=measurements,
                             report=report, model=model)
