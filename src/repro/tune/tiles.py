"""Tile-shape search: make (T_h, T_w, T_oc) a searched compilation decision.

The paper pins T_h/T_oc to the array parallelism and maximizes T_w (Eq. 5/6);
PR 4 calibrated the cost model but still searched only *group partitioning* —
the kernel executed one hard-coded tile heuristic regardless.  This module
closes the ROADMAP's "autotuned tiling" follow-up: for every lowered
``FusedLaunch`` it enumerates the kernel-executable tile shapes that are
feasible under the device's Eq. 6 capacity (:func:`tiling.enumerate_tilings`
— the Pareto frontier over traffic / grid cells / footprint), ranks them
with the fitted :class:`~repro.tune.profile.DeviceProfile` (kernel feature
domain: a tile shape changes the grid-cell count, per-cell staging and
per-tap operand traffic the profile prices), measures the top-K candidates
through the :class:`~repro.tune.measure.MeasurementHarness` (round-robin
passes, MAD rejection — a tile candidate is just another measurable unit),
and records the winner in ``strategy.meta['tile_shapes']``.

From there the shape is a first-class artifact citizen: ``core.lower`` stamps
it onto the launch (``FusedLaunch.tile``), the kernel grids over it, the
memory planner charges its true ping/pong footprints, and the compiled
artifact (format v4) round-trips it.  Groups that are never measured still
get profile-predicted shapes for free through
``CalibratedEvaluator.tile_for`` inside ``pathsearch.search``.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import lower, tiling
from repro.core.xgraph import XGraph
from repro.hw import DeviceModel
from repro.tune.evaluator import _chain_vec, _horizontal_vec, predict_seconds
from repro.tune.profile import DeviceProfile

# A tuned shape must beat the kernel default by more than noise to be
# recorded: measured winners need 1%, profile-predicted winners 2% (a
# prediction is softer evidence than an A/B on the same round-robin passes).
MEASURED_MARGIN = 0.01
PREDICTED_MARGIN = 0.02


def launch_oc(g: XGraph, item: lower.FusedLaunch) -> int:
    """Output channels the launch's OC grid axis tiles."""
    if item.kind == "horizontal":
        return sum(oc for _, oc, _, _ in item.members)
    conv_pos = [i for i, st in enumerate(item.stages) if st[0] == "conv"]
    if conv_pos:
        return g.shape(item.stages[conv_pos[-1]][1])[3]
    return g.shape(item.in_name)[3]


def default_shape(g: XGraph, item: lower.FusedLaunch) -> tuple:
    """The (t_h, t_w, t_oc) the kernel heuristics run without a tile record
    (the PR-4 baseline every candidate must beat)."""
    from repro.kernels.conv_fused.ops import _resolve_tile

    oh, ow = item.out_hw
    has_conv = (item.kind == "horizontal"
                or any(st[0] == "conv" for st in item.stages))
    return _resolve_tile((), oh, ow, launch_oc(g, item), has_conv)


def analytic_shape(g: XGraph, dev: DeviceModel,
                   item: lower.FusedLaunch) -> tuple | None:
    """The paper's Eq. 5/6 shape for this launch's node cover (T_h/T_oc
    pinned to the array parallelism, maximal T_w) — always part of the
    measured candidate set, so the tile search can never do worse than the
    analytic solution it generalizes."""
    t = (tiling.solve_horizontal(g, list(item.nodes), dev)
         if item.kind == "horizontal"
         else tiling.solve(g, list(item.nodes), dev))
    return (t.t_h, t.t_w, t.t_oc) if t.feasible else None


def shape_candidates(g: XGraph, dev: DeviceModel, item: lower.FusedLaunch,
                     max_candidates: int = 16) -> list:
    """Kernel-executable (t_h, t_w, t_oc) candidates for one lowered launch,
    every one feasible under ``dev``'s Eq. 6 capacity — so a chosen shape is
    guaranteed to compile (the bank planner charges its true footprints)."""
    if item.kind == "horizontal":
        oh, _ = item.out_hw
        oc = launch_oc(g, item)
        shapes, seen = [], set()
        for th in tiling._shape_candidates_1d(dev.h_p, oh):
            for toc in tiling._shape_candidates_1d(dev.oc_p, oc):
                if oc % toc:
                    continue        # the OC grid axis cannot run ragged
                t = tiling.solve_horizontal(g, list(item.nodes), dev,
                                            t_h=th, t_oc=toc)
                if not t.feasible:
                    continue
                w, widths = t.t_w, {t.t_w}
                while w > 1 and len(widths) < 3:
                    w = (w + 1) // 2
                    widths.add(w)
                for w in sorted(widths, reverse=True):
                    if (th, w, toc) not in seen:
                        seen.add((th, w, toc))
                        shapes.append((th, w, toc))
        return shapes[:max_candidates]
    cands = tiling.enumerate_tilings(g, list(item.nodes), dev,
                                     max_candidates=max_candidates)
    return [(t.t_h, t.t_w, t.t_oc) for t in cands]


def predict_shape_seconds(profile: DeviceProfile, g: XGraph,
                          item: lower.FusedLaunch, shape: tuple) -> float:
    """Price one tile candidate with the fitted profile: the launch's
    kernel-domain work vector under that shape (grid cells, per-cell staging,
    per-tap operand traffic all move with the tile)."""
    it = dataclasses.replace(item, tile=tuple(int(v) for v in shape))
    f = _horizontal_vec(g, it) if it.kind == "horizontal" else _chain_vec(g, it)
    oh, ow = item.out_hw
    th, tw, _ = shape
    n_fill = max(1, math.ceil(oh / max(1, th)) * math.ceil(ow / max(1, tw)))
    return predict_seconds(profile, f, n_fill)


def predict_best_shape(profile: DeviceProfile, g: XGraph, dev: DeviceModel,
                       item: lower.FusedLaunch,
                       margin: float = PREDICTED_MARGIN) -> tuple | None:
    """Profile-predicted best shape for one launch, or ``None`` when the
    kernel-default heuristics win (within ``margin``) — untuned groups get
    their shapes "for free" through this path."""
    cands = shape_candidates(g, dev, item)
    if not cands:
        return None
    base = predict_shape_seconds(profile, g, item, default_shape(g, item))
    best, best_s = None, base
    for s in cands:
        sec = predict_shape_seconds(profile, g, item, s)
        if sec < best_s:
            best, best_s = s, sec
    if best is None or best_s > base * (1.0 - margin):
        return None
    return tuple(int(v) for v in best)


# ------------------------------------------------------------------- search
@dataclasses.dataclass
class TileSearchReport:
    """What the tile search decided, per lowered unit."""
    tile_shapes: dict               # tile_key -> [t_h, t_w, t_oc] (winners)
    provenance: list                # per-unit candidates + timings
    n_units: int                    # launches considered
    n_tuned: int                    # launches with a non-default winner
    source: str                     # "measured" | "profile"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def search_tile_shapes(g: XGraph, qm, dev: DeviceModel, strategy, *,
                       profile: DeviceProfile | None = None, harness=None,
                       top_k: int = 3, passes: int | None = None,
                       max_candidates: int = 16,
                       min_measurable_s: float = 5e-4) -> TileSearchReport:
    """Search per-launch tile shapes for ``strategy`` and record them in
    ``strategy.meta['tile_shapes']`` (+ ``tile_provenance`` / ``tile_source``).

    With a ``harness`` the top-K profile-ranked candidates of every lowered
    unit (plus the kernel default, always) are measured together in
    round-robin passes and the measured winner is kept; without one the
    profile-predicted best is kept.  Only shapes that beat the default by the
    evidence-appropriate margin are recorded — and only for units whose
    default wall-clock is at least ``min_measurable_s`` (the same 0.5 ms
    resolution floor calibration applies: below it a "winner" is dispatch
    jitter, not evidence).  An empty record IS the PR-4 baseline, so untuned
    programs are byte-identical to before.
    """
    if profile is None and harness is None:
        raise ValueError("search_tile_shapes needs a profile, a harness, "
                         "or both")
    from repro.obs.trace import TRACER
    with TRACER.span("tile_search", cat="compile", track="compile"):
        return _search_tile_shapes(
            g, qm, dev, strategy, profile=profile, harness=harness,
            top_k=top_k, passes=passes, max_candidates=max_candidates,
            min_measurable_s=min_measurable_s)


def _search_tile_shapes(g: XGraph, qm, dev: DeviceModel, strategy, *,
                        profile=None, harness=None, top_k: int = 3,
                        passes: int | None = None, max_candidates: int = 16,
                        min_measurable_s: float = 5e-4) -> TileSearchReport:
    prog = lower.lower_strategy(g, strategy, qm)
    units = []
    for item in prog.launches():
        cands = shape_candidates(g, dev, item, max_candidates=max_candidates)
        default = default_shape(g, item)
        ana = analytic_shape(g, dev, item)
        cands = [s for s in cands if tuple(s) != tuple(default)]
        if profile is not None:
            pred = {tuple(s): predict_shape_seconds(profile, g, item, s)
                    for s in cands}
            cands.sort(key=lambda s: pred[tuple(s)])
            pred[tuple(default)] = predict_shape_seconds(profile, g, item,
                                                         default)
        else:
            # no profile: fewest grid cells first (the dominant interpret-
            # mode cost axis) — measurement arbitrates anyway
            pred = {}
            cands.sort(key=lambda s: (math.ceil(item.out_hw[0] / s[0])
                                      * math.ceil(item.out_hw[1] / s[1])))
        top = cands[:top_k]
        # the Eq. 5/6 shape is always in the measured set: the search result
        # then can never be measured-worse than the analytic solution
        if ana is not None and tuple(ana) != tuple(default) and \
                tuple(ana) not in {tuple(s) for s in top}:
            top.append(tuple(ana))
            if profile is not None:
                pred.setdefault(tuple(ana),
                                predict_shape_seconds(profile, g, item, ana))
        units.append((item, default, top, pred))

    chosen: dict = {}
    provenance: list = []
    source = "measured" if harness is not None else "profile"
    if harness is not None:
        items, index = [], []
        for u, (item, default, top, _) in enumerate(units):
            items.append(item)                     # tile=() == the default
            index.append((u, None))
            for s in top:
                items.append(dataclasses.replace(
                    item, tile=tuple(int(v) for v in s)))
                index.append((u, tuple(s)))
        measured = harness.measure_item_set(items, passes=passes)
        by_unit: dict = {}
        for (u, s), m in zip(index, measured):
            by_unit.setdefault(u, []).append((s, m))
        for u, (item, default, top, pred) in enumerate(units):
            rows = by_unit.get(u, [])
            base = next(m for s, m in rows if s is None)
            win_s, win_m = min(rows, key=lambda r: r[1].seconds)
            keep = (win_s is not None
                    and base.seconds >= min_measurable_s
                    and win_m.seconds < base.seconds * (1 - MEASURED_MARGIN))
            if keep:
                chosen[lower.tile_key(item.nodes)] = [int(v) for v in win_s]
            provenance.append({
                "key": lower.tile_key(item.nodes),
                "nodes": list(item.nodes), "kind": item.kind,
                "default": list(default),
                "chosen": list(win_s) if keep else None,
                "source": "measured",
                "candidates": [
                    {"shape": list(s if s is not None else default),
                     "default": s is None,
                     "predicted": pred.get(s if s is not None
                                           else tuple(default)),
                     "measured": m.seconds, "spread": m.spread}
                    for s, m in rows],
            })
    else:
        for item, default, top, pred in units:
            base = pred[tuple(default)]
            win = min(top, key=lambda s: pred[tuple(s)], default=None)
            keep = (win is not None
                    and pred[tuple(win)] < base * (1 - PREDICTED_MARGIN))
            if keep:
                chosen[lower.tile_key(item.nodes)] = [int(v) for v in win]
            provenance.append({
                "key": lower.tile_key(item.nodes),
                "nodes": list(item.nodes), "kind": item.kind,
                "default": list(default),
                "chosen": list(win) if keep else None,
                "source": "profile",
                "candidates": [
                    {"shape": list(s), "default": tuple(s) == tuple(default),
                     "predicted": pred[tuple(s)], "measured": None}
                    for s in [default] + top],
            })

    report = TileSearchReport(
        tile_shapes=chosen, provenance=provenance, n_units=len(units),
        n_tuned=len(chosen), source=source)
    strategy.meta["tile_shapes"] = dict(chosen)
    strategy.meta["tile_source"] = source
    strategy.meta["tile_provenance"] = provenance
    return report


def tune_lowered(lowered, *, profile=None, harness=None, cache=None,
                 **search_kw):
    """Re-run the tile-shape search over an existing ``stages.Lowered`` and
    return a new ``Lowered`` carrying the tuned shapes.

    This is the staged pipeline's partial-recompile path: pathsearch is NOT
    re-run — the searched group partition is kept, only the per-launch tile
    shapes move.  The input stage is never mutated (its strategy is copied
    before the search writes ``meta['tile_shapes']``), so the untuned and
    tuned lowerings coexist in the stage cache under their own content
    hashes, and downstream ``plan``/``compile`` re-run only for the tuned
    branch.
    """
    import copy

    from repro.tune.profile import resolve_profile

    resolved = resolve_profile(profile) if profile is not None \
        else lowered.profile
    w = lowered.wrapped
    strat = copy.copy(lowered.strategy)
    strat.meta = dict(lowered.strategy.meta)
    search_tile_shapes(w.graph, w.qm, w.device, strat,
                       profile=resolved, harness=harness, **search_kw)
    ph = resolved.hash() if resolved is not None else lowered.profile_hash
    return w.lower(strategy=strat, profile=resolved, profile_hash=ph,
                   cache=cache)
