"""Structured, severity-levelled event log: the runtime's flight journal.

Metrics answer "how much", spans answer "where did the time go"; the event
log answers "what *happened*" — discrete, nameable state changes an operator
or an alerting loop cares about: a compile stage finished, a cache or zoo
entry was evicted, the SLO controller resized a tenant's batch cap, a drift
profiler tripped, an error budget started burning.  Every emission is an
:class:`Event` with a wall-clock timestamp (external log correlation), a
monotonic timestamp on the tracer's clock, and the name of the innermost
open span on the emitting thread — so an event line can be matched back to
the exact Chrome-trace span it happened inside.  Enabled tracers also get a
mirrored instant on an ``events`` track, putting the event markers in the
Perfetto view itself.

Buffering is bounded (a deque of the newest ``capacity`` events; the dropped
count is scrapeable as ``events.dropped``), emission is thread-safe and
cheap, and subscribers — the flight recorder's dump-on-alert hook, a test
asserting an eviction fired — are notified synchronously with exceptions
swallowed (an observability bug must never take down serving).

``to_jsonl`` writes the log in the one-JSON-object-per-line format the CI
artifact uploader and ``python -m repro.obs.dump`` expect.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import threading
import time

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

SEVERITIES = ("debug", "info", "warning", "error")
_SEV_RANK = {s: i for i, s in enumerate(SEVERITIES)}


@dataclasses.dataclass(frozen=True)
class Event:
    """One discrete occurrence.  ``ts`` is wall-clock epoch seconds; ``mono``
    is the tracer's monotonic clock (trace correlation); ``span`` names the
    innermost open span on the emitting thread, if any."""
    seq: int
    ts: float
    mono: float
    severity: str
    kind: str                  # dotted event name: "slo.resize", "zoo.evict"
    message: str
    span: str | None
    fields: dict

    def to_json(self) -> dict:
        return {"seq": self.seq, "ts": self.ts, "mono": self.mono,
                "severity": self.severity, "kind": self.kind,
                "message": self.message, "span": self.span,
                **({"fields": self.fields} if self.fields else {})}


class EventLog:
    """Thread-safe bounded event buffer with severity filtering, synchronous
    subscribers, and tracer correlation."""

    def __init__(self, capacity: int = 2048, *, registry=None, tracer=None,
                 wall_clock=time.time):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self._subs: list = []
        self._lock = threading.Lock()
        self._seq = 0
        self.n_emitted = 0
        self._registry = registry
        self._tracer = tracer
        self._wall = wall_clock

    def _reg(self):
        if self._registry is None:
            self._registry = obs_metrics.REGISTRY
        return self._registry

    def _trc(self):
        if self._tracer is None:
            self._tracer = obs_trace.TRACER
        return self._tracer

    @property
    def n_dropped(self) -> int:
        return self.n_emitted - len(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    # --------------------------------------------------------------- emission
    def emit(self, kind: str, message: str = "", *, severity: str = "info",
             **fields) -> Event:
        """Record one event; returns it.  ``fields`` must be JSON-friendly
        (they land verbatim in the JSONL log and the dump snapshots)."""
        if severity not in _SEV_RANK:
            raise ValueError(f"unknown severity {severity!r}; "
                             f"have {SEVERITIES}")
        tr = self._trc()
        open_span = tr.current_span()
        with self._lock:
            self._seq += 1
            ev = Event(seq=self._seq, ts=self._wall(), mono=tr.clock(),
                       severity=severity, kind=kind, message=message,
                       span=(open_span.name if open_span is not None
                             else None),
                       fields=dict(fields))
            self._buf.append(ev)
            self.n_emitted += 1
            subs = list(self._subs)
        reg = self._reg()
        reg.counter("events.emitted", {"severity": severity}).inc()
        reg.gauge("events.dropped").set(self.n_dropped)
        # mirror into the trace: the event marker sits on an "events" track
        # next to the spans it correlates with
        tr.add_span(kind, ev.mono, ev.mono, cat="event", track="events",
                    args={"seq": ev.seq, "severity": severity, **fields})
        for fn in subs:
            try:
                fn(ev)
            except Exception:       # a broken subscriber must not stop serving
                pass
        return ev

    def subscribe(self, fn) -> None:
        """Register ``fn(event)`` to run synchronously on every emission."""
        with self._lock:
            self._subs.append(fn)

    def unsubscribe(self, fn) -> None:
        with self._lock:
            if fn in self._subs:
                self._subs.remove(fn)

    # ---------------------------------------------------------------- reading
    def records(self, *, min_severity: str | None = None,
                kind: str | None = None, n: int | None = None) -> list[Event]:
        """Newest-last snapshot, optionally filtered by minimum severity
        and/or kind prefix, truncated to the newest ``n``."""
        with self._lock:
            evs = list(self._buf)
        if min_severity is not None:
            floor = _SEV_RANK[min_severity]
            evs = [e for e in evs if _SEV_RANK[e.severity] >= floor]
        if kind is not None:
            evs = [e for e in evs
                   if e.kind == kind or e.kind.startswith(kind + ".")]
        if n is not None:
            evs = evs[-n:]
        return evs

    def snapshot(self, **kw) -> list[dict]:
        return [e.to_json() for e in self.records(**kw)]

    def to_jsonl(self, path: str, **kw) -> str:
        """Write the (filtered) log as JSON Lines; returns the path."""
        with open(path, "w") as f:
            for e in self.records(**kw):
                f.write(json.dumps(e.to_json()) + "\n")
        return path

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.n_emitted = 0
            self._seq = 0


# Shared default log; runtime/compile wiring emits here unless handed its own.
EVENTS = EventLog()
