"""Structured tracing: thread-safe spans + Chrome-trace/Perfetto export.

The validation environment answers "is the output right"; this module answers
"where did the milliseconds go".  A :class:`Tracer` records :class:`SpanRecord`
entries — named, nestable time intervals on logical *tracks* grouped into
*processes* — into a bounded ring buffer (a long-running server must not grow
without bound; the newest spans win).  Spans come from three sources:

* ``tracer.span("pathsearch", cat="compile")`` — a context manager timing the
  enclosed code with the tracer's monotonic clock; nesting is tracked per
  thread, and a child inherits its parent's track so the compile pipeline
  (frontend -> pathsearch -> lower -> memory plan -> tile search -> assemble)
  renders as one stacked flame;
* ``tracer.add_span(...)`` — an externally-timed interval (the serving path
  computes queue-wait from the batcher's own timestamps after the fact);
* ``tracer.add_engine_windows(...)`` — the cycle simulator's per-engine
  occupancy timeline (``simulator.engine_windows`` /
  ``PipelineReport.engine_timeline``) rescaled to seconds, rendered as a
  parallel "modeled" process so the predicted engine overlap sits next to the
  measured wall time in one Perfetto view.

``to_chrome()`` emits the Chrome trace-event JSON (``ph:"X"`` complete events
in microseconds + ``ph:"M"`` process/thread name metadata), loadable by
Perfetto (https://ui.perfetto.dev) and ``chrome://tracing``.

The module-level :data:`TRACER` starts *disabled*: ``span()`` then returns a
shared no-op context manager and ``add_span`` returns immediately, so
instrumented hot paths pay one attribute check and nothing else.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed interval.  ``start``/``end`` are seconds on the tracer's
    clock; ``process``/``track`` place it on a Perfetto row; ``depth`` is the
    per-thread nesting level at record time (0 = top level)."""
    name: str
    start: float
    end: float
    cat: str = ""
    process: str = "measured"
    track: str = ""
    depth: int = 0
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


class _NullSpan:
    """Shared do-nothing context manager for the disabled tracer."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    """Live span handle: records itself into the tracer on ``__exit__``."""
    __slots__ = ("_tracer", "name", "cat", "process", "track", "args",
                 "_start", "_depth")

    def __init__(self, tracer: "Tracer", name: str, cat: str, process: str,
                 track: str | None, args: dict):
        self._tracer = tracer
        self.name, self.cat, self.process = name, cat, process
        self.track = track
        self.args = args

    def set(self, **kw) -> None:
        """Attach/override args while the span is open."""
        self.args.update(kw)

    def __enter__(self):
        stack = self._tracer._stack()
        if self.track is None:       # inherit the enclosing span's track
            self.track = (stack[-1].track if stack
                          else f"thread-{threading.current_thread().name}")
        self._depth = len(stack)
        stack.append(self)
        self._start = self._tracer.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        end = self._tracer.clock()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self._tracer._record(SpanRecord(
            name=self.name, start=self._start, end=end, cat=self.cat,
            process=self.process, track=self.track, depth=self._depth,
            args=self.args))
        return False


class Tracer:
    """Thread-safe span recorder with a bounded ring buffer.

    ``capacity`` bounds retained spans; once full, recording a new span evicts
    the oldest (``n_dropped`` counts evictions).  ``clock`` must be monotonic;
    externally-timed spans (:meth:`add_span`) should use timestamps from the
    same clock or alignment across tracks is lost.
    """

    def __init__(self, capacity: int = 65536, clock=time.monotonic,
                 enabled: bool = False, registry=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.clock = clock
        self._enabled = enabled
        self._lock = threading.Lock()
        self._buf: list[SpanRecord | None] = [None] * capacity
        self._head = 0                  # next write position
        self._size = 0
        self.n_recorded = 0
        self._local = threading.local()
        # span-loss gauges, bound lazily on first record: ring occupancy and
        # drop count become scrapeable instead of living only inside the
        # Chrome export's otherData
        self._registry = registry
        self._g_spans = self._g_dropped = None

    # ----------------------------------------------------------- state
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    @property
    def n_dropped(self) -> int:
        return self.n_recorded - self._size

    def __len__(self) -> int:
        return self._size

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._head = self._size = 0
            self.n_recorded = 0
        if self._g_spans is not None:
            self._g_spans.set(0)
            self._g_dropped.set(0)

    # ----------------------------------------------------------- recording
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            self._buf[self._head] = rec
            self._head = (self._head + 1) % self.capacity
            self._size = min(self._size + 1, self.capacity)
            self.n_recorded += 1
            size, dropped = self._size, self.n_recorded - self._size
        if self._g_spans is None:
            if self._registry is None:
                from repro.obs.metrics import REGISTRY
                self._registry = REGISTRY
            self._g_spans = self._registry.gauge("trace.spans")
            self._g_dropped = self._registry.gauge("trace.dropped")
        self._g_spans.set(size)
        self._g_dropped.set(dropped)

    def current_span(self):
        """The calling thread's innermost open span (None outside any) — the
        event log reads it to correlate events with in-flight spans."""
        stack = self._stack()
        return stack[-1] if stack else None

    def span(self, name: str, *, cat: str = "", process: str = "measured",
             track: str | None = None, **args):
        """Context manager timing the enclosed code.  No-op when disabled."""
        if not self._enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, process, track, args)

    def add_span(self, name: str, start: float, end: float, *, cat: str = "",
                 process: str = "measured", track: str = "",
                 args: dict | None = None) -> None:
        """Record an externally-timed interval (timestamps on this tracer's
        clock).  No-op when disabled."""
        if not self._enabled:
            return
        self._record(SpanRecord(name=name, start=float(start), end=float(end),
                                cat=cat, process=process, track=track,
                                args=dict(args or {})))

    def instant(self, name: str, *, cat: str = "", process: str = "measured",
                track: str = "", **args) -> None:
        if not self._enabled:
            return
        now = self.clock()
        self._record(SpanRecord(name=name, start=now, end=now, cat=cat,
                                process=process, track=track, args=args))

    def add_engine_windows(self, windows: dict, freq_hz: float, *,
                           origin: float | None = None,
                           process: str = "modeled",
                           cat: str = "modeled") -> int:
        """Render a cycle-level engine timeline as spans.

        ``windows`` is ``simulator.engine_windows`` output (or a
        ``PipelineReport.engine_timeline``): engine -> [(start_cycles,
        end_cycles, opcode, tag)].  Cycles are rescaled by ``freq_hz`` to
        seconds and anchored at ``origin`` (default: now), one track per
        engine — the predicted LOAD(i+1)-inside-CONV(i) overlap sits beside
        the measured serve spans in the same exported view.  Returns the
        number of spans recorded."""
        if not self._enabled:
            return 0
        origin = self.clock() if origin is None else origin
        n = 0
        for engine, rows in windows.items():
            for s, e, opcode, tag in rows:
                self._record(SpanRecord(
                    name=f"{opcode}:{tag}", start=origin + s / freq_hz,
                    end=origin + e / freq_hz, cat=cat, process=process,
                    track=str(engine),
                    args={"cycles": int(e - s), "tag": tag}))
                n += 1
        return n

    # ------------------------------------------------------------- reading
    def records(self) -> list[SpanRecord]:
        """Snapshot of the ring buffer, oldest first."""
        with self._lock:
            if self._size < self.capacity:
                return [r for r in self._buf[:self._size]]
            return (self._buf[self._head:] + self._buf[:self._head])  # type: ignore[return-value]

    # -------------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable).

        Processes map to pids, tracks to tids (named via ``ph:"M"`` metadata
        events); spans become ``ph:"X"`` complete events with microsecond
        ``ts``/``dur`` relative to the earliest recorded span."""
        recs = self.records()
        t0 = min((r.start for r in recs), default=0.0)
        pids: dict[str, int] = {}
        tids: dict[tuple, int] = {}
        events: list[dict] = []
        for proc in sorted({r.process for r in recs}):
            pids[proc] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name",
                           "pid": pids[proc], "tid": 0,
                           "args": {"name": proc}})
        for key in sorted({(r.process, r.track) for r in recs}):
            tids[key] = len(tids) + 1
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pids[key[0]], "tid": tids[key],
                           "args": {"name": key[1]}})
        for r in recs:
            events.append({
                "ph": "X", "name": r.name, "cat": r.cat or "default",
                "pid": pids[r.process], "tid": tids[(r.process, r.track)],
                "ts": (r.start - t0) * 1e6,
                "dur": max(0.0, r.duration) * 1e6,
                "args": dict(r.args),
            })
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"n_dropped": self.n_dropped,
                              "clock": "monotonic-relative"}}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# --------------------------------------------------------------- module-level
TRACER = Tracer()


def span(name: str, **kw):
    """``TRACER.span`` shorthand for instrumentation sites."""
    return TRACER.span(name, **kw)


def traced(name: str, *, cat: str = "", process: str = "measured",
           track: str | None = None):
    """Decorator: run the wrapped function inside a span (no-op when the
    module tracer is disabled)."""
    def deco(fn):
        import functools

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not TRACER.enabled:
                return fn(*a, **kw)
            with TRACER.span(name, cat=cat, process=process, track=track):
                return fn(*a, **kw)
        return wrapper
    return deco
