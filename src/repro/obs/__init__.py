"""Observability layer: tracing, metrics, and modeled-vs-measured drift.

The three pieces the paper's validation environment implies but never shows:
``trace`` (where did the milliseconds go — Perfetto-exportable spans across
compile and serve, with the simulator's modeled engine timeline as a parallel
track), ``metrics`` (bounded counters/gauges/histograms the server keeps),
and ``drift`` (is the device profile the plan was ranked under still true).
"""
from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               MetricsRegistry, labeled)
from repro.obs.trace import TRACER, SpanRecord, Tracer, span, traced
from repro.obs.drift import DriftProfiler, DriftReport, UnitDrift

__all__ = [
    "TRACER", "Tracer", "SpanRecord", "span", "traced",
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram", "labeled",
    "DriftProfiler", "DriftReport", "UnitDrift",
]
