"""Observability layer: tracing, metrics, drift — and the production plane.

The three in-process pieces the paper's validation environment implies but
never shows: ``trace`` (where did the milliseconds go — Perfetto-exportable
spans across compile and serve, with the simulator's modeled engine timeline
as a parallel track), ``metrics`` (bounded counters/gauges/histograms the
server keeps), and ``drift`` (is the device profile the plan was ranked
under still true).  On top of them, the exportable plane a fleet router or a
continuous-autotuning loop consumes live: ``export`` (OpenMetrics text
exposition + HTTP scrape endpoint), ``events`` (structured severity-levelled
JSONL event log, trace-correlated), ``flight`` (bounded per-request flight
recorder with forensic auto-dumps), and ``slo`` (per-tenant error-budget
burn-rate tracking with fast/slow-window alerting).
"""
from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               MetricsRegistry, labeled, parse_labels)
from repro.obs.trace import TRACER, SpanRecord, Tracer, span, traced
from repro.obs.drift import DriftProfiler, DriftReport, UnitDrift
from repro.obs.events import EVENTS, Event, EventLog
from repro.obs.export import (ObsHTTPServer, OpenMetricsError, find_samples,
                              parse_openmetrics, render_openmetrics)
from repro.obs.flight import FlightRecord, FlightRecorder
from repro.obs.slo import BurnRateTracker

__all__ = [
    "TRACER", "Tracer", "SpanRecord", "span", "traced",
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "labeled", "parse_labels",
    "DriftProfiler", "DriftReport", "UnitDrift",
    "EVENTS", "Event", "EventLog",
    "ObsHTTPServer", "OpenMetricsError", "find_samples",
    "parse_openmetrics", "render_openmetrics",
    "FlightRecord", "FlightRecorder",
    "BurnRateTracker",
]
