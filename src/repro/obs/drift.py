"""Modeled-vs-measured drift detection: the live calibration check.

A :class:`DeviceProfile` is a snapshot — it priced this machine on the day
``tune.calibrate`` ran.  Thermal state, a JAX upgrade, a noisy neighbour, or a
changed artifact all silently invalidate it, and a plan searched under a stale
profile is quietly mis-ranked.  :class:`DriftProfiler` watches for that at
serve time: every ``every``-th launch it re-times each unit of the compiled
plan (``FusedLaunch`` chains/horizontals and ``RefFallback`` groups, through
the same ``tune.measure.build_item_callable`` path calibration used) and
compares against ``tune.evaluator.predict_item_seconds`` — the prediction the
plan was actually ranked by, searched tile shapes included.

The resulting :class:`DriftReport` carries per-unit relative deviation, the
aggregate (median absolute) deviation versus the paper's 5-10% learned-model
calibration band, and the profile-hash provenance check (does the profile we
are judging against even match the one the artifact was planned under?).
``drifted`` is the boolean the ROADMAP's continuous-autotuning loop consumes
as its re-tune trigger.

Everything heavy is lazy: tune/measure imports happen at first sample, and
:meth:`DriftProfiler.prepare` exists so benchmarks can pay jit warmup outside
their timed window.
"""
from __future__ import annotations

import dataclasses
import statistics

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class UnitDrift:
    """One plan unit's modeled-vs-measured comparison."""
    key: str                   # "+".join(nodes)
    kind: str                  # "chain" | "horizontal" | "fallback"
    predicted: float           # profile-predicted seconds
    measured: float            # median of recent measured seconds
    n_samples: int

    @property
    def deviation(self) -> float:
        """Signed relative error: (measured - predicted) / predicted."""
        return (self.measured - self.predicted) / self.predicted

    def to_json(self) -> dict:
        return {"key": self.key, "kind": self.kind,
                "predicted": self.predicted, "measured": self.measured,
                "deviation": self.deviation, "n_samples": self.n_samples}


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Aggregate drift verdict for one (artifact, profile) pair."""
    units: tuple               # UnitDrift per comparable unit
    skipped: tuple             # (key, reason) for units with no prediction
    aggregate: float | None    # median |deviation| across units
    band: float                # drift threshold the verdict uses
    calibration_band: tuple    # the paper's learned-model band (5-10%)
    profile_deviation: float   # the profile's own fit residual
    profile_hash: str
    artifact_profile_hash: str | None
    n_observed: int            # launches seen by observe_launch()
    n_sampled: int             # sampling passes actually taken

    @property
    def profile_match(self) -> bool:
        return (self.artifact_profile_hash is None
                or self.artifact_profile_hash == self.profile_hash)

    @property
    def drifted(self) -> bool:
        """True when measured unit times left the acceptance band — the
        signal that the profile (and any plan ranked under it) is stale."""
        if self.aggregate is None:
            return not self.profile_match
        return self.aggregate > self.band or not self.profile_match

    def to_json(self) -> dict:
        return {
            "units": [u.to_json() for u in self.units],
            "skipped": [list(s) for s in self.skipped],
            "aggregate_deviation": self.aggregate,
            "band": self.band,
            "calibration_band": list(self.calibration_band),
            "profile_deviation": self.profile_deviation,
            "profile_hash": self.profile_hash,
            "artifact_profile_hash": self.artifact_profile_hash,
            "profile_match": self.profile_match,
            "drifted": self.drifted,
            "n_observed": self.n_observed,
            "n_sampled": self.n_sampled,
        }


def _unit_key(item) -> str:
    return "+".join(item.nodes)


def _unit_kind(item) -> str:
    from repro.core import lower
    if isinstance(item, lower.RefFallback):
        return "fallback"
    return item.kind


class DriftProfiler:
    """Sampling per-unit profiler for a compiled plan.

    ``observe_launch()`` is the serve-path hook: cheap counter bump, and every
    ``every``-th call runs one :meth:`sample` pass timing each plan unit.
    ``measure_fn(item) -> seconds`` can be injected for deterministic tests
    (e.g. the cycle simulator that generated the profile, or a perturbed
    version of it); the default times the real jitted unit callables.
    """

    def __init__(self, g, qm, artifact, dev, profile, *, every: int = 64,
                 warmup: int = 1, repeats: int = 3, band: float | None = None,
                 measure_fn=None, interpret: bool = True,
                 window: int = 8, registry=None, labels: dict | None = None):
        if every < 1:
            raise ValueError("every must be >= 1")
        if artifact.program is None:
            raise ValueError("artifact carries no lowered program "
                             "(ref-backend plans have no units to profile)")
        self.g, self.qm, self.artifact = g, qm, artifact
        self.dev, self.profile = dev, profile
        self.every = every
        self.warmup, self.repeats = warmup, repeats
        self.measure_fn = measure_fn
        self.interpret = interpret
        self.window = window
        self.registry = registry if registry is not None else obs_metrics.REGISTRY
        # ``labels`` tags every emitted gauge (multi-tenant serving labels
        # per-model: ``drift.median_deviation{model=vgg16}``)
        self.labels = dict(labels) if labels else None
        # cheap summary of the most recent report — the flight recorder
        # attaches this to request records without re-pricing any unit
        self.last: dict | None = None
        self._was_drifted = False
        # acceptance: twice the profile's own fit residual, floored at the
        # calibrate ACCEPT_BAND — jitter within the fit's noise is not drift
        if band is None:
            from repro.tune.calibrate import ACCEPT_BAND
            band = max(ACCEPT_BAND, 2.0 * profile.deviation)
        self.band = band
        self.n_observed = 0
        self.n_sampled = 0
        self._callables: dict[str, tuple] = {}
        self._predicted: dict[str, float] = {}
        self._skipped: list[tuple] = []
        self._samples: dict[str, list] = {}
        self._units: list | None = None     # resolved lazily

    @classmethod
    def from_session(cls, session, **kw):
        """Build from a runtime ``Session`` (its graph, quant map, artifact,
        device, and resolved profile)."""
        profile = kw.pop("profile", None) or session.profile
        if profile is None:
            raise ValueError("session has no device profile; pass profile=")
        return cls(session.graph, session.qm, session.artifact,
                   session.device, profile, **kw)

    # ------------------------------------------------------------ unit setup
    def _resolve_units(self) -> list:
        """Plan units with a finite prediction; the rest go to ``skipped``."""
        if self._units is not None:
            return self._units
        from repro.tune.evaluator import predict_item_seconds
        units = []
        for item in self.artifact.program.items:
            key = _unit_key(item)
            pred = predict_item_seconds(self.profile, self.g, self.dev, item)
            if pred is None or pred <= 0:
                self._skipped.append((key, "no finite prediction"))
                continue
            self._predicted[key] = pred
            units.append(item)
        self._units = units
        return units

    def prepare(self) -> None:
        """Build + jit-warm every unit callable now, so the first sampling
        pass inside a timed serving window measures steady-state kernels
        rather than compilation."""
        import jax
        from repro.tune.measure import build_item_callable
        for item in self._resolve_units():
            key = _unit_key(item)
            if self.measure_fn is not None or key in self._callables:
                continue
            fn, ins = build_item_callable(self.g, self.qm, item,
                                          interpret=self.interpret)
            for _ in range(max(1, self.warmup)):
                jax.block_until_ready(fn(*ins))
            self._callables[key] = (fn, ins)

    # -------------------------------------------------------------- sampling
    def observe_launch(self) -> bool:
        """Serve-path hook; returns True when this call triggered a sampling
        pass (the ``every``-th observation, starting at the ``every``-th)."""
        self.n_observed += 1
        if self.n_observed % self.every:
            return False
        self.sample()
        return True

    def _measure(self, item) -> float:
        if self.measure_fn is not None:
            return float(self.measure_fn(item))
        from repro.tune.measure import build_item_callable, time_callable
        key = _unit_key(item)
        if key not in self._callables:
            self._callables[key] = build_item_callable(
                self.g, self.qm, item, interpret=self.interpret)
        fn, ins = self._callables[key]
        seconds, _, _, _, _ = time_callable(fn, ins, warmup=self.warmup,
                                            repeats=self.repeats)
        return seconds

    def sample(self) -> None:
        """Time every unit once and fold into the per-unit sample windows."""
        with obs_trace.TRACER.span("drift_sample", cat="drift",
                                   track="drift"):
            for item in self._resolve_units():
                key = _unit_key(item)
                sec = self._measure(item)
                buf = self._samples.setdefault(key, [])
                buf.append(sec)
                del buf[:-self.window]
        self.n_sampled += 1
        self.registry.counter("drift.samples", self.labels).inc()
        rep = self.report()
        if rep.aggregate is not None:
            self.registry.gauge("drift.aggregate_deviation",
                                self.labels).set(rep.aggregate)
            self.registry.gauge("drift.drifted",
                                self.labels).set(float(rep.drifted))
            # the scrape-facing pair: per-model median deviation + trip bit,
            # so MultiServer tenants expose drift without anyone polling
            # report() objects
            self.registry.gauge("drift.median_deviation",
                                self.labels).set(rep.aggregate)
            self.registry.gauge("drift.tripped",
                                self.labels).set(float(rep.drifted))
        self.last = {"aggregate": rep.aggregate, "drifted": rep.drifted,
                     "band": rep.band, "profile_match": rep.profile_match,
                     "n_sampled": rep.n_sampled}
        if rep.drifted and not self._was_drifted:
            from repro.obs.events import EVENTS
            EVENTS.emit("drift.trip", severity="warning",
                        message="measured unit times left the acceptance "
                                "band; plan ranking may be stale",
                        aggregate=rep.aggregate, band=rep.band,
                        profile_match=rep.profile_match,
                        **(self.labels or {}))
        self._was_drifted = bool(rep.drifted)

    # --------------------------------------------------------------- verdict
    def report(self) -> DriftReport:
        from repro.tune.calibrate import PAPER_MODEL_BAND
        units = []
        for item in self._resolve_units():
            key = _unit_key(item)
            samples = self._samples.get(key)
            if not samples:
                continue
            units.append(UnitDrift(
                key=key, kind=_unit_kind(item),
                predicted=self._predicted[key],
                measured=statistics.median(samples),
                n_samples=len(samples)))
        aggregate = (statistics.median(abs(u.deviation) for u in units)
                     if units else None)
        return DriftReport(
            units=tuple(units), skipped=tuple(self._skipped),
            aggregate=aggregate, band=self.band,
            calibration_band=tuple(PAPER_MODEL_BAND),
            profile_deviation=self.profile.deviation,
            profile_hash=self.profile.hash(),
            artifact_profile_hash=self.artifact.profile_hash,
            n_observed=self.n_observed, n_sampled=self.n_sampled)
