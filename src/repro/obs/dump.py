"""``python -m repro.obs.dump`` — snapshot a running observability plane.

Points at a live :class:`~repro.obs.export.ObsHTTPServer` (the scrape
endpoint a serving bench mounts) and pulls everything it exposes into one
JSON document: the OpenMetrics exposition text (validated through the strict
parser before anything is written — a dump that would not scrape cleanly
fails loudly), the flight-recorder ring + forensic dumps, and the recent
event log.  Without ``--url`` it snapshots the *current process's* shared
registry/event log instead, which is what the tests drive.

    python -m repro.obs.dump --url http://127.0.0.1:9464 --out snap.json
    python -m repro.obs.dump --events-jsonl events.jsonl   # side-write log
"""
from __future__ import annotations

import argparse
import json
import sys
import urllib.request


def _fetch(url: str, timeout: float) -> str:
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.read().decode("utf-8")


def snapshot_url(base_url: str, timeout: float = 10.0) -> dict:
    """Scrape one plane: parse-validated /metrics plus the /snapshot JSON."""
    from repro.obs.export import parse_openmetrics

    base = base_url.rstrip("/")
    text = _fetch(base + "/metrics", timeout)
    families = parse_openmetrics(text)            # strict: bad format raises
    snap = json.loads(_fetch(base + "/snapshot", timeout))
    return {"scraped_from": base, "metrics_text": text,
            "n_families": len(families), **snap}


def snapshot_local() -> dict:
    """In-process fallback: the shared registry, event log, and tracer."""
    from repro.obs import REGISTRY, TRACER
    from repro.obs.events import EVENTS
    from repro.obs.export import parse_openmetrics, render_openmetrics

    text = render_openmetrics(REGISTRY)
    parse_openmetrics(text)
    return {"scraped_from": None, "metrics_text": text,
            "metrics": REGISTRY.snapshot(), "flight": None,
            "events": EVENTS.snapshot(),
            "trace": {"n_spans": len(TRACER), "n_dropped": TRACER.n_dropped,
                      "enabled": TRACER.enabled}}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.dump", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--url", default=None,
                    help="base URL of a running ObsHTTPServer "
                         "(e.g. http://127.0.0.1:9464); omitted = snapshot "
                         "this process's shared registry/event log")
    ap.add_argument("--out", default=None,
                    help="write the combined snapshot JSON here "
                         "(default: stdout)")
    ap.add_argument("--events-jsonl", default=None,
                    help="additionally write the event log as JSON Lines")
    ap.add_argument("--timeout", type=float, default=10.0)
    args = ap.parse_args(argv)

    snap = (snapshot_url(args.url, timeout=args.timeout) if args.url
            else snapshot_local())
    if args.events_jsonl:
        with open(args.events_jsonl, "w") as f:
            for ev in snap.get("events") or []:
                f.write(json.dumps(ev) + "\n")
        print(f"wrote {args.events_jsonl} "
              f"({len(snap.get('events') or [])} events)", file=sys.stderr)
    body = json.dumps(snap, indent=2, default=str)
    if args.out:
        with open(args.out, "w") as f:
            f.write(body + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(body)
    return snap


if __name__ == "__main__":
    main()
