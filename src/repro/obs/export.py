"""OpenMetrics exposition + stdlib HTTP scrape endpoint.

The :class:`~repro.obs.metrics.MetricsRegistry` is Prometheus-*shaped*; this
module makes it Prometheus-*scrapeable*.  :func:`render_openmetrics` turns a
registry (or one of its snapshots) into the OpenMetrics text format:

* metric names are sanitised (``serve.latency_ms`` -> ``serve_latency_ms``)
  and the registry's ``name{model=vgg16}`` label-mangling convention
  (:func:`repro.obs.metrics.labeled`) is de-mangled back into real, quoted,
  escaped label sets;
* counters render as ``<family>_total`` samples, gauges as bare samples,
  histograms as *cumulative* ``_bucket{le="..."}`` series (the registry keeps
  per-bucket counts; exposition requires running totals) plus ``_sum`` and
  ``_count``, with an ``le="+Inf"`` bucket equal to the count;
* families are sorted, samples within a family are sorted by label set, and
  the document ends with ``# EOF`` — the strict-mode terminator.

:func:`parse_openmetrics` is the matching strict parser: it validates the
grammar line by line (TYPE-before-samples, family membership of every sample
name, quoted-label escaping, bucket monotonicity, ``+Inf``/``_count``
agreement, single trailing ``# EOF``) and returns the parsed families.  The
CI smoke gate scrapes a live serving run and feeds the body through it, so
the exposition format is enforced end to end, not assumed.

:class:`ObsHTTPServer` mounts the whole observability plane on a background
``http.server`` thread — ``/metrics`` (OpenMetrics), ``/flight`` (flight
recorder snapshot, JSON), ``/events`` (event log, JSON Lines), ``/snapshot``
(everything at once, JSON; what ``python -m repro.obs.dump`` fetches).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               parse_labels)

CONTENT_TYPE = "application/openmetrics-text; version=1.0.0; charset=utf-8"

_SUFFIXES = {"counter": ("_total",), "gauge": ("",),
             "histogram": ("_bucket", "_sum", "_count")}


def sanitize_name(name: str) -> str:
    """Registry name -> OpenMetrics family name: dots become underscores and
    any other illegal character collapses to ``_``."""
    out = []
    for i, ch in enumerate(name):
        if ch.isalnum() and (i > 0 or not ch.isdigit()) or ch == "_":
            out.append(ch)
        else:
            out.append("_")
    return "".join(out)


def escape_label_value(value: str) -> str:
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt_value(v: float) -> str:
    f = float(v)
    if f != f or f in (float("inf"), float("-inf")):
        return {float("inf"): "+Inf", float("-inf"): "-Inf"}.get(f, "NaN")
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{sanitize_name(k)}="{escape_label_value(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _metric_type(snap_or_metric) -> str:
    if isinstance(snap_or_metric, dict):
        return snap_or_metric["type"]
    return {Counter: "counter", Gauge: "gauge",
            Histogram: "histogram"}[type(snap_or_metric)]


def render_openmetrics(registry_or_snapshot) -> str:
    """OpenMetrics text exposition of a :class:`MetricsRegistry` (or a
    ``registry.snapshot()`` dict).  Deterministic: families and samples are
    sorted, so equal registries render byte-identical documents."""
    snap = (registry_or_snapshot.snapshot()
            if isinstance(registry_or_snapshot, MetricsRegistry)
            else registry_or_snapshot)
    # group label variants under one family: {family: (type, [(labels, snap)])}
    families: dict[str, tuple] = {}
    for name in sorted(snap):
        base, labels = parse_labels(name)
        fam = sanitize_name(base)
        mtype = snap[name]["type"]
        if fam not in families:
            families[fam] = (mtype, [])
        elif families[fam][0] != mtype:
            raise ValueError(
                f"metrics {base!r} map to one family {fam!r} with "
                f"conflicting types {families[fam][0]}/{mtype}")
        families[fam][1].append((labels, snap[name]))

    lines = []
    for fam in sorted(families):
        mtype, series = families[fam]
        lines.append(f"# TYPE {fam} {mtype}")
        for labels, s in sorted(series, key=lambda ls: _fmt_labels(ls[0])):
            ls = _fmt_labels(labels)
            if mtype == "counter":
                lines.append(f"{fam}_total{ls} {_fmt_value(s['value'])}")
            elif mtype == "gauge":
                lines.append(f"{fam}{ls} {_fmt_value(s['value'])}")
            else:                                    # histogram: cumulative
                cum = 0
                for bound, count in s["buckets"].items():
                    if bound == "+inf":
                        continue
                    cum += count
                    ble = _fmt_labels({**labels, "le": bound})
                    lines.append(f"{fam}_bucket{ble} {cum}")
                cum += s["buckets"]["+inf"]
                ble = _fmt_labels({**labels, "le": "+Inf"})
                lines.append(f"{fam}_bucket{ble} {cum}")
                lines.append(f"{fam}_sum{ls} {_fmt_value(s['sum'])}")
                lines.append(f"{fam}_count{ls} {_fmt_value(s['count'])}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------ strict parsing
class OpenMetricsError(ValueError):
    """The document violates the OpenMetrics text format."""


def _parse_label_block(block: str, line_no: int) -> dict:
    """Parse ``k="v",k2="v2"`` with escape handling; strict on grammar."""
    labels: dict[str, str] = {}
    i, n = 0, len(block)
    while i < n:
        eq = block.find("=", i)
        if eq < 0:
            raise OpenMetricsError(f"line {line_no}: malformed label block")
        key = block[i:eq]
        if not key or not all(c.isalnum() or c == "_" for c in key):
            raise OpenMetricsError(f"line {line_no}: bad label name {key!r}")
        if eq + 1 >= n or block[eq + 1] != '"':
            raise OpenMetricsError(f"line {line_no}: label value not quoted")
        j, buf = eq + 2, []
        while j < n:
            c = block[j]
            if c == "\\":
                if j + 1 >= n:
                    raise OpenMetricsError(
                        f"line {line_no}: dangling escape")
                nxt = block[j + 1]
                buf.append({"\\": "\\", '"': '"', "n": "\n"}.get(nxt))
                if buf[-1] is None:
                    raise OpenMetricsError(
                        f"line {line_no}: bad escape \\{nxt}")
                j += 2
            elif c == '"':
                break
            else:
                buf.append(c)
                j += 1
        else:
            raise OpenMetricsError(f"line {line_no}: unterminated value")
        if key in labels:
            raise OpenMetricsError(f"line {line_no}: duplicate label {key!r}")
        labels[key] = "".join(buf)
        i = j + 1
        if i < n:
            if block[i] != ",":
                raise OpenMetricsError(
                    f"line {line_no}: expected ',' between labels")
            i += 1
    return labels


def _family_of(sample_name: str, families: dict) -> tuple[str, str] | None:
    """(family, suffix) the sample belongs to, honouring per-type suffixes.
    Longest match wins so ``x_bucket`` prefers family ``x`` over ``x_bucket``."""
    best = None
    for fam, info in families.items():
        for suf in _SUFFIXES[info["type"]]:
            if sample_name == fam + suf:
                if best is None or len(fam) > len(best[0]):
                    best = (fam, suf)
    return best


def parse_openmetrics(text: str) -> dict:
    """Strictly parse an OpenMetrics document; raises
    :class:`OpenMetricsError` on any format violation.  Returns
    ``{family: {"type": t, "samples": [(sample_name, labels, value)]}}``.

    Validates: single final ``# EOF``; ``# TYPE`` precedes its samples and no
    family repeats; every sample name matches its family + a type-legal
    suffix; histogram ``_bucket`` series carry ``le``, are cumulative
    (non-decreasing), end at ``le="+Inf"``, and agree with ``_count``."""
    lines = text.split("\n")
    if lines and lines[-1] == "":
        lines.pop()
    if not lines or lines[-1] != "# EOF":
        raise OpenMetricsError("document does not end with '# EOF'")
    families: dict[str, dict] = {}
    for ln, line in enumerate(lines[:-1], start=1):
        if line == "# EOF":
            raise OpenMetricsError(f"line {ln}: '# EOF' before end")
        if line.startswith("#"):
            parts = line.split(" ", 3)
            if len(parts) < 3 or parts[0] != "#" or \
                    parts[1] not in ("TYPE", "HELP", "UNIT"):
                raise OpenMetricsError(f"line {ln}: bad comment {line!r}")
            if parts[1] == "TYPE":
                fam, mtype = parts[2], (parts[3] if len(parts) > 3 else "")
                if mtype not in _SUFFIXES:
                    raise OpenMetricsError(
                        f"line {ln}: unsupported type {mtype!r}")
                if fam in families:
                    raise OpenMetricsError(
                        f"line {ln}: family {fam!r} declared twice")
                families[fam] = {"type": mtype, "samples": []}
            continue
        if not line.strip():
            raise OpenMetricsError(f"line {ln}: blank line")
        # sample: name[{labels}] value
        if "{" in line:
            name, _, rest = line.partition("{")
            block, _, tail = rest.partition("}")
            labels = _parse_label_block(block, ln)
            value_str = tail.strip()
        else:
            name, _, value_str = line.partition(" ")
            labels = {}
            value_str = value_str.strip()
        try:
            value = float(value_str.split(" ")[0])
        except (ValueError, IndexError):
            raise OpenMetricsError(f"line {ln}: bad value {value_str!r}")
        hit = _family_of(name, families)
        if hit is None:
            raise OpenMetricsError(
                f"line {ln}: sample {name!r} has no preceding # TYPE family")
        fam, _ = hit
        families[fam]["samples"].append((name, labels, value))

    for fam, info in families.items():
        if info["type"] != "histogram":
            continue
        by_series: dict[tuple, list] = {}
        counts: dict[tuple, float] = {}
        for name, labels, value in info["samples"]:
            key = tuple(sorted((k, v) for k, v in labels.items()
                               if k != "le"))
            if name == fam + "_bucket":
                if "le" not in labels:
                    raise OpenMetricsError(
                        f"{fam}: _bucket sample without 'le'")
                by_series.setdefault(key, []).append((labels["le"], value))
            elif name == fam + "_count":
                counts[key] = value
        for key, buckets in by_series.items():
            if buckets[-1][0] != "+Inf":
                raise OpenMetricsError(f"{fam}: buckets must end at +Inf")
            prev_le, prev_c = float("-inf"), -1.0
            for le, c in buckets:
                fle = float("inf") if le == "+Inf" else float(le)
                if fle <= prev_le:
                    raise OpenMetricsError(
                        f"{fam}: bucket bounds not increasing at le={le}")
                if c < prev_c:
                    raise OpenMetricsError(
                        f"{fam}: bucket counts not cumulative at le={le}")
                prev_le, prev_c = fle, c
            if key in counts and buckets[-1][1] != counts[key]:
                raise OpenMetricsError(
                    f"{fam}: +Inf bucket != _count "
                    f"({buckets[-1][1]} vs {counts[key]})")
    return families


def find_samples(families: dict, family: str, **labels) -> list[tuple]:
    """Samples of ``family`` whose labels include all of ``labels`` —
    smoke-test convenience over :func:`parse_openmetrics` output."""
    info = families.get(family)
    if info is None:
        return []
    return [(n, ls, v) for n, ls, v in info["samples"]
            if all(ls.get(k) == v2 for k, v2 in labels.items())]


# ------------------------------------------------------------- HTTP endpoint
class ObsHTTPServer:
    """The observability plane's scrape endpoint, on a daemon thread.

    Serves the shared (or given) registry/flight-recorder/event-log:
    ``/metrics`` OpenMetrics text, ``/flight`` JSON, ``/events`` JSON Lines,
    ``/snapshot`` one combined JSON document, ``/explain`` the registered
    compile-report providers (``/explain`` lists models; ``/explain/<model>``
    returns that model's CompileReport as JSON — see ``add_explain``).
    ``port=0`` binds an ephemeral port (read it back from ``.port``);
    ``close()`` joins the thread."""

    def __init__(self, registry=None, *, flight=None, events=None,
                 tracer=None, explain=None, host: str = "127.0.0.1",
                 port: int = 0):
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        from repro.obs.events import EVENTS

        self.registry = registry if registry is not None \
            else obs_metrics.REGISTRY
        self.flight = flight
        self.events = events if events is not None else EVENTS
        self.tracer = tracer if tracer is not None else obs_trace.TRACER
        # model name -> zero-arg callable returning a JSON-safe CompileReport
        # (``Session.explain`` bound by the serving layer; lazy so each scrape
        # sees the CURRENT report — after a hot-swap the route follows)
        self._explain: dict = dict(explain or {})
        plane = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):       # scrapes must not spam stderr
                pass

            def _send(self, body: str, ctype: str, code: int = 200):
                data = body.encode("utf-8")
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                try:
                    if path in ("/metrics", "/"):
                        plane.registry.counter("obs.scrapes").inc()
                        self._send(render_openmetrics(plane.registry),
                                   CONTENT_TYPE)
                    elif path == "/flight":
                        snap = (plane.flight.snapshot()
                                if plane.flight is not None else {})
                        self._send(json.dumps(snap, default=str),
                                   "application/json")
                    elif path == "/events":
                        body = "".join(json.dumps(e) + "\n"
                                       for e in plane.events.snapshot())
                        self._send(body, "application/jsonl")
                    elif path == "/snapshot":
                        self._send(json.dumps(plane.snapshot(), default=str),
                                   "application/json")
                    elif path == "/explain" or path == "/explain/":
                        self._send(json.dumps(
                            {"models": sorted(plane._explain)}),
                            "application/json")
                    elif path.startswith("/explain/"):
                        model = path[len("/explain/"):]
                        fn = plane._explain.get(model)
                        if fn is None:
                            self._send(
                                json.dumps({"error": f"unknown model "
                                                     f"{model!r}",
                                            "models": sorted(plane._explain)}),
                                "application/json", 404)
                        else:
                            plane.registry.counter(
                                "obs.explain_scrapes",
                                {"model": model}).inc()
                            self._send(json.dumps(fn(), default=str),
                                       "application/json")
                    else:
                        self._send("not found\n", "text/plain", 404)
                except Exception as e:       # surface, don't kill the thread
                    self._send(f"error: {e}\n", "text/plain", 500)

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="dnnvm-obs-http", daemon=True)
        self._thread.start()

    def add_explain(self, model: str, provider) -> None:
        """Register (or replace) the ``/explain/<model>`` provider: a
        zero-arg callable returning the model's current CompileReport dict
        (typically ``session.explain`` — re-evaluated per scrape, so a
        hot-swapped artifact explains its new plan immediately)."""
        self._explain[model] = provider

    def remove_explain(self, model: str) -> None:
        self._explain.pop(model, None)

    def url(self, path: str = "/metrics") -> str:
        return f"http://{self.host}:{self.port}{path}"

    def snapshot(self) -> dict:
        """Everything the plane knows, one JSON-friendly dict (what
        ``/snapshot`` serves and ``repro.obs.dump`` persists)."""
        return {
            "metrics": self.registry.snapshot(),
            "flight": (self.flight.snapshot()
                       if self.flight is not None else None),
            "events": self.events.snapshot(),
            "trace": {"n_spans": len(self.tracer),
                      "n_dropped": self.tracer.n_dropped,
                      "enabled": self.tracer.enabled},
        }

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
