"""Per-request flight recorder: the last N requests, ready for forensics.

Latency histograms tell you *that* a gold tenant blew its p99; they cannot
tell you *which* request, behind *which* batch, after *how much* queue wait.
The :class:`FlightRecorder` keeps a bounded ring of :class:`FlightRecord`
entries — one per served request, fed by the batcher's observer hook — each
carrying the request id, tenant, queue-wait/execute windows, the batch it
rode in (id, size, co-members), and the tenant's drift state at completion
time.  Static per-tenant context (SLO class and target, the launched tile
shapes of the tenant's compiled plan) is registered once via
:meth:`set_context` rather than copied into every record.

``trigger(reason)`` freezes the ring into a forensic dump — a JSON document
with the recent records, per-tenant context, and the trigger's detail — and
three conditions auto-trigger it:

* an **executor exception** (a record arrives with ``status="error"``);
* an **admission rejection** (:meth:`note_rejection`, called by the
  multi-tenant front door when it sheds load);
* an **SLO violation** (the burn-rate tracker's alert hook calls
  :meth:`trigger` with ``reason="slo_violation"``).

Dumps are retained in a bounded deque (``/flight`` serves them), optionally
written to ``dump_dir`` as ``flight-<seq>-<reason>.json``, and rate-limited
per reason (``min_interval_s``) so an error storm produces one dump, not a
disk full of them.  Every dump also emits an ``flight.dump`` event, so the
JSONL log cross-references the forensic file.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import threading
import time


@dataclasses.dataclass(frozen=True)
class FlightRecord:
    """One request's flight data.  Times are seconds on the batcher's
    monotonic clock (``submit_s``) and window durations."""
    req_id: int
    tenant: str | None
    submit_s: float
    queue_wait_s: float
    execute_s: float
    latency_s: float
    batch_id: int
    batch_size: int
    batch_members: tuple          # req_ids that shared the launch
    status: str                   # "ok" | "error" | "rejected"
    error: str | None = None
    drift: dict | None = None     # tenant drift summary at record time

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["batch_members"] = list(self.batch_members)
        return d


class FlightRecorder:
    """Bounded ring of per-request records with auto-dumping triggers."""

    def __init__(self, capacity: int = 512, *, dump_dir: str | None = None,
                 max_dumps: int = 16, min_interval_s: float = 1.0,
                 registry=None, events=None, clock=time.monotonic):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.dump_dir = dump_dir
        self.min_interval_s = min_interval_s
        self._records: collections.deque = collections.deque(maxlen=capacity)
        self._dumps: collections.deque = collections.deque(maxlen=max_dumps)
        self._context: dict[str, dict] = {}
        self._lock = threading.Lock()
        self._clock = clock
        self._last_dump: dict[str, float] = {}     # reason -> clock() of last
        self.n_recorded = 0
        self.n_dumps = 0
        self._registry = registry
        self._events = events

    def _reg(self):
        if self._registry is None:
            from repro.obs import metrics as obs_metrics
            self._registry = obs_metrics.REGISTRY
        return self._registry

    def _evt(self):
        if self._events is None:
            from repro.obs.events import EVENTS
            self._events = EVENTS
        return self._events

    # ---------------------------------------------------------------- context
    def set_context(self, tenant: str, **ctx) -> None:
        """Attach static per-tenant context (SLO class/target, tile shapes of
        the compiled plan, ...) that every dump should carry once."""
        with self._lock:
            self._context.setdefault(tenant, {}).update(ctx)

    def bind(self, tenant: str | None = None, drift_state=None):
        """A batcher observer feeding this recorder: called with the per-
        request record dict the :class:`~repro.runtime.batching
        .DynamicBatcher` emits.  ``drift_state`` is a zero-arg callable
        returning the tenant's current drift summary (or None)."""
        def observe(rec: dict) -> None:
            self.record(tenant=tenant,
                        drift=(drift_state() if drift_state is not None
                               else None),
                        **rec)
        return observe

    # -------------------------------------------------------------- recording
    def record(self, *, req_id: int, tenant: str | None = None,
               submit_s: float = 0.0, queue_wait_s: float = 0.0,
               execute_s: float = 0.0, latency_s: float = 0.0,
               batch_id: int = -1, batch_size: int = 0,
               batch_members=(), status: str = "ok",
               error: str | None = None, drift: dict | None = None
               ) -> FlightRecord:
        rec = FlightRecord(req_id=req_id, tenant=tenant, submit_s=submit_s,
                           queue_wait_s=queue_wait_s, execute_s=execute_s,
                           latency_s=latency_s, batch_id=batch_id,
                           batch_size=batch_size,
                           batch_members=tuple(batch_members), status=status,
                           error=error, drift=drift)
        with self._lock:
            self._records.append(rec)
            self.n_recorded += 1
        self._reg().gauge("flight.records").set(len(self._records))
        if status == "error":
            self.trigger("executor_exception", tenant=tenant,
                         detail={"req_id": req_id, "error": error})
        return rec

    def note_rejection(self, tenant: str, pending: int, bound: int
                       ) -> FlightRecord:
        """Admission control shed a request: record it (no batch, no
        latency) and dump — rejections are exactly the moments an operator
        wants the recent-request picture for."""
        rec = self.record(req_id=-1, tenant=tenant, status="rejected",
                          error=f"admission bound {bound} hit "
                                f"({pending} pending)")
        self.trigger("admission_rejection", tenant=tenant,
                     detail={"pending": pending, "bound": bound})
        return rec

    # ----------------------------------------------------------------- dumps
    def trigger(self, reason: str, *, tenant: str | None = None,
                detail: dict | None = None) -> dict | None:
        """Freeze the ring into a forensic dump.  Rate-limited per reason;
        returns the dump dict (None when suppressed by the rate limit)."""
        now = self._clock()
        with self._lock:
            last = self._last_dump.get(reason)
            if last is not None and now - last < self.min_interval_s:
                self._reg().counter("flight.dumps_suppressed").inc()
                return None
            self._last_dump[reason] = now
            self.n_dumps += 1
            dump = {
                "seq": self.n_dumps,
                "reason": reason,
                "tenant": tenant,
                "detail": dict(detail or {}),
                "ts": time.time(),
                "mono": now,
                "n_recorded": self.n_recorded,
                "context": {t: dict(c) for t, c in self._context.items()},
                "records": [r.to_json() for r in self._records],
            }
            self._dumps.append(dump)
        path = None
        if self.dump_dir:
            os.makedirs(self.dump_dir, exist_ok=True)
            path = os.path.join(self.dump_dir,
                                f"flight-{dump['seq']}-{reason}.json")
            with open(path, "w") as f:
                json.dump(dump, f, indent=2, default=str)
            dump["path"] = path
        self._reg().counter("flight.dumps").inc()
        self._evt().emit("flight.dump", severity="error", reason=reason,
                         tenant=tenant, n_records=len(dump["records"]),
                         **({"path": path} if path else {}))
        return dump

    # ---------------------------------------------------------------- reading
    def records(self, n: int | None = None) -> list[FlightRecord]:
        with self._lock:
            recs = list(self._records)
        return recs[-n:] if n is not None else recs

    def dumps(self) -> list[dict]:
        with self._lock:
            return list(self._dumps)

    def snapshot(self) -> dict:
        """JSON-friendly view for the ``/flight`` endpoint and the dump CLI."""
        with self._lock:
            return {
                "capacity": self.capacity,
                "n_recorded": self.n_recorded,
                "n_dumps": self.n_dumps,
                "context": {t: dict(c) for t, c in self._context.items()},
                "records": [r.to_json() for r in self._records],
                "dumps": [dict(d) for d in self._dumps],
            }

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
            self._dumps.clear()
            self._last_dump.clear()
            self.n_recorded = 0
            self.n_dumps = 0
