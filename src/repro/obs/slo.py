"""Per-tenant SLO error-budget burn-rate tracking (fast + slow windows).

A latency SLO of the form "99% of requests complete under ``target_ms``"
grants an *error budget*: 1% of requests may violate.  The operational
question is never "did one request violate" (one always will) but "how fast
is the budget burning": a burn rate of 1.0 consumes exactly the budget; 10.0
exhausts a day's budget in 2.4 hours.  :class:`BurnRateTracker` implements
the standard multi-window form — the violation fraction over a short *fast*
window (seconds of serving: catches pages-worthy regressions quickly) and a
longer *slow* window (smooths blips) — and alerts only when **both** exceed
``alert_burn``: the fast window gives low detection latency, the slow window
vetoes one-batch transients.

Every observation updates the ``slo.burn_rate{...,window=fast|slow}`` gauges
(the labels carry the tenant's ``model`` and SLO ``class``), so the scrape
endpoint exposes live burn next to the latency histograms.  An alert emits
an ``slo.alert`` event (severity ``error``), bumps ``slo.alerts``, and calls
the ``on_alert`` hook — the multi-tenant server wires that to the flight
recorder, so the forensic dump lands the moment the budget catches fire.
Alerts are rate-limited by ``cooldown_s``; clocks are injectable so the
window math is unit-testable under synthetic violation schedules.
"""
from __future__ import annotations

import collections
import threading
import time


class BurnRateTracker:
    """Error-budget burn rate for one (tenant, SLO target) pair."""

    def __init__(self, target_ms: float, *, budget: float = 0.01,
                 fast_window_s: float = 30.0, slow_window_s: float = 300.0,
                 alert_burn: float = 2.0, min_samples: int = 8,
                 cooldown_s: float = 30.0, max_samples: int = 16384,
                 labels: dict | None = None, registry=None, events=None,
                 on_alert=None, clock=time.monotonic):
        if target_ms <= 0:
            raise ValueError("target_ms must be > 0")
        if not 0 < budget < 1:
            raise ValueError("budget must be in (0, 1)")
        if fast_window_s >= slow_window_s:
            raise ValueError("fast window must be shorter than slow window")
        self.target_ms = float(target_ms)
        self.budget = float(budget)
        self.fast_window_s = float(fast_window_s)
        self.slow_window_s = float(slow_window_s)
        self.alert_burn = float(alert_burn)
        self.min_samples = min_samples
        self.cooldown_s = cooldown_s
        self.labels = dict(labels) if labels else None
        self.on_alert = on_alert
        self._clock = clock
        self._samples: collections.deque = collections.deque(
            maxlen=max_samples)                     # (t, violated)
        self._lock = threading.Lock()
        self._last_alert: float | None = None
        self.n_observed = 0
        self.n_violations = 0
        self.n_alerts = 0
        self._registry = registry
        self._events = events

    def _reg(self):
        if self._registry is None:
            from repro.obs import metrics as obs_metrics
            self._registry = obs_metrics.REGISTRY
        return self._registry

    def _evt(self):
        if self._events is None:
            from repro.obs.events import EVENTS
            self._events = EVENTS
        return self._events

    # ------------------------------------------------------------ window math
    def _rate(self, window_s: float, now: float) -> tuple[float, int]:
        """(burn rate, samples considered) over the trailing window — the
        violation fraction divided by the error budget."""
        lo = now - window_s
        n = bad = 0
        for t, violated in reversed(self._samples):
            if t < lo:
                break
            n += 1
            bad += violated
        if n == 0:
            return 0.0, 0
        return (bad / n) / self.budget, n

    def burn_rates(self, now: float | None = None) -> dict:
        """Current fast/slow burn rates (and their sample counts)."""
        now = self._clock() if now is None else now
        with self._lock:
            fast, n_fast = self._rate(self.fast_window_s, now)
            slow, n_slow = self._rate(self.slow_window_s, now)
        return {"fast": fast, "slow": slow,
                "n_fast": n_fast, "n_slow": n_slow}

    # ------------------------------------------------------------ observation
    def observe(self, latency_ms: float, *, t: float | None = None) -> bool:
        """Fold one served request in; returns True when this observation
        fired an alert (both windows burning past ``alert_burn``, enough
        samples, outside the cooldown)."""
        now = self._clock() if t is None else t
        violated = latency_ms > self.target_ms
        with self._lock:
            self._samples.append((now, violated))
            self.n_observed += 1
            self.n_violations += violated
            fast, n_fast = self._rate(self.fast_window_s, now)
            slow, n_slow = self._rate(self.slow_window_s, now)
            firing = (n_fast >= self.min_samples
                      and fast >= self.alert_burn
                      and slow >= self.alert_burn
                      and (self._last_alert is None
                           or now - self._last_alert >= self.cooldown_s))
            if firing:
                self._last_alert = now
                self.n_alerts += 1
        reg = self._reg()
        reg.gauge("slo.burn_rate",
                  {**(self.labels or {}), "window": "fast"}).set(fast)
        reg.gauge("slo.burn_rate",
                  {**(self.labels or {}), "window": "slow"}).set(slow)
        if firing:
            reg.counter("slo.alerts", self.labels).inc()
            self._evt().emit(
                "slo.alert", severity="error",
                message=f"error budget burning at {fast:.1f}x (fast) / "
                        f"{slow:.1f}x (slow); target {self.target_ms} ms",
                target_ms=self.target_ms, fast_burn=fast, slow_burn=slow,
                latency_ms=latency_ms, **(self.labels or {}))
            if self.on_alert is not None:
                try:
                    self.on_alert(self, fast, slow)
                except Exception:   # alerting must never take down serving
                    pass
        return firing

    def observer(self):
        """A batcher observer feeding this tracker: reads ``latency_s`` off
        the per-request record dict."""
        def observe(rec: dict) -> None:
            if rec.get("status") == "ok":
                self.observe(rec["latency_s"] * 1e3)
        return observe

    # --------------------------------------------------------------- reporting
    def snapshot(self) -> dict:
        rates = self.burn_rates()
        return {"target_ms": self.target_ms, "budget": self.budget,
                "alert_burn": self.alert_burn,
                "fast_window_s": self.fast_window_s,
                "slow_window_s": self.slow_window_s,
                "n_observed": self.n_observed,
                "n_violations": self.n_violations,
                "n_alerts": self.n_alerts, **rates}
