"""Metrics registry: counters, gauges, and fixed-bucket histograms.

Prometheus-shaped but in-process: the serving path increments named metrics
(queue depth, batch-size histogram, request latency, plan-cache hits, fused vs
fallback launches, SLO shrink/grow events) and :meth:`MetricsRegistry.snapshot`
renders everything as one stable, JSON-serialisable dict that ``serve_bench``
emits next to its throughput numbers.

Memory is bounded by construction: a counter/gauge is two floats, a histogram
is a fixed bucket array plus running sum/count/min/max (no sample retention),
and the registry refuses to grow past ``max_metrics`` distinct names — a typo
in a hot loop cannot leak memory.  All mutation is lock-protected; the
serving worker thread and caller threads share one registry.
"""
from __future__ import annotations

import bisect
import threading

# Request latencies in serving land between ~0.1 ms (cached toy graphs) and
# seconds (cold jit); buckets are in *milliseconds*, roughly logarithmic.
DEFAULT_LATENCY_BUCKETS_MS = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
    100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0,
)
# Batch sizes are small integers; one bucket per power of two up to 256.
DEFAULT_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def labeled(name: str, labels: dict | None) -> str:
    """Mangle a metric name with sorted key=value labels, Prometheus-style:
    ``labeled("requests", {"model": "vgg16"}) == 'requests{model=vgg16}'``.
    Labels must stay low-cardinality — each combination is a distinct metric
    counted against the registry cap."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def parse_labels(name: str) -> tuple[str, dict]:
    """Inverse of :func:`labeled`: split a mangled metric name back into
    ``(base_name, labels)`` — ``'requests{model=vgg16}'`` becomes
    ``('requests', {'model': 'vgg16'})``.  Names without labels return an
    empty dict.  The OpenMetrics exporter and the registry's ``labelled``
    query both de-mangle through here, so the round trip is pinned in one
    place."""
    if not name.endswith("}") or "{" not in name:
        return name, {}
    base, _, inner = name[:-1].partition("{")
    labels = {}
    for part in inner.split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        labels[k] = v
    return base, labels


class Counter:
    """Monotonically increasing count."""
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a gauge")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self):
        return {"type": "counter", "value": self._value}


class Gauge:
    """Point-in-time value (queue depth, current batch cap)."""
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self):
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Fixed-bound bucket histogram with running sum/count/min/max.

    ``bounds`` are upper bucket edges; observations above the last bound land
    in a +inf overflow bucket.  ``percentile`` interpolates within the winning
    bucket — exact enough for p50/p99 dashboards without retaining samples.
    """
    __slots__ = ("name", "bounds", "counts", "_sum", "_count", "_min", "_max",
                 "_lock")

    def __init__(self, name: str, bounds=DEFAULT_LATENCY_BUCKETS_MS):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError("bucket bounds must be non-empty and sorted")
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # + overflow
        self._sum = 0.0
        self._count = 0
        self._min = float("inf")
        self._max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self.counts[i] += 1
            self._sum += value
            self._count += 1
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate q-quantile (q in [0, 1]) by linear interpolation inside
        the bucket containing the rank; the overflow bucket reports the
        observed max."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        with self._lock:
            if self._count == 0:
                return 0.0
            rank = q * self._count
            seen = 0
            for i, c in enumerate(self.counts):
                if c == 0:
                    continue
                if seen + c >= rank:
                    if i == len(self.bounds):        # overflow bucket
                        return self._max
                    lo = self.bounds[i - 1] if i else min(self._min,
                                                          self.bounds[i])
                    hi = self.bounds[i]
                    frac = (rank - seen) / c
                    return lo + (hi - lo) * frac
                seen += c
            return self._max

    def snapshot(self):
        with self._lock:
            return {
                "type": "histogram",
                "count": self._count,
                "sum": self._sum,
                "min": self._min if self._count else None,
                "max": self._max if self._count else None,
                "buckets": {
                    **{str(b): self.counts[i]
                       for i, b in enumerate(self.bounds)},
                    "+inf": self.counts[-1],
                },
            }


class MetricsRegistry:
    """Thread-safe name -> metric table with get-or-create accessors.

    Re-requesting a name returns the existing instance; requesting it as a
    different type raises.  The registry caps distinct names at
    ``max_metrics``."""

    def __init__(self, max_metrics: int = 1024):
        self.max_metrics = max_metrics
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as "
                        f"{type(m).__name__}, requested {cls.__name__}")
                return m
            if len(self._metrics) >= self.max_metrics:
                raise RuntimeError(
                    f"metrics registry full ({self.max_metrics}); "
                    "metric names must be low-cardinality")
            m = factory()
            self._metrics[name] = m
            return m

    def counter(self, name: str, labels: dict | None = None) -> Counter:
        name = labeled(name, labels)
        return self._get_or_create(name, Counter, lambda: Counter(name))

    def gauge(self, name: str, labels: dict | None = None) -> Gauge:
        name = labeled(name, labels)
        return self._get_or_create(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str, bounds=DEFAULT_LATENCY_BUCKETS_MS,
                  labels: dict | None = None) -> Histogram:
        name = labeled(name, labels)
        return self._get_or_create(name, Histogram,
                                   lambda: Histogram(name, bounds))

    def get(self, name: str):
        return self._metrics.get(name)

    def labelled(self, name: str, label: str = "model") -> dict:
        """Every metric registered under base name ``name``, keyed by the
        value of ``label``: ``labelled("serve.rejected")`` returns
        ``{"vgg16": Counter, "resnet50": Counter, ...}``.  An unlabeled
        metric of the same base name appears under ``None``.  This is the
        query API for per-tenant stats — callers never hand-format
        ``'name{model=...}'`` lookups."""
        with self._lock:
            items = list(self._metrics.items())
        out = {}
        for full, m in items:
            base, labels = parse_labels(full)
            if base != name:
                continue
            if not labels:
                out[None] = m
            elif label in labels:
                out[labels[label]] = m
        return out

    def __len__(self) -> int:
        return len(self._metrics)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> dict:
        """Stable (sorted-name) JSON-serialisable view of every metric.
        Histograms additionally report p50/p99 for dashboard convenience."""
        with self._lock:
            items = sorted(self._metrics.items())
        out = {}
        for name, m in items:
            snap = m.snapshot()
            if isinstance(m, Histogram) and m.count:
                snap["p50"] = m.percentile(0.50)
                snap["p99"] = m.percentile(0.99)
                snap["mean"] = m.mean
            out[name] = snap
        return out


# Shared default registry; the runtime wires into this unless handed its own.
REGISTRY = MetricsRegistry()
