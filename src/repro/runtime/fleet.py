"""Fault-tolerant serving fleet: replicated Sessions with health-driven
failover.

The runtime so far assumed one accelerator that never fails.  A production
serving plane must keep answering when a replica dies mid-batch, so the
:class:`Fleet` places N data-parallel :class:`~repro.runtime.session.Session`
replicas across ``jax.devices()`` (forced-host devices in CI; each replica's
plan cache seeded from ONE shared :class:`~repro.asm.artifact.
CompiledArtifact`, so the fleet compiles nothing) and puts a failover router
in front of their per-replica :class:`~repro.runtime.server.Server`s:

* **routing** — each request goes to the active replica with the smallest
  expected drain time, ``(queue depth + 1) x recent p99`` (cold replicas tie
  at zero and round-robin on depth alone);
* **health** — the previously idle :class:`~repro.distributed.health.
  HeartbeatMonitor` is wired into the serve loop: every completed batch
  beats its replica with the measured execute time, idle healthy replicas
  are beaten by the monitor thread, and a replica sitting on work without
  completing goes heartbeat-dead.  Dead replicas, replicas with consecutive
  failed batches, straggling replicas (step-time EWMA beyond ``factor`` x
  the fleet median, >= 3 replicas), and replicas failing a health probe are
  **evicted**: routing stops, their in-flight requests are transparently
  re-dispatched to survivors, a ``replica.evict`` event fires and the flight
  recorder freezes a forensic dump;
* **retries** — a failed or timed-out attempt is retried on a different
  replica with exponential backoff, bounded by ``max_retries`` and a
  per-request deadline.  Whichever attempt completes FIRST resolves the
  client future; late completions (a hung replica finally answering) are
  suppressed by request id (``fleet.duplicates_suppressed``);
* **re-admission** — an evicted replica is probed with a warmup canary
  through its own serve queue; once the probe answers bit-exactly it is
  elastically re-admitted (``replica.admit``) and traffic flows back;
* **load shedding** — when capacity shrinks below demand, ``submit`` raises
  :class:`~repro.runtime.multitenant.AdmissionError` past
  ``max_queue_per_replica x active replicas`` pending requests: degraded,
  not wedged.

Everything is observable on the PR-8 plane: ``fleet.*`` labelled metrics,
``replica.evict`` / ``replica.admit`` / ``request.retry`` events, and flight
dumps on every eviction.  The deterministic fault injector that drives the
chaos gate lives in :mod:`repro.runtime.chaos`.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

from repro.distributed.health import HeartbeatMonitor
from repro.runtime.multitenant import AdmissionError
from repro.runtime.server import Server
from repro.runtime.session import Session


class FleetError(RuntimeError):
    """A request could not be completed by any replica."""


class RetriesExhausted(FleetError):
    """Every allowed attempt failed (last cause in the message)."""


class DeadlineExceeded(FleetError):
    """The request's deadline passed before any attempt completed."""


@dataclasses.dataclass
class Replica:
    """One Session + Server pair, placed on one device."""
    rid: str
    index: int
    device: object
    session: Session
    server: Server
    state: str = "active"               # "active" | "evicted"
    strikes: int = 0                    # consecutive failed batches
    last_error_batch: int | None = None
    inflight: dict = dataclasses.field(default_factory=dict)  # req_id -> req
    lat: deque = dataclasses.field(default_factory=lambda: deque(maxlen=128))
    evictions: int = 0
    admissions: int = 0
    evict_reason: str | None = None
    probe: tuple | None = None          # (future, expires_at)
    next_probe: float = 0.0

    def p99_s(self) -> float:
        lats = sorted(self.lat)
        if not lats:
            return 0.0
        return lats[min(len(lats) - 1, int(0.99 * (len(lats) - 1)))]


@dataclasses.dataclass
class _Request:
    req_id: int
    x: object
    future: Future
    deadline: float
    attempts: int = 0                   # dispatches so far
    attempt_no: int = 0                 # monotonically superseding id
    current_rid: str | None = None
    attempt_expires: float = 0.0
    tried: set = dataclasses.field(default_factory=set)
    done: bool = False


class Fleet:
    """N data-parallel Session replicas behind one failover front door."""

    def __init__(self, artifact, *, n_replicas: int | None = None,
                 devices=None, backend: str = "ref", interpret: bool = True,
                 max_retries: int = 3, retry_backoff_s: float = 0.01,
                 request_deadline_s: float = 60.0,
                 attempt_timeout_s: float = 10.0,
                 heartbeat_timeout_s: float = 2.0,
                 straggler_factor: float = 3.0,
                 max_consecutive_errors: int = 2,
                 check_interval_s: float = 0.02,
                 probe_interval_s: float = 0.25,
                 probe_timeout_s: float = 5.0,
                 max_queue_per_replica: int = 64,
                 session_kw: dict | None = None,
                 server_kw: dict | None = None,
                 monitor: HeartbeatMonitor | None = None,
                 flight=None, events=None, registry=None,
                 clock=time.monotonic):
        """``artifact`` is the one shared compiled model every replica serves
        (each replica's plan cache is seeded from it — no recompilation).
        ``n_replicas`` defaults to ``len(devices)``; with fewer devices than
        replicas, placement wraps round-robin (multi-session-per-device).
        ``max_retries`` bounds RE-dispatches per request (so a request runs
        at most ``1 + max_retries`` attempts); ``retry_backoff_s`` doubles
        per attempt.  ``attempt_timeout_s`` is the hang detector: an attempt
        not answered within it is retried elsewhere without waiting for the
        replica to be declared dead.  ``monitor`` defaults to a
        :class:`HeartbeatMonitor` with ``heartbeat_timeout_s``."""
        import jax

        from repro.obs import events as obs_events
        from repro.obs import metrics as obs_metrics
        from repro.obs.flight import FlightRecorder

        self.devices = list(devices) if devices is not None else jax.devices()
        self.n_replicas = int(n_replicas if n_replicas is not None
                              else len(self.devices))
        if self.n_replicas < 1:
            raise ValueError("a fleet needs at least one replica")
        self.backend = backend
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.request_deadline_s = request_deadline_s
        self.attempt_timeout_s = attempt_timeout_s
        self.straggler_factor = straggler_factor
        self.max_consecutive_errors = max_consecutive_errors
        self.check_interval_s = check_interval_s
        self.probe_interval_s = probe_interval_s
        self.probe_timeout_s = probe_timeout_s
        self.max_queue_per_replica = max_queue_per_replica
        self._clock = clock
        self._registry = (registry if registry is not None
                          else obs_metrics.REGISTRY)
        self._events = events if events is not None else obs_events.EVENTS
        self.flight = flight if flight is not None else FlightRecorder(
            registry=self._registry, events=self._events)
        self.monitor = monitor if monitor is not None else HeartbeatMonitor(
            timeout_s=heartbeat_timeout_s, clock=clock)

        self._lock = threading.RLock()
        self._replicas: dict[str, Replica] = {}
        self._requests: dict[int, _Request] = {}
        self._retry_due: list = []      # [due_s, req, exclude, reason]
        self._seq = 0
        self._closed = False
        self.n_duplicates = 0

        self._m_submitted = self._registry.counter("fleet.submitted")
        self._m_completed = self._registry.counter("fleet.completed")
        self._m_rejected = self._registry.counter("fleet.rejected")
        self._m_retries = self._registry.counter("fleet.retries")
        self._m_duplicates = self._registry.counter(
            "fleet.duplicates_suppressed")
        self._m_deadline = self._registry.counter("fleet.deadline_exceeded")
        self._m_active = self._registry.gauge("fleet.active_replicas")
        self._m_pending = self._registry.gauge("fleet.pending")

        session_kw = dict(session_kw or {})
        server_kw = dict(server_kw or {})
        for i in range(self.n_replicas):
            rid = f"r{i}"
            dev = self.devices[i % len(self.devices)]
            session = Session.from_artifact(
                artifact, backend=backend, interpret=interpret,
                cache=_fresh_plan_cache(), placement=dev, **session_kw)
            server = Server(session,
                            labels={"replica": rid},
                            observers=[self._observer(rid),
                                       self.flight.bind(
                                           tenant=rid,
                                           drift_state=session.drift_state)],
                            events=self._events, **server_kw)
            self._replicas[rid] = Replica(rid=rid, index=i, device=dev,
                                          session=session, server=server)
            self.flight.set_context(rid, device=str(dev), backend=backend)
            self.monitor.beat(rid)
            self._events.emit(
                "replica.admit", replica=rid, initial=True,
                device=str(dev),
                message=f"replica {rid} placed on {dev} (initial)")
        self._m_active.set(self.n_replicas)

        # warmup canary: the probe input every health check replays, and the
        # bit-exact expected answer (replica 0's executor, pre-chaos)
        shape = artifact.rebuild_graph().shape(
            next(nd["name"] for nd in artifact.graph_nodes
                 if nd["op"] == "input"))
        rng = np.random.default_rng(0)
        self._canary_x = rng.integers(-128, 128, size=(1,) + tuple(shape[1:]),
                                      dtype=np.int64).astype(np.int8)
        # through the replica's launch path (placement context, no hook is
        # attached yet) so the warmed-up compile cache is reused
        self._canary_expected = self._replicas["r0"].session._launch(
            self._canary_x)

        self._stop = threading.Event()
        # construction (warmups, canary) can take longer than the heartbeat
        # timeout: staleness must be measured from serving start, not from
        # each replica's own creation instant
        for rid in self._replicas:
            self.monitor.beat(rid)
        self._monitor_thread = threading.Thread(
            target=self._monitor_loop, daemon=True, name="dnnvm-fleet-monitor")
        self._monitor_thread.start()

    # ----------------------------------------------------------------- client
    def submit(self, x) -> Future:
        """Enqueue one request; returns a future that resolves with the first
        successful attempt's output dict (or raises :class:`FleetError` /
        :class:`AdmissionError`)."""
        with self._lock:
            if self._closed:
                raise FleetError("fleet is closed")
            active = self._active()
            if not active:
                self._shed("no active replicas", 0, 0)
            bound = self.max_queue_per_replica * len(active)
            if len(self._requests) >= bound:
                self._shed(f"{len(self._requests)} pending >= bound {bound} "
                           f"({len(active)} active replicas)",
                           len(self._requests), bound)
            self._seq += 1
            req = _Request(req_id=self._seq, x=x, future=Future(),
                           deadline=self._clock() + self.request_deadline_s)
            self._requests[req.req_id] = req
            self._m_submitted.inc()
            self._m_pending.set(len(self._requests))
            self._dispatch(req)
        return req.future

    def _shed(self, why: str, pending: int, bound: int):
        self._m_rejected.inc()
        self._events.emit("admission.reject", severity="warning",
                          scope="fleet", pending=pending, bound=bound,
                          message=f"fleet shed a request: {why}")
        raise AdmissionError(f"fleet overloaded: {why}")

    # ---------------------------------------------------------------- routing
    def _active(self) -> list[Replica]:
        return [r for r in self._replicas.values() if r.state == "active"]

    @staticmethod
    def _score(r: Replica) -> float:
        """Expected drain time: queue depth x recent p99 (epsilon floor so
        cold replicas still order by depth)."""
        return (r.server.pending + len(r.inflight) + 1) * max(r.p99_s(), 1e-6)

    def _dispatch(self, req: _Request, *, exclude: set | None = None,
                  reason: str | None = None) -> None:
        """Route one attempt.  Called under the lock for fresh submits; takes
        it for retries."""
        with self._lock:
            if req.done:
                return
            active = self._active()
            if not active:
                # no capacity right now: park the request for the monitor to
                # re-dispatch once a replica is re-admitted (deadline still
                # applies, so an empty fleet fails requests at the deadline)
                self._retry_due.append([self._clock() + self.check_interval_s,
                                        req, set(exclude or ()), "no_replica"])
                return
            pool = ([r for r in active if r.rid not in (exclude or ())
                     and r.rid not in req.tried]
                    or [r for r in active if r.rid not in (exclude or ())]
                    or active)
            r = min(pool, key=self._score)
            req.attempts += 1
            req.attempt_no += 1
            req.current_rid = r.rid
            req.tried.add(r.rid)
            req.attempt_expires = self._clock() + self.attempt_timeout_s
            r.inflight[req.req_id] = req
            attempt = req.attempt_no
        if reason is not None:
            self._m_retries.inc()
            self._events.emit(
                "request.retry", severity="warning", req_id=req.req_id,
                attempt=req.attempts, to_replica=r.rid, reason=reason,
                message=f"request {req.req_id} attempt {req.attempts} "
                        f"-> {r.rid} ({reason})")
        try:
            fut = r.server.submit(req.x)
        except Exception as e:          # replica refused outright
            self._attempt_failed(req, r.rid, attempt, e, "submit_failed")
            return
        fut.add_done_callback(
            lambda f, rid=r.rid, a=attempt: self._attempt_done(req, rid, a, f))

    # -------------------------------------------------------------- attempts
    def _attempt_done(self, req: _Request, rid: str, attempt: int,
                      fut: Future) -> None:
        """Runs on the completing replica's batcher worker."""
        with self._lock:
            r = self._replicas.get(rid)
            if r is not None:
                r.inflight.pop(req.req_id, None)
            stale = attempt != req.attempt_no
        err = fut.exception()
        if err is None:
            self._resolve(req, result=fut.result())
        elif not stale and not req.done:
            self._attempt_failed(req, rid, attempt, err, "error")
        # a stale failed attempt is already being retried — nothing to do

    def _attempt_failed(self, req: _Request, rid: str, attempt: int,
                        err: BaseException, reason: str) -> None:
        now = self._clock()
        with self._lock:
            if req.done or attempt != req.attempt_no:
                return
            if now > req.deadline:
                self._m_deadline.inc()
                self._resolve(req, error=DeadlineExceeded(
                    f"request {req.req_id} missed its deadline after "
                    f"{req.attempts} attempts (last: {err!r})"))
                return
            if req.attempts > self.max_retries:
                self._resolve(req, error=RetriesExhausted(
                    f"request {req.req_id} failed after {req.attempts} "
                    f"attempts (last on {rid}: {err!r})"))
                return
            backoff = self.retry_backoff_s * (2 ** (req.attempts - 1))
            self._retry_due.append([now + backoff, req, {rid}, reason])

    def _resolve(self, req: _Request, result=None,
                 error: BaseException | None = None) -> bool:
        """First writer wins; late successes are duplicate-suppressed."""
        with self._lock:
            if req.done:
                if error is None:
                    self.n_duplicates += 1
                    self._m_duplicates.inc()
                return False
            req.done = True
            self._requests.pop(req.req_id, None)
            self._m_pending.set(len(self._requests))
        if error is None:
            self._m_completed.inc()
            req.future.set_result(result)
        else:
            req.future.set_exception(error)
        return True

    # -------------------------------------------------------------- observer
    def _observer(self, rid: str):
        """Per-request completion hook on the replica's batcher: heartbeats,
        latency window, consecutive-error strikes (per batch, not per
        request — one poisoned batch of 8 is ONE strike)."""
        def observe(rec: dict) -> None:
            with self._lock:
                r = self._replicas.get(rid)
                if r is None:
                    return
                if rec["status"] == "ok":
                    self.monitor.beat(rid, step_time_s=rec["execute_s"])
                    r.strikes = 0
                    r.last_error_batch = None
                    r.lat.append(rec["latency_s"])
                elif rec["batch_id"] != r.last_error_batch:
                    r.last_error_batch = rec["batch_id"]
                    r.strikes += 1
        return observe

    # --------------------------------------------------------------- monitor
    def _monitor_loop(self) -> None:
        while not self._stop.wait(self.check_interval_s):
            try:
                self._tick()
            except Exception:           # the fleet must outlive its monitor
                pass

    def _tick(self) -> None:
        now = self._clock()
        with self._lock:
            active = self._active()
            # unsuspected replicas beat by proxy: a replica stops being
            # beaten once it is striking out or holding an attempt past its
            # window (a long-but-legitimate batch is NOT stale — the attempt
            # timeout, not wall silence, is what marks work as stuck)
            for r in active:
                if r.strikes == 0 and all(now <= q.attempt_expires
                                          for q in r.inflight.values()):
                    self.monitor.beat(r.rid)
            dead = set(self.monitor.dead())
            stragglers = (set(self.monitor.stragglers(self.straggler_factor))
                          if len(active) > 1 else set())
            to_evict = []
            for r in active:
                if r.rid in dead:
                    to_evict.append((r, "heartbeat_timeout"))
                elif r.strikes >= self.max_consecutive_errors:
                    to_evict.append((r, "consecutive_errors"))
                elif r.rid in stragglers:
                    to_evict.append((r, "straggler"))
        for r, reason in to_evict:
            self._evict(r, reason)

        # per-attempt timeouts + per-request deadlines
        with self._lock:
            reqs = list(self._requests.values())
        for req in reqs:
            timed_out = None
            with self._lock:
                if req.done:
                    continue
                if now > req.deadline:
                    self._m_deadline.inc()
                    self._resolve(req, error=DeadlineExceeded(
                        f"request {req.req_id} missed its deadline after "
                        f"{req.attempts} attempts"))
                    continue
                if (req.current_rid is not None
                        and now > req.attempt_expires
                        and req.attempts <= self.max_retries):
                    timed_out = req.current_rid
                    r = self._replicas.get(timed_out)
                    if r is not None and r.state == "active":
                        r.strikes += 1
                    req.attempt_no += 1     # supersede the stuck attempt
            if timed_out is not None:
                self._dispatch(req, exclude={timed_out},
                               reason="attempt_timeout")

        # due retries (backoff elapsed / parked for capacity)
        with self._lock:
            due = [e for e in self._retry_due if e[0] <= now]
            self._retry_due = [e for e in self._retry_due if e[0] > now]
        for _, req, exclude, reason in due:
            self._dispatch(req, exclude=exclude, reason=reason)

        # health probes: suspect-active (strikes but no verdict yet) and
        # evicted replicas awaiting re-admission
        with self._lock:
            probees = [r for r in self._replicas.values()
                       if (r.state == "evicted" or r.strikes > 0)]
        for r in probees:
            self._check_probe(r, now)

    # ----------------------------------------------------------- probe/evict
    def _check_probe(self, r: Replica, now: float) -> None:
        with self._lock:
            probe = r.probe
            if probe is None:
                if now >= r.next_probe and r.server is not None:
                    try:
                        fut = r.server.submit(self._canary_x)
                    except Exception:
                        r.next_probe = now + self.probe_interval_s
                        return
                    r.probe = (fut, now + self.probe_timeout_s)
                    self._registry.counter("fleet.probes",
                                           {"replica": r.rid}).inc()
                return
            fut, expires = probe
        if fut.done():
            err = fut.exception()
            ok = err is None and self._canary_ok(fut.result())
            with self._lock:
                r.probe = None
                r.next_probe = now + self.probe_interval_s
            if ok:
                if r.state == "evicted":
                    self._admit(r)
                else:                   # suspect replica vindicated
                    with self._lock:
                        r.strikes = 0
                        r.last_error_batch = None
                        self.monitor.beat(r.rid)
            else:
                self._registry.counter("fleet.probe_failures",
                                       {"replica": r.rid}).inc()
                if r.state == "active":
                    self._evict(r, "probe_failed")
        elif now > expires:
            # probe hung: drop it (a late answer is just a canary output);
            # an active replica that cannot answer a canary is evicted
            with self._lock:
                r.probe = None
                r.next_probe = now + self.probe_interval_s
            self._registry.counter("fleet.probe_failures",
                                   {"replica": r.rid}).inc()
            if r.state == "active":
                self._evict(r, "probe_timeout")

    def _canary_ok(self, out: dict) -> bool:
        exp = self._canary_expected
        return all(np.array_equal(exp[k], out[k]) for k in exp)

    def _evict(self, r: Replica, reason: str) -> None:
        with self._lock:
            if r.state != "active":
                return
            r.state = "evicted"
            r.evictions += 1
            r.evict_reason = reason
            r.strikes = 0
            r.probe = None
            r.next_probe = self._clock() + self.probe_interval_s
            self.monitor.forget(r.rid)
            migrated = [req for req in r.inflight.values() if not req.done]
            r.inflight.clear()
            for req in migrated:
                req.attempt_no += 1     # supersede the doomed attempt
            n_active = len(self._active())
            self._m_active.set(n_active)
        self._registry.counter("fleet.evictions", {"replica": r.rid}).inc()
        self._events.emit(
            "replica.evict", severity="error", replica=r.rid, reason=reason,
            migrated=len(migrated), active=n_active,
            message=f"replica {r.rid} evicted ({reason}); "
                    f"{len(migrated)} in-flight migrated, "
                    f"{n_active} active remain")
        self.flight.trigger("replica_evict", tenant=r.rid,
                            detail={"reason": reason,
                                    "migrated": len(migrated),
                                    "active_replicas": n_active})
        for req in migrated:
            self._dispatch(req, exclude={r.rid}, reason="replica_evicted")

    def _admit(self, r: Replica) -> None:
        with self._lock:
            if r.state == "active":
                return
            r.state = "active"
            r.strikes = 0
            r.last_error_batch = None
            r.evict_reason = None
            r.admissions += 1
            self.monitor.beat(r.rid)
            n_active = len(self._active())
            self._m_active.set(n_active)
        self._registry.counter("fleet.admissions", {"replica": r.rid}).inc()
        self._events.emit(
            "replica.admit", replica=r.rid, initial=False, active=n_active,
            message=f"replica {r.rid} re-admitted after warmup probe "
                    f"({n_active} active)")

    # ---------------------------------------------------------------- stats
    def replicas(self) -> dict[str, Replica]:
        with self._lock:
            return dict(self._replicas)

    def active_replicas(self) -> list[str]:
        with self._lock:
            return [r.rid for r in self._active()]

    def wait_active(self, rid: str, timeout_s: float = 10.0) -> bool:
        """Block until ``rid`` is active again (tests and orchestration);
        False on timeout."""
        t0 = time.monotonic()
        while time.monotonic() - t0 < timeout_s:
            with self._lock:
                r = self._replicas.get(rid)
                if r is not None and r.state == "active":
                    return True
            time.sleep(self.check_interval_s)
        return False

    def stats(self) -> dict:
        with self._lock:
            per = {}
            for rid, r in self._replicas.items():
                st = r.server.stats()
                per[rid] = {
                    "state": r.state,
                    "device": str(r.device),
                    "pending": r.server.pending,
                    "inflight": len(r.inflight),
                    "strikes": r.strikes,
                    "n_served": st["n_served"],
                    "n_batches": st["n_batches"],
                    "p99_ms": r.p99_s() * 1e3,
                    "evictions": r.evictions,
                    "admissions": r.admissions,
                    "evict_reason": r.evict_reason,
                    "step_ema_s": (self.monitor.hosts[rid].step_ema
                                   if rid in self.monitor.hosts else None),
                }
            return {
                "replicas": per,
                "n_replicas": self.n_replicas,
                "active": [r.rid for r in self._active()],
                "pending": len(self._requests),
                "submitted": self._m_submitted.value,
                "completed": self._m_completed.value,
                "rejected": self._m_rejected.value,
                "retries": self._m_retries.value,
                "duplicates_suppressed": self.n_duplicates,
                "deadline_exceeded": self._m_deadline.value,
            }

    def serve_metrics(self, host: str = "127.0.0.1", port: int = 0):
        """Mount the fleet's observability plane (shared registry + this
        fleet's flight recorder and event log)."""
        from repro.obs.export import ObsHTTPServer
        return ObsHTTPServer(self._registry, flight=self.flight,
                             events=self._events, host=host, port=port)

    # ---------------------------------------------------------------- close
    def close(self, wait: bool = True) -> None:
        """Stop the monitor, drain the replicas, fail anything left.  Every
        join is bounded: a replica wedged inside a fault (heal chaos first
        for a clean drain) cannot hang the fleet's own shutdown."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._stop.set()
        self._monitor_thread.join(timeout=5.0)
        for r in self.replicas().values():
            r.server.close(wait=wait,
                           timeout_s=5.0 if r.state == "active" else 0.5)
        with self._lock:
            leftovers = [req for req in self._requests.values()
                         if not req.done]
        for req in leftovers:
            self._resolve(req, error=FleetError(
                f"fleet closed with request {req.req_id} unresolved"))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _fresh_plan_cache():
    from repro.asm import PlanCache
    return PlanCache()
