"""Deterministic fault injection for the serving fleet.

Robustness claims need a falsifier: :class:`ChaosInjector` attaches to a
:class:`~repro.runtime.fleet.Fleet` through each replica Session's launch
hook (``Session.set_launch_hook``) and fires scripted faults at exact launch
counts — no randomness, so the ``make fleet-smoke`` chaos gate reproduces
bit-for-bit:

* ``kill(rid)``        — every launch on the replica raises (a crashed
  device: the fleet must evict and retry elsewhere);
* ``poison(rid, n)``   — the next ``n`` launches raise, then the replica is
  healthy again (a transient fault: strikes, maybe eviction, then the
  warmup probe re-admits it);
* ``hang(rid)``        — launches block until :meth:`heal` (a wedged DMA:
  the attempt timeout must fire and the request drain elsewhere while the
  hung thread is duplicate-suppressed on wakeup);
* ``slow(rid, delay)`` — launches sleep first (a straggler: the step-time
  EWMA climbs until the straggler detector evicts; also the knob the bench
  uses to inject a uniform launch cost so scaling measurements are
  device-bound rather than host-BLAS-bound).

Faults trigger *after* ``after_launches`` healthy launches on that replica
(0 = immediately), so "kill r1 mid-run" is expressible as data.  Every
fired fault is appended to :attr:`ChaosInjector.log` for the bench to
assert against.  ``heal(rid)`` clears faults and releases hangs.
"""
from __future__ import annotations

import threading
import time


class ChaosError(RuntimeError):
    """The injected fault — distinguishable from real executor errors."""


class ChaosInjector:
    """Scripted, launch-counted fault injection on fleet replicas."""

    def __init__(self, *, clock=time.monotonic, sleep=time.sleep):
        self._clock = clock
        self._sleep = sleep
        self._lock = threading.Lock()
        self._faults: dict[str, list[dict]] = {}    # rid -> active faults
        self._launches: dict[str, int] = {}         # rid -> launch count
        self._hang_gates: dict[str, threading.Event] = {}
        self._fleet = None
        self.log: list[dict] = []       # every fired fault, in order

    # ---------------------------------------------------------------- attach
    def attach(self, fleet) -> "ChaosInjector":
        """Install this injector's hook on every replica of ``fleet``
        (idempotent; replaces any previous hook)."""
        self._fleet = fleet
        for rid, r in fleet.replicas().items():
            r.session.set_launch_hook(self._hook(rid))
        return self

    def detach(self) -> None:
        if self._fleet is not None:
            for r in self._fleet.replicas().values():
                r.session.set_launch_hook(None)
        self.heal_all()

    # ---------------------------------------------------------------- faults
    def _arm(self, rid: str, fault: dict) -> None:
        with self._lock:
            self._faults.setdefault(rid, []).append(fault)

    def kill(self, rid: str, *, after_launches: int = 0) -> None:
        """Every launch on ``rid`` raises once armed — a dead replica."""
        self._arm(rid, {"kind": "kill", "after": after_launches})

    def poison(self, rid: str, n_launches: int = 1, *,
               after_launches: int = 0) -> None:
        """The next ``n_launches`` launches raise, then healthy again."""
        self._arm(rid, {"kind": "poison", "after": after_launches,
                        "left": int(n_launches)})

    def hang(self, rid: str, *, after_launches: int = 0) -> None:
        """Launches block until :meth:`heal`; the blocked launch then
        proceeds (its late result is the fleet's duplicate to suppress)."""
        with self._lock:
            self._hang_gates.setdefault(rid, threading.Event()).clear()
        self._arm(rid, {"kind": "hang", "after": after_launches})

    def slow(self, rid: str, delay_s: float, *, after_launches: int = 0,
             n_launches: int | None = None) -> None:
        """Launches sleep ``delay_s`` first; ``n_launches=None`` = forever."""
        self._arm(rid, {"kind": "slow", "after": after_launches,
                        "delay": float(delay_s),
                        "left": None if n_launches is None else int(n_launches)})

    def heal(self, rid: str) -> None:
        """Clear every fault on ``rid`` and release any hung launch."""
        with self._lock:
            self._faults.pop(rid, None)
            gate = self._hang_gates.get(rid)
        if gate is not None:
            gate.set()

    def heal_all(self) -> None:
        for rid in list(self._faults) + list(self._hang_gates):
            self.heal(rid)

    def fired(self, kind: str | None = None, rid: str | None = None) -> int:
        with self._lock:
            return sum(1 for e in self.log
                       if (kind is None or e["kind"] == kind)
                       and (rid is None or e["rid"] == rid))

    # ------------------------------------------------------------------ hook
    def _hook(self, rid: str):
        def on_launch(x) -> None:
            with self._lock:
                self._launches[rid] = n = self._launches.get(rid, 0) + 1
                todo = []
                for f in list(self._faults.get(rid, ())):
                    if f["after"] > 0:      # still counting healthy launches
                        f["after"] -= 1
                        continue
                    todo.append(f)
                    if f["kind"] == "poison":
                        f["left"] -= 1
                        if f["left"] <= 0:
                            self._faults[rid].remove(f)
                    elif f["kind"] == "slow" and f["left"] is not None:
                        f["left"] -= 1
                        if f["left"] <= 0:
                            self._faults[rid].remove(f)
                for f in todo:
                    self.log.append({"rid": rid, "kind": f["kind"],
                                     "launch": n})
                gate = self._hang_gates.get(rid)
            # fire OUTSIDE the lock: hangs and sleeps must not serialize
            # other replicas' hooks
            for f in todo:
                if f["kind"] == "slow":
                    self._sleep(f["delay"])
                elif f["kind"] == "hang":
                    if gate is not None:
                        gate.wait()
                elif f["kind"] == "kill":
                    raise ChaosError(f"chaos: replica {rid} killed "
                                     f"(launch {n})")
                elif f["kind"] == "poison":
                    raise ChaosError(f"chaos: replica {rid} poisoned launch "
                                     f"{n}")
        return on_launch
