"""Multi-tenant serving: many compiled models, one device, one front door.

The zoo makes artifacts cheap to hold; this module makes them cheap to
*serve together*.  A :class:`MultiServer` routes per-model request streams to
per-model :class:`~repro.runtime.session.Session`/:class:`~repro.runtime.
server.Server` pairs that share one device:

* **DDR partitioning** — every resident model's memory plan claims a
  disjoint DDR region (base offset + its planned ``peak_ddr_bytes``);
  ``add_model`` refuses a model whose footprint would overflow the device's
  (or a configured) budget, so co-residency is checked at admission time,
  not discovered as corruption at run time;
* **per-tenant SLO classes** — ``slo="gold" | "silver" | "best_effort"``
  maps to a target p99 per Server; the PR-6 SLO controller then walks each
  tenant's batch cap independently, and its queue-bound vs launch-bound
  shrink split tells an operator *which* tenant needs smaller batches vs
  more capacity;
* **admission control** — beyond ``max_queue`` pending requests a tenant's
  ``submit`` raises :class:`AdmissionError` instead of queueing (counted
  under ``serve.rejected{model=...}``): under overload the backlog is
  bounded and the SLO classes stay meaningful.

All per-model metrics are labelled (``serve.requests{model=vgg16}``), so one
registry snapshot shows every tenant side by side.
"""
from __future__ import annotations


class AdmissionError(RuntimeError):
    """submit() refused: the tenant's queue is at its admission bound."""


# SLO class -> target p99 (ms) handed to the per-tenant Server controller.
# best_effort runs uncontrolled (no target: largest batches, no shrink).
SLO_CLASSES = {"gold": 10.0, "silver": 50.0, "best_effort": None}


class MultiServer:
    """Serve several compiled models on one shared device."""

    def __init__(self, *, ddr_budget_bytes: int | None = None,
                 max_queue: int = 256, slo_classes: dict | None = None,
                 plan_cache_max_entries: int | None = None,
                 flight=None, events=None, burn_kw: dict | None = None):
        """``ddr_budget_bytes`` caps the summed planned footprints of all
        resident models (default: the shared device's ``ddr_bytes``).
        ``max_queue`` is the default per-tenant admission bound.
        ``plan_cache_max_entries`` rebounds the shared ``asm.PLAN_CACHE`` —
        a many-model host sets it to cap resident compiled artifacts.

        The host owns one observability plane for all tenants: ``flight`` is
        the shared :class:`~repro.obs.flight.FlightRecorder` (one is created
        when not given), ``events`` overrides the shared event log, and
        ``burn_kw`` forwards to every per-tenant
        :class:`~repro.obs.slo.BurnRateTracker` (window lengths, budget,
        alert threshold — tests shorten the windows)."""
        from repro.obs.events import EVENTS
        from repro.obs.flight import FlightRecorder
        from repro.obs.metrics import REGISTRY

        self.ddr_budget_bytes = ddr_budget_bytes
        self.max_queue = max_queue
        self.slo_classes = dict(SLO_CLASSES)
        if slo_classes:
            self.slo_classes.update(slo_classes)
        self._models: dict[str, dict] = {}
        self._device = None             # pinned by the first add_model
        self._registry = REGISTRY
        self._events = events if events is not None else EVENTS
        self.flight = flight if flight is not None else FlightRecorder()
        self._burn_kw = dict(burn_kw) if burn_kw else {}
        self._obs_http = None
        if plan_cache_max_entries is not None:
            from repro import asm
            asm.PLAN_CACHE.max_entries = plan_cache_max_entries

    # ---------------------------------------------------------------- models
    def _as_session(self, model, backend, session_kw):
        """Accept a stages.Compiled, a CompiledArtifact, or a live Session."""
        from repro.asm.artifact import CompiledArtifact
        from repro.runtime.session import Session

        if isinstance(model, Session):
            return model
        if isinstance(model, CompiledArtifact):
            return Session.from_artifact(model, backend=backend, **session_kw)
        art = getattr(model, "artifact", None)      # stages.Compiled
        if isinstance(art, CompiledArtifact):
            return Session.from_artifact(art, backend=backend, **session_kw)
        raise TypeError(f"cannot serve {type(model).__name__}; expected a "
                        "Session, CompiledArtifact, or stages.Compiled")

    def add_model(self, name: str, model, *, slo: str = "best_effort",
                  target_p99_ms: float | None = None,
                  max_queue: int | None = None, backend: str = "ref",
                  session_kw: dict | None = None, **server_kw):
        """Admit one model under ``name`` and start serving it.

        ``slo`` picks the tenant's SLO class (an explicit ``target_p99_ms``
        overrides the class target).  Raises :class:`MemoryError` when the
        model's planned DDR footprint does not fit the remaining partition
        budget, and ``ValueError`` on name/device conflicts."""
        if name in self._models:
            raise ValueError(f"model {name!r} already registered")
        if slo not in self.slo_classes:
            raise ValueError(f"unknown SLO class {slo!r}; have "
                             f"{sorted(self.slo_classes)}")
        session = self._as_session(model, backend, session_kw or {})
        if self._device is None:
            self._device = session.device
        elif session.device.name != self._device.name:
            raise ValueError(
                f"model {name!r} targets device {session.device.name!r} but "
                f"this server hosts {self._device.name!r}")

        budget = self.ddr_budget_bytes or self._device.ddr_bytes
        used = sum(m["ddr_bytes"] for m in self._models.values())
        need = int(session.artifact.peak_ddr_bytes)
        if used + need > budget:
            raise MemoryError(
                f"model {name!r} needs {need} B of DDR but only "
                f"{budget - used} of {budget} B remain "
                f"({len(self._models)} resident models)")

        if target_p99_ms is None:
            target_p99_ms = self.slo_classes[slo]
        # per-tenant error-budget burn tracking: every completed request
        # feeds the tracker through the batcher's observer hook; an alert
        # (fast AND slow windows burning hot) freezes the flight ring
        burn = None
        observers = []
        if target_p99_ms is not None:
            from repro.obs.slo import BurnRateTracker
            burn = BurnRateTracker(
                target_p99_ms, labels={"model": name, "class": slo},
                registry=self._registry, events=self._events,
                on_alert=lambda tracker, fast, slow, _n=name:
                    self.flight.trigger(
                        "slo_violation", tenant=_n,
                        detail={"fast_burn": fast, "slow_burn": slow,
                                "target_p99_ms": tracker.target_ms}),
                **self._burn_kw)
            observers.append(burn.observer())
        server = session.serve(target_p99_ms=target_p99_ms,
                               labels={"model": name}, flight=self.flight,
                               events=self._events, observers=observers,
                               **server_kw)
        self.flight.set_context(name, slo_class=slo)
        self._models[name] = {
            "session": session, "server": server, "slo": slo,
            "burn": burn,
            "ddr_base": used, "ddr_bytes": need,
            "max_queue": max_queue if max_queue is not None
            else self.max_queue,
        }
        self._events.emit("tenant.admit", model=name, slo=slo,
                          message=f"model {name!r} admitted "
                                  f"({need} B DDR, class {slo})",
                          ddr_bytes=need, ddr_base=used)
        if self._obs_http is not None:
            self._obs_http.add_explain(name, session.explain)
        return server

    def remove_model(self, name: str, wait: bool = True) -> None:
        m = self._models.pop(name)
        m["server"].close(wait=wait)
        if self._obs_http is not None:
            self._obs_http.remove_explain(name)
        self._events.emit("tenant.remove", model=name,
                          message=f"model {name!r} removed")
        # re-pack the partition: survivors keep their order, bases close up
        base = 0
        for m in self._models.values():
            m["ddr_base"] = base
            base += m["ddr_bytes"]

    def models(self) -> list[str]:
        return list(self._models)

    def attach_drift(self, name: str, **kw):
        """Attach a per-tenant :class:`~repro.obs.drift.DriftProfiler` to
        ``name``'s session, labelled ``{model: name}`` so its gauges land
        next to the tenant's serve metrics on the scrape endpoint.  The
        flight recorder then stamps the tenant's records with the latest
        drift summary.  Returns the profiler (``prepare()`` it before a
        timed window)."""
        from repro.obs.drift import DriftProfiler
        session = self._models[name]["session"]
        kw.setdefault("labels", {"model": name})
        prof = DriftProfiler.from_session(session, **kw)
        session.attach_drift(prof)
        return prof

    # ---------------------------------------------------------------- client
    def submit(self, name: str, x):
        """Enqueue one request for tenant ``name``; returns a future.

        Raises :class:`AdmissionError` (and counts it) when the tenant's
        queue is at its admission bound — overload sheds load here instead
        of letting one hot model starve every SLO."""
        m = self._models[name]
        pending = m["server"]._batcher.pending
        if pending >= m["max_queue"]:
            self._registry.counter("serve.rejected",
                                   {"model": name}).inc()
            self._events.emit("admission.reject", severity="warning",
                              model=name, pending=pending,
                              bound=m["max_queue"],
                              message=f"model {name!r} queue at admission "
                                      f"bound ({pending} pending)")
            self.flight.note_rejection(name, pending, m["max_queue"])
            raise AdmissionError(
                f"model {name!r} queue at admission bound "
                f"({m['max_queue']} pending)")
        return m["server"].submit(x)

    # --------------------------------------------------------------- reports
    def ddr_partition(self) -> list[dict]:
        """The device-DDR carve-up: one disjoint [base, base+bytes) region
        per resident model, in admission order."""
        return [{"model": name, "base": m["ddr_base"],
                 "bytes": m["ddr_bytes"], "slo": m["slo"]}
                for name, m in self._models.items()]

    def stats(self) -> dict:
        budget = (self.ddr_budget_bytes
                  or (self._device.ddr_bytes if self._device else 0))
        # per-tenant counter families come straight off the registry's label
        # index — no hand-formatted "name{model=...}" lookups
        per_tenant = {}
        for family in ("serve.rejected", "serve.requests", "serve.errors"):
            by_model = self._registry.labelled(family)
            per_tenant[family] = {
                name: (by_model[name].value if name in by_model else 0.0)
                for name in self._models}
        rejected = per_tenant["serve.rejected"]
        return {
            "models": {name: m["server"].stats()
                       for name, m in self._models.items()},
            "slo": {name: m["slo"] for name, m in self._models.items()},
            "rejected": rejected,
            "requests": per_tenant["serve.requests"],
            "errors": per_tenant["serve.errors"],
            "burn": {name: (m["burn"].burn_rates() if m["burn"] else None)
                     for name, m in self._models.items()},
            "ddr_partition": self.ddr_partition(),
            "ddr_budget_bytes": budget,
            "ddr_used_bytes": sum(m["ddr_bytes"]
                                  for m in self._models.values()),
        }

    def serve_metrics(self, host: str = "127.0.0.1", port: int = 0):
        """Mount the OpenMetrics scrape endpoint for the whole host: every
        tenant's labelled series, the shared flight recorder, and the event
        log behind one ``/metrics`` (+ ``/flight``, ``/events``,
        ``/snapshot``, per-tenant ``/explain/<model>``).  Returns the running
        :class:`~repro.obs.export.ObsHTTPServer`; closed with the host."""
        from repro.obs.export import ObsHTTPServer
        if self._obs_http is None:
            self._obs_http = ObsHTTPServer(
                self._registry, flight=self.flight, events=self._events,
                host=host, port=port)
        # (re)register every resident tenant's explain provider — models
        # admitted after the endpoint came up are picked up on the next call
        for name, m in self._models.items():
            self._obs_http.add_explain(name, m["session"].explain)
        return self._obs_http

    def close(self, wait: bool = True) -> None:
        for m in self._models.values():
            m["server"].close(wait=wait)
        if self._obs_http is not None:
            self._obs_http.close()
            self._obs_http = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
