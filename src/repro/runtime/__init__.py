"""Runtime supporter (paper §1, §3.2): serve compiled artifacts end to end.

DNNVM is "an integration of optimizers ..., an assembler, a runtime supporter
and a validation environment"; this package is the runtime supporter — the
host-side layer that feeds the accelerator:

* :class:`Session`         — owns one compiled model (artifact via PlanCache,
                             executor, memory plan); ``run`` / ``run_batch``.
* :class:`DynamicBatcher`  — async request queue with max-batch / max-latency
                             knobs; one worker flushes queued images as one
                             batched launch.
* :class:`Server`          — Session + batcher + latency/batch metrics.
* :class:`MultiServer`     — many models on one device: DDR partitioning,
                             per-tenant SLO classes, admission control.
* :class:`Fleet`           — N data-parallel Session replicas across
                             ``jax.devices()``: health-driven failover,
                             bounded retries, elastic re-admission.
* :class:`ChaosInjector`   — deterministic fault injection (kill / poison /
                             hang / slow) on fleet replicas, for the chaos
                             gate.
* :func:`pipeline_report`  — engine-level cross-request schedule: the
                             artifact's addressed instruction stream,
                             software-pipelined across requests on the time
                             wheel and audited by the memory-hazard oracle.
"""
from repro.runtime.batching import BatcherClosed, DynamicBatcher
from repro.runtime.chaos import ChaosError, ChaosInjector
from repro.runtime.fleet import (DeadlineExceeded, Fleet, FleetError,
                                 RetriesExhausted)
from repro.runtime.multitenant import (SLO_CLASSES, AdmissionError,
                                       MultiServer)
from repro.runtime.schedule import (PipelineReport, pipeline_report,
                                    pipeline_stream)
from repro.runtime.server import Server
from repro.runtime.session import Session

__all__ = ["AdmissionError", "BatcherClosed", "ChaosError", "ChaosInjector",
           "DeadlineExceeded", "DynamicBatcher", "Fleet", "FleetError",
           "MultiServer", "PipelineReport", "RetriesExhausted", "SLO_CLASSES",
           "Server", "Session", "pipeline_report", "pipeline_stream"]
