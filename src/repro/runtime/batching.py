"""Async dynamic-batching front end for the runtime supporter.

Requests arrive one image at a time; the accelerator is happiest launching
once per *batch* (one Pallas grid covers all N images).  The
:class:`DynamicBatcher` sits between the two: ``submit`` enqueues a request
and returns a future immediately, a single worker drains the queue into
batches bounded by two knobs —

* ``max_batch``     — never launch more than this many images at once;
* ``max_latency_s`` — never hold the *oldest* queued request longer than
  this before flushing a partial batch.

The worker owns all executor calls (JAX dispatch stays single-threaded);
completion is delivered through ``concurrent.futures.Future``, so callers can
block, poll, or chain callbacks.  ``close()`` drains outstanding requests and
joins the worker; submitting after close raises :class:`BatcherClosed`.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import DEFAULT_BATCH_BUCKETS


class BatcherClosed(RuntimeError):
    """submit() after close()."""


class DynamicBatcher:
    def __init__(self, run_batch, *, max_batch: int = 8,
                 max_latency_s: float = 2e-3, clock=time.monotonic,
                 latency_window: int = 16384, registry=None, tracer=None,
                 labels: dict | None = None, observers=None):
        """``run_batch(xs) -> list[result]`` executes one batch (one result
        per request, same order).  ``latency_window`` bounds the retained
        latency samples (a long-running server must not grow without bound).

        Besides end-to-end ``latencies`` (submit -> result), the batcher keeps
        ``queue_waits`` (submit -> batch formed, per request) and
        ``execute_s`` (batch formed -> results back, per batch) so an SLO
        controller can tell a queue-bound p99 violation from a launch-bound
        one.  When the shared tracer is enabled, each request gets a
        queue-wait + execute track and each batch a batch-track span.
        ``labels`` tags every emitted metric (multi-tenant serving labels
        per-model: ``serve.requests{model=vgg16}``).

        ``observers`` are callables invoked on the worker thread once per
        request after its batch completes (and on batch failure), with one
        record dict: ``req_id``, ``submit_s``, ``queue_wait_s``,
        ``execute_s``, ``latency_s``, ``batch_id``, ``batch_size``,
        ``batch_members``, ``status`` ("ok" | "error"), ``error``.  The
        flight recorder and the SLO burn-rate tracker plug in here; observer
        exceptions are swallowed — observability must not break serving."""
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._run_batch = run_batch
        self.max_batch = max_batch
        self.max_latency_s = max_latency_s
        self._clock = clock
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: collections.deque = collections.deque()
        self._closed = False
        self._seq = 0                    # request sequence id (trace tracks)
        self._n_batches = 0
        self.batch_sizes: collections.Counter = collections.Counter()
        self.n_served = 0
        # submit -> result per request, most recent latency_window samples;
        # recorded BEFORE the future resolves, so a caller reading stats
        # right after result() returns never sees a partial sample set
        self.latencies: collections.deque = collections.deque(
            maxlen=latency_window)
        # submit -> batch formation, per request (same window discipline)
        self.queue_waits: collections.deque = collections.deque(
            maxlen=latency_window)
        # batch formation -> results back, per BATCH
        self.execute_s: collections.deque = collections.deque(
            maxlen=latency_window)
        self._registry = (registry if registry is not None
                          else obs_metrics.REGISTRY)
        self._tracer = tracer if tracer is not None else obs_trace.TRACER
        self.labels = dict(labels) if labels else None
        self._observers = list(observers) if observers else []
        self._m_requests = self._registry.counter("serve.requests", self.labels)
        self._m_batches = self._registry.counter("serve.batches", self.labels)
        self._m_errors = self._registry.counter("serve.errors", self.labels)
        self._m_depth = self._registry.gauge("serve.queue_depth", self.labels)
        self._m_batch = self._registry.histogram("serve.batch_size",
                                                 DEFAULT_BATCH_BUCKETS,
                                                 labels=self.labels)
        self._m_latency = self._registry.histogram("serve.latency_ms",
                                                   labels=self.labels)
        self._m_wait = self._registry.histogram("serve.queue_wait_ms",
                                                labels=self.labels)
        self._m_exec = self._registry.histogram("serve.execute_ms",
                                                labels=self.labels)
        self._worker = threading.Thread(target=self._loop, daemon=True,
                                        name="dnnvm-batcher")
        self._worker.start()

    # --------------------------------------------------------------- client
    def submit(self, x) -> Future:
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise BatcherClosed("batcher is closed")
            self._seq += 1
            self._queue.append((x, fut, self._clock(), self._seq))
            self._m_depth.set(len(self._queue))
            self._cv.notify_all()
        self._m_requests.inc()
        return fut

    def set_max_batch(self, n: int) -> None:
        """Retarget the batch-size cap (latency-SLO-aware serving shrinks and
        regrows it at run time).  Takes effect for the next formed batch; the
        worker is woken in case the queue already satisfies the new cap."""
        if n < 1:
            raise ValueError("max_batch must be >= 1")
        with self._cv:
            self.max_batch = n
            self._cv.notify_all()

    def close(self, wait: bool = True, timeout_s: float | None = None) -> None:
        """Flush whatever is queued, then stop the worker.  Idempotent; with
        an empty queue this returns as soon as the worker observes the flag.
        ``timeout_s`` bounds the join (the fleet closes possibly-wedged
        replicas without hanging its own shutdown)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if wait:
            self._worker.join(timeout=timeout_s)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    # --------------------------------------------------------------- worker
    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:      # closed and drained
                    return
                # batch-forming window: flush when full, when the OLDEST
                # request has waited max_latency_s since submit (it may
                # already have waited out a previous batch's execution), or
                # at shutdown
                deadline = self._queue[0][2] + self.max_latency_s
                while (len(self._queue) < self.max_batch
                       and not self._closed):
                    remaining = deadline - self._clock()
                    if remaining <= 0:
                        break
                    self._cv.wait(timeout=remaining)
                batch = [self._queue.popleft()
                         for _ in range(min(self.max_batch,
                                            len(self._queue)))]
                self._m_depth.set(len(self._queue))
            self._execute(batch)

    def add_observer(self, fn) -> None:
        """Register a per-request completion observer (see ``observers``)."""
        self._observers.append(fn)

    def _notify(self, batch, t_form: float, t_done: float, status: str,
                error: str | None) -> None:
        if not self._observers:
            return
        members = tuple(seq for _, _, _, seq in batch)
        bid = self._n_batches
        for _, _, t0, seq in batch:
            rec = {"req_id": seq, "submit_s": t0,
                   "queue_wait_s": t_form - t0,
                   "execute_s": t_done - t_form,
                   "latency_s": t_done - t0,
                   "batch_id": bid, "batch_size": len(batch),
                   "batch_members": members,
                   "status": status, "error": error}
            for fn in self._observers:
                try:
                    fn(rec)
                except Exception:    # observers must never break serving
                    pass

    def _execute(self, batch) -> None:
        t_form = self._clock()
        xs = [x for x, _, _, _ in batch]
        try:
            results = self._run_batch(xs)
        except Exception as e:  # surface the failure on every waiting future
            self._m_errors.inc(len(batch))
            self._notify(batch, t_form, self._clock(), "error",
                         f"{type(e).__name__}: {e}")
            for _, fut, _, _ in batch:
                fut.set_exception(e)
            return
        t_done = self._clock()
        self.batch_sizes[len(batch)] += 1
        self.n_served += len(batch)
        self._n_batches += 1
        self.execute_s.append(t_done - t_form)
        self._m_batches.inc()
        self._m_batch.observe(len(batch))
        self._m_exec.observe((t_done - t_form) * 1e3)
        for _, _, t0, _ in batch:
            self.queue_waits.append(t_form - t0)
            self.latencies.append(t_done - t0)
            self._m_wait.observe((t_form - t0) * 1e3)
            self._m_latency.observe((t_done - t0) * 1e3)
        for (_, fut, _, _), res in zip(batch, results):
            fut.set_result(res)
        self._notify(batch, t_form, t_done, "ok", None)
        if self._tracer.enabled:
            self._trace_batch(batch, t_form, t_done, self._clock())

    def _trace_batch(self, batch, t_form: float, t_done: float,
                     t_resolved: float) -> None:
        """Emit serve spans for one completed batch: per-request queue-wait +
        execute on a ``req<seq>`` track, plus batch-form / launch / resolve on
        the shared batch track.  Timestamps are the batcher's own clock
        (``time.monotonic`` by default — the tracer's default clock too, so
        these land on the same axis as compile spans)."""
        tr = self._tracer
        bid = self._n_batches
        for _, _, t0, seq in batch:
            track = f"req{seq}"
            tr.add_span("queue_wait", t0, t_form, cat="serve", track=track,
                        args={"batch": bid})
            tr.add_span("execute", t_form, t_done, cat="serve", track=track,
                        args={"batch": bid})
        oldest = min(t0 for _, _, t0, _ in batch)
        tr.add_span("batch_form", oldest, t_form, cat="serve", track="batch",
                    args={"batch": bid, "size": len(batch)})
        tr.add_span("batch_execute", t_form, t_done, cat="serve",
                    track="batch", args={"batch": bid, "size": len(batch)})
        tr.add_span("resolve", t_done, t_resolved, cat="serve", track="batch",
                    args={"batch": bid})
