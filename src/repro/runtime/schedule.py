"""Engine-level schedule view: cross-request software pipelining (Fig. 8/9).

The paper's 1.26x comes from pipelining coarse-grained instructions across
engines: while CONV(t) runs, the Dispatcher already issues LOAD(t+1) into the
other ping/pong bank.  A serving runtime extends the same idea across
*requests*: request i+1's LOADs stream in while request i computes, and the
steady-state throughput is bound by the busiest engine, not by the
single-request latency.

:func:`pipeline_stream` builds that schedule from a compiled artifact's
addressed instruction stream — it replicates the stream once per request and
threads exactly the dependency bits the hardware would need:

* **ping/pong continuation** — request r's first LOADs into group g's input
  banks wait for request r-1's last consumer of the same bank (the in-bank
  wrap-around of ``isa.emit_group``, continued across the request boundary);
* **out-bank continuation** — request r's first computes of group g wait for
  request r-1's last SAVE draining the same output bank;
* **DDR ping/pong** — activation buffers are double-buffered across requests
  (request r uses DDR slot ``r % ddr_slots``), so write-after-read conflicts
  only arise at distance ``ddr_slots``: request r's first SAVE of group g
  waits for request r-ddr_slots's last LOAD/SAVE touching the same region,
  and request r's reads of pre-loaded (input) regions wait for any recycled
  write of request r-ddr_slots to retire.

The result is *checkable*: the stream carries real addresses and banks, so
``simulator.check`` audits it with the same memory-hazard oracle that audits
single-request plans — :func:`pipeline_report` hard-errors on any hazard.
"""
from __future__ import annotations

import dataclasses

from repro.core import simulator
from repro.core.isa import COMPUTE_ENGINES, ENGINES, Instr


def _overlaps(a0: int, al: int, b0: int, bl: int) -> bool:
    return a0 < b0 + bl and b0 < a0 + al


def _base_bookkeeping(instrs: list[Instr], banks: list[dict]) -> dict:
    """Per-group resource hand-off points of one request's stream."""
    tiles = simulator.tile_accesses(instrs)

    in_cont: dict[tuple, int] = {}      # (gid, in_bank)  -> consumer iid
    out_cont: dict[tuple, int] = {}     # (gid, out_bank) -> last SAVE iid
    first_receiver: dict[tuple, int] = {}  # (gid, tile) -> iid taking out-bank dep
    for (gid, tile), t in sorted(tiles.items()):
        consumer = (t["compute"][-1] if t["compute"]
                    else t["save"][-1] if t["save"] else None)
        if t["load"] and t["load"][0].bank >= 0 and consumer is not None:
            in_cont[(gid, t["load"][0].bank)] = consumer.iid  # last tile wins
        if t["save"] and t["save"][0].bank >= 0:
            out_cont[(gid, t["save"][0].bank)] = t["save"][-1].iid
        recv = t["compute"][0] if t["compute"] else \
            (t["save"][0] if t["save"] else None)
        if recv is not None:
            first_receiver[(gid, tile)] = recv.iid

    # DDR regions: per-group output region + conflict targets (EVERY LOAD /
    # SAVE of the stream overlapping that region — address reuse means these
    # may belong to *any* group, including pre-loaded inputs).  All of them,
    # not just the last: the merged pipelined program may legally reorder
    # instructions of different requests, so no single target is guaranteed
    # to retire last.
    out_region: dict[int, tuple[int, int]] = {}
    for ins in instrs:
        if ins.opcode == "SAVE" and ins.ddr_addr >= 0:
            out_region.setdefault(ins.group_id, (ins.ddr_addr, ins.ddr_len))
    conflicts: dict[int, list[int]] = {}
    for gid, (a, ln) in out_region.items():
        conflicts[gid] = [i.iid for i in instrs
                          if i.opcode in ("LOAD", "SAVE") and i.ddr_addr >= 0
                          and _overlaps(i.ddr_addr, i.ddr_len, a, ln)]

    # pre-loaded reads: a LOAD whose region no earlier instruction of the
    # same request wrote reads data staged by the host (the graph input).
    # Address recycling means a *later* group of an earlier same-parity
    # request may write over it, so each such LOAD waits for every
    # overlapping SAVE of request r - ddr_slots to retire.
    pre_guard: dict[int, list[int]] = {}
    saves = [i for i in instrs if i.opcode == "SAVE" and i.ddr_addr >= 0]
    for ins in instrs:
        if ins.opcode != "LOAD" or ins.ddr_addr < 0:
            continue
        earlier = [s for s in saves if s.iid < ins.iid
                   and _overlaps(s.ddr_addr, s.ddr_len,
                                 ins.ddr_addr, ins.ddr_len)]
        if earlier:
            continue                       # produced in-request; entry deps +
                                           # SAVE-side conflict bits cover it
        guards = [s.iid for s in saves
                  if _overlaps(s.ddr_addr, s.ddr_len,
                               ins.ddr_addr, ins.ddr_len)]
        if guards:
            pre_guard[ins.iid] = guards

    n_bi = {g: b.get("n_in", 1) for g, b in enumerate(banks)}
    n_bo = {g: b.get("n_out", 1) for g, b in enumerate(banks)}
    return {"in_cont": in_cont, "out_cont": out_cont,
            "first_receiver": first_receiver,
            "conflicts": conflicts, "pre_guard": pre_guard,
            "n_bi": n_bi, "n_bo": n_bo}


def _interleave(instrs: list[Instr], n_base: int) -> list[Instr]:
    """Software-pipeline the merged program: list-schedule the request-major
    stream into the order a cross-request dispatcher would issue.

    Engines retire instructions in *program* order (``simulator.run_times``),
    so a request-major concatenation lets request r's very first LOAD queue
    behind request r-1's LAST load — zero overlap.  The runtime owns the
    merged program, so it list-schedules instead: each request's *own*
    instruction order is preserved (the per-request stream order carries
    implicit semantics — entry deps sit only on a group's first tile, later
    tiles ride the engine's in-order retirement), and among the R request
    heads whose dependencies are all emitted, the dispatcher issues the one
    that can *start* earliest on the time wheel (ties: earliest stream
    position).  Time-awareness matters: emitting a dependency-clear but
    far-future instruction early would head-of-line-block its whole engine
    for every later-emitted request.  Dependencies are preserved exactly;
    only issue order changes.
    """
    n = len(instrs)
    n_req = n // n_base
    emitted = [False] * n                  # global position == iid
    done = [0] * n                         # retire time of emitted instrs
    engine_free: dict[str, int] = {e: 0 for e in ENGINES}
    pos = [r * n_base for r in range(n_req)]
    out: list[Instr] = []
    while len(out) < n:
        best = None
        for r in range(n_req):
            if pos[r] >= (r + 1) * n_base:
                continue
            ins = instrs[pos[r]]
            if any(not emitted[d] for d in ins.deps):
                continue
            start = max(engine_free[ins.engine],
                        max((done[d] for d in ins.deps), default=0))
            key = (start, pos[r] - r * n_base, r)
            if best is None or key < best[0]:
                best = (key, r, ins, start)
        assert best is not None, "pipeline stream deadlocked (dep cycle?)"
        _, r, ins, start = best
        emitted[ins.iid] = True
        done[ins.iid] = start + ins.cycles
        engine_free[ins.engine] = start + ins.cycles
        pos[r] += 1
        out.append(ins)
    return out


def pipeline_stream(art, n_requests: int, ddr_slots: int = 2,
                    interleave: bool = True, _bk_out: dict | None = None
                    ) -> list[Instr]:
    """Replicate ``art.instrs`` per request with cross-request dependency bits
    and per-request DDR slot offsets, then software-pipeline the merged
    program order.  ``simulator.check``-clean by design.

    ``_bk_out``: caller-provided dict filled with the per-request resource
    bookkeeping (``pipeline_report`` reads the pre-load guard count from it
    without recomputing the pass)."""
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    if ddr_slots < 1:
        raise ValueError("ddr_slots must be >= 1")
    base = art.instrs
    n_base = len(base)
    n_groups = len(art.exec_items)
    banks = art.mem_summary.get("banks", [])
    bk = _base_bookkeeping(base, banks)
    if _bk_out is not None:
        _bk_out.update(bk)

    from repro.hw import get_device
    align = get_device(art.device).ddr_align if art.device else 64
    top = max((i.ddr_addr + i.ddr_len for i in base if i.ddr_addr >= 0),
              default=0)
    slot_stride = -(-top // max(1, align)) * max(1, align)

    out: list[Instr] = []
    for r in range(n_requests):
        off = r * n_base
        poff = (r - 1) * n_base
        qoff = (r - ddr_slots) * n_base
        for ins in base:
            deps = [d + off for d in ins.deps]
            g, t = ins.group_id, ins.tile
            if r >= 1 and g >= 0 and t >= 0:
                if (ins.opcode == "LOAD" and ins.bank >= 0
                        and t < bk["n_bi"].get(g, 1)):
                    cont = bk["in_cont"].get((g, ins.bank))
                    if cont is not None:
                        deps.append(cont + poff)
                if (bk["first_receiver"].get((g, t)) == ins.iid
                        and t < bk["n_bo"].get(g, 1)):
                    cont = bk["out_cont"].get(
                        (g, t % max(1, bk["n_bo"].get(g, 1))))
                    if cont is not None:
                        deps.append(cont + poff)
            if r >= ddr_slots:
                if ins.opcode == "SAVE" and g >= 0:
                    deps.extend(d + qoff for d in bk["conflicts"].get(g, ()))
                deps.extend(d + qoff for d in bk["pre_guard"].get(ins.iid, ()))
            addr = ins.ddr_addr
            if addr >= 0:
                addr += (r % ddr_slots) * slot_stride
            out.append(Instr(
                ins.iid + off, ins.engine, ins.opcode, ins.cycles,
                tuple(sorted(set(deps))), tag=f"r{r}:{ins.tag}",
                ddr_addr=addr, ddr_len=ins.ddr_len, bank=ins.bank,
                group_id=(g + r * n_groups if g >= 0 else -1), tile=t))
    return _interleave(out, n_base) if interleave else out


# ------------------------------------------------------- ddr_slots selection
def choose_ddr_slots(art, profile=None, *, max_slots: int = 4,
                     default: int = 2) -> int:
    """Pick the DDR double-buffer slot depth from the stream's DRAM/compute
    ratio instead of the fixed default.

    Request r's SAVEs must wait for request r-ddr_slots's conflicting
    LOAD/SAVEs to retire, so when a request spends more time on the DDR
    channels than on its busiest compute engine the distance-2 guard becomes
    the pipeline's critical path — each extra slot pushes the write-after-
    read horizon one request further at the cost of one more activation
    footprint in DDR.  Compute-bound streams keep the classic ping/pong
    ``default``.

    ``profile`` (a calibrated ``tune.DeviceProfile``) rescales the DDR busy
    cycles by measured-vs-modeled bandwidth: the instruction cycles were
    emitted under the hand-written device model, but the slot decision should
    reflect the bandwidth this machine actually delivers.
    """
    import math

    from repro.core.isa import COMPUTE_ENGINES
    from repro.hw import get_device

    rep = simulator.run(art.instrs)
    busy = rep.busy_cycles
    ddr = busy.get("DDR_RD", 0) + busy.get("DDR_WR", 0)
    comp = max((busy.get(e, 0) for e in COMPUTE_ENGINES), default=0)
    if profile is not None and art.device:
        eff = getattr(profile, "dram_rd_bytes_per_s", 0.0)
        if eff and math.isfinite(eff):
            ddr *= get_device(art.device).dram_bw_bytes_per_s / eff
    if comp <= 0 or ddr <= comp:
        return default
    return int(min(max_slots, max(default, math.ceil(ddr / comp) + 1)))


# ------------------------------------------------------------------- report
@dataclasses.dataclass
class PipelineReport:
    """Modeled steady-state serving behaviour of a pipelined request stream."""
    n_requests: int
    total_cycles: int
    single_request_cycles: int     # time-wheel latency of one request alone
    busy_cycles: dict              # engine -> busy cycles over the whole run
    request_windows: list          # per request (first start, last end) cycles
    ddr_slots: int
    n_instructions: int
    # True when the artifact's memory plan pinned the network input's DDR
    # region out of the reuse pool: no recycled write ever lands on a
    # pre-loaded region, so the distance-ddr_slots pre-load guard vanishes
    # from the stream and request r+1's first LOADs issue earlier.
    pin_input: bool = False
    # pre-load guard dependencies per pipelined request (0 when the plan pins
    # the input region): each is an edge from request r's pre-loaded LOAD to
    # a recycled SAVE of request r-ddr_slots
    n_preload_guards: int = 0
    # how ddr_slots was decided: "explicit" (caller-passed), "auto" (stream
    # DRAM/compute ratio under the hand-written device model), or "profile"
    # (ratio rescaled by the calibrated profile's measured bandwidth)
    ddr_slots_source: str = "explicit"
    engine_timeline: dict = dataclasses.field(default_factory=dict)
    # engine -> [(start, end, opcode, "r<i>:<node>@t<k>")] in schedule order
    # (simulator.engine_windows over the pipelined stream — the Fig. 8/9
    # gantt; LOAD rows of request i+1 sit inside CONV rows of request i)

    @property
    def sequential_cycles(self) -> int:
        return self.n_requests * self.single_request_cycles

    @property
    def modeled_speedup(self) -> float:
        """Pipelined vs strictly sequential back-to-back execution."""
        return self.sequential_cycles / max(1, self.total_cycles)

    @property
    def overlap(self) -> float:
        """Fraction of sequential time hidden by cross-request pipelining."""
        return 1.0 - self.total_cycles / max(1, self.sequential_cycles)

    def utilization(self, engine: str | None = None):
        if engine is not None:
            return self.busy_cycles.get(engine, 0) / max(1, self.total_cycles)
        return {e: self.utilization(e) for e in self.busy_cycles}

    @property
    def bottleneck(self) -> str:
        return max(self.busy_cycles, key=lambda e: self.busy_cycles[e])

    def throughput_images_per_s(self, freq_hz: float) -> float:
        return self.n_requests * freq_hz / max(1, self.total_cycles)

    def request_latency_cycles(self) -> list:
        return [e - s for s, e in self.request_windows]


def pipeline_report(art, n_requests: int, ddr_slots: int | None = 2,
                    profile=None) -> PipelineReport:
    """Schedule ``n_requests`` pipelined copies of the artifact's stream on
    the time wheel, audit the memory plan (raises
    :class:`~repro.core.simulator.MemoryHazardError` on any hazard), and
    report per-engine utilization + modeled cross-request overlap.

    ``ddr_slots=None`` picks the slot depth from the stream's DRAM/compute
    ratio (:func:`choose_ddr_slots`), rescaled by ``profile`` when given —
    the report records which path decided it (``ddr_slots_source``)."""
    source = "explicit"
    if ddr_slots is None:
        ddr_slots = choose_ddr_slots(art, profile)
        source = "profile" if profile is not None else "auto"
    bk: dict = {}
    stream = pipeline_stream(art, n_requests, ddr_slots=ddr_slots, _bk_out=bk)
    rep, times = simulator.run_times(stream)
    hazards = simulator.memory_hazards(stream, times)
    # The bank audit keys windows by (group, bank), and the stream renumbers
    # groups per request (DDR regions need that), which would hide
    # cross-request collisions on the same physical bank.  Re-run it with the
    # base group ids restored (tiles offset per request to stay distinct).
    n_base = len(art.instrs)
    n_groups = max(1, len(art.exec_items))
    tile_stride = 1 + max((i.tile for i in art.instrs), default=0)
    relabelled = [dataclasses.replace(
        i, group_id=i.group_id % n_groups,
        tile=i.tile + (i.iid // n_base) * tile_stride)
        for i in stream if i.group_id >= 0 and i.tile >= 0]
    hazards += simulator.bank_hazards(relabelled, times)
    if hazards:
        raise simulator.MemoryHazardError(
            f"pipelined stream has {len(hazards)} hazard(s):\n  "
            + "\n  ".join(hazards[:10]))
    spans: dict[int, list] = {}
    for ins in stream:   # interleaved issue order: bucket by request id
        spans.setdefault(ins.iid // n_base, []).append(times[ins.iid])
    windows = [(min(s for s, _ in spans[r]), max(e for _, e in spans[r]))
               for r in range(n_requests)]
    single = art.sim_total_cycles or simulator.run(art.instrs).total_cycles
    return PipelineReport(
        n_requests=n_requests, total_cycles=rep.total_cycles,
        single_request_cycles=single, busy_cycles=dict(rep.busy_cycles),
        request_windows=windows, ddr_slots=ddr_slots,
        n_instructions=rep.n_instructions,
        pin_input=bool(art.mem_summary.get("pin_input")),
        n_preload_guards=sum(len(v) for v in bk["pre_guard"].values()),
        ddr_slots_source=source,
        engine_timeline=simulator.engine_windows(stream, times))
