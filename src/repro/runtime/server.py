"""Serving front end: Session + DynamicBatcher + metrics.

``Server.submit`` is the whole client API — hand in one int8 image, get a
future for its output dict.  Internally queued requests are flushed as
batches (see :mod:`repro.runtime.batching`), each batch padded up to the
nearest *allowed* size so the jitted executor only ever traces a handful of
batch shapes, and every completion is timestamped for the latency
percentiles the serving benchmark reports.
"""
from __future__ import annotations

import numpy as np


def _default_sizes(max_batch: int) -> list[int]:
    sizes, s = [], 1
    while s < max_batch:
        sizes.append(s)
        s *= 2
    sizes.append(max_batch)
    return sorted(set(sizes))


class Server:
    def __init__(self, session, *, max_batch: int = 8,
                 max_latency_s: float = 2e-3, allowed_sizes=None,
                 warmup: bool = True):
        from repro.runtime.batching import DynamicBatcher

        self.session = session
        self.allowed_sizes = (sorted(set(allowed_sizes)) if allowed_sizes
                              else _default_sizes(max_batch))
        if self.allowed_sizes[-1] < max_batch:
            self.allowed_sizes.append(max_batch)
        if warmup:
            self._warmup()
        self._batcher = DynamicBatcher(self._run, max_batch=max_batch,
                                       max_latency_s=max_latency_s)

    def _warmup(self) -> None:
        """Trace every allowed batch shape once so steady-state serving never
        pays jit compilation inside a latency-sensitive flush.  Goes straight
        to the executor: warmup must not count as served traffic in the
        session's stats."""
        shape = self.session.graph.shape(
            next(n.name for n in self.session.graph if n.op == "input"))
        for s in self.allowed_sizes:
            self.session.executor(np.zeros((s,) + tuple(shape[1:]), np.int8))

    def _pad_size(self, n: int) -> int:
        for s in self.allowed_sizes:
            if s >= n:
                return s
        return n

    def _run(self, xs):
        return self.session.run_batch(xs, pad_to=self._pad_size(len(xs)))

    # ---------------------------------------------------------------- client
    def submit(self, x):
        return self._batcher.submit(x)   # the batcher timestamps + records

    def close(self, wait: bool = True) -> None:
        self._batcher.close(wait=wait)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # --------------------------------------------------------------- metrics
    def stats(self) -> dict:
        lats = sorted(self._batcher.latencies)
        pct = (lambda q: lats[min(len(lats) - 1,
                                  int(q * (len(lats) - 1)))] * 1e3) \
            if lats else (lambda q: 0.0)
        hist = dict(sorted(self._batcher.batch_sizes.items()))
        n = self._batcher.n_served
        return {
            "n_served": n,
            "n_batches": sum(hist.values()),
            "batch_histogram": hist,
            "mean_batch": (n / sum(hist.values())) if hist else 0.0,
            "p50_ms": pct(0.50),
            "p99_ms": pct(0.99),
            "allowed_sizes": list(self.allowed_sizes),
        }
