"""Serving front end: Session + DynamicBatcher + metrics.

``Server.submit`` is the whole client API — hand in one int8 image, get a
future for its output dict.  Internally queued requests are flushed as
batches (see :mod:`repro.runtime.batching`), each batch padded up to the
nearest *allowed* size so the jitted executor only ever traces a handful of
batch shapes, and every completion is timestamped for the latency
percentiles the serving benchmark reports.
"""
from __future__ import annotations

import numpy as np


def _default_sizes(max_batch: int) -> list[int]:
    sizes, s = [], 1
    while s < max_batch:
        sizes.append(s)
        s *= 2
    sizes.append(max_batch)
    return sorted(set(sizes))


class Server:
    def __init__(self, session, *, max_batch: int = 8,
                 max_latency_s: float = 2e-3, allowed_sizes=None,
                 warmup: bool = True, target_p99_ms: float | None = None,
                 slo_window: int = 64, labels: dict | None = None,
                 observers=None, flight=None, events=None):
        """``target_p99_ms`` turns on latency-SLO-aware batch sizing: the
        server watches the p99 of the batcher's bounded latency window
        (last ``slo_window`` submit->result samples) and walks the effective
        max batch down the allowed-size ladder while the SLO is violated —
        a smaller cap both shortens the batch-forming wait and the batched
        launch itself — then back up once p99 clears the target with margin.
        ``max_batch`` stays the hard ceiling.  ``labels`` tags every metric
        this server emits (multi-tenant hosts label per-model).

        ``observers`` forwards per-request completion observers to the
        batcher (see :class:`~repro.runtime.batching.DynamicBatcher`).
        ``flight`` attaches an :class:`~repro.obs.flight.FlightRecorder`:
        the server binds it as an observer (tenant = ``labels["model"]``),
        seeds its per-tenant context with the session's launched tile shapes
        and the SLO target, and keeps request records stamped with the
        drift profiler's latest state.  ``events`` overrides the shared
        :data:`~repro.obs.events.EVENTS` log the SLO resizer reports to."""
        from repro.runtime.batching import DynamicBatcher

        self.session = session
        self.allowed_sizes = (sorted(set(allowed_sizes)) if allowed_sizes
                              else _default_sizes(max_batch))
        if self.allowed_sizes[-1] < max_batch:
            self.allowed_sizes.append(max_batch)
        self.max_batch = max_batch
        self.target_p99_ms = target_p99_ms
        self._slo_window = max(8, slo_window)
        self._slo_mark = 0              # n_served at the last cap change
        self.slo_shrinks = 0
        self.slo_grows = 0
        # shrink causes, from the batcher's split timings: queue-bound means
        # the p99 violation lived in batch-forming wait, launch-bound in the
        # batched execute itself (different remedies: the first wants a
        # smaller forming window / more replicas, the second a smaller batch)
        self.slo_shrinks_queue_bound = 0
        self.slo_shrinks_launch_bound = 0
        from repro.obs import metrics as obs_metrics
        from repro.obs import events as obs_events
        self._registry = obs_metrics.REGISTRY
        self._events = events if events is not None else obs_events.EVENTS
        self.labels = dict(labels) if labels else None
        self.flight = flight
        self._obs_http = None
        obs = list(observers) if observers else []
        if flight is not None:
            tenant = (self.labels or {}).get("model")
            flight.set_context(tenant, tiles=session.tile_summary(),
                               target_p99_ms=target_p99_ms,
                               allowed_sizes=list(self.allowed_sizes))
            obs.append(flight.bind(tenant=tenant,
                                   drift_state=session.drift_state))
        if warmup:
            self._warmup()
        self._batcher = DynamicBatcher(self._run, max_batch=max_batch,
                                       max_latency_s=max_latency_s,
                                       labels=self.labels, observers=obs)

    def _warmup(self) -> None:
        """Trace every allowed batch shape once so steady-state serving never
        pays jit compilation inside a latency-sensitive flush.  Uses the
        session's launch path (NOT the bare executor) so the compile happens
        under the same device-placement context as serving — jit caches key
        on ``jax.default_device``, so warming up outside a replica's
        placement would recompile on the first real batch.  Warmup still
        must not count as served traffic in the session's stats (``_launch``
        bumps no counters)."""
        shape = self.session.graph.shape(
            next(n.name for n in self.session.graph if n.op == "input"))
        for s in self.allowed_sizes:
            self.session._launch(np.zeros((s,) + tuple(shape[1:]), np.int8))

    def _pad_size(self, n: int) -> int:
        for s in self.allowed_sizes:
            if s >= n:
                return s
        return n

    def _run(self, xs):
        self._adjust_for_slo()
        return self.session.run_batch(xs, pad_to=self._pad_size(len(xs)))

    # ------------------------------------------------- SLO-aware batch cap
    @property
    def effective_max_batch(self) -> int:
        return self._batcher.max_batch if hasattr(self, "_batcher") \
            else self.max_batch

    @staticmethod
    def _p99_ms(samples) -> float | None:
        lats = sorted(samples)
        if not lats:
            return None
        return lats[min(len(lats) - 1, int(0.99 * (len(lats) - 1)))] * 1e3

    def _recent_p99_ms(self, n_fresh: int) -> float | None:
        """p99 over the freshest ``n_fresh`` samples of the bounded window —
        never over latencies recorded before the last cap change, which
        describe a batch size that no longer exists."""
        lats = list(self._batcher.latencies)[-min(self._slo_window, n_fresh):]
        if len(lats) < 4:
            return None
        return self._p99_ms(lats)

    def _classify_violation(self, n_fresh: int) -> str:
        """Which half of the fresh latency window dominates its p99: the
        per-request queue wait or the batched launch."""
        k = min(self._slo_window, n_fresh)
        wait = self._p99_ms(list(self._batcher.queue_waits)[-k:]) or 0.0
        execute = self._p99_ms(list(self._batcher.execute_s)[-k:]) or 0.0
        return "queue" if wait > execute else "launch"

    def _adjust_for_slo(self) -> None:
        """Runs on the batcher worker before each launch (single-threaded
        with batch formation, so the cap never changes mid-batch).  Each cap
        change starts a cooldown: no further move until enough requests have
        been served *under the new cap* to judge it — otherwise one transient
        violation cascades the cap straight to the floor on stale samples."""
        if self.target_p99_ms is None:
            return
        cur = self._batcher.max_batch
        n_fresh = self._batcher.n_served - self._slo_mark
        if n_fresh < max(4, cur):
            return
        p99 = self._recent_p99_ms(n_fresh)
        if p99 is None:
            return
        if p99 > self.target_p99_ms:
            smaller = [s for s in self.allowed_sizes if s < cur]
            if smaller:
                self._batcher.set_max_batch(smaller[-1])
                self._slo_mark = self._batcher.n_served
                self.slo_shrinks += 1
                cause = self._classify_violation(n_fresh)
                if cause == "queue":
                    self.slo_shrinks_queue_bound += 1
                else:
                    self.slo_shrinks_launch_bound += 1
                self._registry.counter(f"serve.slo_shrink.{cause}_bound",
                                       self.labels).inc()
                self._events.emit(
                    "slo.resize", severity="warning",
                    message=f"p99 {p99:.2f}ms over {self.target_p99_ms}ms "
                            f"target; batch cap {cur} -> {smaller[-1]} "
                            f"({cause}-bound)",
                    direction="shrink", cause=cause, old_cap=cur,
                    new_cap=smaller[-1], p99_ms=p99,
                    target_p99_ms=self.target_p99_ms,
                    **(self.labels or {}))
                if self.flight is not None:
                    self.flight.trigger(
                        "slo_violation", tenant=(self.labels or {}).get("model"),
                        detail={"p99_ms": p99,
                                "target_p99_ms": self.target_p99_ms,
                                "cause": cause, "old_cap": cur,
                                "new_cap": smaller[-1]})
        elif p99 < 0.5 * self.target_p99_ms and cur < self.max_batch:
            bigger = [s for s in self.allowed_sizes
                      if cur < s <= self.max_batch]
            if bigger:
                self._batcher.set_max_batch(bigger[0])
                self._slo_mark = self._batcher.n_served
                self.slo_grows += 1
                self._registry.counter("serve.slo_grow", self.labels).inc()
                self._events.emit(
                    "slo.resize", severity="info",
                    message=f"p99 {p99:.2f}ms well under "
                            f"{self.target_p99_ms}ms target; batch cap "
                            f"{cur} -> {bigger[0]}",
                    direction="grow", old_cap=cur, new_cap=bigger[0],
                    p99_ms=p99, target_p99_ms=self.target_p99_ms,
                    **(self.labels or {}))

    # ---------------------------------------------------------------- client
    def submit(self, x):
        return self._batcher.submit(x)   # the batcher timestamps + records

    @property
    def pending(self) -> int:
        """Requests queued but not yet formed into a batch (the admission
        and fleet-routing signal)."""
        return self._batcher.pending

    def serve_metrics(self, host: str = "127.0.0.1", port: int = 0):
        """Mount the OpenMetrics scrape endpoint (plus /flight, /events,
        /snapshot, /explain) for this server's plane; returns the running
        :class:`~repro.obs.export.ObsHTTPServer` (closed with the server)."""
        from repro.obs.export import ObsHTTPServer
        if self._obs_http is None:
            self._obs_http = ObsHTTPServer(
                self._registry, flight=self.flight, events=self._events,
                host=host, port=port)
            # /explain/<model>: the served session's compile report, joined
            # with its live drift samples on every scrape
            model = ((self.labels or {}).get("model")
                     or self.session.graph.name)
            self._obs_http.add_explain(model, self.session.explain)
        return self._obs_http

    def close(self, wait: bool = True, timeout_s: float | None = None) -> None:
        self._batcher.close(wait=wait, timeout_s=timeout_s)
        if self._obs_http is not None:
            self._obs_http.close()
            self._obs_http = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # --------------------------------------------------------------- metrics
    def stats(self) -> dict:
        lats = sorted(self._batcher.latencies)
        pct = (lambda q: lats[min(len(lats) - 1,
                                  int(q * (len(lats) - 1)))] * 1e3) \
            if lats else (lambda q: 0.0)
        hist = dict(sorted(self._batcher.batch_sizes.items()))
        n = self._batcher.n_served
        return {
            "n_served": n,
            "n_batches": sum(hist.values()),
            "batch_histogram": hist,
            "mean_batch": (n / sum(hist.values())) if hist else 0.0,
            "p50_ms": pct(0.50),
            "p99_ms": pct(0.99),
            "queue_wait_p99_ms": self._p99_ms(self._batcher.queue_waits),
            "execute_p99_ms": self._p99_ms(self._batcher.execute_s),
            "allowed_sizes": list(self.allowed_sizes),
            "target_p99_ms": self.target_p99_ms,
            "effective_max_batch": self.effective_max_batch,
            "slo_shrinks": self.slo_shrinks,
            "slo_grows": self.slo_grows,
            "slo_shrinks_queue_bound": self.slo_shrinks_queue_bound,
            "slo_shrinks_launch_bound": self.slo_shrinks_launch_bound,
        }
