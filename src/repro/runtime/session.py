"""The runtime supporter's unit of ownership: one compiled model, served.

A :class:`Session` binds together everything needed to run inference against
one (graph, strategy, device, quantization) tuple:

* the :class:`~repro.asm.artifact.CompiledArtifact`, obtained through a
  :class:`~repro.asm.artifact.PlanCache` — the serving path compiles once and
  every later construction is a dictionary hit;
* the :class:`~repro.core.executor.Int8Executor` over the artifact's lowered
  ``GroupProgram`` (ref oracle or Pallas fused launches);
* the memory plan + addressed instruction stream, from which
  :meth:`pipeline_report` derives the engine-level cross-request schedule.

``run`` serves one request; ``run_batch`` stacks N queued requests into one
batched launch (one Pallas grid covers all N images — the executor's batch
dimension is free); ``serve`` wraps the session in the dynamic-batching
:class:`~repro.runtime.server.Server`.
"""
from __future__ import annotations

import numpy as np


def _resolve_profile(profile):
    """None | DeviceProfile | name/path -> DeviceProfile | None (lazy tune
    import: the runtime must not pay for the tuner unless profiles are used)."""
    if profile is None:
        return None
    from repro.tune.profile import resolve_profile
    return resolve_profile(profile)


class Session:
    """Owns the executor + memory plan for one compiled model."""

    def __init__(self, g, strategy, dev, qm, *, backend: str = "ref",
                 cache=None, interpret: bool = True, profile=None,
                 pin_input: bool | None = None,
                 cache_max_entries: int | None = None, placement=None):
        """``profile`` names the calibrated device profile to compile under —
        a ``tune.DeviceProfile``, a profile name/path resolved through the
        on-disk ``tune.ProfileCache``, or None (the analytic model; a
        strategy picked by a profile-guided search still keys by the profile
        hash it carries).  ``pin_input`` forwards to the memory planner.
        ``cache_max_entries`` rebounds the plan cache this session compiles
        through (a multi-model host sets it once to cap resident artifacts).
        ``placement`` pins every launch to one ``jax.Device`` (the fleet
        layer places data-parallel replicas across ``jax.devices()``)."""
        from repro import asm
        from repro.core.executor import Int8Executor

        self.profile = _resolve_profile(profile)
        self.cache = cache if cache is not None else asm.PLAN_CACHE
        if cache_max_entries is not None:
            self.cache.max_entries = cache_max_entries
        self.artifact, self.cache_hit = self.cache.get_or_compile(
            g, strategy, dev, qm=qm, profile=self.profile,
            pin_input=pin_input)
        self.graph, self.qm, self.device = g, qm, dev
        self.backend = backend
        self.executor = Int8Executor(g, qm, strategy=self.artifact,
                                     backend=backend, interpret=interpret)
        self.outputs = [n.name for n in g if not g.consumers(n.name)]
        self.n_runs = 0
        self.images_served = 0
        self.drift = None               # optional DriftProfiler (attach_drift)
        self.placement = placement      # optional jax.Device to launch on
        self._launch_hook = None        # optional pre-launch hook (chaos)

    @classmethod
    def from_artifact(cls, art, *, backend: str = "ref", cache=None,
                      interpret: bool = True, profile=None,
                      cache_max_entries: int | None = None,
                      placement=None) -> "Session":
        """Open a session on a loaded DNNVM object file — no recompilation:
        the artifact is seeded into the plan cache under its own key.

        The artifact records the device-profile hash it was planned under;
        loading it under a *different* profile (or under none, when it was
        profile-planned) warns — the plan was tuned for measured rates this
        deployment may not match."""
        import warnings

        from repro import asm
        from repro.hw import get_device

        resolved = _resolve_profile(profile)
        got = resolved.hash() if resolved is not None else None
        want = art.profile_hash
        if got != want:
            warnings.warn(
                f"artifact was planned under device profile "
                f"{want or 'analytic'} ({art.meta.get('profile_name') or 'n/a'}) "
                f"but is being loaded under {got or 'analytic'} — its "
                f"strategy was tuned for measured rates this session may not "
                f"match; recompile under the current profile to re-tune",
                stacklevel=2)
        g = art.rebuild_graph()
        qm = art.quantized_model()
        dev = get_device(art.device)
        cache = cache if cache is not None else asm.PLAN_CACHE
        # seed and construct under the SAME resolved profile so the cache key
        # matches (no recompile) and the session keeps the profile — dropping
        # it here used to lose profile-guided ddr_slots auto-selection in
        # pipeline_report and the session-side profile_hash provenance
        cache.put(g, art, dev, art, qm=qm, profile=resolved)
        return cls(g, art, dev, qm, backend=backend, cache=cache,
                   interpret=interpret, profile=resolved,
                   cache_max_entries=cache_max_entries, placement=placement)

    # ------------------------------------------------------------- execution
    def _stack(self, xs, pad_to: int | None = None):
        rows = [np.asarray(x) for x in xs]
        rows = [r[None] if r.ndim == 3 else r for r in rows]
        x = np.concatenate(rows, axis=0)
        n = x.shape[0]
        if pad_to is not None and pad_to > n:
            # pad with zero images up to an allowed batch size: bounds the
            # number of distinct batch shapes the jitted executor ever traces
            x = np.concatenate(
                [x, np.zeros((pad_to - n,) + x.shape[1:], x.dtype)], axis=0)
        return x, n

    def attach_drift(self, profiler) -> None:
        """Attach an ``obs.DriftProfiler``; every ``run``/``run_batch`` then
        counts as one observed launch (the profiler samples every Nth)."""
        self.drift = profiler

    def set_launch_hook(self, fn) -> None:
        """Install (or with None, clear) a pre-launch hook: called with the
        stacked input batch immediately before every executor launch.  An
        exception raised here fails the launch exactly as an executor fault
        would — the seam the chaos injector (``runtime.chaos``) uses to kill,
        hang, slow, or poison one replica deterministically."""
        self._launch_hook = fn

    def _launch(self, x):
        """One executor launch, through the hook and onto the placement
        device (``jax.default_device``; a no-op for the numpy ref backend's
        compute, but keeps any jax arrays the launch creates on the replica's
        device)."""
        if self._launch_hook is not None:
            self._launch_hook(x)
        if self.placement is None:
            return self.executor(x)
        import jax
        with jax.default_device(self.placement):
            return self.executor(x)

    def drift_state(self) -> dict | None:
        """The attached profiler's most recent summary (None when no drift
        profiler is attached or it has not sampled yet) — what the flight
        recorder stamps onto request records."""
        return self.drift.last if self.drift is not None else None

    def tile_summary(self) -> list[dict]:
        """Launched tile shape per lowered unit — the static per-tenant
        context the flight recorder carries in forensic dumps.  ``tile``
        is the searched (t_h, t_w, t_oc), or None when the kernel's
        heuristic shapes run."""
        from repro.core import lower
        if self.artifact.program is None:
            return []
        out = []
        for item in self.artifact.program.items:
            if isinstance(item, lower.RefFallback):
                out.append({"nodes": "+".join(item.nodes),
                            "kind": "fallback", "tile": None})
            else:
                out.append({"nodes": "+".join(item.nodes), "kind": item.kind,
                            "tile": list(item.tile) if item.tile else None})
        return out

    def run(self, x) -> dict:
        """One request; accepts (H, W, C) or (1, H, W, C) int8."""
        x = np.asarray(x)
        out = self._launch(x[None] if x.ndim == 3 else x)
        self.n_runs += 1
        self.images_served += 1
        if self.drift is not None:
            self.drift.observe_launch()
        return out

    def run_batch(self, xs, pad_to: int | None = None) -> list[dict]:
        """Serve N queued requests as ONE batched launch; returns one output
        dict per request (leading batch dim 1, so results are directly
        comparable with per-request execution)."""
        from repro.obs.trace import TRACER
        with TRACER.span("pad", cat="serve", track="batch", n=len(xs),
                         pad_to=pad_to):
            x, n = self._stack(xs, pad_to=pad_to)
        with TRACER.span("launch", cat="serve", track="batch",
                         batch=int(x.shape[0])):
            out = self._launch(x)
        self.n_runs += 1
        self.images_served += n
        if self.drift is not None:
            self.drift.observe_launch()
        return [{k: v[i:i + 1] for k, v in out.items()} for i in range(n)]

    # -------------------------------------------------------- schedule view
    def pipeline_report(self, n_requests: int, ddr_slots: int | None = 2,
                        profile=None):
        """Engine-level cross-request schedule of ``n_requests`` pipelined
        copies of this session's instruction stream (hazard-audited).

        ``ddr_slots=None`` selects the double-buffer slot depth from the
        stream's DRAM/compute ratio under ``profile`` (defaulting to the
        profile this session was compiled with)."""
        from repro.runtime.schedule import pipeline_report
        return pipeline_report(self.artifact, n_requests, ddr_slots=ddr_slots,
                               profile=(profile if profile is not None
                                        else self.profile))

    # --------------------------------------------------------------- explain
    def explain(self, *, render: bool = False):
        """This session's compile-decision provenance, joined with live drift.

        Returns the artifact's ``CompileReport`` (``repro.explain``) extended
        with a ``drift`` section when a :class:`~repro.obs.drift.DriftProfiler`
        is attached and has samples: per-unit measured-vs-predicted seconds —
        the static plan's predictions next to what this deployment actually
        measures.  ``render=True`` returns the text rendering instead."""
        from repro.explain import render_report, report_of
        from repro.obs.events import EVENTS

        rep = dict(report_of(self.artifact))
        drift_rows = None
        if self.drift is not None:
            dr = self.drift.report()
            drift_rows = [{
                "key": u.key.replace("+", "|"),
                "kind": u.kind,
                "predicted": u.predicted,
                "measured": u.measured,
                "deviation": u.deviation,
                "n_samples": u.n_samples,
            } for u in dr.units]
            rep["drift"] = {
                "units": drift_rows,
                "drifted": bool(dr.drifted),
                "aggregate_deviation": dr.aggregate,
                "profile_match": dr.profile_match,
            }
        EVENTS.emit("explain.report",
                    message=f"explain {rep['model']} (session"
                            f"{', with drift' if drift_rows else ''})",
                    model=rep["model"], device=rep["device"],
                    degraded=rep.get("degraded", False),
                    n_drift_units=len(drift_rows or []))
        if render:
            return render_report(rep, drift=drift_rows)
        return rep

    # -------------------------------------------------------------- serving
    def serve(self, **kw):
        from repro.runtime.server import Server
        return Server(self, **kw)

    def stats(self) -> dict:
        return {"n_runs": self.n_runs, "images_served": self.images_served,
                "cache_hit": self.cache_hit,
                "cache_hits": self.cache.hits, "cache_misses": self.cache.misses,
                "fused_coverage": self.artifact.fused_coverage,
                "sim_cycles_per_image": self.artifact.sim_total_cycles,
                "profile_hash": self.artifact.profile_hash,
                "session_profile_hash": (self.profile.hash()
                                         if self.profile else None),
                "pin_input": self.artifact.pin_input}
