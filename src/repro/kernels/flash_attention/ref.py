"""Pure-jnp oracle for flash attention: unfused softmax(QK^T)V — the exact
computation the fused kernel must reproduce (it materializes the S x S score
matrix, which is why it fails DNNVM's fusion condition 1 at long S)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def attention_ref(q, k, v, *, q_offset=0, causal=True):
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    s *= 1.0 / d ** 0.5
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, NEG)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return o.reshape(b, sq, h, d)
