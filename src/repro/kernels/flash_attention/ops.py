"""jit'd wrapper for the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@partial(jax.jit, static_argnames=("q_offset", "causal", "blk_q", "blk_k",
                                   "interpret"))
def flash_attention(q, k, v, *, q_offset=0, causal=True, blk_q=128,
                    blk_k=128, interpret=True):
    return flash_attention_pallas(q, k, v, blk_q=blk_q, blk_k=blk_k,
                                  q_offset=q_offset, causal=causal,
                                  interpret=interpret)
