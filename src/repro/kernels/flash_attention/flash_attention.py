"""Pallas TPU kernel: causal flash attention (online softmax).

This is the DNNVM story on the transformer side (DESIGN.md §3): the
``attn_score -> softmax -> attn_out`` subgraph is a *kernel-fusion group*
whose unfused form materializes an S x S score matrix in HBM — failing the
paper's fusion condition 1 — while the fused form streams KV blocks through
VMEM with online max/sum renormalization.  The lm_bridge planner picks this
kernel exactly when the blocked working set fits the VMEM budget.

Tiling: grid = (batch*kv_heads, q_blocks); each cell owns one q tile
(BLK_Q x d) for one kv-head group and loops over kv blocks with
``jax.lax.fori_loop``, keeping the running (m, l, acc) statistics in VMEM
registers.  Causality skips kv blocks strictly above the diagonal.
Block sizes default to 128 (MXU-aligned); d is the full head_dim.

Numerics: fp32 softmax statistics, input-dtype matmuls (bf16 on TPU),
matching the jnp oracle in ref.py to ~1e-2 bf16 / 1e-5 fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, blk_q, blk_k, n_k, scale,
            q_offset, causal):
    # q_ref: (1, blk_q, g, d) one q block for one kv head (g = group heads)
    # k_ref/v_ref: (1, S_k, d) the full kv stream for this head
    qi = pl.program_id(1)
    q = q_ref[0]                                       # (blk_q, g, d)
    bq, g, d = q.shape
    q2 = (q * scale).reshape(bq * g, d).astype(jnp.float32)

    m0 = jnp.full((bq * g,), NEG, jnp.float32)
    l0 = jnp.zeros((bq * g,), jnp.float32)
    a0 = jnp.zeros((bq * g, d), jnp.float32)

    q_start = qi * blk_q + q_offset                    # absolute q positions

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(ki * blk_k, blk_k)].astype(jnp.float32)
        v = v_ref[0, pl.dslice(ki * blk_k, blk_k)].astype(jnp.float32)
        s = q2 @ k.T                                   # (bq*g, blk_k)
        if causal:
            qpos = q_start + jnp.repeat(
                jax.lax.iota(jnp.int32, bq), g, total_repeat_length=bq * g)
            kpos = ki * blk_k + jax.lax.iota(jnp.int32, blk_k)
            s = jnp.where(kpos[None, :] <= qpos[:, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    if causal:
        # kv blocks strictly above the diagonal contribute nothing
        last = jnp.minimum(
            n_k, (q_start + blk_q + blk_k - 1) // blk_k).astype(jnp.int32)
    else:
        last = n_k
    m, l, acc = jax.lax.fori_loop(0, last, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.reshape(bq, g, d).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, blk_q=128, blk_k=128, q_offset=0,
                           causal=True, interpret=True):
    """q (B,Sq,H,D); k/v (B,Sk,KV,D), H % KV == 0.  Returns (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0
    # layout: (B*KV, Sq, g, d) so one grid row owns one kv head's stream
    qr = q.reshape(b, sq, kv, g, d).transpose(0, 2, 1, 3, 4) \
          .reshape(b * kv, sq, g, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    grid = (b * kv, sq // blk_q)
    kern = functools.partial(
        _kernel, blk_q=blk_q, blk_k=blk_k, n_k=sk // blk_k,
        scale=1.0 / d ** 0.5, q_offset=q_offset, causal=causal)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, g, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, g, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, sq, g, d), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, kv, sq, g, d).transpose(0, 2, 1, 3, 4) \
              .reshape(b, sq, h, d)
