from repro.kernels.conv_fused.ops import fused_conv_block, supports  # noqa: F401
