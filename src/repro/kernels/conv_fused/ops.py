"""jit'd wrapper + executor bridge for the fused conv kernel.

``fused_conv_block``    — pads, picks tiles, launches the Pallas kernel.
``supports``            — static pattern check (what the kernel accelerates);
                          unsupported patterns fall back to the ref executor —
                          this *is* the mixed-compilation boundary on TPU.
``group_descriptor`` /
``run_group``           — the Int8Executor hook: recognize a planned fused
                          group ([conv], [conv,maxpool], [conv,eltwise]) and
                          run it as one kernel launch.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.xgraph import XGraph, _padding
from repro.kernels.conv_fused.conv_fused import fused_conv_pallas


def _tile_rows(oh: int, pref=(8, 4, 2, 1)) -> int:
    for t in pref:
        if oh % t == 0:
            return t
    return 1


def _tile_oc(oc: int) -> int:
    for t in (128, 64, 32, 16, 8, 4, 2, 1):
        if oc % t == 0 and t <= oc:
            return t
    return oc


def supports(*, kernel, stride, dilation=(1, 1), depthwise=False,
             pool=None, conv_oh=None, conv_ow=None) -> bool:
    if depthwise or dilation != (1, 1):
        return False
    if stride[0] != stride[1]:
        return False
    if pool is not None:
        kp, sp = pool
        # pool windows must tile the conv output exactly (no ceil extension)
        if (conv_oh - kp) % sp != 0 or (conv_ow - kp) % sp != 0:
            return False
    return True


@partial(jax.jit, static_argnames=("stride", "pad", "shift", "relu", "pool",
                                   "elt_shifts", "interpret"))
def _launch(x, w, b, side, *, stride, pad, shift, relu, pool, elt_shifts,
            interpret):
    n, h, w_, ic = x.shape
    kh, kw, _, oc = w.shape
    sh, sw = stride
    ph, pw = pad
    oh_c = (h + 2 * ph - kh) // sh + 1
    ow_c = (w_ + 2 * pw - kw) // sw + 1
    if pool is not None:
        kp, sp = pool
        oh = (oh_c - kp) // sp + 1
        ow = (ow_c - kp) // sp + 1
    else:
        oh, ow = oh_c, ow_c
    th = _tile_rows(oh)
    toc = _tile_oc(oc)
    # pad: conv padding + slack for the slice-reshape stride trick (zeros
    # beyond the receptive field are sliced then dropped, never used)
    slack_h = sh * (pool[1] if pool else 1) + kh
    slack_w = sw * (pool[1] if pool else 1) + kw
    xp = jnp.pad(x, ((0, 0), (ph, ph + slack_h), (pw, pw + slack_w), (0, 0)))
    eltwise = None
    if elt_shifts is not None:
        s_conv, s_side, relu_out = elt_shifts
        eltwise = (side, s_conv, s_side, relu_out)
    return fused_conv_pallas(xp, w, b, stride=stride, shift=shift, relu=relu,
                             th=th, toc=toc, oh=oh, ow=ow,
                             pool=pool, eltwise=eltwise, interpret=interpret)


def fused_conv_block(x, w, b, *, stride=(1, 1), pad=(0, 0), shift=0,
                     relu=False, pool=None, eltwise=None, interpret=True):
    """Public wrapper.  eltwise = (side, s_conv, s_side, relu_out) or None."""
    side = eltwise[0] if eltwise is not None else jnp.zeros((1,), jnp.int8)
    elt_shifts = tuple(eltwise[1:]) if eltwise is not None else None
    return _launch(x, w, b, side, stride=tuple(stride), pad=tuple(pad),
                   shift=int(shift), relu=bool(relu), pool=pool,
                   elt_shifts=elt_shifts, interpret=interpret)


# ------------------------------------------------------- executor bridge
@dataclasses.dataclass
class GroupDesc:
    kind: str                 # "conv" | "conv_pool" | "conv_eltwise"
    conv: str
    tail: str | None
    in_name: str
    side_name: str | None
    kwargs: dict


def group_descriptor(g: XGraph, qm, group: list) -> GroupDesc | None:
    """Recognize a planned group the kernel can run; None => ref fallback."""
    ops = [g.nodes[nm].op for nm in group]
    conv = group[0]
    node = g.nodes[conv]
    if node.op != "conv" or conv not in qm.weights:
        return None
    a = node.attrs
    kh, kw = a["kernel"]
    stride = tuple(a.get("stride", (1, 1)))
    dil = tuple(a.get("dilation", (1, 1)))
    ph, pw = _padding(a.get("pad", "same"), dil[0] * (kh - 1) + 1,
                      dil[1] * (kw - 1) + 1)
    shift = qm.shift_for(g, conv)
    relu = bool(a.get("relu"))
    base = dict(stride=stride, pad=(ph, pw), shift=shift, relu=relu)
    oh_c, ow_c = g.shape(conv)[1], g.shape(conv)[2]

    if ops == ["conv"]:
        if not supports(kernel=(kh, kw), stride=stride, dilation=dil):
            return None
        return GroupDesc("conv", conv, None, node.inputs[0], None, base)

    if len(group) == 2 and ops == ["conv", "maxpool"]:
        tail = g.nodes[group[1]]
        ta = tail.attrs
        kp = ta["kernel"][0]
        sp = ta.get("stride", ta["kernel"])[0]
        if ta["kernel"][0] != ta["kernel"][1]:
            return None
        tph, tpw = _padding(ta.get("pad", "valid"), kp, kp)
        if (tph, tpw) != (0, 0):
            return None
        if not supports(kernel=(kh, kw), stride=stride, dilation=dil,
                        pool=(kp, sp), conv_oh=oh_c, conv_ow=ow_c):
            return None
        return GroupDesc("conv_pool", conv, group[1], node.inputs[0], None,
                         dict(base, pool=(kp, sp)))

    if len(group) == 2 and ops == ["conv", "eltwise_add"]:
        tail = g.nodes[group[1]]
        side = [i for i in tail.inputs if i != conv]
        if len(side) != 1:
            return None
        if not supports(kernel=(kh, kw), stride=stride, dilation=dil):
            return None
        f_out = qm.f_a[group[1]]
        s_conv = qm.f_a[conv] - f_out
        s_side = qm.f_a[side[0]] - f_out
        relu_out = bool(tail.attrs.get("relu"))
        return GroupDesc("conv_eltwise", conv, group[1], node.inputs[0],
                         side[0], dict(base, elt=(s_conv, s_side, relu_out)))
    return None


def run_group(desc: GroupDesc, env: dict, qm, interpret: bool = True) -> dict:
    x = env[desc.in_name]
    w = jnp.asarray(qm.weights[desc.conv])
    b = jnp.asarray(qm.biases[desc.conv])
    kw = dict(desc.kwargs)
    eltwise = None
    if desc.kind == "conv_eltwise":
        s_conv, s_side, relu_out = kw.pop("elt")
        eltwise = (env[desc.side_name], s_conv, s_side, relu_out)
    y = fused_conv_block(x, w, b, eltwise=eltwise, interpret=interpret, **kw)
    return {(desc.tail or desc.conv): y}
