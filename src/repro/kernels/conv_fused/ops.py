"""Launcher + executor bridge for the fused chain kernel.

``run_launch``      — execute one ``lower.FusedLaunch`` against an activation
                      env.  This is the ``Int8Executor`` dispatch hook: the
                      launch already carries every resolved parameter, so NO
                      graph inspection or pattern matching happens at run
                      time — lowering decided everything at compile time.
``fused_conv_block``— legacy single-conv(+tail) wrapper (kernel tests,
                      micro-benchmarks).
``supports``        — static support predicate of the chain kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.conv_fused.conv_fused import (
    I8_MIN, chain_geometry, fused_chain_pallas, fused_horizontal_pallas)


def _tile_rows(oh: int, pref=(8, 4, 2, 1)) -> int:
    for t in pref:
        if oh % t == 0:
            return t
    return 1


def _tile_oc(oc: int) -> int:
    for t in (128, 64, 32, 16, 8, 4, 2, 1):
        if oc % t == 0 and t <= oc:
            return t
    return oc


def _resolve_tile(tile, oh: int, ow: int, oc: int, has_conv: bool) -> tuple:
    """(th, tw, toc) the launch executes.

    A serialized tile shape (``FusedLaunch.tile``, chosen by the tile-shape
    search) wins, clamped to the output extents; a T_oc that does not divide
    OC falls back to the divisor heuristic (the kernel's OC grid axis cannot
    run ragged — weights would need padding).  Without a shape the PR-4
    heuristics apply: full width, row tiles from the largest divisor, T_oc
    from the power-of-two divisor ladder.
    """
    if tile:
        th = max(1, min(int(tile[0]), oh))
        tw = max(1, min(int(tile[1]), ow))
        toc = max(1, min(int(tile[2]), oc))
        if not has_conv:
            toc = oc
        elif oc % toc:
            toc = _tile_oc(oc)
        return th, tw, toc
    return _tile_rows(oh), ow, (_tile_oc(oc) if has_conv else oc)


def supports(*, depthwise=False, **_ignored) -> bool:
    """What the chain kernel accepts.  Depthwise convolution is the only
    structural exclusion; dilation, anisotropic strides/kernels and
    ceil/padded pool tails are all handled by the staged kernel's
    padded-coordinate masking (extra keyword capabilities are accepted for
    historical call sites and ignored)."""
    return not depthwise


def _pad_to(x, top: int, left: int, h_req: int, w_req: int, fill: int):
    n, h, w, c = x.shape
    bottom = max(0, h_req - top - h)
    right = max(0, w_req - left - w)
    return jnp.pad(x, ((0, 0), (top, bottom), (left, right), (0, 0)),
                   constant_values=np.int8(fill))


@partial(jax.jit, static_argnames=("chain", "oh", "ow", "oc", "interpret",
                                   "tile"))
def _run_chain(x, weights, biases, sides, *, chain, oh, ow, oc, interpret,
               tile=()):
    has_conv = any(st[0] == "conv" for st in chain)
    th, tw, toc = _resolve_tile(tile, oh, ow, oc, has_conv)
    geom = chain_geometry(chain, th, oh, ow, tw)
    xp = _pad_to(x, geom["q_in"][0], geom["q_in"][1],
                 geom["h_req"], geom["w_req"], geom["fill0"])
    sp = tuple(_pad_to(s, sg["q"][0], sg["q"][1], sg["h_req"], sg["w_req"], 0)
               for s, sg in zip(sides, geom["sides"]))
    return fused_chain_pallas(xp, weights, biases, sp, chain=chain, th=th,
                              tw=tw, toc=toc, oh=oh, ow=ow, oc=oc,
                              interpret=interpret)


@partial(jax.jit, static_argnames=("stride", "pad", "oh", "ow", "interpret",
                                   "tile"))
def _run_horizontal(x, w, b, shift_vec, relu_vec, *, stride, pad, oh, ow,
                    interpret, tile=()):
    kh, kw = w.shape[:2]
    sh, sw = stride
    th, tw, toc = _resolve_tile(tile, oh, ow, int(w.shape[-1]), True)
    n_h = -(-oh // th)
    n_w = -(-ow // tw)
    xp = _pad_to(x, pad[0], pad[1], (n_h * th - 1) * sh + kh,
                 (n_w * tw - 1) * sw + kw, 0)
    return fused_horizontal_pallas(xp, w, b, shift_vec, relu_vec,
                                   stride=stride, th=th, tw=tw, toc=toc,
                                   oh=oh, ow=ow, interpret=interpret)


# ------------------------------------------------------------ executor hook
def run_launch(launch, env: dict, qm, interpret: bool = True) -> dict:
    """Execute one FusedLaunch; returns {tensor name: int8 array}."""
    if launch.kind == "horizontal":
        x = env[launch.in_name]
        w = jnp.concatenate(
            [jnp.asarray(qm.weights[m]) for m, *_ in launch.members], axis=-1)
        b = jnp.concatenate(
            [jnp.asarray(qm.biases[m]) for m, *_ in launch.members])
        shift_vec = jnp.asarray(np.concatenate(
            [np.full(oc, s, np.int32) for _, oc, s, _ in launch.members]))
        relu_vec = jnp.asarray(np.concatenate(
            [np.full(oc, int(r), np.int32) for _, oc, _, r in launch.members]))
        oh, ow = launch.out_hw
        y = _run_horizontal(x, w, b, shift_vec, relu_vec,
                            stride=tuple(launch.stride),
                            pad=tuple(launch.pad), oh=oh, ow=ow,
                            interpret=interpret,
                            tile=tuple(launch.tile))
        outs, off = {}, 0
        for m, oc_m, _, _ in launch.members:
            outs[m] = y[..., off:off + oc_m]
            off += oc_m
        return outs

    x = env[launch.in_name]
    if launch.fc_reshape:
        x = x.reshape(x.shape[0], 1, 1, -1)
    weights, biases = [], []
    for st in launch.stages:
        if st[0] == "conv":
            w = jnp.asarray(qm.weights[st[1]])
            if launch.fc_reshape:
                w = w.reshape(1, 1, *w.shape)
            weights.append(w)
            biases.append(jnp.asarray(qm.biases[st[1]]))
    sides = tuple(env[s] for s in launch.sides)
    oh, ow = launch.out_hw
    oc = int(weights[-1].shape[-1]) if weights else int(x.shape[-1])
    y = _run_chain(x, tuple(weights), tuple(biases), sides,
                   chain=launch.stages, oh=oh, ow=ow, oc=oc,
                   interpret=interpret, tile=tuple(launch.tile))
    return {launch.out_name: y}


# ------------------------------------------------------------ legacy wrapper
def fused_conv_block(x, w, b, *, stride=(1, 1), pad=(0, 0), shift=0,
                     relu=False, pool=None, eltwise=None, interpret=True):
    """Single conv (+maxpool | +eltwise) as a 1-2 stage chain.

    eltwise = (side, s_conv, s_side, relu_out) or None; pool = (kp, sp) with
    VALID floor semantics (the historical test contract)."""
    n, h, w_, ic = x.shape
    kh, kw, _, oc = w.shape
    sh, sw = stride
    ph, pw = pad
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (w_ + 2 * pw - kw) // sw + 1
    stages = [("conv", "w0", kh, kw, sh, sw, ph, pw, 1, 1,
               int(shift), bool(relu), oh, ow)]
    sides = ()
    if pool is not None:
        kp, sp = pool
        oh = (oh - kp) // sp + 1
        ow = (ow - kp) // sp + 1
        stages.append(("pool", "p0", "max", kp, kp, sp, sp, 0, 0, oh, ow,
                       kp * kp))
    if eltwise is not None:
        side, s_conv, s_side, relu_out = eltwise
        stages.append(("elt", "e0", int(s_conv), int(s_side),
                       bool(relu_out), oh, ow))
        sides = (side,)
    return _run_chain(x, (w,), (b,), sides, chain=tuple(stages), oh=oh,
                      ow=ow, oc=oc, interpret=interpret)
