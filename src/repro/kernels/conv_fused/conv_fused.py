"""Pallas TPU kernel: fused int8 op-chain programs.

This is the paper's fused-operation executed as ONE on-chip program — the
LOAD/CONV/POOL/MISC/SAVE pipeline of Fig. 8/9 mapped to the TPU, generalized
from single conv(+tail) patterns to whole lowered *chains*
(``lower.FusedLaunch.stages``):

* LOAD  -> Pallas grid DMA: BlockSpecs stage the padded input image, each
           stage's weight panel and bias slice, and any eltwise side inputs
           into VMEM (double-buffered across grid steps by the Pallas
           pipeline);
* CONV  -> MXU matmuls: every conv stage is computed as kh*kw shifted
           patch-matmuls accumulated in int32 — intermediate feature maps of
           the chain stay resident in VMEM and NEVER touch HBM;
* MISC  -> requantize (+ReLU), eltwise-add on a DMA'd side input, and
           max/avg/global pooling run on the VPU over the resident tile;
* SAVE  -> the output BlockSpec writes the finished int8 tile back.

Coordinate convention (how padding/ceil semantics stay bit-exact): every
tensor of the chain lives in *padded coordinates*.  Walking backward from the
final output (offset 0), each stage with stride ``s`` and pad ``p`` maps its
output offset ``Q`` to an input offset ``Q*s + p``; the external image is
physically pre-padded by the accumulated offset (with the first stage's pad
identity), and after each stage the kernel masks rows/cols falling outside
the stage's true extent to the *consumer's* pad identity (0 for conv/eltwise/
avg-sum, -128 for maxpool).  That reproduces exactly the reference semantics
of zero-padded conv, -128-padded (and ceil-extended) maxpool, and zero-padded
(and ceil-extended, count-include-pad) avgpool from ``int8_ops``.

Tile shape: the grid is (batch, row tiles, width tiles, OC tiles) — all
three tile extents are compile-time decisions (``FusedLaunch.tile``, chosen
by the tile-shape search; kernel heuristics otherwise).  Width tiles read
halo-overlapped input slices (``jax.lax.dynamic_slice`` off the staged
image, mirroring the row axis), and ragged bottom/right tiles compute into
padded-output slack that the launcher slices off — the same padded-
coordinate masking that handles ceil-mode pools keeps every valid position
bit-exact at interior tile boundaries.  The OC axis tiles the FINAL conv's
output channels (TOC); stages upstream of it compute full channels (a conv
consumer needs all of them), stages downstream are channelwise and ride the
TOC slice.

Numerics are EXACTLY ``int8_ops``: int32 accumulate, round-half-away shift,
saturate — validate.py enforces bit-equality.  The horizontal variant batches
sibling convs over OC-stacked weights with *per-channel* shift/ReLU vectors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I8_MIN = -128


def _round_shift(x, s: int):
    if s == 0:
        return x
    if s < 0:
        return x << (-s)
    ax = jnp.abs(x)
    r = (ax + (1 << (s - 1))) >> s
    return jnp.sign(x) * r


def _round_shift_vec(x, s):
    """x (..., C) int32, s (C,) int32 per-channel shift (may be negative)."""
    s = s.reshape((1,) * (x.ndim - 1) + (-1,))
    sp = jnp.maximum(s, 1)
    right = jnp.sign(x) * ((jnp.abs(x) + (1 << (sp - 1))) >> sp)
    return jnp.where(s > 0, right, x << jnp.maximum(-s, 0))


def _sat8(x):
    return jnp.clip(x, -128, 127).astype(jnp.int8)


# ------------------------------------------------------------ static geometry
def _stage_geom(st):
    """(ekh, ekw, sh, sw, ph, pw) of one stage spec."""
    if st[0] == "conv":
        _, _, kh, kw, sh, sw, ph, pw, dh, dw = st[:10]
        return (dh * (kh - 1) + 1, dw * (kw - 1) + 1, sh, sw, ph, pw)
    if st[0] == "pool":
        _, _, _, kph, kpw, sph, spw, pph, ppw = st[:9]
        return (kph, kpw, sph, spw, pph, ppw)
    return (1, 1, 1, 1, 0, 0)   # elt


def _fill_of(st) -> int:
    """Pad identity a stage wants on its *input*."""
    return I8_MIN if (st[0] == "pool" and st[2] == "max") else 0


def chain_geometry(chain, th: int, oh: int, ow: int, tw: int | None = None
                   ) -> dict:
    """Static tile geometry of a lowered chain.

    Shared by the kernel body (trace-time python) and the launcher (physical
    padding); the two must agree or masking goes stale.  ``tw`` tiles the
    width axis (default: the full output width — the PR-4 single-column
    grid); neighbouring width tiles read halo-overlapped input regions, and
    ragged bottom/right tiles run on padded coordinates masked back to the
    true extents (``n_h``/``n_w`` are ceil-divided).
    """
    tw = ow if tw is None else tw
    m = len(chain)
    rows = [0] * m
    cols = [0] * m
    fout = [0] * m           # padded row-offset factor of stage i's output
    foutw = [0] * m          # padded col-offset factor of stage i's output
    q = [(0, 0)] * m         # padded-coordinate offset of stage i's output
    r, c, f, fw, qq = th, tw, th, tw, (0, 0)
    for i in range(m - 1, -1, -1):
        rows[i], cols[i], fout[i], foutw[i], q[i] = r, c, f, fw, qq
        ekh, ekw, sh, sw, ph, pw = _stage_geom(chain[i])
        r = (r - 1) * sh + ekh
        c = (c - 1) * sw + ekw
        f = f * sh
        fw = fw * sw
        qq = (qq[0] * sh + ph, qq[1] * sw + pw)
    n_h = -(-oh // th)
    n_w = -(-ow // tw)
    sides = []
    for i, st in enumerate(chain):
        if st[0] == "elt":
            q_in = q[i]      # elt: input coords == output coords
            sides.append({"q": q_in, "rows": rows[i], "cols": cols[i],
                          "h_req": (n_h - 1) * fout[i] + rows[i],
                          "w_req": (n_w - 1) * foutw[i] + cols[i],
                          "f": fout[i], "fw": foutw[i]})
    return {
        "in_rows": r, "in_cols": c, "f_in": f, "fw_in": fw, "q_in": qq,
        "h_req": (n_h - 1) * f + r, "w_req": (n_w - 1) * fw + c,
        "rows": rows, "cols": cols, "fout": fout, "foutw": foutw, "q": q,
        "fill0": _fill_of(chain[0]) if chain else 0,
        "sides": sides, "th": th, "tw": tw, "n_h": n_h, "n_w": n_w,
    }


# ------------------------------------------------------------------- kernels
def _conv_apply(t, w_ref, b_ref, st, out_r, out_c):
    _, _, kh, kw, sh, sw, _, _, dh, dw, shift, relu = st[:12]
    in_r, in_c, ic = t.shape
    acc = jnp.zeros((out_r * out_c, w_ref.shape[-1]), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            sl = jax.lax.slice(
                t, (i * dh, j * dw, 0),
                (i * dh + (out_r - 1) * sh + 1,
                 j * dw + (out_c - 1) * sw + 1, ic),
                (sh, sw, 1))
            acc = acc + jnp.dot(sl.reshape(out_r * out_c, ic),
                                w_ref[i, j].astype(jnp.int32),
                                preferred_element_type=jnp.int32)
    acc = acc + b_ref[...].astype(jnp.int32)[None, :]
    y = _round_shift(acc, shift)
    if relu:
        y = jnp.maximum(y, 0)
    return jnp.clip(y, -128, 127).reshape(out_r, out_c, -1)


def _pool_apply(t, st, out_r, out_c):
    _, _, pkind, kph, kpw, sph, spw = st[:7]
    cnt = st[11]
    c = t.shape[-1]
    if pkind == "max":
        best = None
        for i in range(kph):
            for j in range(kpw):
                win = jax.lax.slice(
                    t, (i, j, 0),
                    (i + (out_r - 1) * sph + 1, j + (out_c - 1) * spw + 1, c),
                    (sph, spw, 1))
                best = win if best is None else jnp.maximum(best, win)
        return best
    if pkind == "gap":
        s = jnp.sum(t, axis=(0, 1), keepdims=True)
    else:
        s = None
        for i in range(kph):
            for j in range(kpw):
                win = jax.lax.slice(
                    t, (i, j, 0),
                    (i + (out_r - 1) * sph + 1, j + (out_c - 1) * spw + 1, c),
                    (sph, spw, 1))
                s = win if s is None else s + win
    return jnp.sign(s) * ((jnp.abs(s) + cnt // 2) // cnt)


def _elt_apply(t, side, st):
    _, _, s_main, s_side, relu_out = st[:5]
    z = _round_shift(t, s_main) + _round_shift(side, s_side)
    if relu_out:
        z = jnp.maximum(z, 0)
    return jnp.clip(z, -128, 127)


def _mask(t, row0, col0, q, true_h, true_w, fill):
    out_r, out_c, _ = t.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (out_r, out_c, 1), 0) + row0
    cols = jax.lax.broadcasted_iota(jnp.int32, (out_r, out_c, 1), 1) + col0
    valid = ((rows >= q[0]) & (rows < q[0] + true_h)
             & (cols >= q[1]) & (cols < q[1] + true_w))
    return jnp.where(valid, t, fill)


def _chain_kernel(*refs, chain, geom):
    n_conv = sum(1 for st in chain if st[0] == "conv")
    n_side = sum(1 for st in chain if st[0] == "elt")
    x_ref = refs[0]
    wrefs = refs[1:1 + 2 * n_conv]
    srefs = refs[1 + 2 * n_conv:1 + 2 * n_conv + n_side]
    o_ref = refs[-1]
    j = pl.program_id(1)
    jw = pl.program_id(2)

    t = x_ref[0, pl.dslice(j * geom["f_in"], geom["in_rows"])]
    t = jax.lax.dynamic_slice_in_dim(
        t, jw * geom["fw_in"], geom["in_cols"], axis=1).astype(jnp.int32)
    wi = si = 0
    for i, st in enumerate(chain):
        out_r, out_c = geom["rows"][i], geom["cols"][i]
        if st[0] == "conv":
            t = _conv_apply(t, wrefs[2 * wi], wrefs[2 * wi + 1], st,
                            out_r, out_c)
            wi += 1
        elif st[0] == "pool":
            t = _pool_apply(t, st, out_r, out_c)
        else:
            side = srefs[si][0, pl.dslice(j * geom["fout"][i], out_r)]
            side = jax.lax.dynamic_slice_in_dim(
                side, jw * geom["foutw"][i], out_c, axis=1).astype(jnp.int32)
            t = _elt_apply(t, side, st)
            si += 1
        if i + 1 < len(chain):
            true_h, true_w = (st[12], st[13]) if st[0] == "conv" else \
                             (st[9], st[10]) if st[0] == "pool" else \
                             (st[5], st[6])
            t = _mask(t, j * geom["fout"][i], jw * geom["foutw"][i],
                      geom["q"][i], true_h, true_w, _fill_of(chain[i + 1]))
    o_ref[0] = _sat8(t)


def fused_chain_pallas(x_pad, weights, biases, sides, *, chain, th, toc,
                       oh, ow, oc, tw=None, interpret=True):
    """Launch a lowered chain as one kernel.

    x_pad:   (N, Hp, Wp, C) int8, pre-padded per ``chain_geometry`` with the
             first stage's pad identity.
    weights: one (KH, KW, IC, OC) int8 panel per conv stage, in chain order.
    biases:  one (OC,) int32 per conv stage.
    sides:   one pre-padded (N, sHp, sWp, OCs) int8 per elt stage.
    chain:   static stage specs (see ``core.lower``).
    tw:      width-tile size (default: full width).  Ragged bottom/right
             tiles compute into a padded output that is sliced back to
             (oh, ow) here — intermediate masking keeps every valid position
             bit-exact, the sliced-off slack is never read.
    """
    n, hp, wp, c = x_pad.shape
    geom = chain_geometry(chain, th, oh, ow, tw)
    tw = geom["tw"]
    conv_idx = [i for i, st in enumerate(chain) if st[0] == "conv"]
    last_conv = conv_idx[-1] if conv_idx else -1

    grid = (n, geom["n_h"], geom["n_w"], oc // toc)
    in_specs = [pl.BlockSpec((1, hp, wp, c), lambda i, j, jw, k: (i, 0, 0, 0))]
    args = [x_pad]
    for w, b, ci in zip(weights, biases, conv_idx):
        kh, kw, ic, oc_i = w.shape
        if ci == last_conv:
            in_specs.append(pl.BlockSpec((kh, kw, ic, toc),
                                         lambda i, j, jw, k: (0, 0, 0, k)))
            in_specs.append(pl.BlockSpec((toc,), lambda i, j, jw, k: (k,)))
        else:
            in_specs.append(pl.BlockSpec((kh, kw, ic, oc_i),
                                         lambda i, j, jw, k: (0, 0, 0, 0)))
            in_specs.append(pl.BlockSpec((oc_i,), lambda i, j, jw, k: (0,)))
        args.extend([w, b])
    elt_idx = [i for i, st in enumerate(chain) if st[0] == "elt"]
    for ei, s in zip(elt_idx, sides):
        sn, shp, swp, sc = s.shape
        if ei > last_conv:   # rides the TOC slice of the final conv
            in_specs.append(pl.BlockSpec((1, shp, swp, toc),
                                         lambda i, j, jw, k: (i, 0, 0, k)))
        else:
            in_specs.append(pl.BlockSpec((1, shp, swp, sc),
                                         lambda i, j, jw, k: (i, 0, 0, 0)))
        args.append(s)

    kern = functools.partial(_chain_kernel, chain=chain, geom=geom)
    fn = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, th, tw, toc),
                               lambda i, j, jw, k: (i, j, jw, k)),
        out_shape=jax.ShapeDtypeStruct(
            (n, geom["n_h"] * th, geom["n_w"] * tw, oc), jnp.int8),
        interpret=interpret,
    )
    return fn(*args)[:, :oh, :ow]


# ------------------------------------------------------ horizontal (stacked)
def _horizontal_kernel(x_ref, w_ref, b_ref, s_ref, r_ref, o_ref, *,
                       kh, kw, sh, sw, th, tw):
    j = pl.program_id(1)
    jw = pl.program_id(2)
    in_rows = (th - 1) * sh + kh
    in_cols = (tw - 1) * sw + kw
    t = x_ref[0, pl.dslice(j * th * sh, in_rows)]
    t = jax.lax.dynamic_slice_in_dim(
        t, jw * tw * sw, in_cols, axis=1).astype(jnp.int32)
    ic = t.shape[-1]
    toc = w_ref.shape[-1]
    acc = jnp.zeros((th * tw, toc), jnp.int32)
    for i in range(kh):
        for jj in range(kw):
            sl = jax.lax.slice(t, (i, jj, 0),
                               (i + (th - 1) * sh + 1,
                                jj + (tw - 1) * sw + 1, ic), (sh, sw, 1))
            acc = acc + jnp.dot(sl.reshape(th * tw, ic),
                                w_ref[i, jj].astype(jnp.int32),
                                preferred_element_type=jnp.int32)
    acc = acc + b_ref[...].astype(jnp.int32)[None, :]
    y = _round_shift_vec(acc.reshape(th, tw, toc), s_ref[...])
    y = jnp.where(r_ref[...].reshape(1, 1, toc) != 0, jnp.maximum(y, 0), y)
    o_ref[0] = _sat8(y)


def fused_horizontal_pallas(x_pad, w, b, shift_vec, relu_vec, *, stride,
                            th, toc, oh, ow, tw=None, interpret=True):
    """Sibling convs batched over OC-stacked weights.

    w: (KH, KW, IC, sum_OC) int8 stacked along OC; shift_vec/relu_vec: int32
    per-channel requantization shift / ReLU mask.  x_pad pre-padded (the
    launcher pads enough physical slack for the ragged bottom/right tiles;
    their zero-fed slack positions are sliced off here).
    """
    n, hp, wp, ic = x_pad.shape
    kh, kw, _, oc = w.shape
    sh, sw = stride
    tw = ow if tw is None else tw
    n_h = -(-oh // th)
    n_w = -(-ow // tw)
    kern = functools.partial(_horizontal_kernel, kh=kh, kw=kw, sh=sh, sw=sw,
                             th=th, tw=tw)
    fn = pl.pallas_call(
        kern,
        grid=(n, n_h, n_w, oc // toc),
        in_specs=[
            pl.BlockSpec((1, hp, wp, ic), lambda i, j, jw, k: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ic, toc), lambda i, j, jw, k: (0, 0, 0, k)),
            pl.BlockSpec((toc,), lambda i, j, jw, k: (k,)),
            pl.BlockSpec((toc,), lambda i, j, jw, k: (k,)),
            pl.BlockSpec((toc,), lambda i, j, jw, k: (k,)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, toc),
                               lambda i, j, jw, k: (i, j, jw, k)),
        out_shape=jax.ShapeDtypeStruct((n, n_h * th, n_w * tw, oc), jnp.int8),
        interpret=interpret,
    )
    return fn(x_pad, w, b, shift_vec, relu_vec)[:, :oh, :ow]
