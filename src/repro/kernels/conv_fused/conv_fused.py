"""Pallas TPU kernel: fused int8 conv (+bias+requant+ReLU) (+maxpool|+eltwise).

This is the paper's fused-operation executed as ONE on-chip program — the
LOAD/CONV/POOL/MISC/SAVE pipeline of Fig. 8/9 mapped to the TPU:

* LOAD  -> Pallas grid DMA: the BlockSpecs below stage the padded input
           image, the weight panel for the current oc tile and the bias slice
           into VMEM (double-buffered across grid steps by the Pallas
           pipeline, the analogue of the paper's instruction-level overlap);
* CONV  -> MXU matmuls: conv is computed as kh*kw shifted patch-matmuls
           ((TH*OW, IC) @ (IC, TOC)) accumulated in int32 VMEM registers —
           the TPU-native rethinking of the FPGA MAC-array loop nest
           (DESIGN.md §2, adaptation note 1);
* MISC  -> the requantize (+ReLU) and the optional fused tail (maxpool or
           eltwise-add on a DMA'd side input) run on the VPU over the tile
           still resident in VMEM — the intermediate NEVER touches HBM;
* SAVE  -> the output BlockSpec writes the finished int8 tile back.

Tiling contract (chosen by ops.py, validated against the tiling solver):
grid = (N, OH_t, OC_t); each cell produces the FINAL tile (TH, OW, TOC) —
when pooling is fused, TH/OW are pool-output rows/cols and the conv stage
computes the pool's receptive rows (recompute overlap when pool stride <
kernel, documented).  Strided input rows are fetched with the
slice-then-reshape trick so all indexing is lane-aligned.

MXU alignment: TOC should be a multiple of 128 and TH*OW a multiple of 8 for
peak efficiency on real hardware; correctness does not depend on it and the
interpret-mode tests sweep ragged shapes too.

Numerics are EXACTLY ``int8_ops``: int32 accumulate, round-half-away shift,
saturate — the validation bench (validate.py) enforces bit-equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_shift(x, s: int):
    if s == 0:
        return x
    if s < 0:
        return x << (-s)
    ax = jnp.abs(x)
    r = (ax + (1 << (s - 1))) >> s
    return jnp.sign(x) * r


def _sat8(x):
    return jnp.clip(x, -128, 127).astype(jnp.int8)


def _conv_tile(x_ref, w_ref, b_ref, *, kh, kw, sh, sw, th_c, ow_c, row0):
    """int32 conv accumulator for th_c x ow_c x TOC starting at out-row row0."""
    toc = w_ref.shape[-1]
    ic = w_ref.shape[-2]
    acc = jnp.zeros((th_c * ow_c, toc), jnp.int32)
    for dh in range(kh):
        for dw in range(kw):
            # rows row0*sh+dh .. step sh, th_c of them  (slice-reshape stride)
            rows = x_ref[0, pl.dslice(row0 * sh + dh, th_c * sh)]
            rows = rows.reshape(th_c, sh, *rows.shape[1:])[:, 0]
            cols = jax.lax.slice_in_dim(rows, dw, dw + ow_c * sw, axis=1)
            cols = cols.reshape(th_c, ow_c, sw, ic)[:, :, 0]
            patch = cols.reshape(th_c * ow_c, ic).astype(jnp.int32)
            wmat = w_ref[dh, dw].astype(jnp.int32)
            acc = acc + jnp.dot(patch, wmat, preferred_element_type=jnp.int32)
    return (acc + b_ref[...].astype(jnp.int32)[None, :]).reshape(th_c, ow_c, toc)


def _kernel_plain(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sh, sw, th, ow,
                  shift, relu):
    r0 = pl.program_id(1) * th
    acc = _conv_tile(x_ref, w_ref, b_ref, kh=kh, kw=kw, sh=sh, sw=sw,
                     th_c=th, ow_c=ow, row0=r0)
    y = _round_shift(acc, shift)
    if relu:
        y = jnp.maximum(y, 0)
    o_ref[0] = _sat8(y)


def _kernel_pool(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sh, sw, th, ow,
                 shift, relu, kp, sp, ow_c):
    # th/ow are POOL-output tile dims; conv stage covers the receptive rows
    th_c = (th - 1) * sp + kp
    r0 = pl.program_id(1) * th * sp  # conv out-row of this pool tile's top
    acc = _conv_tile(x_ref, w_ref, b_ref, kh=kh, kw=kw, sh=sh, sw=sw,
                     th_c=th_c, ow_c=ow_c, row0=r0)
    y = _round_shift(acc, shift)
    if relu:
        y = jnp.maximum(y, 0)
    y = jnp.clip(y, -128, 127)
    # maxpool on the resident tile (VPU stage) — window max via shifted slices
    toc = y.shape[-1]
    best = jnp.full((th, ow, toc), -(2 ** 31 - 1), jnp.int32)
    for ph in range(kp):
        for pw_ in range(kp):
            win = jax.lax.slice(y, (ph, pw_, 0),
                                (ph + (th - 1) * sp + 1, pw_ + (ow - 1) * sp + 1, toc),
                                (sp, sp, 1))
            best = jnp.maximum(best, win)
    o_ref[0] = best.astype(jnp.int8)


def _kernel_eltwise(x_ref, w_ref, b_ref, side_ref, o_ref, *, kh, kw, sh, sw,
                    th, ow, shift, relu, s_conv, s_side, relu_out):
    r0 = pl.program_id(1) * th
    acc = _conv_tile(x_ref, w_ref, b_ref, kh=kh, kw=kw, sh=sh, sw=sw,
                     th_c=th, ow_c=ow, row0=r0)
    y = _round_shift(acc, shift)          # conv result at its own fraction
    if relu:
        y = jnp.maximum(y, 0)
    y = jnp.clip(y, -128, 127)
    # eltwise-add: rescale both operands to the output fraction, add, saturate
    side = side_ref[0].astype(jnp.int32)
    z = _round_shift(y, s_conv) + _round_shift(side, s_side)
    if relu_out:
        z = jnp.maximum(z, 0)
    o_ref[0] = _sat8(z)


def fused_conv_pallas(x_pad, w, b, *, stride, shift, relu,
                      th, toc, oh, ow, pool=None, eltwise=None,
                      interpret=True):
    """Launch the fused kernel.

    x_pad: (N, Hp, Wp, IC) int8, already padded (pad is fused into LOAD,
           paper §4.1.1).  w: (KH, KW, IC, OC) int8.  b: (OC,) int32.
    pool:  None | (kp, sp)   — fused maxpool tail.
    eltwise: None | (side_array int8 (N,OH,OW,OC), s_conv, s_side, relu_out).
    th/toc: tile rows (of the FINAL output) and oc tile; must divide oh/oc.
    """
    n, hp, wp, ic = x_pad.shape
    kh, kw, _, oc = w.shape
    sh, sw = stride
    if pool is not None:
        kp, sp = pool
        oh_f, ow_f = oh, ow               # pool-output dims
        ow_c = (ow - 1) * sp + kp         # conv cols needed
        kern = functools.partial(_kernel_pool, kh=kh, kw=kw, sh=sh, sw=sw,
                                 th=th, ow=ow_f, shift=shift, relu=relu,
                                 kp=kp, sp=sp, ow_c=ow_c)
    elif eltwise is not None:
        _, s_conv, s_side, relu_out = eltwise
        oh_f, ow_f = oh, ow
        kern = functools.partial(_kernel_eltwise, kh=kh, kw=kw, sh=sh, sw=sw,
                                 th=th, ow=ow_f, shift=shift, relu=relu,
                                 s_conv=s_conv, s_side=s_side, relu_out=relu_out)
    else:
        oh_f, ow_f = oh, ow
        kern = functools.partial(_kernel_plain, kh=kh, kw=kw, sh=sh, sw=sw,
                                 th=th, ow=ow_f, shift=shift, relu=relu)

    grid = (n, oh_f // th, oc // toc)
    in_specs = [
        # full padded image per batch element (T_w = full width, paper Eq. 5)
        pl.BlockSpec((1, hp, wp, ic), lambda i, j, k: (i, 0, 0, 0)),
        pl.BlockSpec((kh, kw, ic, toc), lambda i, j, k: (0, 0, 0, k)),
        pl.BlockSpec((toc,), lambda i, j, k: (k,)),
    ]
    args = [x_pad, w, b]
    if eltwise is not None:
        side = eltwise[0]
        in_specs.append(pl.BlockSpec((1, th, ow_f, toc),
                                     lambda i, j, k: (i, j, 0, k)))
        args.append(side)
    out_spec = pl.BlockSpec((1, th, ow_f, toc), lambda i, j, k: (i, j, 0, k))
    fn = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, oh_f, ow_f, oc), jnp.int8),
        interpret=interpret,
    )
    return fn(*args)
