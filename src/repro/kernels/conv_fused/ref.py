"""Pure-jnp oracle for the fused conv kernel.

Composes the canonical ``int8_ops`` semantics exactly as the unfused executor
would — the kernel must match this bit-for-bit (validate.py / kernel tests).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import int8_ops


def fused_conv_ref(x, w, b, *, stride, pad, shift, relu,
                   pool=None, eltwise=None):
    """x (N,H,W,IC) int8 unpadded; w (KH,KW,IC,OC) int8; b (OC,) int32.

    pool:    None | (kp, sp)  fused maxpool (VALID, no ceil extension).
    eltwise: None | (side int8 at OH/OW/OC, s_conv, s_side, relu_out).
    """
    y = int8_ops.conv2d(x, w, b, stride=stride, pad=pad, shift=shift, relu=relu)
    if pool is not None:
        kp, sp = pool
        y = int8_ops.maxpool(y, kernel=(kp, kp), stride=(sp, sp), pad=(0, 0),
                             ceil_mode=False)
    if eltwise is not None:
        side, s_conv, s_side, relu_out = eltwise
        acc = (int8_ops.round_shift(y.astype(jnp.int32), s_conv)
               + int8_ops.round_shift(side.astype(jnp.int32), s_side))
        if relu_out:
            acc = jnp.maximum(acc, 0)
        y = int8_ops.sat8(acc)
    return y
