"""jit'd wrapper for the chunked SSM-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssm_scan.ssm_scan import ssm_scan_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(q, k, v, log_a, *, chunk=128, interpret=True):
    return ssm_scan_pallas(q, k, v, log_a, chunk=chunk, interpret=interpret)
