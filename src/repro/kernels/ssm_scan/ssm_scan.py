"""Pallas TPU kernel: chunked linear-recurrence scan (mLSTM / Mamba2 SSD).

The recurrence S_t = a_t S_{t-1} + k_t v_t^T, y_t = S_t^T q_t is evaluated
chunk-parallel: the (L x L) decay-masked intra-chunk contraction runs on the
MXU while the (K x V) state tile stays resident in VMEM across the chunk
loop — DNNVM's fusion condition 1 picks the chunk length L so that
(3 L d + L^2 + K V) elements fit the VMEM budget (the same tiling solver
vocabulary as the conv kernels; DESIGN.md §5).

Grid = (B*H,); each cell owns one head's full sequence and walks its chunks
with a fori_loop carrying the fp32 state.  Numerics match
``repro.nn.recurrent.chunked_linear_scan`` (the jnp oracle) to fp32 tol.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, la_ref, o_ref, *, L, n_chunks):
    dk = q_ref.shape[-1]
    dv = v_ref.shape[-1]
    tri = jnp.tril(jnp.ones((L, L), jnp.float32))

    def body(ci, S):
        sl = pl.dslice(ci * L, L)
        qb = q_ref[0, sl].astype(jnp.float32)          # (L, K)
        kb = k_ref[0, sl].astype(jnp.float32)
        vb = v_ref[0, sl].astype(jnp.float32)          # (L, V)
        lab = la_ref[0, sl].astype(jnp.float32)        # (L,)
        cum = jnp.cumsum(lab)
        A = jnp.exp(cum[:, None] - cum[None, :]) * tri
        scores = (qb @ kb.T) * A
        intra = scores @ vb
        inter = (qb * jnp.exp(cum)[:, None]) @ S
        o_ref[0, sl] = (intra + inter).astype(o_ref.dtype)
        total = cum[-1]
        w = jnp.exp(total - cum)[:, None]
        S = jnp.exp(total) * S + (kb * w).T @ vb
        return S

    jax.lax.fori_loop(0, n_chunks, body,
                      jnp.zeros((dk, dv), jnp.float32))


def ssm_scan_pallas(q, k, v, log_a, *, chunk=128, interpret=True):
    """q,k (B,S,H,K); v (B,S,H,V); log_a (B,S,H).  Returns y (B,S,H,V)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, s)
    assert s % L == 0
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, dk)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, s, dk)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, s, dv)
    lar = log_a.transpose(0, 2, 1).reshape(b * h, s)
    kern = functools.partial(_kernel, L=L, n_chunks=s // L)
    out = pl.pallas_call(
        kern,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, s, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, dv), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), v.dtype),
        interpret=interpret,
    )(qr, kr, vr, lar)
    return out.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
