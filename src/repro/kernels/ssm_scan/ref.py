"""Oracles for the SSM scan kernel.

``chunked_ref`` is the production jnp implementation; ``sequential_ref`` is
the definitionally-true O(S) recurrence both must match."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.recurrent import chunked_linear_scan


def chunked_ref(q, k, v, log_a, chunk=128):
    y, _ = chunked_linear_scan(q, k, v, log_a, chunk=chunk)
    return y


def sequential_ref(q, k, v, log_a):
    """Step-by-step recurrence: S_t = a_t S_{t-1} + k_t v_t^T; y = S^T q."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]

    def step(S, xs):
        qt, kt, vt, lat = xs                      # (B,H,K),(B,H,K),(B,H,V),(B,H)
        a = jnp.exp(lat.astype(jnp.float32))[..., None, None]
        S = a * S + jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32),
                               vt.astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), S)
        return S, y

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_a.transpose(1, 0, 2))
    S0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3).astype(v.dtype)
