"""Family dispatcher: one API over all assigned architectures.

* ``init_params(cfg, rng)``        real arrays (smoke tests / training)
* ``abstract_params(cfg)``         ShapeDtypeStructs (dry-run; no allocation)
* ``loss_fn(cfg, params, batch)``  scalar LM loss
* ``init_cache / decode_step``     serving path (one token, KV/SSM state)
* ``input_specs(cfg, shape)``      ShapeDtypeStruct stand-ins for every model
                                   input of an (arch x shape) cell
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCfg
from repro.nn import encdec, model, xlstm, zamba

ENC_FRACTION = {  # seamless: encoder/decoder split of seq_len per shape kind
    "train": 0.5, "prefill": 0.875, "decode": None,
}
SEAMLESS_DECODE_ENC_LEN = 4096


def _mod(cfg: ArchConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return model
    if cfg.family == "ssm":
        return xlstm
    if cfg.family == "hybrid":
        return zamba
    if cfg.family == "audio":
        return encdec
    raise ValueError(f"unknown family {cfg.family}")


def init_params(cfg: ArchConfig, rng=None):
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    return _mod(cfg).init_params(cfg, rng)


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def loss_fn(cfg: ArchConfig, params, batch):
    return _mod(cfg).loss_fn(cfg, params, batch)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.family == "audio":
        return encdec.init_cache(cfg, batch, max_len, SEAMLESS_DECODE_ENC_LEN)
    return _mod(cfg).init_cache(cfg, batch, max_len)


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    return _mod(cfg).decode_step(cfg, params, cache, tokens, pos)


# ------------------------------------------------------------- input specs
def input_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    train/prefill: the token/frame batch (modality stubs included);
    decode: one token per sequence + the absolute position scalar (the KV
    cache is part of the serve state, see abstract_cache)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            frac = ENC_FRACTION[shape.kind]
            se = int(S * frac)
            sd = S - se
            return {
                "frames": jax.ShapeDtypeStruct((B, se, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, sd), i32),
                "labels": jax.ShapeDtypeStruct((B, sd), i32),
            }
        if cfg.family == "vlm":
            npat = min(cfg.n_patches, S // 2)
            st = S - npat
            return {
                "patch_embeds": jax.ShapeDtypeStruct((B, npat, cfg.d_model),
                                                     jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, st), i32),
                "labels": jax.ShapeDtypeStruct((B, st), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
