from repro.models.api import (  # noqa: F401
    abstract_params, decode_step, init_cache, init_params, input_specs,
    loss_fn,
)
