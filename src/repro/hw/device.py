"""Hardware device models.

A ``DeviceModel`` captures exactly what the DNNVM optimizers need to know:

* on-chip buffer budget (BRAM on the FPGA, VMEM on TPU) split into input /
  weight / output regions, mirroring the paper's pre-allocated BRAM banks
  (B_in, B_weights, B_out in Eq. 6);
* the compute-array parallelism (ic_p, oc_p, h_p) — for TPU these become the
  MXU lane/sublane tile factors;
* clock frequency, off-chip bandwidth, and per-cycle MAC throughput, which
  the time-wheel simulator converts into LOAD/COMPUTE/SAVE lane occupancy.

The paper's published numbers:
  ZU2 @330 MHz: ic_p=24, oc_p=12, h_p=4, 0.66 MB BRAM, peak 380 GOPs/s (int8)
  ZU9 @330 MHz: ic_p=32, oc_p=16, h_p=8, 4 MB BRAM, peak 4.05 TOPs/s¹ (int8)
  (¹ peak at 330 MHz with batch 3; our model uses the single-sample engine.)

TPU v5e (target): 197 TFLOP/s bf16 (≈394 TOPs int8), 819 GB/s HBM,
~128 MB VMEM/core of which we budget 96 MB for data (rest: semaphores,
double-buffering headroom), ICI ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    freq_hz: float                 # clock for the cycle simulator
    ic_p: int                      # parallelism along input channels
    oc_p: int                      # parallelism along output channels
    h_p: int                       # parallelism along feature-map height
    buf_in_bytes: int              # B_in   (Eq. 6)
    buf_weights_bytes: int         # B_weights
    buf_out_bytes: int             # B_out
    dram_bw_bytes_per_s: float     # off-chip bandwidth (DDR / HBM)
    elem_bytes: int = 1            # int8 data path by default (paper §2.3.4)
    # off-chip capacity + alignment for the memory planner (memory/planner.py):
    # activation peak must fit ddr_bytes (0 => unbounded), buffers are placed
    # at ddr_align boundaries (AXI burst alignment).
    ddr_bytes: int = 0
    ddr_align: int = 64
    # engine throughput (elements/cycle).  Calibrated against the paper's own
    # micro-timings (Fig. 8: 3x3 pool over 28x28x256 takes 0.242 ms => ~22
    # elems/cycle on ZU2; Fig. 9: eltwise-add over ~0.8 MB takes 0.833 ms =>
    # ~8 elems/cycle).  0 => derived defaults below.
    pool_lanes: int = 0            # 0 => oc_p * h_p // 2
    misc_lanes: int = 0            # 0 => max(8, oc_p * h_p // 6)
    # ICI for multi-chip rooflines (0 for the FPGA single-chip devices)
    ici_bw_bytes_per_s: float = 0.0
    # Published peak (OPs/s, MAC=2 ops).  The paper's peak numbers (380 GOPs/s
    # ZU2) imply an *effective* MAC rate below the raw ic_p*oc_p*h_p array
    # product (DSP packing bookkeeping); when set, compute cycles are derived
    # from this effective rate while the published (ic_p, oc_p, h_p) still
    # drive tiling and ragged-tile rounding.  0 => use the array product.
    peak_ops_override: float = 0.0

    def replace(self, **overrides) -> "DeviceModel":
        """A copy with some fields overridden.  This is how a calibrated
        ``tune.DeviceProfile`` projects measured effective rates (DRAM
        bandwidth, peak OPs, pool/misc lanes) back onto a device model for
        consumers of the analytic pipeline cost (``profile.to_device_model``);
        the array geometry (ic_p/oc_p/h_p) that drives tiling stays put
        unless explicitly overridden."""
        return dataclasses.replace(self, **overrides)

    @property
    def macs_per_cycle(self) -> int:
        return self.ic_p * self.oc_p * self.h_p

    @property
    def macs_per_cycle_eff(self) -> float:
        if self.peak_ops_override:
            return self.peak_ops_override / (2.0 * self.freq_hz)
        return float(self.macs_per_cycle)

    @property
    def peak_ops_per_s(self) -> float:
        # 1 MAC = 2 ops, the paper's GOPs/s convention.
        return self.peak_ops_override or 2.0 * self.macs_per_cycle * self.freq_hz

    @property
    def onchip_bytes(self) -> int:
        return self.buf_in_bytes + self.buf_weights_bytes + self.buf_out_bytes

    @property
    def pool_elems_per_cycle(self) -> int:
        return self.pool_lanes or max(1, self.oc_p * self.h_p // 2)

    @property
    def misc_elems_per_cycle(self) -> int:
        return self.misc_lanes or max(8, self.oc_p * self.h_p // 6)


# --- The paper's FPGA devices -------------------------------------------------
# BRAM split: the paper pre-allocates fixed banks for ifmaps / weights / ofmaps
# (§3.1); the exact split is unpublished, we use 45% / 35% / 20% which admits
# the paper's own fused examples (Fig. 8: 28x28x32 in, 5x5x32x256 w, 28x28x256
# out tiles).  DDR bandwidth is likewise unpublished; ZU2 boards ship a 32-bit
# DDR4-2400 interface => ~9.6 GB/s theoretical, we model 6.0 GB/s sustained.
_ZU2_BRAM = int(0.66 * 1024 * 1024)
_ZU9_BRAM = 4 * 1024 * 1024

ZU2 = DeviceModel(
    name="zu2",
    freq_hz=330e6,
    ic_p=24, oc_p=12, h_p=4,              # => 380.2 GOPs/s peak, matches paper
    buf_in_bytes=int(_ZU2_BRAM * 0.45),
    buf_weights_bytes=int(_ZU2_BRAM * 0.35),
    buf_out_bytes=int(_ZU2_BRAM * 0.20),
    dram_bw_bytes_per_s=3.4e9,            # calibrated: see EXPERIMENTS.md §Repro
    peak_ops_override=380e9,              # paper's published ZU2 peak
    ddr_bytes=2 * 1024 ** 3,              # 2 GB board DDR4
)

ZU9 = DeviceModel(
    name="zu9",
    freq_hz=330e6,
    ic_p=32, oc_p=16, h_p=8,              # 2.7 TOPs engine; ZU9 runs batch 3
    buf_in_bytes=int(_ZU9_BRAM * 0.45),
    buf_weights_bytes=int(_ZU9_BRAM * 0.35),
    buf_out_bytes=int(_ZU9_BRAM * 0.20),
    dram_bw_bytes_per_s=6.0e9,            # paper §6.2.3 reports bandwidth
                                          # saturation on ZU9; calibrated
    peak_ops_override=4.05e12,            # paper's ZU9 peak (batch-3 engine)
    ddr_bytes=4 * 1024 ** 3,              # 4 GB board DDR4
)

# --- TPU v5e ------------------------------------------------------------------
# The MXU is a 128x128 systolic array: ic_p=oc_p=128 (contraction/output
# lanes), h_p=8 (sublanes).  Effective compute rate comes from the published
# 197 TFLOP/s bf16 peak via peak_ops_override; (ic_p, oc_p, h_p) still drive
# tile alignment and ragged-tile rounding.
_V5E_VMEM = 96 * 1024 * 1024

TPU_V5E = DeviceModel(
    name="tpu_v5e",
    freq_hz=940e6,
    ic_p=128, oc_p=128, h_p=8,
    buf_in_bytes=int(_V5E_VMEM * 0.45),
    buf_weights_bytes=int(_V5E_VMEM * 0.35),
    buf_out_bytes=int(_V5E_VMEM * 0.20),
    dram_bw_bytes_per_s=819e9,
    elem_bytes=1,                          # int8 inference data path
    ici_bw_bytes_per_s=50e9,
    peak_ops_override=197e12,
    pool_lanes=1024, misc_lanes=1024,      # VPU 8x128 lanes
    ddr_bytes=16 * 1024 ** 3,              # 16 GB HBM
    ddr_align=512,                         # HBM burst / lane-tile alignment
)

_DEVICES = {d.name: d for d in (ZU2, ZU9, TPU_V5E)}


def get_device(name: str) -> DeviceModel:
    try:
        return _DEVICES[name]
    except KeyError:
        raise KeyError(f"unknown device {name!r}; have {sorted(_DEVICES)}") from None
