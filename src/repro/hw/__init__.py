"""Device models: the hardware parameters that drive tiling, fusion-capacity
checks and the cycle simulator.

The paper's accelerator (Angel-Eye-derived, ZU2/ZU9) and our TPU v5e target are
described by the same small set of numbers, so the whole compiler stack is
hardware-parameterized (DESIGN.md §2).
"""
from repro.hw.device import DeviceModel, ZU2, ZU9, TPU_V5E, get_device

__all__ = ["DeviceModel", "ZU2", "ZU9", "TPU_V5E", "get_device"]
