"""Memory planning (paper §3.1 "data layouts" + §3.2 assembler support).

The paper describes DNNVM as "an integration of optimizers for graphs, loops
and data layouts, and an assembler"; this package is the data-layout half:

* ``liveness``  — activation lifetimes over the group execution order;
* ``ddr_alloc`` — first-fit interval allocation of DDR offsets with reuse;
* ``banks``     — ping/pong split of the B_in / B_out BRAM budgets (Eq. 6)
  for double buffering;
* ``planner``   — ties the three together into a :class:`MemoryPlan` the
  assembler (``core.isa``) threads into address-bearing instructions.
"""
from repro.memory.banks import BankPlan, plan_banks
from repro.memory.ddr_alloc import DDRPlan, Placement, first_fit
from repro.memory.liveness import Interval, activation_intervals
from repro.memory.planner import MemoryPlan, MemoryPlanError, plan_memory

__all__ = [
    "Interval", "activation_intervals",
    "DDRPlan", "Placement", "first_fit",
    "BankPlan", "plan_banks",
    "MemoryPlan", "MemoryPlanError", "plan_memory",
]
