"""Ping/pong bank split of the on-chip buffers (paper §3.1 / Eq. 6).

The accelerator pre-allocates BRAM regions B_in and B_out.  Double buffering
— LOAD(t+1) streaming into one bank while CONV(t) reads the other — needs
*two* tile-sized banks per region.  The planner:

* assigns 2 banks when two tile working sets fit the region (the normal,
  fully pipelined case);
* falls back to 1 bank when only one tile fits — the tile chain serializes
  (LOAD(t+1) must wait for the consumer of tile t), which the assembler
  enforces with bank-reuse dependency bits;
* rejects the tiling outright when even a single tile exceeds the region
  (cannot happen for tilings produced by ``tiling.solve``, which checks the
  same bound, but callers may hand-construct tilings).

Full-channel intermediates of a fused conv->conv chain stay resident in B_out
across oc passes, so only the final output tile swings between banks — the
resident bytes are charged once, not per bank.
"""
from __future__ import annotations

import dataclasses

from repro.core.tiling import GroupTiling
from repro.hw import DeviceModel


@dataclasses.dataclass(frozen=True)
class BankPlan:
    feasible: bool
    n_banks_in: int = 1
    n_banks_out: int = 1
    in_bank_bytes: int = 0         # capacity of one B_in bank
    out_bank_bytes: int = 0        # capacity of one B_out bank
    reason: str = ""
    # bank-assignment policy is tile % n_banks, implemented where the banks
    # are stamped onto instructions (isa.emit_group) — single source of truth


def plan_banks(tiling: GroupTiling, dev: DeviceModel) -> BankPlan:
    """Bank assignment for one group's tiling on ``dev``."""
    if not tiling.feasible:
        return BankPlan(False, reason="tiling itself is infeasible")
    in_need = tiling.in_tile_bytes
    out_need = tiling.out_tile_bytes
    resident = tiling.resident_bytes
    if in_need > dev.buf_in_bytes:
        return BankPlan(False, reason=(
            f"input tile {in_need}B exceeds B_in {dev.buf_in_bytes}B"))
    if out_need + resident > dev.buf_out_bytes:
        return BankPlan(False, reason=(
            f"output tile {out_need}B + resident {resident}B exceeds "
            f"B_out {dev.buf_out_bytes}B"))
    n_in = 2 if 2 * in_need <= dev.buf_in_bytes else 1
    n_out = 2 if 2 * out_need + resident <= dev.buf_out_bytes else 1
    return BankPlan(
        True, n_banks_in=n_in, n_banks_out=n_out,
        in_bank_bytes=dev.buf_in_bytes // n_in,
        out_bank_bytes=(dev.buf_out_bytes - resident) // n_out)
