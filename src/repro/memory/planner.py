"""Top-level memory planner: liveness -> DDR offsets -> BRAM banks.

``plan_memory`` turns an ordered execution strategy (groups + their tilings)
into a :class:`MemoryPlan`: every DDR activation buffer gets an offset, every
group gets a ping/pong bank assignment, and every address reuse records which
expired buffers it recycles so the assembler can emit write-after-read
dependency bits.  The plan is what upgrades the timing-only instruction
streams of ``core.isa`` into an addressed program a runtime could actually
execute — and what the simulator's hazard checker audits.
"""
from __future__ import annotations

import dataclasses

from repro.core.tiling import GroupTiling
from repro.core.xgraph import XGraph
from repro.hw import DeviceModel
from repro.memory.banks import BankPlan, plan_banks
from repro.memory.ddr_alloc import DDRPlan, first_fit
from repro.memory.liveness import activation_intervals


class MemoryPlanError(ValueError):
    """A strategy that cannot be laid out on the device."""


@dataclasses.dataclass
class MemoryPlan:
    ddr: DDRPlan
    intervals: list                 # list[Interval], schedule order
    banks: list                     # list[BankPlan], one per group
    buf_of_node: dict               # exposed node / graph input -> buffer name
    war: list                       # per group: tuple of recycled buffer names
    pin_input: bool = False         # graph-input regions kept out of reuse

    @property
    def peak_bytes(self) -> int:
        return self.ddr.peak_bytes

    @property
    def no_reuse_bytes(self) -> int:
        return self.ddr.no_reuse_bytes

    @property
    def reuse_factor(self) -> float:
        return self.ddr.reuse_factor

    def node_region(self, node: str) -> tuple[int, int]:
        """(DDR offset, bytes) of one node's feature map within its buffer."""
        buf = self.buf_of_node[node]
        base, _ = self.ddr.region_of(buf)
        iv = self.ddr.placements[buf].interval
        names = sorted(iv.parts, key=iv.parts.get)
        i = names.index(node)
        end = iv.parts[names[i + 1]] if i + 1 < len(names) else iv.nbytes
        return base + iv.parts[node], end - iv.parts[node]

    def group_out_region(self, gid: int) -> tuple[int, int]:
        """(DDR offset, bytes) of one group's whole output buffer."""
        iv = self.intervals_by_gid().get(gid)
        if iv is None or iv.nbytes == 0:
            return -1, 0
        base, size = self.ddr.region_of(iv.name)
        return base, size

    def intervals_by_gid(self) -> dict:
        by_gid = getattr(self, "_by_gid", None)
        if by_gid is None:
            by_gid = {iv.writer_gid: iv for iv in self.intervals
                      if iv.writer_gid >= 0}
            self._by_gid = by_gid
        return by_gid

    def summary(self) -> dict:
        return {
            "n_buffers": len(self.intervals),
            "peak_bytes": self.peak_bytes,
            "no_reuse_bytes": self.no_reuse_bytes,
            "reuse_factor": self.reuse_factor,
            "n_reused": len(self.ddr.reuses),
            "double_buffered_groups": sum(
                1 for b in self.banks if b.n_banks_in == 2),
            "pin_input": self.pin_input,
        }


def plan_memory(g: XGraph, groups: list[list[str]],
                tilings: list[GroupTiling], dev: DeviceModel,
                pin_input: bool = False) -> MemoryPlan:
    """Plan DDR + bank layout for ``groups`` (execution order) on ``dev``.

    ``pin_input`` reserves the network input's DDR region for the whole
    schedule (never recycled) — slightly higher peak, but the serving
    runtime's cross-request pre-load guard disappears (see
    ``memory.liveness.activation_intervals``).

    Raises :class:`MemoryPlanError` when a group's tile cannot fit the BRAM
    banks or the activation peak exceeds the device's DDR capacity.
    """
    if len(groups) != len(tilings):
        raise ValueError(f"{len(groups)} groups vs {len(tilings)} tilings")
    eb = dev.elem_bytes
    intervals = activation_intervals(g, groups, eb, pin_input=pin_input)
    ddr = first_fit(intervals, align=dev.ddr_align)
    cap = getattr(dev, "ddr_bytes", 0)
    if cap and ddr.peak_bytes > cap:
        raise MemoryPlanError(
            f"activation peak {ddr.peak_bytes}B exceeds DDR capacity {cap}B "
            f"on {dev.name}")

    banks: list[BankPlan] = []
    for grp, t in zip(groups, tilings):
        bp = plan_banks(t, dev)
        if not bp.feasible:
            raise MemoryPlanError(f"group {grp}: {bp.reason}")
        banks.append(bp)

    buf_of_node = {}
    for iv in intervals:
        for nm in iv.parts:
            buf_of_node[nm] = iv.name

    by_gid = {iv.writer_gid: iv for iv in intervals if iv.writer_gid >= 0}
    war = []
    for gi in range(len(groups)):
        iv = by_gid.get(gi)
        war.append(tuple(ddr.reuses.get(iv.name, ())) if iv else ())

    return MemoryPlan(ddr=ddr, intervals=intervals, banks=banks,
                      buf_of_node=buf_of_node, war=war, pin_input=pin_input)
