"""Activation liveness over the group execution order.

Every DDR-resident activation buffer is either a graph input (written by the
host before step 0) or the exposed output of one execution group (written by
that group's SAVEs).  Exposure is ``XGraph.exposed_outputs`` — the same
helper the assembler (``isa.emit_strategy``) uses, so planner and emitted
SAVE stream cannot desync: a chain group exposes only its tail, a horizontal
group exposes every member; interior nodes of a fused chain never touch DDR,
which is the whole point of kernel fusion.

A buffer's lifetime is the closed step interval [writer step, last reader
step].  Readers outside any group (host-partitioned ops, graph outputs) pin
the buffer to the end of the schedule — the host reads it after the
accelerator finishes, so its space is never recycled.
"""
from __future__ import annotations

import dataclasses

from repro.core.xgraph import XGraph


@dataclasses.dataclass
class Interval:
    name: str                      # buffer label, unique per plan
    nbytes: int
    start: int                     # writing step (-1: graph input, pre-loaded)
    end: int                       # last reading step (len(groups): live to end)
    writer_gid: int                # group index, -1 for graph inputs
    parts: dict = dataclasses.field(default_factory=dict)  # node -> byte offset

    def overlaps(self, other: "Interval") -> bool:
        return self.start <= other.end and other.start <= self.end


def activation_intervals(g: XGraph, groups: list[list[str]],
                         elem_bytes: int = 1,
                         pin_input: bool = False) -> list[Interval]:
    """Lifetimes of every DDR activation buffer for ``groups`` in execution
    order.  Buffers with no in-schedule reader (graph outputs, host-consumed
    activations) end at ``len(groups)``.

    ``pin_input`` extends every graph-input buffer to the end of the
    schedule, keeping its DDR region out of the reuse pool: a later group's
    output can then never recycle the input's address, so a pipelined
    serving runtime needs no write-after-read guard between request r's
    recycled SAVEs and request r+ddr_slots's pre-loaded input reads (the
    guard that throttles cross-request overlap in ``runtime.schedule``)."""
    nsteps = len(groups)
    owner: dict[str, int] = {}
    for gi, grp in enumerate(groups):
        for nm in grp:
            owner[nm] = gi

    def last_reader(node_name: str, writer_gid: int) -> int:
        cons = g.consumers(node_name)
        if not cons:
            return nsteps
        end = writer_gid
        for c in cons:
            ci = owner.get(c)
            if ci is None:            # host op or unplanned consumer
                return nsteps
            if ci != writer_gid:      # intra-group reads stay on chip
                end = max(end, ci)
        return end

    intervals: list[Interval] = []
    for node in g:
        if node.op != "input":
            continue
        end = nsteps if pin_input else last_reader(node.name, -1)
        iv = Interval(f"in:{node.name}", g.fmap_bytes(node.name, elem_bytes),
                      start=-1, end=end, writer_gid=-1,
                      parts={node.name: 0})
        intervals.append(iv)

    for gi, grp in enumerate(groups):
        parts, off, end = {}, 0, gi
        for nm in g.exposed_outputs(grp):
            parts[nm] = off
            off += g.fmap_bytes(nm, elem_bytes)
            end = max(end, last_reader(nm, gi))
        intervals.append(Interval(f"g{gi}:{grp[-1]}", off, start=gi, end=end,
                                  writer_gid=gi, parts=parts))
    return intervals
