"""First-fit DDR offset allocation over activation lifetimes.

Classic interval-graph register allocation applied to DDR: process buffers in
schedule order and place each at the lowest aligned offset that does not
collide with any *concurrently live* buffer.  Buffers whose lifetimes are
disjoint may share addresses — that reuse is what separates the peak DDR
footprint from the sum-of-all-buffers baseline, and every reuse is recorded so
the assembler can emit the write-after-read dependency protecting it (the
previous tenant's last LOAD must retire before the new tenant's first SAVE).
"""
from __future__ import annotations

import dataclasses

from repro.memory.liveness import Interval


@dataclasses.dataclass
class Placement:
    interval: Interval
    offset: int
    size: int                      # aligned size actually reserved

    @property
    def limit(self) -> int:
        return self.offset + self.size


@dataclasses.dataclass
class DDRPlan:
    placements: dict                # buffer name -> Placement
    peak_bytes: int                 # max concurrent footprint (with reuse)
    no_reuse_bytes: int             # sum of all buffers (baseline)
    align: int
    reuses: dict                    # buffer name -> [expired buffer names whose
                                    #                 address range it recycles]

    @property
    def reuse_factor(self) -> float:
        return self.no_reuse_bytes / max(1, self.peak_bytes)

    def region_of(self, buf_name: str) -> tuple[int, int]:
        p = self.placements[buf_name]
        return p.offset, p.interval.nbytes


def first_fit(intervals: list[Interval], align: int = 64) -> DDRPlan:
    """Place every interval; returns the plan with peak/no-reuse footprints."""
    def up(n: int) -> int:
        return max(align, (n + align - 1) // align * align)

    placed: list[Placement] = []
    placements: dict[str, Placement] = {}
    reuses: dict[str, list[str]] = {}
    order = sorted(intervals, key=lambda iv: (iv.start, -iv.nbytes, iv.name))
    for iv in order:
        size = up(iv.nbytes)
        live = sorted((p for p in placed if p.interval.overlaps(iv)),
                      key=lambda p: p.offset)
        off = 0
        for p in live:
            if off + size <= p.offset:
                break
            off = max(off, p.limit)
        pl = Placement(iv, off, size)
        placed.append(pl)
        placements[iv.name] = pl
        recycled = [p.interval.name for p in placed[:-1]
                    if not p.interval.overlaps(iv)
                    and p.offset < pl.limit and off < p.limit]
        if recycled:
            reuses[iv.name] = recycled
    peak = max((p.limit for p in placed), default=0)
    total = sum(p.size for p in placed)
    return DDRPlan(placements=placements, peak_bytes=peak,
                   no_reuse_bytes=total, align=align, reuses=reuses)
