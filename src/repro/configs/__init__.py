"""Assigned-architecture registry: ``get("granite-8b")`` etc."""
from repro.configs.base import ArchConfig, MoECfg, SHAPES, ShapeCfg, shapes_for

from repro.configs.granite_8b import CONFIG as granite_8b
from repro.configs.starcoder2_3b import CONFIG as starcoder2_3b
from repro.configs.smollm_360m import CONFIG as smollm_360m
from repro.configs.llama3_405b import CONFIG as llama3_405b
from repro.configs.mixtral_8x22b import CONFIG as mixtral_8x22b
from repro.configs.mixtral_8x7b import CONFIG as mixtral_8x7b
from repro.configs.xlstm_1_3b import CONFIG as xlstm_1_3b
from repro.configs.qwen2_vl_7b import CONFIG as qwen2_vl_7b
from repro.configs.seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from repro.configs.zamba2_1_2b import CONFIG as zamba2_1_2b

ARCHS = {c.name: c for c in (
    granite_8b, starcoder2_3b, smollm_360m, llama3_405b, mixtral_8x22b,
    mixtral_8x7b, xlstm_1_3b, qwen2_vl_7b, seamless_m4t_large_v2, zamba2_1_2b,
)}


def get(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}") from None
