"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: the mLSTM block
carries its own 2x up-projection.  An sLSTM block every 8th layer ([7:1]
flavor); mLSTM uses the chunked-parallel linear-recurrence form, sigmoid
gating (exponential-gating stabilizer omitted — DESIGN.md §5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, slstm_every=8, tie_embeddings=True,
)
