"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM; hf].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152, rope_theta=1e4,
    notes="15 heads are not divisible by the 16-way model axis: attention "
          "weights replicate, FFN/vocab still TP-shard (DESIGN.md §5).",
)
