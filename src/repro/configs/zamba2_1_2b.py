"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H (kv=32 => MHA) d_ff=8192 vocab=32000, ssm_state=64.
38 mamba2 blocks with ONE shared-weight attention+MLP block applied every
6th layer (distinct per-application LayerNorm + rank-64 LoRA on the shared
projections, following the Zamba2 paper's shared-block design)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, ssm_state=64,
    shared_attn_every=6, shared_attn_lora_rank=64,
)
