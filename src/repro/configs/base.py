"""Architecture configuration schema + the shape suite.

One ``ArchConfig`` per assigned architecture lives in configs/<id>.py; the
reduced smoke variant is derived by ``cfg.smoke()``.  Shapes follow the
assignment: train_4k / prefill_32k / decode_32k / long_500k, with per-arch
applicability (``shapes_for``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int = 8
    top_k: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 => d_model // n_heads
    act: str = "silu_gated"         # silu_gated | gelu
    moe: Optional[MoECfg] = None
    window: int = 0                 # >0 => sliding-window attention
    rope_theta: float = 1e6
    mrope: bool = False             # M-RoPE (qwen2-vl)
    tie_embeddings: bool = True
    # ssm / hybrid
    ssm_state: int = 0
    slstm_every: int = 0            # xlstm: an sLSTM block every k layers
    shared_attn_every: int = 0      # zamba2: shared attn block every k layers
    shared_attn_lora_rank: int = 0
    # enc-dec (audio)
    enc_layers: int = 0             # >0 => encoder-decoder
    # vlm stub
    n_patches: int = 0              # patch-embedding positions per sample
    # production defaults reflect the §Perf hillclimb (EXPERIMENTS.md):
    # chunked (flash-style) attention + dots-saveable remat
    dtype: str = "bfloat16"
    attn_impl: str = "xla_chunked"  # xla | xla_chunked | flash (Pallas)
    remat: bool = True
    remat_policy: str = "dots"      # full | dots (save matmul outputs)
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def n_params(self) -> float:
        """Rough parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.head_dim
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        if self.family == "ssm":
            inner = 2 * d
            hd_i = inner // max(self.n_heads, 1)
            per_layer = (d * 2 * inner                      # up (value+gate)
                         + self.n_heads * hd_i * (2 * hd_i + 2)  # blocked qk
                         + inner * d)                       # down
        elif self.family == "hybrid":
            inner = 2 * d
            per_layer = d * inner * 2 + inner * d + inner * (2 * self.ssm_state)
        else:
            ff = d * f * (3 if self.act == "silu_gated" else 2)
            per_layer = attn + (ff * self.moe.n_experts if self.moe else ff)
        total = L * per_layer + self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.enc_layers:
            total += self.enc_layers * (attn + d * f * 2)  # encoder stack
        return float(total)

    @property
    def n_params_active(self) -> float:
        if not self.moe:
            return self.n_params
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dense_ff = d * f * 3
        return self.n_params - L * dense_ff * (self.moe.n_experts - self.moe.top_k)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if not self.shared_attn_every else 8),
            d_model=128,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=max(1, min(2, self.n_kv_heads)),
            d_head=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            moe=MoECfg(4, 2) if self.moe else None,
            window=min(self.window, 64) if self.window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            shared_attn_every=(min(self.shared_attn_every, 3)
                               if self.shared_attn_every else 0),
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            dtype="float32",
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatch: int = 0   # train only; 0 => heuristic


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524288, 1),
}

# archs allowed to run long_500k (sub-quadratic attention; DESIGN.md §5)
SUBQUADRATIC = {"xlstm-1.3b", "zamba2-1.2b", "mixtral-8x7b", "mixtral-8x22b"}


def shapes_for(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.name in SUBQUADRATIC:
        out.append("long_500k")
    return out
