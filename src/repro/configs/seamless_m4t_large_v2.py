"""seamless-m4t-large-v2 [audio] — enc-dec multimodal [arXiv:2308.11596; hf].

24L d_model=1024 16H (kv=16 => MHA) d_ff=8192 vocab=256206.  Speech frontend
is a stub: input_specs() supplies precomputed frame embeddings (B, S_enc,
1024).  24 encoder + 24 decoder layers (per-stack depth; DESIGN.md §5).
Shape mapping: train_4k = enc 2048 frames + dec 2048 tokens; prefill_32k =
enc 28672 + dec 4096; decode_32k = decoder KV 32768, cross-attn to 4096
encoder frames."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, enc_layers=24, act="gelu",
    tie_embeddings=False,
)
