"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000."""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, moe=MoECfg(8, 2), window=4096,
    rope_theta=1e6, tie_embeddings=False,
)
