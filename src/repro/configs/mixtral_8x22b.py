"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768."""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768, moe=MoECfg(8, 2), window=4096,
    rope_theta=1e6, tie_embeddings=False,
    notes="SWA window 4096 => long_500k runs with a rolling KV cache. "
          "8 experts < 16-way model axis: TP inside experts (DESIGN.md §5).",
)
