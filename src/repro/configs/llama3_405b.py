"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783; unverified].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab=128256, rope_theta=5e5, tie_embeddings=False,
    remat_policy="full",  # dots-saveable holds 53k-wide hiddens: 894 GiB temp
                          # vs 46 GiB with full remat (§Perf, per-arch knob)
    notes="Training on one 256-chip v5e pod requires ZeRO-1 + bf16 optimizer "
          "moments + grad accumulation (EXPERIMENTS.md §Dry-run).",
)
