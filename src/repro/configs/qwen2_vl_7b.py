"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  Backbone only:
the ViT frontend is a stub; input_specs() supplies precomputed patch
embeddings occupying the first n_patches positions, with 3-section M-RoPE
(temporal/height/width) position ids."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, mrope=True, rope_theta=1e6,
    n_patches=1024, tie_embeddings=False,
)
