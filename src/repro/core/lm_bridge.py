"""DNNVM planner applied to a transformer block (DESIGN.md §3).

The block is expressed as an XGraph-style op chain with LM ops
(matmul / attn_score / softmax / attn_av / add / norm); the same three-step
DNNVM pipeline runs against the TPU device model:

  1. template embeddings — the attention kernel-fusion template
     (attn_score -> softmax -> attn_av) plus point-wise groups;
  2. fusion condition 1 — a VMEM-capacity check for the fused group's
     blocked working set (the flash-attention tiling: q tile + kv blocks +
     running stats resident on-chip);
  3. cost-based path selection — fused vs unfused HBM traffic + FLOP time;
     the unfused form pays the S x S score-matrix round trip to HBM.

The chosen strategy maps to the execution impl: fused attention group =>
the Pallas flash-attention kernel; per-arch planner decisions are logged in
EXPERIMENTS.md §Repro.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.hw import DeviceModel, TPU_V5E


@dataclasses.dataclass
class AttnPlan:
    fused: bool              # True => flash kernel; False => unfused XLA
    blk_q: int
    blk_k: int
    fused_cost_s: float
    unfused_cost_s: float
    vmem_bytes: int
    reason: str


def plan_attention(cfg: ArchConfig, seq_len: int, batch_per_device: int,
                   dev: DeviceModel = TPU_V5E, elem_bytes: int = 2) -> AttnPlan:
    """Cost the fused (flash) vs unfused attention for one block.

    Fusion condition 1 (paper §4): the blocked working set —
    q tile (blk_q x d), k/v blocks (2 x blk_k x d), score tile
    (blk_q x blk_k) and accumulators — must fit the VMEM budget.  Block
    sizes start MXU-aligned (128) and halve until they fit.
    """
    h, kv, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = seq_len
    b = max(1, batch_per_device)
    g = max(1, h // kv)
    vmem = dev.onchip_bytes

    blk_q = blk_k = 128
    while blk_q >= 8:
        work = (blk_q * g * d + 2 * blk_k * d + blk_q * g * blk_k
                + 2 * blk_q * g * d) * 4  # fp32 accumulators
        if work <= vmem:
            break
        blk_q //= 2
        blk_k //= 2
    feasible = blk_q >= 8

    # traffic (per device, one head-group pass, causal ~ 1/2 the square)
    qkv_bytes = b * s * (h + 2 * kv) * d * elem_bytes
    out_bytes = b * s * h * d * elem_bytes
    score_bytes = b * kv * g * s * s * elem_bytes // 2
    flops = 2 * b * h * s * s * d  # QK^T + AV, causal halves, x2 terms cancel

    t_compute = flops / dev.peak_ops_per_s
    bw = dev.dram_bw_bytes_per_s
    # unfused: scores written + read twice (softmax read/write, AV read)
    unfused = max(t_compute, (qkv_bytes + out_bytes + 3 * score_bytes) / bw)
    fused = max(t_compute, (qkv_bytes + out_bytes) / bw)

    if not feasible:
        return AttnPlan(False, 0, 0, float("inf"), unfused, vmem,
                        "no block size fits VMEM (condition 1 fails)")
    if fused <= unfused:
        return AttnPlan(True, blk_q, blk_k, fused, unfused, vmem,
                        f"fused saves {(unfused - fused) * 1e3:.2f} ms "
                        f"(score matrix {score_bytes / 1e9:.2f} GB stays on-chip)")
    return AttnPlan(False, blk_q, blk_k, fused, unfused, vmem,
                    "unfused cheaper (short sequence)")


def plan_ssm_chunk(cfg: ArchConfig, seq_len: int,
                   dev: DeviceModel = TPU_V5E) -> int:
    """Chunk length for the linear-recurrence kernels: largest power-of-two
    L <= 512 whose (3 L d + L^2 + K V) fp32 working set fits VMEM — the same
    Eq. 5/6 vocabulary, applied to the SSD scan (DESIGN.md §5)."""
    inner = 2 * cfg.d_model
    h = max(cfg.n_heads, 1)
    dk = cfg.ssm_state or inner // h
    dv = inner // h
    vmem = dev.onchip_bytes
    L = 512
    while L > 16:
        work = (3 * L * max(dk, dv) + L * L + dk * dv) * 4
        if work <= vmem and seq_len % L == 0:
            return L
        L //= 2
    return max(16, L)


def report(cfg: ArchConfig, seq_len: int = 32768,
           batch_per_device: int = 1) -> str:
    if cfg.family in ("ssm", "hybrid"):
        L = plan_ssm_chunk(cfg, seq_len)
        return (f"{cfg.name}: chunked scan, chunk={L} "
                f"(condition-1 tiling on VMEM)")
    p = plan_attention(cfg, seq_len, batch_per_device)
    kind = "FUSED flash kernel" if p.fused else "unfused XLA"
    return (f"{cfg.name}: attention group -> {kind} "
            f"(blk_q={p.blk_q}, blk_k={p.blk_k}; fused "
            f"{p.fused_cost_s*1e3:.2f} ms vs unfused "
            f"{p.unfused_cost_s*1e3:.2f} ms) — {p.reason}")
