"""Cycle-level time-wheel simulator (paper §5.1, evaluation method 3).

"We record the number of cycles consumption for each hardware block according
to our hardware design ... then we insert each instruction into a time wheel
after analyzing the dependencies among them."

Engines mirror the accelerator's execution modules: one DDR port (shared by
LOAD and SAVE — the Bank-arbiter view), a CONV array, a POOL unit and a MISC
unit.  Each engine retires its instructions in program order; an instruction
starts at max(engine free, all deps done).  That single rule reproduces the
pipelining the paper exploits: LOAD(t+1) overlaps CONV(t) because nothing
orders them, while CONV(t) -> POOL(t) -> SAVE(t) chain through their
dependency bits (Fig. 8/9 timelines).

For address-bearing streams (``isa.emit_strategy`` with a MemoryPlan) the
simulator doubles as a *memory-correctness oracle*, the scheduling analogue
of the validation environment's bit-exactness oracle: ``memory_hazards``
replays the schedule and flags

* overlapping live DDR windows — two groups' SAVE regions share addresses
  while one of them is still being read (a broken reuse plan would silently
  corrupt activations on real hardware);
* ping/pong bank hazards — a LOAD streams into a BRAM bank a previous tile
  is still computing from, or a compute overwrites an out-bank before its
  SAVE drained it.

``check`` turns any hazard into a hard :class:`MemoryHazardError`.
"""
from __future__ import annotations

import dataclasses

from repro.core.isa import Instr, ENGINES, COMPUTE_ENGINES


class MemoryHazardError(AssertionError):
    """An addressed instruction stream whose schedule corrupts memory."""


@dataclasses.dataclass
class SimReport:
    total_cycles: int
    busy_cycles: dict      # engine -> busy
    n_instructions: int

    def utilization(self, engine: str) -> float:
        return self.busy_cycles.get(engine, 0) / max(1, self.total_cycles)

    def seconds(self, freq_hz: float) -> float:
        return self.total_cycles / freq_hz


def run_times(instrs: list[Instr]) -> tuple[SimReport, dict]:
    """Time-wheel schedule; returns (report, iid -> (start, end) cycles)."""
    times: dict[int, tuple[int, int]] = {}
    done: dict[int, int] = {}
    engine_free = {e: 0 for e in ENGINES}
    busy = {e: 0 for e in ENGINES}
    for ins in instrs:  # program order == topological order of deps
        dep_ready = max((done[d] for d in ins.deps), default=0)
        start = max(engine_free[ins.engine], dep_ready)
        end = start + ins.cycles
        done[ins.iid] = end
        times[ins.iid] = (start, end)
        engine_free[ins.engine] = end
        busy[ins.engine] += ins.cycles
    total = max(done.values(), default=0)
    return SimReport(total_cycles=total, busy_cycles=busy,
                     n_instructions=len(instrs)), times


def run(instrs: list[Instr]) -> SimReport:
    return run_times(instrs)[0]


def engine_windows(instrs: list[Instr], times: dict) -> dict:
    """Per-engine occupancy timeline: engine -> [(start, end, opcode, tag)],
    in schedule order.  This is the Fig. 8/9 view — the runtime supporter
    renders it per request to show LOAD(i+1) overlapping CONV(i)."""
    out: dict[str, list] = {e: [] for e in ENGINES}
    for ins in instrs:
        s, e = times[ins.iid]
        out[ins.engine].append((s, e, ins.opcode, ins.tag))
    return out


def check(instrs: list[Instr]) -> SimReport:
    """Simulate and audit the memory plan; raises MemoryHazardError."""
    from repro.obs.trace import TRACER

    with TRACER.span("simulate", cat="compile", track="compile",
                     n_instrs=len(instrs)) as sp:
        rep, times = run_times(instrs)
        hazards = memory_hazards(instrs, times)
        if hazards:
            raise MemoryHazardError(
                f"{len(hazards)} memory hazard(s):\n  "
                + "\n  ".join(hazards[:10]))
        sp.set(total_cycles=rep.total_cycles)
    return rep


# --------------------------------------------------------------- hazard audit
def memory_hazards(instrs: list[Instr], times: dict) -> list[str]:
    """Audit an addressed stream against its time-wheel schedule.

    Returns human-readable hazard descriptions (empty list == clean plan).
    Instructions without addresses/banks (timing-only streams) are ignored.
    """
    return _ddr_hazards(instrs, times) + _bank_hazards(instrs, times)


def _ranges_overlap(a0: int, a1: int, b0: int, b1: int) -> bool:
    return a0 < b1 and b0 < a1           # half-open [start, end)


def _windows_overlap(a: tuple[int, int], b: tuple[int, int]) -> bool:
    return _ranges_overlap(a[0], a[1], b[0], b[1])


def _ddr_hazards(instrs: list[Instr], times: dict) -> list[str]:
    # One DDR "region" per writing group: [addr, addr+len) with a live window
    # spanning first write start -> last read end.  Reads with no preceding
    # writer model pre-loaded buffers (graph inputs), written at time 0.
    writers: dict[tuple, list] = {}   # (gid, addr, len) -> [wstart, wend]
    for ins in instrs:
        if ins.opcode != "SAVE" or ins.ddr_addr < 0:
            continue
        key = (ins.group_id, ins.ddr_addr, ins.ddr_len)
        s, e = times[ins.iid]
        if key in writers:
            writers[key][0] = min(writers[key][0], s)
            writers[key][1] = max(writers[key][1], e)
        else:
            writers[key] = [s, e]
    regions = [{"gid": gid, "addr": a, "len": ln,
                "start": w[0], "wend": w[1], "end": w[1]}
               for (gid, a, ln), w in writers.items()]

    pre: dict[tuple, dict] = {}       # pre-loaded (read-only) regions
    for ins in instrs:
        if ins.opcode != "LOAD" or ins.ddr_addr < 0:
            continue
        rs, re_ = times[ins.iid]
        a0, a1 = ins.ddr_addr, ins.ddr_addr + ins.ddr_len
        # attribute the read to the latest region whose write fully retired
        # before the read begins — the only region a correct plan could be
        # reading (a later in-flight writer overlapping this read is exactly
        # the hazard the pairwise window check below reports)
        best = None
        for r in regions:
            if (_ranges_overlap(a0, a1, r["addr"], r["addr"] + r["len"])
                    and r["wend"] <= rs
                    and (best is None or r["start"] > best["start"])):
                best = r
        if best is not None:
            best["end"] = max(best["end"], re_)
        else:
            key = (ins.ddr_addr, ins.ddr_len)
            if key in pre:
                pre[key]["end"] = max(pre[key]["end"], re_)
            else:
                pre[key] = {"gid": -1, "addr": ins.ddr_addr, "len": ins.ddr_len,
                            "start": 0, "wend": 0, "end": re_}
    regions.extend(pre.values())

    out = []
    for i, r1 in enumerate(regions):
        for r2 in regions[i + 1:]:
            if r1["gid"] == r2["gid"] and r1["gid"] >= 0:
                continue
            if not _ranges_overlap(r1["addr"], r1["addr"] + r1["len"],
                                   r2["addr"], r2["addr"] + r2["len"]):
                continue
            if _windows_overlap((r1["start"], r1["end"]),
                                (r2["start"], r2["end"])):
                out.append(
                    f"DDR overlap: group {r1['gid']} "
                    f"[{r1['addr']}, +{r1['len']}) live cycles "
                    f"[{r1['start']}, {r1['end']}) vs group {r2['gid']} "
                    f"[{r2['addr']}, +{r2['len']}) live "
                    f"[{r2['start']}, {r2['end']})")
    return out


def tile_accesses(instrs: list[Instr]) -> dict:
    """Bucket an addressed stream per (group_id, tile) into its LOAD / SAVE /
    compute instructions — the unit both the bank-hazard audit and the
    runtime's cross-request schedule reason about."""
    tiles: dict[tuple, dict] = {}
    for ins in instrs:
        if ins.group_id < 0 or ins.tile < 0:
            continue
        t = tiles.setdefault((ins.group_id, ins.tile),
                             {"load": [], "save": [], "compute": []})
        if ins.opcode == "LOAD":
            t["load"].append(ins)
        elif ins.opcode == "SAVE":
            t["save"].append(ins)
        elif ins.engine in COMPUTE_ENGINES:
            t["compute"].append(ins)
    return tiles


def bank_hazards(instrs: list[Instr], times: dict) -> list[str]:
    """Ping/pong BRAM bank audit alone (the bank half of
    :func:`memory_hazards`).  Public so the runtime can re-run it over a
    *relabelled* pipelined stream — bank windows key on (group, bank), which
    a per-request group renumbering would otherwise hide."""
    return _bank_hazards(instrs, times)


def _bank_hazards(instrs: list[Instr], times: dict) -> list[str]:
    # Per (group, tile): the in-bank is occupied from its LOAD's start until
    # its last compute retires (SAVE if the tile has no compute); the out-bank
    # from its first compute's start until its SAVE retires.
    tiles = tile_accesses(instrs)

    in_windows: dict[tuple, list] = {}    # (gid, bank) -> [(s, e, tile)]
    out_windows: dict[tuple, list] = {}
    for (gid, tile), t in tiles.items():
        if not t["load"] and not t["save"]:
            continue
        consumers = t["compute"] or t["save"]
        if t["load"] and t["load"][0].bank >= 0:
            s = min(times[i.iid][0] for i in t["load"])
            e = max(times[i.iid][1] for i in consumers) if consumers else s
            in_windows.setdefault((gid, t["load"][0].bank), []).append(
                (s, e, tile))
        if t["save"] and t["save"][0].bank >= 0:
            producers = t["compute"] or t["load"]
            s = (min(times[i.iid][0] for i in producers) if producers
                 else times[t["save"][0].iid][0])
            e = max(times[i.iid][1] for i in t["save"])
            out_windows.setdefault((gid, t["save"][0].bank), []).append(
                (s, e, tile))

    out = []
    for kind, windows in (("in", in_windows), ("out", out_windows)):
        for (gid, bank), ws in windows.items():
            ws.sort()
            for (s1, e1, t1), (s2, e2, t2) in zip(ws, ws[1:]):
                if _windows_overlap((s1, e1), (s2, e2)):
                    out.append(
                        f"{kind}-bank hazard: group {gid} bank {bank} tiles "
                        f"{t1}/{t2} overlap cycles [{s1},{e1}) vs [{s2},{e2})")
    return out
