"""Cycle-level time-wheel simulator (paper §5.1, evaluation method 3).

"We record the number of cycles consumption for each hardware block according
to our hardware design ... then we insert each instruction into a time wheel
after analyzing the dependencies among them."

Engines mirror the accelerator's execution modules: one DDR port (shared by
LOAD and SAVE — the Bank-arbiter view), a CONV array, a POOL unit and a MISC
unit.  Each engine retires its instructions in program order; an instruction
starts at max(engine free, all deps done).  That single rule reproduces the
pipelining the paper exploits: LOAD(t+1) overlaps CONV(t) because nothing
orders them, while CONV(t) -> POOL(t) -> SAVE(t) chain through their
dependency bits (Fig. 8/9 timelines).
"""
from __future__ import annotations

import dataclasses

from repro.core.isa import Instr, ENGINES


@dataclasses.dataclass
class SimReport:
    total_cycles: int
    busy_cycles: dict      # engine -> busy
    n_instructions: int

    def utilization(self, engine: str) -> float:
        return self.busy_cycles.get(engine, 0) / max(1, self.total_cycles)

    def seconds(self, freq_hz: float) -> float:
        return self.total_cycles / freq_hz


def run(instrs: list[Instr]) -> SimReport:
    done: dict[int, int] = {}
    engine_free = {e: 0 for e in ENGINES}
    busy = {e: 0 for e in ENGINES}
    for ins in instrs:  # program order == topological order of deps
        dep_ready = max((done[d] for d in ins.deps), default=0)
        start = max(engine_free[ins.engine], dep_ready)
        end = start + ins.cycles
        done[ins.iid] = end
        engine_free[ins.engine] = end
        busy[ins.engine] += ins.cycles
    total = max(done.values(), default=0)
    return SimReport(total_cycles=total, busy_cycles=busy,
                     n_instructions=len(instrs))
