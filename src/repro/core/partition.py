"""Mixed compilation (paper §2.3.5): automatically distribute operations
between the accelerator and the host CPU.

The paper maps everything except fully-connected layers onto the FPGA and
compiles the remainder (softmax, detection post-processing, ...) for the CPU
with LLVM.  Our "host" is plain XLA; the partition decides which nodes the
DNNVM planner may schedule on the virtual accelerator.
"""
from __future__ import annotations

from repro.core.xgraph import XGraph, HOST_OPS

POLICIES = ("paper", "all_acc")


def assign(g: XGraph, policy: str = "paper") -> dict:
    """Node -> "acc" | "cpu".  ``paper``: FC on CPU (as deployed in §6.1);
    ``all_acc``: FC on the accelerator (our ISA supports it as a 1x1 conv)."""
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}")
    out = {}
    for node in g:
        if node.op == "input":
            continue
        if node.op in HOST_OPS:
            out[node.name] = "cpu"
        elif node.op == "fc" and policy == "paper":
            out[node.name] = "cpu"
        else:
            out[node.name] = "acc"
    return out


def device_of(g: XGraph, policy: str = "paper"):
    table = assign(g, policy)
    return lambda name: table.get(name, "cpu")
