"""Bit-exact int8 fixed-point operator semantics (paper §2.3.4, §3.2).

Every tensor is int8 with a per-tensor fraction ``f``: real ≈ q · 2^{-f}.
Accumulation is int32; requantization uses round-half-away-from-zero and
saturates to [-128, 127] — the Angel-Eye-style shifting/truncation/rounding
the validation bench must reproduce "without even a one-bit difference".

These functions are THE semantics: the Pallas fused kernel, the jnp fallback
executor and the validation oracle all call (or replicate exactly) what is
defined here.  Everything is pure jnp and jit-safe.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

I8_MIN, I8_MAX = -128, 127


def ceil_extension(h: int, w: int, kernel, stride, pad) -> tuple[int, int]:
    """Caffe ceil-mode pooling: extra bottom/right padding (eh, ew) so every
    output window is covered.  Shared by maxpool and avgpool — the formula
    must match ``xgraph`` shape inference or masking goes stale."""
    kh, kw = kernel
    sh, sw = stride
    ph, pw = pad
    oh = math.ceil((h + 2 * ph - kh) / sh) + 1
    ow = math.ceil((w + 2 * pw - kw) / sw) + 1
    return (max(0, (oh - 1) * sh + kh - h - 2 * ph),
            max(0, (ow - 1) * sw + kw - w - 2 * pw))


def round_shift(x: jnp.ndarray, s) -> jnp.ndarray:
    """x * 2^{-s} with round-half-away-from-zero; x int32, s may be negative
    (negative s = left shift, exact)."""
    x = x.astype(jnp.int32)

    def right(x, s):
        ax = jnp.abs(x)
        r = (ax + (1 << (s - 1))) >> s
        return jnp.sign(x) * r

    s = jnp.asarray(s, jnp.int32)
    return jnp.where(s > 0, right(x, jnp.maximum(s, 1)),
                     x << jnp.maximum(-s, 0))


def sat8(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(x, I8_MIN, I8_MAX).astype(jnp.int8)


def requantize(acc: jnp.ndarray, shift, relu: bool = False) -> jnp.ndarray:
    """int32 accumulator -> int8 output at the target fraction."""
    y = round_shift(acc, shift)
    if relu:
        y = jnp.maximum(y, 0)
    return sat8(y)


def rescale(q: jnp.ndarray, f_from: int, f_to: int) -> jnp.ndarray:
    """Change fraction of an int8 tensor (returns int32, NOT saturated —
    callers saturate after combining)."""
    return round_shift(q.astype(jnp.int32), f_from - f_to)


# ----------------------------------------------------------------- operators
def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
           stride=(1, 1), pad=(0, 0), dilation=(1, 1), groups: int = 1,
           shift: int = 0, relu: bool = False) -> jnp.ndarray:
    """x (N,H,W,IC) int8 | w (KH,KW,IC/g,OC) int8 | b (OC,) int32 at f_x+f_w.
    Output int8 at f_y where shift = f_x + f_w - f_y."""
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32),
        window_strides=stride,
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        rhs_dilation=dilation,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=jnp.int32)
    acc = acc + b.astype(jnp.int32)
    return requantize(acc, shift, relu)


def depthwise_conv2d(x, w, b, *, stride=(1, 1), pad=(0, 0), shift=0, relu=False):
    c = x.shape[-1]
    return conv2d(x, w, b, stride=stride, pad=pad, groups=c, shift=shift, relu=relu)


def fc(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *, shift: int = 0,
       relu: bool = False) -> jnp.ndarray:
    """x (N,H,W,C) int8 -> (N,1,1,OC); w ((H*W*C), OC)."""
    n = x.shape[0]
    acc = jnp.dot(x.reshape(n, -1).astype(jnp.int32), w.astype(jnp.int32),
                  preferred_element_type=jnp.int32) + b.astype(jnp.int32)
    return requantize(acc, shift, relu).reshape(n, 1, 1, -1)


def maxpool(x: jnp.ndarray, *, kernel, stride, pad=(0, 0),
            ceil_mode: bool = True) -> jnp.ndarray:
    kh, kw = kernel
    sh, sw = stride
    n, h, w, c = x.shape
    ph, pw = pad
    eh, ew = (ceil_extension(h, w, kernel, stride, pad) if ceil_mode
              else (0, 0))
    return jax.lax.reduce_window(
        x, jnp.int8(I8_MIN), jax.lax.max,
        window_dimensions=(1, kh, kw, 1), window_strides=(1, sh, sw, 1),
        padding=((0, 0), (ph, ph + eh), (pw, pw + ew), (0, 0)))


def avgpool(x: jnp.ndarray, *, kernel, stride, pad=(0, 0),
            ceil_mode: bool = True) -> jnp.ndarray:
    kh, kw = kernel
    sh, sw = stride
    n, h, w, c = x.shape
    ph, pw = pad
    # ceil extension reads zeros; the divisor stays kh*kw (count_include_pad)
    eh, ew = (ceil_extension(h, w, kernel, stride, pad) if ceil_mode
              else (0, 0))
    s = jax.lax.reduce_window(
        x.astype(jnp.int32), jnp.int32(0), jax.lax.add,
        window_dimensions=(1, kh, kw, 1), window_strides=(1, sh, sw, 1),
        padding=((0, 0), (ph, ph + eh), (pw, pw + ew), (0, 0)))
    cnt = kh * kw
    return sat8(jnp.sign(s) * ((jnp.abs(s) + cnt // 2) // cnt))


def global_avgpool(x: jnp.ndarray) -> jnp.ndarray:
    n, h, w, c = x.shape
    s = jnp.sum(x.astype(jnp.int32), axis=(1, 2), keepdims=True)
    cnt = h * w
    return sat8(jnp.sign(s) * ((jnp.abs(s) + cnt // 2) // cnt))


def eltwise_add(xs, fs, f_out: int, relu: bool = False) -> jnp.ndarray:
    acc = sum(rescale(x, f, f_out) for x, f in zip(xs, fs))
    if relu:
        acc = jnp.maximum(acc, 0)
    return sat8(acc)


def concat(xs, fs, f_out: int) -> jnp.ndarray:
    return jnp.concatenate([sat8(rescale(x, f, f_out)) for x, f in zip(xs, fs)],
                           axis=-1)


def upsample(x: jnp.ndarray, factor: int = 2) -> jnp.ndarray:
    return jnp.repeat(jnp.repeat(x, factor, axis=1), factor, axis=2)


def reorg(x: jnp.ndarray, stride: int = 2) -> jnp.ndarray:
    n, h, w, c = x.shape
    s = stride
    x = x.reshape(n, h // s, s, w // s, s, c)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(n, h // s, w // s, c * s * s)
