"""Fused-operation cost evaluation (paper §5.1, Table 2).

The paper evaluates fused-op cost three ways: on-board (<1 s, 0% deviation),
a learned model (<1 min, 5–10%), and a cycle-accurate simulator (>10 min, 0%).
We provide all three, plus the fast analytic pipeline model used *inside* the
path search (the role the on-board measurement plays in the paper):

  * ``AnalyticEvaluator``  — closed-form steady-state pipeline bound:
        t = max(DDR, CONV, POOL/MISC) + fill
    from the tiling solution; also exposes CTC (Eq. 1/2).
  * ``SimulatorEvaluator`` — assembles the group's ISA stream and runs the
    time wheel; the reference cost.
  * ``ModelEvaluator``     — least-squares model over (MACs, DRAM bytes,
    misc elems, tiles) features, fitted against the simulator; reproduces the
    paper's 5–10% deviation band (EXPERIMENTS.md §Repro).
  * ``OnBoardEvaluator``   — wall-clock of the actual JAX executor; on this
    container "on board" is XLA-on-CPU, so it validates relative ordering,
    not absolute accelerator time (documented deviation source).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.hw import DeviceModel
from repro.core import isa, simulator, tiling
from repro.core.xgraph import XGraph

INFEASIBLE = float("inf")


@dataclasses.dataclass
class GroupCost:
    seconds: float
    tiling: tiling.GroupTiling

    @property
    def feasible(self) -> bool:
        return math.isfinite(self.seconds)


def _pipeline_seconds(t: tiling.GroupTiling, dev: DeviceModel) -> float:
    """Steady-state pipeline bound: engines overlap across tiles; the fill
    cost of the non-dominant stages is paid once.  LOAD and SAVE ride the
    independent AXI read/write channels (cf. isa.ENGINES)."""
    rd = (t.load_bytes + t.weight_bytes) / dev.dram_bw_bytes_per_s
    wr = t.save_bytes / dev.dram_bw_bytes_per_s
    conv = t.conv_cycles / dev.freq_hz
    pool = t.pool_cycles / dev.freq_hz
    misc = t.misc_cycles / dev.freq_hz
    stages = (rd, wr, conv, pool, misc)
    steady = max(stages)
    return steady + (sum(stages) - steady) / max(1, t.n_spatial_tiles)


class AnalyticEvaluator:
    """Steady-state pipeline model — the default inside path search."""

    def __init__(self, g: XGraph, dev: DeviceModel):
        self.g, self.dev = g, dev
        self._cache: dict[tuple, GroupCost] = {}

    def __call__(self, group: list[str]) -> float:
        return self.cost(group).seconds

    def cost(self, group: list[str]) -> GroupCost:
        key = tuple(group)
        if key in self._cache:
            return self._cache[key]
        if all(self.g.nodes[nm].op == "concat" and
               self.g.nodes[nm].attrs.get("folded") for nm in group):
            gc = GroupCost(0.0, tiling.GroupTiling(True))  # layout-pruned
        else:
            t = tiling.solve(self.g, group, self.dev)
            gc = (GroupCost(INFEASIBLE, t) if not t.feasible
                  else GroupCost(_pipeline_seconds(t, self.dev), t))
        self._cache[key] = gc
        return gc

    def ctc(self, group: list[str]) -> float:
        """Computation-to-communication ratio (paper Eq. 1/2), ops per byte."""
        gc = self.cost(group)
        if not gc.feasible or gc.tiling.dram_bytes == 0:
            return 0.0
        comp = sum(self.g.ops(nm) for nm in group)
        return comp / gc.tiling.dram_bytes

    def horizontal_cost(self, heads: list[str]) -> float:
        t = tiling.solve_horizontal(self.g, heads, self.dev)
        if not t.feasible:
            return INFEASIBLE
        return _pipeline_seconds(t, self.dev)


class SimulatorEvaluator:
    """Time-wheel reference cost (evaluation method 3)."""

    def __init__(self, g: XGraph, dev: DeviceModel):
        self.g, self.dev = g, dev
        self._analytic = AnalyticEvaluator(g, dev)
        self._cache: dict[tuple, float] = {}

    def __call__(self, group: list[str]) -> float:
        key = tuple(group)
        if key not in self._cache:
            t = self._analytic.cost(group).tiling
            if not t.feasible:
                self._cache[key] = INFEASIBLE
            else:
                instrs = isa.emit_group(self.g, group, t, self.dev)
                self._cache[key] = simulator.run(instrs).seconds(self.dev.freq_hz)
        return self._cache[key]

    def horizontal_cost(self, heads: list[str]) -> float:
        t = tiling.solve_horizontal(self.g, heads, self.dev)
        if not t.feasible:
            return INFEASIBLE
        instrs = isa.emit_group(self.g, heads, t, self.dev)
        return simulator.run(instrs).seconds(self.dev.freq_hz)

    def strategy_report(self, strategy_or_groups) -> simulator.SimReport:
        """Simulate a whole strategy (chain groups + horizontal groups)."""
        if isinstance(strategy_or_groups, list):
            items = list(strategy_or_groups)
            tilings = [self._require(gr) for gr in items]
        else:
            s = strategy_or_groups
            from repro.core.pathsearch import order_groups

            items = list(s.groups) + list(s.horizontal)
            items = order_groups(self.g, items)
            hset = {tuple(h) for h in s.horizontal}
            tilings = [
                tiling.solve_horizontal(self.g, gr, self.dev)
                if tuple(gr) in hset else self._require(gr)
                for gr in items
            ]
        instrs = isa.emit_strategy(self.g, items, tilings, self.dev)
        return simulator.run(instrs)

    def _require(self, gr: list[str]) -> tiling.GroupTiling:
        t = self._analytic.cost(gr).tiling
        if not t.feasible:
            raise ValueError(f"infeasible group {gr}")
        return t


class ModelEvaluator:
    """Learned cost model (evaluation method 2): least squares over
    engine-occupancy features (the per-engine times a pipelined execution
    interleaves — the paper fits a small NN to the same signal), trained
    against the simulator on this graph's candidate groups."""

    # No max-term feature on purpose: a linear model must APPROXIMATE the
    # pipelined max() the way the paper's NN approximates real hardware —
    # that's where the 5-10% deviation band comes from.
    FEATURES = ("t_rd", "t_wr", "t_conv", "t_pool", "t_misc", "one")

    def __init__(self, g: XGraph, dev: DeviceModel, train_groups: list[list[str]],
                 targets: list[float] | None = None):
        """``targets`` (seconds per train group) overrides the simulator as
        the fit's ground truth — the autotuner refits this model against
        harness-measured wall-clock (``tune.calibrate``)."""
        self.g, self.dev = g, dev
        if targets is not None and len(targets) != len(train_groups):
            raise ValueError(f"{len(targets)} targets for "
                             f"{len(train_groups)} train groups")
        self._sim = None if targets is not None else SimulatorEvaluator(g, dev)
        self._analytic = AnalyticEvaluator(g, dev)
        X, y = [], []
        for k, gr in enumerate(train_groups):
            c = targets[k] if targets is not None else self._sim(gr)
            if c is None or not math.isfinite(c):
                continue
            X.append(self._features(gr))
            y.append(c)
        X, y = np.asarray(X), np.asarray(y)
        self.coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        pred = X @ self.coef
        self.fit_mape = float(np.mean(np.abs(pred - y) / np.maximum(y, 1e-12)))

    def _features(self, group: list[str]) -> list[float]:
        t = self._analytic.cost(group).tiling
        dev = self.dev
        rd = (t.load_bytes + t.weight_bytes) / dev.dram_bw_bytes_per_s
        wr = t.save_bytes / dev.dram_bw_bytes_per_s
        conv = t.conv_cycles / dev.freq_hz
        pool = t.pool_cycles / dev.freq_hz
        misc = t.misc_cycles / dev.freq_hz
        return [rd, wr, conv, pool, misc, 1.0]

    def __call__(self, group: list[str]) -> float:
        t = tiling.solve(self.g, group, self.dev)
        if not t.feasible:
            return INFEASIBLE
        return float(np.dot(self._features(group), self.coef))


class OnBoardEvaluator:
    """Wall-clock the compiled JAX executor for a group (method 1).

    Built lazily to avoid importing the executor at planner time."""

    def __init__(self, g: XGraph, params, repeats: int = 3):
        self.g, self.params, self.repeats = g, params, repeats

    def __call__(self, group: list[str]) -> float:
        import time

        from repro.core import executor

        fn, inputs = executor.build_group_callable(self.g, group, self.params)
        fn(*inputs)  # compile + warmup
        t0 = time.perf_counter()
        for _ in range(self.repeats):
            out = fn(*inputs)
        import jax

        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / self.repeats
