"""XGraph — DNNVM's coarse-grained, framework-independent computing-graph IR.

An ``XGraph`` is a DAG <U, E, T> (paper §4.2): vertices are coarse NN
operations, edges are dataflow dependencies, and every vertex carries a
labelling (op type + attributes) used by the fusion templates.

Data layout convention (paper §3.1 / Fig. 2c): feature maps are NHWC with
batch N=1 by default; weights are matmul panels (kh*kw*IC, OC).  Dimension
transformation ops (flatten / concat) exist as nodes after the front-end only
if they could not be folded; the layout pass marks them ``folded=True`` so the
back-end emits strided SAVEs instead of data movement (DESIGN.md §2.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Iterator

# Op taxonomy.  COMPUTE ops map to CONV/POOL/MISC engines; the rest are either
# folded by the front-end or scheduled to the host by the partition pass.
CONV_LIKE = {"conv", "deconv", "depthwise_conv", "dilated_conv", "fc"}
POOL_LIKE = {"maxpool", "avgpool", "global_avgpool"}
MISC_OPS = {"eltwise_add", "upsample", "reorg", "concat", "flatten"}
POINTWISE = {"relu", "relu6", "leaky_relu", "sigmoid", "tanh"}
INTRINSIC = {"bn", "scale", "bias_add", "pad"}  # folded by intrinsic fusion
HOST_OPS = {"softmax", "detection", "nms"}
# ``injective`` per paper §4.1: ops the kernel-fusion templates may include.
INJECTIVE = CONV_LIKE | POOL_LIKE | {"eltwise_add", "upsample", "reorg"}


@dataclasses.dataclass
class XNode:
    name: str
    op: str
    inputs: tuple[str, ...]
    attrs: dict = dataclasses.field(default_factory=dict)

    def __repr__(self) -> str:  # compact for debug dumps
        return f"XNode({self.name}:{self.op}<-{list(self.inputs)})"


class XGraph:
    """Insertion-ordered DAG of XNodes with NHWC shape inference."""

    def __init__(self, name: str = "xgraph"):
        self.name = name
        self.nodes: dict[str, XNode] = {}
        self._consumers: dict[str, list[str]] = {}
        self._shapes: dict[str, tuple[int, int, int, int]] = {}

    # ------------------------------------------------------------- building
    def add(self, op: str, name: str, inputs: Iterable[str] = (), **attrs) -> str:
        if name in self.nodes:
            raise ValueError(f"duplicate node {name!r}")
        inputs = tuple(inputs)
        for i in inputs:
            if i not in self.nodes:
                raise ValueError(f"{name!r} references unknown input {i!r}")
        node = XNode(name, op, inputs, attrs)
        self.nodes[name] = node
        self._consumers[name] = []
        for i in inputs:
            self._consumers[i].append(name)
        self._shapes[name] = self._infer_shape(node)
        return name

    def input(self, name: str, shape: tuple[int, int, int, int]) -> str:
        return self.add("input", name, (), shape=tuple(shape))

    # ---------------------------------------------------------- structure
    def consumers(self, name: str) -> list[str]:
        return list(self._consumers[name])

    def producers(self, name: str) -> list[str]:
        return list(self.nodes[name].inputs)

    def topo_order(self) -> list[str]:
        return list(self.nodes)  # insertion order is topological by add()

    def __iter__(self) -> Iterator[XNode]:
        return iter(self.nodes.values())

    def __len__(self) -> int:
        return len(self.nodes)

    def shape(self, name: str) -> tuple[int, int, int, int]:
        return self._shapes[name]

    def compute_nodes(self) -> list[str]:
        return [n.name for n in self if n.op != "input"]

    def remove(self, name: str) -> None:
        """Remove a node, reconnecting its consumers to its single input."""
        node = self.nodes[name]
        if len(node.inputs) != 1:
            raise ValueError(f"can only remove single-input nodes, {name} has {node.inputs}")
        (src,) = node.inputs
        for c in self._consumers[name]:
            cn = self.nodes[c]
            cn.inputs = tuple(src if i == name else i for i in cn.inputs)
            self._consumers[src].append(c)
        self._consumers[src].remove(name)
        del self.nodes[name], self._consumers[name], self._shapes[name]

    def replace_op(self, name: str, op: str, **attr_updates) -> None:
        self.nodes[name].op = op
        self.nodes[name].attrs.update(attr_updates)
        self._shapes[name] = self._infer_shape(self.nodes[name])

    # ------------------------------------------------------ shape inference
    def _infer_shape(self, node: XNode) -> tuple[int, int, int, int]:
        a = node.attrs
        op = node.op
        if op == "input":
            return tuple(a["shape"])
        ish = [self._shapes[i] for i in node.inputs]
        n, h, w, c = ish[0]
        if op in ("conv", "dilated_conv", "depthwise_conv"):
            kh, kw = a["kernel"]
            sh, sw = a.get("stride", (1, 1))
            dh, dw = a.get("dilation", (1, 1))
            ekh, ekw = dh * (kh - 1) + 1, dw * (kw - 1) + 1
            ph, pw = _padding(a.get("pad", "same"), ekh, ekw)
            oh = (h + 2 * ph - ekh) // sh + 1
            ow = (w + 2 * pw - ekw) // sw + 1
            oc = c if op == "depthwise_conv" else a["oc"]
            return (n, oh, ow, oc)
        if op == "deconv":
            kh, kw = a["kernel"]
            sh, sw = a.get("stride", (2, 2))
            return (n, h * sh, w * sw, a["oc"])
        if op in ("maxpool", "avgpool"):
            kh, kw = a["kernel"]
            sh, sw = a.get("stride", a["kernel"])
            ph, pw = _padding(a.get("pad", "valid"), kh, kw)
            ceil = a.get("ceil_mode", True)  # Caffe convention
            rnd: Callable[[float], int] = math.ceil if ceil else math.floor
            oh = int(rnd((h + 2 * ph - kh) / sh)) + 1
            ow = int(rnd((w + 2 * pw - kw) / sw)) + 1
            return (n, oh, ow, c)
        if op == "global_avgpool":
            return (n, 1, 1, c)
        if op == "fc":
            return (n, 1, 1, a["oc"])
        if op == "eltwise_add":
            for s in ish[1:]:
                if s != ish[0]:
                    raise ValueError(f"eltwise_add shape mismatch {ish}")
            return ish[0]
        if op == "concat":
            axis_c = sum(s[3] for s in ish)
            for s in ish[1:]:
                if s[:3] != ish[0][:3]:
                    raise ValueError(f"concat spatial mismatch {ish}")
            return (n, h, w, axis_c)
        if op == "flatten":
            return (n, 1, 1, h * w * c)
        if op == "upsample":
            f = a.get("factor", 2)
            return (n, h * f, w * f, c)
        if op == "reorg":
            s = a.get("stride", 2)
            return (n, h // s, w // s, c * s * s)
        if op in POINTWISE or op in INTRINSIC or op in HOST_OPS:
            return ish[0]
        raise ValueError(f"shape inference: unknown op {op!r}")

    # --------------------------------------------------------- cost helpers
    def macs(self, name: str) -> int:
        """Multiply-accumulates of one op (paper Eq. 3 divided by 2)."""
        node = self.nodes[name]
        a, op = node.attrs, node.op
        n, oh, ow, oc = self.shape(name)
        if op in ("conv", "dilated_conv"):
            ic = self.shape(node.inputs[0])[3]
            kh, kw = a["kernel"]
            return n * oh * ow * oc * ic * kh * kw
        if op == "depthwise_conv":
            kh, kw = a["kernel"]
            return n * oh * ow * oc * kh * kw
        if op == "deconv":
            ic = self.shape(node.inputs[0])[3]
            kh, kw = a["kernel"]
            return n * oh * ow * oc * ic * kh * kw // (a.get("stride", (2, 2))[0] ** 2)
        if op == "fc":
            ish = self.shape(node.inputs[0])
            return n * oc * ish[1] * ish[2] * ish[3]
        if op in ("maxpool", "avgpool", "global_avgpool"):
            return 0  # POOL engine, counted as misc elems not MACs
        return 0

    def ops(self, name: str) -> int:
        return 2 * self.macs(name)

    def total_ops(self) -> int:
        return sum(self.ops(n) for n in self.nodes)

    def misc_elems(self, name: str) -> int:
        """Element throughput demand for POOL/MISC engines."""
        node = self.nodes[name]
        n, oh, ow, oc = self.shape(name)
        if node.op in ("maxpool", "avgpool"):
            kh, kw = node.attrs["kernel"]
            return n * oh * ow * oc * kh * kw
        if node.op == "global_avgpool":
            ish = self.shape(node.inputs[0])
            return n * ish[1] * ish[2] * ish[3]
        if node.op in ("eltwise_add", "upsample", "reorg"):
            return n * oh * ow * oc * len(node.inputs)
        return 0

    def fmap_bytes(self, name: str, elem_bytes: int = 1) -> int:
        n, h, w, c = self.shape(name)
        return n * h * w * c * elem_bytes

    def param_bytes(self, name: str, elem_bytes: int = 1) -> int:
        node = self.nodes[name]
        a, op = node.attrs, node.op
        if op in ("conv", "dilated_conv", "deconv"):
            ic = self.shape(node.inputs[0])[3]
            kh, kw = a["kernel"]
            oc = a["oc"]
            return kh * kw * ic * oc * elem_bytes + oc * 4  # int32 bias
        if op == "depthwise_conv":
            kh, kw = a["kernel"]
            c = self.shape(node.inputs[0])[3]
            return kh * kw * c * elem_bytes + c * 4
        if op == "fc":
            ish = self.shape(node.inputs[0])
            return ish[1] * ish[2] * ish[3] * a["oc"] * elem_bytes + a["oc"] * 4
        return 0

    # ----------------------------------------------------------- utilities
    def is_chain(self, group: list) -> bool:
        """True when ``group`` is a linear producer chain (or a single op)."""
        return all(group[i] in self.nodes[group[i + 1]].inputs
                   for i in range(len(group) - 1)) or len(group) == 1

    def exposed_outputs(self, group: list) -> list:
        """Nodes of an execution group whose feature maps land in DDR: a
        chain exposes only its tail, a horizontal (sibling) group exposes
        every member.  Shared by the assembler and the memory planner — the
        two must agree or addresses go stale."""
        return [group[-1]] if self.is_chain(group) else list(group)

    def validate(self) -> None:
        seen: set[str] = set()
        for node in self:
            for i in node.inputs:
                if i not in seen:
                    raise ValueError(f"{node.name} uses {i} before definition")
            seen.add(node.name)

    def summary(self) -> str:
        lines = [f"XGraph {self.name}: {len(self)} nodes, {self.total_ops()/1e9:.2f} GOPs"]
        for node in self:
            lines.append(
                f"  {node.name:28s} {node.op:16s} {str(self.shape(node.name)):>22s}"
                f" <- {','.join(node.inputs)}")
        return "\n".join(lines)


def _padding(pad, kh: int, kw: int) -> tuple[int, int]:
    if pad == "same":
        return (kh - 1) // 2, (kw - 1) // 2
    if pad == "valid":
        return 0, 0
    if isinstance(pad, (tuple, list)):
        return tuple(pad)  # type: ignore[return-value]
    if isinstance(pad, int):
        return pad, pad
    raise ValueError(f"bad pad {pad!r}")
