"""Validation environment (paper §3.2, "Validation Module").

"Our validation tools can prevent even a one-bit difference between the
results by the CPU and the results by the FPGA."

Here: the pure-jnp fixed-point executor is the CPU-side oracle; the Pallas
fused-kernel executor (interpret mode on this container, real MXU on TPU) is
the hardware side.  ``bit_exact`` fails on a single differing int8 value.
It also checks that *fusion itself* never changes numerics: any strategy must
produce the same bits as the unfused naive execution.

``fused_coverage`` audits the *lowering* the same way the bit-exactness bench
audits numerics: what fraction of the strategy's groups actually execute as
fused kernel launches, and an explicit reason for every group that does not
(no silent fallback).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import lower
from repro.core.executor import Int8Executor, build_float_fn
from repro.core.quantize import QuantizedModel
from repro.core.xgraph import XGraph


@dataclasses.dataclass
class ValidationReport:
    bit_exact: bool
    n_outputs: int
    max_abs_diff: int
    sqnr_db: dict  # vs float reference, per output

    def __bool__(self) -> bool:
        return self.bit_exact


@dataclasses.dataclass
class CoverageReport:
    """How much of a strategy the compiler lowered to fused launches."""
    n_groups: int            # strategy groups (excl. host + folded concat)
    n_fused: int             # groups entirely covered by FusedLaunch items
    n_launches: int
    fallback_reasons: dict   # reason -> count (every entry allow-listed)
    kinds: dict              # launch kind -> count

    @property
    def ratio(self) -> float:
        return (self.n_fused / self.n_groups) if self.n_groups else 1.0


def fused_coverage(g: XGraph, strategy, qm: QuantizedModel | None = None
                   ) -> CoverageReport:
    """Lower ``strategy`` (or read a CompiledArtifact's program) and report
    the fused-execution coverage.  Every non-fused group must carry a reason
    from ``lower.FALLBACK_REASONS`` — lowering raises otherwise."""
    prog = getattr(strategy, "program", None)
    if prog is None:
        prog = lower.lower_strategy(g, strategy, qm)
    m = prog.meta
    return CoverageReport(
        n_groups=m["n_units"], n_fused=m["n_fused_units"],
        n_launches=m["n_launches"],
        fallback_reasons=dict(m["fallback_reasons"]), kinds=dict(m["kinds"]))


def bit_exact(g: XGraph, qm: QuantizedModel, x: np.ndarray, strategy=None,
              backend: str = "pallas", float_params=None) -> ValidationReport:
    ref = Int8Executor(g, qm, strategy=None, backend="ref")(x)        # naive, unfused
    got = Int8Executor(g, qm, strategy=strategy, backend=backend)(x)  # fused path
    assert set(ref) == set(got), f"output sets differ: {set(ref)} vs {set(got)}"
    max_diff = 0
    exact = True
    for k in ref:
        r, o = np.asarray(ref[k]), np.asarray(got[k])
        if r.dtype != o.dtype or not np.array_equal(r, o):
            exact = False
            if r.shape == o.shape:
                max_diff = max(max_diff,
                               int(np.max(np.abs(r.astype(np.int64) - o.astype(np.int64)))))
            else:
                max_diff = -1
    sqnr = {}
    if float_params is not None:
        fl = build_float_fn(g, float_params)(x.astype(np.float32))
        for k in ref:
            f = np.asarray(fl[k], np.float64)
            q = np.asarray(ref[k], np.float64)
            if np.issubdtype(np.asarray(ref[k]).dtype, np.integer):
                q = q * 2.0 ** -qm.f_a[k]
            p_sig = float(np.mean(f ** 2)) or 1e-12
            p_err = float(np.mean((f - q) ** 2)) or 1e-12
            sqnr[k] = 10.0 * np.log10(p_sig / p_err)
    return ValidationReport(exact, len(ref), max_diff, sqnr)


def artifact_round_trip(g: XGraph, qm: QuantizedModel, x: np.ndarray,
                        strategy, dev, path: str,
                        backend: str = "ref") -> ValidationReport:
    """Memory-plan analogue of :func:`bit_exact`: compile ``strategy`` to a
    DNNVM object file, save -> load, execute the *loaded* artifact on its
    *rebuilt* graph, and require bit-identity with the in-memory plan's
    execution (which itself must match the unfused oracle).  A single
    differing int8 value anywhere fails the round trip."""
    from repro.asm import (compile_strategy, graph_signature, load_artifact,
                           save_artifact)

    art = compile_strategy(g, strategy, dev, qm=qm)
    save_artifact(art, path)
    loaded = load_artifact(path)
    # re-sign the *reconstructed* graph: catches any attr the npz round trip
    # dropped or mangled, not just a corrupted stored string
    assert graph_signature(loaded.rebuild_graph()) == art.graph_sig, \
        "graph signature drifted through the artifact round trip"

    mem = Int8Executor(g, qm, strategy=art, backend=backend)(x)
    got = loaded.executor(backend=backend)(x)
    ref = Int8Executor(g, qm, strategy=None, backend="ref")(x)
    assert set(ref) == set(got) == set(mem), "output sets differ"
    max_diff, exact = 0, True
    for k in ref:
        r = np.asarray(ref[k])
        for o in (np.asarray(mem[k]), np.asarray(got[k])):
            if r.dtype != o.dtype or not np.array_equal(r, o):
                exact = False
                if r.shape == o.shape:
                    max_diff = max(max_diff, int(np.max(np.abs(
                        r.astype(np.int64) - o.astype(np.int64)))))
                else:
                    max_diff = -1
    return ValidationReport(exact, len(ref), max_diff, {})
