"""Compile-time lowering: ``pathsearch.Strategy`` -> executable ``GroupProgram``.

The path search decides *what* to fuse; this pass decides — once, at compile
time — *how* every execution group runs on the accelerator backend.  The
result is a :class:`GroupProgram`: a topo-ordered list of

* :class:`FusedLaunch` — one Pallas kernel launch executing a whole group
  (an op chain ``conv -> ... -> {maxpool|avgpool|eltwise_add|gap}`` as a
  staged on-chip program, an ``fc`` re-expressed as a 1x1 conv, or a
  horizontal shared-input group batched over stacked weights), with every
  parameter the kernel needs (pads, strides, dilations, requantization
  shifts, masking extents) resolved; and
* :class:`RefFallback` — groups the kernel cannot run, each carrying a
  machine-readable ``reason`` from :data:`FALLBACK_REASONS`.

The executor becomes a dumb dispatcher over the program: it never inspects
the graph at run time, so fallback is an explicit, measured compiler decision
(``GroupProgram.meta['coverage']``) instead of a silent trace-time crutch.
The program serializes into the ``CompiledArtifact`` (``asm.artifact``), which
makes a loaded artifact self-contained.

Stage specs are plain tuples (JSON-safe, hashable — they become jit static
arguments):

  ("conv", node, kh, kw, sh, sw, ph, pw, dh, dw, shift, relu, out_h, out_w)
  ("pool", node, pkind, kph, kpw, sph, spw, pph, ppw, out_h, out_w, cnt)
  ("elt",  node, s_main, s_side, relu_out, out_h, out_w)

``pkind`` is "max" | "avg" | "gap"; ``cnt`` is the averaging divisor.  All
extents are *true* (unpadded) output extents — the kernel masks ragged/ceil
regions against them.
"""
from __future__ import annotations

import dataclasses
import math
from collections import Counter

from repro.core.xgraph import XGraph, _padding

# Machine-readable fallback vocabulary.  Tests allow-list against this; any
# reason outside it is a lowering bug, not a legitimate fallback.
FALLBACK_REASONS = frozenset({
    "host_op",         # partitioned to the host by the mixed-compilation pass
    "folded_concat",   # layout no-op: producers SAVE with strides (zero cost)
    "unsupported_op",  # op with no fused-kernel support (softmax, reorg, ...)
    "unquantized",     # conv/fc weights missing from the QuantizedModel
    "gap_mid_chain",   # global pooling feeding further fused ops
})

# Ops the chain kernel can execute as stages.
_CHAIN_OPS = frozenset({"conv", "dilated_conv", "fc", "maxpool", "avgpool",
                        "global_avgpool", "eltwise_add"})


@dataclasses.dataclass(frozen=True)
class FusedLaunch:
    """One kernel launch, fully resolved at compile time."""
    kind: str                       # "chain" | "horizontal"
    nodes: tuple                    # graph nodes this launch covers
    in_name: str                    # external input tensor
    out_name: str = ""              # chain: env key written (== nodes[-1])
    stages: tuple = ()              # chain stage specs (see module docstring)
    sides: tuple = ()               # side tensor names, one per "elt" stage
    members: tuple = ()             # horizontal: (name, oc, shift, relu) each
    kernel: tuple = ()              # horizontal shared conv kernel (kh, kw)
    stride: tuple = ()              # horizontal shared stride
    pad: tuple = ()                 # horizontal shared explicit pad (ph, pw)
    out_hw: tuple = ()              # (oh, ow) of the final output
    fc_reshape: bool = False        # fc-as-1x1-conv: flatten input first
    tile: tuple = ()                # searched (t_h, t_w, t_oc); () = kernel
                                    # heuristics (see ops._resolve_tile)


@dataclasses.dataclass(frozen=True)
class RefFallback:
    """A group the compiler decided NOT to fuse, and why."""
    nodes: tuple
    reason: str                     # one of FALLBACK_REASONS
    detail: str = ""

    def __post_init__(self):
        if self.reason not in FALLBACK_REASONS:
            raise ValueError(f"unknown fallback reason {self.reason!r}")


@dataclasses.dataclass
class GroupProgram:
    """Topo-ordered lowered program + coverage accounting."""
    items: list                     # FusedLaunch | RefFallback
    meta: dict

    @property
    def coverage(self) -> float:
        return self.meta["coverage"]

    def launches(self):
        return [i for i in self.items if isinstance(i, FusedLaunch)]

    def fallbacks(self):
        return [i for i in self.items if isinstance(i, RefFallback)]


# ------------------------------------------------------------- stage builders
def _conv_stage(g: XGraph, qm, name: str):
    node = g.nodes[name]
    a = node.attrs
    kh, kw = a["kernel"]
    dh, dw = a.get("dilation", (1, 1))
    sh, sw = a.get("stride", (1, 1))
    ph, pw = _padding(a.get("pad", "same"), dh * (kh - 1) + 1, dw * (kw - 1) + 1)
    shift = qm.shift_for(g, name) if qm is not None else 0
    _, oh, ow, _ = g.shape(name)
    return ("conv", name, kh, kw, sh, sw, ph, pw, dh, dw,
            int(shift), bool(a.get("relu")), oh, ow)


def _fc_stage(g: XGraph, qm, name: str):
    shift = qm.shift_for(g, name) if qm is not None else 0
    return ("conv", name, 1, 1, 1, 1, 0, 0, 1, 1,
            int(shift), bool(g.nodes[name].attrs.get("relu")), 1, 1)


def _pool_stage(g: XGraph, name: str):
    """Returns a stage spec, or a RefFallback reason string."""
    node = g.nodes[name]
    a = node.attrs
    _, oh, ow, _ = g.shape(name)
    if node.op == "global_avgpool":
        _, ih, iw, _ = g.shape(node.inputs[0])
        return ("pool", name, "gap", ih, iw, 1, 1, 0, 0, 1, 1, ih * iw)
    kh, kw = a["kernel"]
    sh, sw = a.get("stride", a["kernel"])
    ph, pw = _padding(a.get("pad", "valid"), kh, kw)
    if node.op == "avgpool":
        # Ceil-extended windows read zeros (the avg pad identity) and keep the
        # kh*kw divisor — count_include_pad semantics, same as int8_ops.avgpool.
        return ("pool", name, "avg", kh, kw, sh, sw, ph, pw, oh, ow, kh * kw)
    return ("pool", name, "max", kh, kw, sh, sw, ph, pw, oh, ow, kh * kw)


def _elt_stage(g: XGraph, qm, name: str, main_input: str):
    node = g.nodes[name]
    side = [i for i in node.inputs if i != main_input]
    if len(node.inputs) != 2 or len(side) != 1:
        return None, None
    if qm is not None:
        s_main = qm.f_a[main_input] - qm.f_a[name]
        s_side = qm.f_a[side[0]] - qm.f_a[name]
    else:
        s_main = s_side = 0
    _, oh, ow, _ = g.shape(name)
    return ("elt", name, int(s_main), int(s_side),
            bool(node.attrs.get("relu")), oh, ow), side[0]


# ------------------------------------------------------------- group lowering
def tile_key(nodes) -> str:
    """JSON-safe key of a launch's node cover inside
    ``strategy.meta['tile_shapes']`` (node names never contain '|')."""
    return "|".join(nodes)


def lower_group(g: XGraph, qm, group: list,
                tile: tuple = ()) -> FusedLaunch | RefFallback:
    """Lower one chain group to a launch, or a reasoned fallback.

    ``tile`` is the searched (t_h, t_w, t_oc) shape the launch must execute
    (empty: the kernel's own heuristics)."""
    nodes = tuple(group)
    ops = [g.nodes[n].op for n in group]

    if all(op == "concat" and g.nodes[n].attrs.get("folded")
           for n, op in zip(group, ops)):
        return RefFallback(nodes, "folded_concat")
    for n, op in zip(group, ops):
        if op not in _CHAIN_OPS:
            return RefFallback(nodes, "unsupported_op", detail=op)
    if "fc" in ops and len(group) > 1:
        return RefFallback(nodes, "unsupported_op", detail="fc in chain")
    if qm is not None:
        for n, op in zip(group, ops):
            if op in ("conv", "dilated_conv", "fc") and n not in qm.weights:
                return RefFallback(nodes, "unquantized", detail=n)
    if "global_avgpool" in ops and ops.index("global_avgpool") != len(ops) - 1:
        return RefFallback(nodes, "gap_mid_chain")

    stages, sides = [], []
    head = g.nodes[group[0]]
    in_name = head.inputs[0]
    prev = in_name
    for n, op in zip(group, ops):
        if op in ("conv", "dilated_conv"):
            stages.append(_conv_stage(g, qm, n))
        elif op == "fc":
            stages.append(_fc_stage(g, qm, n))
        elif op == "eltwise_add":
            st, side = _elt_stage(g, qm, n, prev)
            if st is None:
                return RefFallback(nodes, "unsupported_op",
                                   detail=f"{len(g.nodes[n].inputs)}-ary eltwise")
            stages.append(st)
            sides.append(side)
        else:
            st = _pool_stage(g, n)
            if isinstance(st, str):
                return RefFallback(nodes, st)
            stages.append(st)
        prev = n
    _, oh, ow, _ = g.shape(group[-1])
    return FusedLaunch(kind="chain", nodes=nodes, in_name=in_name,
                       out_name=group[-1], stages=tuple(stages),
                       sides=tuple(sides), out_hw=(oh, ow),
                       fc_reshape=(ops == ["fc"]),
                       tile=tuple(int(t) for t in tile))


def lower_horizontal(g: XGraph, qm, members: list,
                     tile_map: dict | None = None) -> list:
    """Lower a horizontal (shared-input) group.

    Compatible plain-conv members (same kernel/stride/pad, dilation 1,
    quantized) become ONE batched launch over OC-stacked weights with
    per-channel requantization shifts; the rest lower individually (a lone
    conv or pool member is still a fused launch of its own).  ``tile_map``
    maps :func:`tile_key` of a launch's node cover to its searched tile
    shape."""
    tile_map = tile_map or {}
    classes: dict[tuple, list] = {}
    rest = []
    for m in members:
        node = g.nodes[m]
        a = node.attrs
        if (node.op == "conv" and tuple(a.get("dilation", (1, 1))) == (1, 1)
                and (qm is None or m in qm.weights)):
            kh, kw = a["kernel"]
            key = (kh, kw, tuple(a.get("stride", (1, 1))),
                   _padding(a.get("pad", "same"), kh, kw))
            classes.setdefault(key, []).append(m)
        else:
            rest.append(m)
    items = []
    for (kh, kw, stride, pad), ms in sorted(classes.items()):
        if len(ms) < 2:
            rest.extend(ms)
            continue
        mem = tuple(
            (m, g.shape(m)[3],
             int(qm.shift_for(g, m)) if qm is not None else 0,
             bool(g.nodes[m].attrs.get("relu")))
            for m in ms)
        _, oh, ow, _ = g.shape(ms[0])
        items.append(FusedLaunch(
            kind="horizontal", nodes=tuple(ms),
            in_name=g.nodes[ms[0]].inputs[0], members=mem,
            kernel=(kh, kw), stride=stride, pad=pad, out_hw=(oh, ow),
            tile=tuple(int(t) for t in tile_map.get(tile_key(ms), ()))))
    for m in sorted(rest, key=list(g.nodes).index):
        items.append(lower_group(g, qm, [m],
                                 tile=tile_map.get(tile_key((m,)), ())))
    return items


# ---------------------------------------------------------- strategy lowering
def lower_strategy(g: XGraph, strategy, qm=None) -> GroupProgram:
    """Lower a whole strategy (or per-node naive execution when ``strategy``
    is None) into a topo-ordered :class:`GroupProgram`.

    ``qm`` resolves requantization shifts; without it the program is
    *structural* (valid coverage accounting, zeroed shifts) and is re-lowered
    by the executor before running — ``meta['quantized']`` records which.

    ``strategy.meta['tile_shapes']`` (:func:`tile_key` of a launch's nodes ->
    (t_h, t_w, t_oc), written by the tile-shape search) is stamped onto the
    matching launches, so a tuned tile shape is a compile-time decision that
    rides the program into the artifact."""
    from repro.core.pathsearch import order_groups

    tile_map: dict = {}
    if strategy is None:
        groups = [[n] for n in g.compute_nodes()]
        horizontal: list = []
        host: list = []
    else:
        groups = [list(grp) for grp in strategy.groups]
        horizontal = [list(h) for h in strategy.horizontal]
        host = list(strategy.meta.get("host_nodes", []))
        tile_map = dict(strategy.meta.get("tile_shapes") or {})

    units = order_groups(g, groups + horizontal + [[h] for h in host])
    hset = {tuple(h) for h in horizontal}
    host_set = set(host)

    items: list = []
    n_units = n_fused = n_host = n_folded = 0
    reasons: Counter = Counter()
    kinds: Counter = Counter()
    for unit in units:
        if len(unit) == 1 and unit[0] in host_set:
            items.append(RefFallback((unit[0],), "host_op"))
            reasons["host_op"] += 1
            n_host += 1
            continue
        got = (lower_horizontal(g, qm, unit, tile_map=tile_map)
               if tuple(unit) in hset
               else [lower_group(g, qm, unit,
                                 tile=tile_map.get(tile_key(unit), ()))])
        items.extend(got)
        if all(isinstance(i, RefFallback) and i.reason == "folded_concat"
               for i in got):
            n_folded += 1
            reasons["folded_concat"] += len(got)
            continue
        n_units += 1
        if all(isinstance(i, FusedLaunch) for i in got):
            n_fused += 1
        for i in got:
            if isinstance(i, FusedLaunch):
                kinds[i.kind] += 1
            else:
                reasons[i.reason] += 1

    meta = {
        "quantized": qm is not None,
        "n_units": n_units,            # strategy groups (excl. host & folded)
        "n_fused_units": n_fused,
        "coverage": (n_fused / n_units) if n_units else 1.0,
        "n_launches": sum(kinds.values()),
        "n_tiled_launches": sum(1 for i in items
                                if isinstance(i, FusedLaunch) and i.tile),
        "n_fallbacks": sum(1 for i in items if isinstance(i, RefFallback)),
        "n_host_units": n_host,
        "n_folded_units": n_folded,
        "kinds": dict(kinds),
        "fallback_reasons": dict(reasons),
    }
    return GroupProgram(items=items, meta=meta)


# -------------------------------------------------------------- serialization
def _tuplify(x):
    if isinstance(x, list):
        return tuple(_tuplify(v) for v in x)
    return x


def program_to_json(prog: GroupProgram) -> dict:
    out = []
    for item in prog.items:
        if isinstance(item, FusedLaunch):
            d = dataclasses.asdict(item)
            d["t"] = "launch"
        else:
            d = dataclasses.asdict(item)
            d["t"] = "fallback"
        out.append(d)
    return {"items": out, "meta": prog.meta}


def program_from_json(payload: dict) -> GroupProgram:
    items: list = []
    for d in payload["items"]:
        d = dict(d)
        t = d.pop("t")
        if t == "launch":
            items.append(FusedLaunch(**{k: _tuplify(v) if isinstance(v, list)
                                        else v for k, v in d.items()}))
        else:
            items.append(RefFallback(nodes=tuple(d["nodes"]),
                                     reason=d["reason"],
                                     detail=d.get("detail", "")))
    meta = dict(payload["meta"])
    return GroupProgram(items=items, meta=meta)
