"""Algorithm 1: heuristic subgraph isomorphism for fusion-opportunity search.

Faithful to the paper's pseudo-code (§4.2), which itself distils Ullmann/VF2/
boostIso ideas:

  * ``FilterCandidates``  — per query vertex, all graph vertices of matching
    type; abort early if any candidate set is empty (lines 2–7).
  * ``DefineStartPoint``  — the query vertex whose type occurs *least often*
    in the data graph (the paper's Conv-vs-Pool example), minimizing the
    recursion tree (line 8).
  * ``SubgraphSearch``    — recursive extension in BFS order from the start
    vertex; ``RefineCandidates`` prunes candidates not adjacent (with correct
    edge direction) to already-matched vertices; ``Matching`` checks type,
    adjacency, injectivity and the template's semantic predicate (lines 10–22).

Enumerates *all* distinct embeddings — this is exactly what the greedy
matchers in GPP compilers don't do, and what feeds the global path search.
"""
from __future__ import annotations

from collections import deque
from typing import Iterator

from repro.core.templates import Template
from repro.core.xgraph import XGraph


def find_embeddings(g: XGraph, template: Template) -> list[dict]:
    """All distinct embeddings of ``template`` in ``g`` as {var: node_name}."""
    return list(iter_embeddings(g, template))


def iter_embeddings(g: XGraph, template: Template) -> Iterator[dict]:
    # --- FilterCandidates ---------------------------------------------------
    candidates: dict[str, list[str]] = {}
    for var, types in template.vertices.items():
        cand = [n.name for n in g if n.op in types]
        if not cand:
            return  # some query vertex has no candidate: no embeddings
        candidates[var] = cand

    # --- DefineStartPoint: rarest candidate set -----------------------------
    start = min(candidates, key=lambda v: len(candidates[v]))

    # --- BFS order over the (undirected view of the) pattern ----------------
    adj: dict[str, list[tuple[str, bool]]] = {v: [] for v in template.vertices}
    for (u, v) in template.edges:
        adj[u].append((v, True))    # u -> v : True means "v consumes u"
        adj[v].append((u, False))
    order = [start]
    seen = {start}
    dq = deque([start])
    while dq:
        cur = dq.popleft()
        for nxt, _ in adj[cur]:
            if nxt not in seen:
                seen.add(nxt)
                order.append(nxt)
                dq.append(nxt)
    if len(order) != len(template.vertices):
        raise ValueError(f"template {template.name} is not connected")

    # --- SubgraphSearch ------------------------------------------------------
    M: dict[str, str] = {}

    def refine(var: str) -> list[str]:
        """RefineCandidates: keep candidates adjacent to matched neighbours."""
        cand = candidates[var]
        for nbr, nbr_consumes_var_src in adj[var]:
            if nbr not in M:
                continue
            u = M[nbr]
            if nbr_consumes_var_src:
                # pattern edge var -> nbr : graph node must be a producer of u
                allowed = set(g.producers(u))
            else:
                allowed = set(g.consumers(u))
            cand = [c for c in cand if c in allowed]
        return cand

    def matching(node: str, var: str) -> bool:
        if node in M.values():
            return False  # injective
        if g.nodes[node].op not in template.var_types(var):
            return False
        return True

    def search(depth: int) -> Iterator[dict]:
        if depth == len(order):
            m = dict(M)
            if template.predicate is None or template.predicate(g, m):
                yield m
            return
        var = order[depth]
        for u in refine(var):
            if matching(u, var):
                M[var] = u
                yield from search(depth + 1)
                del M[var]

    yield from search(0)


def find_all(g: XGraph, templates) -> dict:
    """Embeddings for every template: {Template: [embedding, ...]}."""
    return {t: find_embeddings(g, t) for t in templates}
