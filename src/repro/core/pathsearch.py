"""Algorithm 2: heuristic shortest-path search for the best execution strategy.

The paper exchanges node/edge attributes so fused ops become *edges* weighted
by cost, sets *barriers* at operations that depend on more than one operation
or are depended on by different operations, runs Floyd between adjacent
barrier pairs, and enumerates the special cases (eltwise-add absorbed into one
incoming branch; horizontal fusion of convolutions sharing an input) at the
barriers themselves (§5.2, Fig. 4c/d, Algorithm 2 lines 4–12).

Concretely here:

  1. the compute DAG is decomposed into maximal single-in/single-out *chains*
     (barrier-to-barrier segments);
  2. each chain is optimally partitioned into fused segments by Floyd over
     cut-points — edge (i, j) exists iff ops[i+1..j] is a valid fused group
     (consecutive pairs match a kernel-fusion template AND the tiling solver
     proves fusion condition 1), weighted by the cost evaluator;
  3. at each eltwise merge barrier we enumerate absorbing the eltwise into
     each incoming branch vs. standalone, and keep the cheapest;
  4. at each fork barrier whose consumers are convolutions we enumerate
     horizontal fusion of the sibling heads.

A greedy baseline (what GPP compilers do, per §4.2) and the naive no-fusion
strategy are provided for the Table-3 comparison.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import isomorphism, templates, tiling
from repro.core.cost import AnalyticEvaluator, INFEASIBLE
from repro.core.xgraph import XGraph
from repro.hw import DeviceModel

HORIZONTAL_OK = templates.CONVS | templates.POOLS

# Bounds for the recorded search trace (``Strategy.meta['search_trace']``).
# The trace is an audit record, not a database: per chain it keeps the chosen
# partition, the cheapest few scored-but-not-chosen alternatives, and a
# bounded sample of rejections — enough for ``repro.explain`` to say *why*
# this strategy and not another, at a few KB per model.
TRACE_MAX_CHAINS = 64
TRACE_MAX_ALTERNATIVES = 8
TRACE_MAX_REJECT_EXAMPLES = 4

# Machine-readable rejection vocabulary (mirrors lower.FALLBACK_REASONS in
# spirit): every candidate segment the search discards carries one of these.
REJECT_REASONS = frozenset({
    "no_fusion_template",   # a consecutive pair matches no kernel template
    "infeasible_tiling",    # tiling solver failed fusion condition 1 (Eq. 6)
})


@dataclasses.dataclass
class Strategy:
    groups: list[list[str]]          # topo-ordered; covers compute nodes once
    horizontal: list[list[str]]      # horizontal (shared-input) groups
    cost: float
    meta: dict = dataclasses.field(default_factory=dict)

    def covered(self) -> set:
        out: set[str] = set()
        for grp in self.groups + self.horizontal:
            out |= set(grp)
        return out


# ---------------------------------------------------------------- chains
def chains_of(g: XGraph, plannable: set) -> list[list[str]]:
    """Maximal chains of plannable nodes with single-in/single-out interiors."""
    def is_continuation(name: str) -> bool:
        node = g.nodes[name]
        preds = [p for p in node.inputs]
        if len(preds) != 1 or preds[0] not in plannable:
            return False
        return len(g.consumers(preds[0])) == 1

    chains = []
    for name in g.topo_order():
        if name not in plannable or is_continuation(name):
            continue
        chain = [name]
        cur = name
        while True:
            cons = g.consumers(cur)
            if len(cons) != 1:
                break
            nxt = cons[0]
            if nxt not in plannable or len(g.nodes[nxt].inputs) != 1:
                break
            chain.append(nxt)
            cur = nxt
        chains.append(chain)
    return chains


# ------------------------------------------------------------- chain Floyd
def _segment_valid(g: XGraph, ops: list[str], pairs: set) -> bool:
    return all((ops[k], ops[k + 1]) in pairs for k in range(len(ops) - 1))


def partition_chain(g: XGraph, chain: list[str], pairs: set, evaluator, *,
                    collect: dict | None = None,
                    seg_costs: dict | None = None) -> tuple[list[list[str]], float]:
    """Optimal partition of one chain into fused segments via Floyd (paper's
    choice; O(m^3) with m = chain length, m is small for real CNNs).

    ``collect``/``seg_costs`` are optional trace sinks: direct per-segment
    evaluator costs must be captured here at matrix-fill time, because the
    Floyd relaxation below overwrites ``cost[i][j]`` with multi-segment path
    costs and the candidate scores are unrecoverable afterwards."""
    m = len(chain)
    big = INFEASIBLE
    cost = [[big] * (m + 1) for _ in range(m + 1)]
    for i in range(m + 1):
        cost[i][i] = 0.0
    n_feasible = 0
    for i in range(m):
        for j in range(i + 1, m + 1):
            seg = chain[i:j]
            if j - i > 1 and not _segment_valid(g, seg, pairs):
                if collect is not None:
                    collect["rejected"].append((seg, "no_fusion_template"))
                continue
            c = evaluator(seg)
            if math.isfinite(c):
                cost[i][j] = c
                n_feasible += 1
                if seg_costs is not None:
                    seg_costs[tuple(seg)] = c
                if collect is not None:
                    collect["scored"].append((seg, c))
            elif collect is not None:
                collect["rejected"].append((seg, "infeasible_tiling"))
    if collect is not None:
        collect["m"] = m
        collect["n_feasible_segments"] = n_feasible
    nxt = [[-1] * (m + 1) for _ in range(m + 1)]
    for i in range(m + 1):
        for j in range(m + 1):
            if math.isfinite(cost[i][j]):
                nxt[i][j] = j
    # Floyd–Warshall (paper Algorithm 2 lines 17–25)
    for k in range(m + 1):
        ck = cost[k]
        for i in range(m + 1):
            cik = cost[i][k]
            if not math.isfinite(cik):
                continue
            ci = cost[i]
            for j in range(m + 1):
                c = cik + ck[j]
                if c < ci[j]:
                    ci[j] = c
                    nxt[i][j] = nxt[i][k]
    if not math.isfinite(cost[0][m]):
        raise RuntimeError(f"no feasible execution path for chain {chain}")
    # reconstruct segments
    segs, i = [], 0
    while i != m:
        j = nxt[i][m]
        segs.append(chain[i:j])
        i = j
    return segs, cost[0][m]


# ------------------------------------------------------------ the search
def search(g: XGraph, dev: DeviceModel, evaluator=None,
           device_of=None, enable_horizontal: bool = True,
           trace: bool = True) -> Strategy:
    from repro.obs.trace import TRACER
    with TRACER.span("pathsearch", cat="compile", track="compile",
                     graph=g.name):
        return _search(g, dev, evaluator, device_of, enable_horizontal, trace)


def _search(g: XGraph, dev: DeviceModel, evaluator=None,
            device_of=None, enable_horizontal: bool = True,
            trace: bool = True) -> Strategy:
    evaluator = evaluator or AnalyticEvaluator(g, dev)
    plannable = {n.name for n in g
                 if n.op != "input" and (device_of is None or device_of(n.name) == "acc")}
    matches = isomorphism.find_all(g, templates.KERNEL_TEMPLATES)
    pairs = templates.pairwise_fusable(matches)

    chains = chains_of(g, plannable)
    chain_of_node = {}
    for idx, ch in enumerate(chains):
        for nm in ch:
            chain_of_node[nm] = idx

    # seg_costs is the global direct-cost ledger: every partition_chain call
    # (including the speculative eltwise-absorb / horizontal-tail probes below)
    # feeds it, so every segment that ends up a final group has its evaluator
    # score on record regardless of which probe first scored it.
    seg_costs: dict[tuple, float] | None = {} if trace else None
    chain_traces: list[dict] = []
    eltwise_trace: list[dict] = []
    horizontal_trace: list[dict] = []

    def _collector() -> dict | None:
        if not trace or len(chain_traces) >= TRACE_MAX_CHAINS:
            return None
        c = {"scored": [], "rejected": []}
        chain_traces.append(c)
        return c

    solved: dict[int, tuple[list[list[str]], float]] = {}
    for idx, ch in enumerate(chains):
        collect = _collector()
        solved[idx] = partition_chain(g, ch, pairs, evaluator,
                                      collect=collect, seg_costs=seg_costs)
        if collect is not None:
            collect["nodes"] = list(ch)
            collect["chosen"] = [list(s) for s in solved[idx][0]]
            collect["cost"] = solved[idx][1]

    # --- barrier case 1: absorb an eltwise merge into one incoming branch ----
    for idx, ch in enumerate(chains):
        head = ch[0]
        node = g.nodes[head]
        if node.op != "eltwise_add" or len(node.inputs) != 2:
            continue
        best_delta, best_move = 0.0, None
        options: list[dict] = []
        for prod in node.inputs:
            if prod not in chain_of_node or (prod, head) not in pairs:
                continue
            pidx = chain_of_node[prod]
            pch = chains[pidx]
            if pch[-1] != prod or pidx == idx:
                continue
            # candidate: chain' = pch + [head], this chain loses its head
            try:
                new_p, cost_p = partition_chain(g, pch + [head], pairs,
                                                evaluator, seg_costs=seg_costs)
            except RuntimeError:
                continue
            rest = ch[1:]
            if rest:
                new_c, cost_c = partition_chain(g, rest, pairs, evaluator,
                                                seg_costs=seg_costs)
            else:
                new_c, cost_c = [], 0.0
            old = solved[pidx][1] + solved[idx][1]
            delta = (cost_p + cost_c) - old
            options.append({"producer": prod, "delta_s": delta})
            if delta < best_delta:
                best_delta = delta
                best_move = (pidx, new_p, cost_p, new_c, cost_c, prod)
        if best_move:
            pidx, new_p, cost_p, new_c, cost_c, prod = best_move
            solved[pidx] = (new_p, cost_p)
            solved[idx] = (new_c, cost_c)
            chains[pidx] = chains[pidx] + [head]
            chains[idx] = ch[1:]
            chain_of_node[head] = pidx
        if trace and options:
            eltwise_trace.append({
                "eltwise": head,
                "absorbed": best_move is not None,
                "into": best_move[5] if best_move else None,
                "delta_s": best_delta if best_move else 0.0,
                "options": options,
            })

    # --- barrier case 2: horizontal fusion at forks ---------------------------
    horizontal: list[list[str]] = []
    h_cost = 0.0
    h_cost_of: dict[tuple, float] = {}
    if enable_horizontal:
        for name in g.topo_order():
            cons = [c for c in g.consumers(name)
                    if c in plannable and g.nodes[c].op in HORIZONTAL_OK]
            if len(cons) < 2:
                continue
            # only heads of their chains can be pulled out without splitting
            heads = [c for c in cons
                     if c in chain_of_node and chains[chain_of_node[c]][0] == c]
            if len(heads) < 2:
                continue
            if hasattr(evaluator, "horizontal_cost"):
                hcost = evaluator.horizontal_cost(heads)
            else:
                t = tiling.solve_horizontal(g, heads, dev)
                hcost = _tiling_seconds(t, dev) if t.feasible else INFEASIBLE
            if not math.isfinite(hcost):
                if trace:
                    horizontal_trace.append({
                        "input": name, "heads": list(heads), "fused": False,
                        "reason": "infeasible_tiling"})
                continue
            # compare: horizontal group + tails   vs   current chains
            olds, news, tails_groups = 0.0, hcost, []
            ok = True
            for c in heads:
                cidx = chain_of_node[c]
                olds += solved[cidx][1]
                rest = chains[cidx][1:]
                if rest:
                    try:
                        tg, tc = partition_chain(g, rest, pairs, evaluator,
                                                 seg_costs=seg_costs)
                    except RuntimeError:
                        ok = False
                        break
                else:
                    tg, tc = [], 0.0
                news += tc
                tails_groups.append((cidx, tg, tc))
            fused = ok and news < olds
            if fused:
                horizontal.append(heads)
                h_cost += hcost
                h_cost_of[tuple(heads)] = hcost
                for cidx, tg, tc in tails_groups:
                    solved[cidx] = (tg, tc)
            if trace:
                horizontal_trace.append({
                    "input": name, "heads": list(heads), "fused": fused,
                    "fused_cost_s": hcost,
                    "with_tails_cost_s": news if ok else None,
                    "split_cost_s": olds,
                })

    groups: list[list[str]] = []
    total = h_cost
    for idx in range(len(chains)):
        segs, c = solved[idx]
        groups.extend(segs)
        total += c
    # host / non-plannable compute nodes execute as their own units (cost 0 in
    # the accelerator schedule; the host handles them, paper §2.3.5)
    host_nodes = [n.name for n in g
                  if n.op != "input" and n.name not in plannable]
    strategy = Strategy(groups=_topo_sort_groups(g, groups), horizontal=horizontal,
                        cost=total, meta={"host_nodes": host_nodes,
                                          "n_pairs": len(pairs),
                                          "n_chains": len(chains)})
    if trace:
        strategy.meta["search_trace"] = _build_trace(
            g, dev, evaluator, matches, pairs, chains, chain_traces,
            eltwise_trace, horizontal_trace, seg_costs, h_cost_of, strategy)
    # provenance: which cost oracle picked this strategy.  A profile-guided
    # evaluator (tune.CalibratedEvaluator) carries its DeviceProfile; the hash
    # flows into the compiled artifact so a loaded plan knows what it was
    # tuned for (asm.artifact / runtime.Session surface mismatches).
    strategy.meta["evaluator"] = type(evaluator).__name__
    profile = getattr(evaluator, "profile", None)
    if profile is not None and hasattr(profile, "hash"):
        strategy.meta["profile_hash"] = profile.hash()
        strategy.meta["profile_name"] = profile.name
    # Tile-shape provenance: a profile-guided evaluator (tune.
    # CalibratedEvaluator) predicts the best kernel tile shape per group, so
    # every searched strategy carries shapes even before the measured tile
    # search (tune.tiles.search_tile_shapes) refines them.  Keys are
    # lower.tile_key of each launch's node cover; absent key = the kernel's
    # default heuristics (the PR-4 behaviour).
    if hasattr(evaluator, "tile_for"):
        from repro.core.lower import tile_key

        tile_shapes = {}
        for grp in strategy.groups:
            shape = evaluator.tile_for(list(grp))
            if shape:
                tile_shapes[tile_key(grp)] = [int(v) for v in shape]
        if hasattr(evaluator, "tile_for_horizontal"):
            for heads in strategy.horizontal:
                for k, shape in evaluator.tile_for_horizontal(
                        list(heads)).items():
                    tile_shapes[k] = [int(v) for v in shape]
        if tile_shapes:
            strategy.meta["tile_shapes"] = tile_shapes
            strategy.meta["tile_source"] = "profile"
    _check_cover(g, strategy, plannable)
    return strategy


def greedy(g: XGraph, dev: DeviceModel, evaluator=None, device_of=None) -> Strategy:
    """Greedy template matching in topo order — the GPP-compiler baseline."""
    evaluator = evaluator or AnalyticEvaluator(g, dev)
    plannable = {n.name for n in g
                 if n.op != "input" and (device_of is None or device_of(n.name) == "acc")}
    matches = isomorphism.find_all(g, templates.KERNEL_TEMPLATES)
    pairs = templates.pairwise_fusable(matches)
    chains = chains_of(g, plannable)
    groups, total = [], 0.0
    for ch in chains:
        cur = [ch[0]]
        for nm in ch[1:]:
            cand = cur + [nm]
            # greedy: extend when the local pairwise fuse is profitable NOW —
            # this is the myopic rule the paper contrasts with (it commits to
            # the first profitable fuse and misses combinations, §4.2/Fig. 4b)
            if ((cur[-1], nm) in pairs
                    and evaluator(cand) < evaluator(cur) + evaluator([nm])):
                cur = cand
            else:
                groups.append(cur)
                total += evaluator(cur)
                cur = [nm]
        groups.append(cur)
        total += evaluator(cur)
    host_nodes = [n.name for n in g if n.op != "input" and n.name not in plannable]
    return Strategy(groups=_topo_sort_groups(g, groups), horizontal=[], cost=total,
                    meta={"host_nodes": host_nodes})


def naive(g: XGraph, dev: DeviceModel, evaluator=None, device_of=None) -> Strategy:
    """No kernel fusion: every op is its own group (paper's baseline)."""
    evaluator = evaluator or AnalyticEvaluator(g, dev)
    plannable = [n.name for n in g
                 if n.op != "input" and (device_of is None or device_of(n.name) == "acc")]
    groups = [[nm] for nm in plannable]
    total = sum(evaluator(grp) for grp in groups)
    host_nodes = [n.name for n in g if n.op != "input" and n.name not in set(plannable)]
    return Strategy(groups=groups, horizontal=[], cost=total,
                    meta={"host_nodes": host_nodes})


# ----------------------------------------------------------------- trace
def _build_trace(g, dev, evaluator, matches, pairs, chains, chain_traces,
                 eltwise_trace, horizontal_trace, seg_costs, h_cost_of,
                 strategy) -> dict:
    """Assemble the bounded, JSON-native search trace for strategy.meta.

    The trace answers three questions the final Strategy alone cannot: which
    fusion candidates were *considered* (scored alternatives with their costs),
    which were *rejected* and why (machine-readable reasons), and how the two
    barrier heuristics (eltwise absorb, horizontal fusion) decided.  When the
    evaluator is profile-guided, each final group also carries the analytic
    Eq. 5/6 prediction next to the calibrated one, so calibration influence
    stays visible per decision."""
    from repro.core.lower import tile_key

    chosen_keys = {tuple(grp) for grp in strategy.groups}
    chain_records = []
    for ct in chain_traces:
        if "nodes" not in ct:       # collector allocated but chain never solved
            continue
        alternatives = sorted(
            ((seg, c) for seg, c in ct["scored"]
             if tuple(seg) not in chosen_keys),
            key=lambda sc: sc[1])[:TRACE_MAX_ALTERNATIVES]
        reasons: dict[str, int] = {}
        examples: list[dict] = []
        for seg, why in ct["rejected"]:
            reasons[why] = reasons.get(why, 0) + 1
            if len(examples) < TRACE_MAX_REJECT_EXAMPLES:
                examples.append({"nodes": list(seg), "reason": why})
        chain_records.append({
            "nodes": list(ct["nodes"]),
            "m": ct.get("m", len(ct["nodes"])),
            # frontier: how many candidate segments survived template matching
            # and the tiling-feasibility probe for this chain's Floyd matrix
            "frontier": ct.get("n_feasible_segments", 0),
            "cost_s": ct.get("cost"),
            "chosen": [{"nodes": list(s), "cost_s": seg_costs.get(tuple(s))}
                       for s in ct.get("chosen", [])],
            "alternatives": [{"nodes": list(s), "cost_s": c}
                             for s, c in alternatives],
            "n_rejected": reasons,
            "rejected_examples": examples,
        })

    # final group costs (direct evaluator scores, pre-Floyd-relaxation) keyed
    # the same way lowering/tiling key launches, so downstream consumers join
    # trivially; plus the analytic comparison when search was profile-guided.
    analytic = (evaluator if type(evaluator).__name__ == "AnalyticEvaluator"
                else AnalyticEvaluator(g, dev))
    group_costs: dict[str, dict] = {}
    for grp in strategy.groups:
        entry: dict = {"kind": "chain"}
        c = seg_costs.get(tuple(grp))
        if c is not None:
            entry["cost_s"] = c
        try:
            a = analytic(list(grp))
            entry["analytic_cost_s"] = a if math.isfinite(a) else None
        except Exception:
            entry["analytic_cost_s"] = None
        group_costs[tile_key(grp)] = entry
    for heads in strategy.horizontal:
        entry = {"kind": "horizontal"}
        c = h_cost_of.get(tuple(heads))
        if c is not None:
            entry["cost_s"] = c
        try:
            a = analytic.horizontal_cost(list(heads))
            entry["analytic_cost_s"] = a if math.isfinite(a) else None
        except Exception:
            entry["analytic_cost_s"] = None
        group_costs[tile_key(heads)] = entry

    return {
        "evaluator": type(evaluator).__name__,
        "templates": {t.name: len(embs) for t, embs in matches.items()},
        "n_fusable_pairs": len(pairs),
        "n_chains": len(chains),
        "n_chains_recorded": len(chain_records),
        "chains": chain_records,
        "eltwise_absorb": eltwise_trace,
        "horizontal": horizontal_trace,
        "group_costs": group_costs,
        "total_cost_s": strategy.cost,
        "bounds": {"max_chains": TRACE_MAX_CHAINS,
                   "max_alternatives": TRACE_MAX_ALTERNATIVES,
                   "max_reject_examples": TRACE_MAX_REJECT_EXAMPLES},
    }


# ----------------------------------------------------------------- helpers
def _tiling_seconds(t: tiling.GroupTiling, dev: DeviceModel) -> float:
    ddr = t.dram_bytes / dev.dram_bw_bytes_per_s
    conv = t.conv_cycles / dev.freq_hz
    misc = t.misc_cycles / dev.freq_hz
    steady = max(ddr, conv, misc)
    return steady + (ddr + conv + misc - steady) / max(1, t.n_spatial_tiles)


def _topo_sort_groups(g: XGraph, groups: list[list[str]]) -> list[list[str]]:
    return order_groups(g, groups)


def order_groups(g: XGraph, groups: list[list[str]]) -> list[list[str]]:
    """Topological order over groups: A before B if B consumes A's outputs.

    Stable tie-break by first-node graph position.  Works for any partition
    of (a subset of) compute nodes into disjoint groups."""
    import heapq

    pos = {nm: i for i, nm in enumerate(g.topo_order())}
    owner = {}
    for gi, grp in enumerate(groups):
        for nm in grp:
            owner[nm] = gi
    indeg = [0] * len(groups)
    succs: list[set] = [set() for _ in groups]
    for gi, grp in enumerate(groups):
        for nm in grp:
            for inp in g.nodes[nm].inputs:
                pi = owner.get(inp)
                if pi is not None and pi != gi and gi not in succs[pi]:
                    succs[pi].add(gi)
                    indeg[gi] += 1
    heap = [(pos[groups[i][0]], i) for i in range(len(groups)) if indeg[i] == 0]
    heapq.heapify(heap)
    out = []
    while heap:
        _, gi = heapq.heappop(heap)
        out.append(groups[gi])
        for si in succs[gi]:
            indeg[si] -= 1
            if indeg[si] == 0:
                heapq.heappush(heap, (pos[groups[si][0]], si))
    if len(out) != len(groups):
        raise AssertionError("cycle in group ordering — invalid fusion strategy")
    return out


def _check_cover(g: XGraph, s: Strategy, plannable: set) -> None:
    got = s.covered()
    if got != plannable:
        missing = plannable - got
        extra = got - plannable
        raise AssertionError(
            f"strategy cover mismatch: missing={sorted(missing)[:5]} extra={sorted(extra)[:5]}")
