"""Int8 fixed-point quantization (paper §2.3.4 / §6.1).

"Our data quantization method is similar with Angel-Eye: the radix position
of the fixed-point data in each layer is chosen differently and we adopt the
quantization method with the best accuracy by enumerating possible solutions."

* Weights: per-layer fraction from the weight range, refined by enumerating
  neighbouring radix positions and keeping the lowest quantization MSE.
* Activations: per-node fraction from a float calibration run.
* Biases: int32 at fraction f_in + f_w (so they add directly into the
  accumulator).
* Intrinsic folds: conv+BN+Scale parameter pre-computation happens here, at
  weight-preparation time — the graph pass (frontend.fold_intrinsics) only
  records what to fold.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.xgraph import XGraph

F_MIN, F_MAX = -12, 24


def best_fraction(data: np.ndarray, bits: int = 8, search: int = 1) -> int:
    """Radix position minimizing quantization MSE (enumerated, paper-style)."""
    amax = float(np.max(np.abs(data))) or 1e-9
    qmax = 2 ** (bits - 1) - 1
    f0 = int(np.floor(np.log2(qmax / amax)))
    best_f, best_err = f0, None
    for f in range(f0 - search, f0 + search + 1):
        q = np.clip(np.round(data * 2.0 ** f), -(qmax + 1), qmax)
        err = float(np.mean((q * 2.0 ** -f - data) ** 2))
        if best_err is None or err < best_err:
            best_f, best_err = f, err
    return int(np.clip(best_f, F_MIN, F_MAX))


def quantize_to(data: np.ndarray, f: int, bits: int = 8) -> np.ndarray:
    qmax = 2 ** (bits - 1) - 1
    q = np.clip(np.round(data * 2.0 ** f), -(qmax + 1), qmax)
    return q.astype(np.int8 if bits == 8 else np.int32)


def fold_conv_intrinsics(w: np.ndarray, b: np.ndarray, folded: list) -> tuple:
    """Pre-compute conv+BN+Scale/bias chains into (w', b') (paper §4.1.1).

    ``folded`` is the conv node's ``folded_intrinsics`` attr: a list of
    (op, params) applied in graph order after the conv.
    """
    w, b = w.copy(), b.copy()
    for op, p in folded:
        if op == "bn":
            g_ = p.get("gamma", 1.0)
            beta = p.get("beta", 0.0)
            mu, var, eps = p["mean"], p["var"], p.get("eps", 1e-5)
            scale = g_ / np.sqrt(var + eps)
            w = w * scale  # broadcast over OC (last axis of HWIO)
            b = (b - mu) * scale + beta
        elif op == "scale":
            w = w * p["alpha"]
            b = b * p["alpha"] + p.get("beta", 0.0)
        elif op == "bias_add":
            b = b + p.get("bias", 0.0)
        else:
            raise ValueError(f"unknown intrinsic {op}")
    return w, b


@dataclasses.dataclass
class QuantizedModel:
    weights: dict      # node -> int8 ndarray (HWIO / (IN,OC) for fc)
    biases: dict       # node -> int32 ndarray at fraction f_in + f_w
    f_w: dict          # node -> weight fraction
    f_a: dict          # node -> activation fraction (every node, incl. input)

    def shift_for(self, g: XGraph, name: str) -> int:
        """Requantization shift of a conv/fc node: f_in + f_w - f_out."""
        f_in = self.f_a[g.nodes[name].inputs[0]]
        return f_in + self.f_w[name] - self.f_a[name]


def calibrate(g: XGraph, float_params: dict, calib_input: np.ndarray,
              run_float) -> QuantizedModel:
    """Quantize a float model given one calibration batch.

    ``run_float(g, float_params, x) -> {node: activation}`` is provided by the
    executor (avoids a circular import).
    """
    acts = run_float(g, float_params, calib_input)
    f_a = {name: best_fraction(np.asarray(a)) for name, a in acts.items()}
    # concat/eltwise require a shared output fraction <= each input's
    for node in g:
        if node.op in ("concat", "eltwise_add"):
            f_a[node.name] = min([f_a[node.name]] + [f_a[i] for i in node.inputs])

    weights, biases, f_w = {}, {}, {}
    for node in g:
        if node.name not in float_params:
            continue
        p = float_params[node.name]
        w, b = p["w"], p.get("b", np.zeros(p["w"].shape[-1], np.float32))
        if node.attrs.get("folded_intrinsics"):
            w, b = fold_conv_intrinsics(w, b, node.attrs["folded_intrinsics"])
        fw = best_fraction(w)
        f_in = f_a[node.inputs[0]]
        weights[node.name] = quantize_to(w, fw)
        biases[node.name] = quantize_to(b, f_in + fw, bits=32)
        f_w[node.name] = fw
    return QuantizedModel(weights, biases, f_w, f_a)
