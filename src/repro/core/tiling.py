"""Capacity-constrained tiling (paper §4.3, Eq. 5–6), generalized to fused
chains.

The paper pins the tile sizes along height and output channel to the hardware
parallelism (Eq. 5: T_h = h_p, T_oc = oc_p, T_ic = inc_p) and maximizes the
tile width T_w subject to the three buffer constraints (Eq. 6).  F^{-1}/G^{-1}
map an output tile back to the input region it needs — for a fused chain this
is the *composed* receptive field of every op in the group.

Fused-chain capacity semantics (DESIGN.md §2, item 1):

* channel-wise consumers (pool / eltwise / upsample / reorg / relu) stream the
  producer's T_oc-channel tile — intermediate tiles are T_oc deep;
* a conv consumer needs *all* channels of its input, so any conv->conv
  boundary forces the upstream intermediate to be full-channel and resident in
  the output buffer (computed once per spatial tile, reused across the final
  op's oc passes — no recompute, the Alwani-style pyramid cost is avoided at
  the price of buffer space, which the constraint below charges for).

Traffic model (drives the CTC improvement of Eq. 1 -> Eq. 2):

* input feature maps are re-streamed once per final-oc pass (the paper's
  Fig. 6 loop order has oc outermost) unless the whole input fits in B_in;
* weights are loaded once if the group's working set fits B_weights, else
  once per spatial tile;
* intermediate feature maps inside a fused group never touch DRAM — that is
  the whole point of kernel fusion.
"""
from __future__ import annotations

import dataclasses
import math

from repro.hw import DeviceModel
from repro.core.xgraph import XGraph

CHANNELWISE = {"maxpool", "avgpool", "global_avgpool", "eltwise_add",
               "upsample", "reorg"}


@dataclasses.dataclass
class GroupTiling:
    feasible: bool
    t_w: int = 0
    t_h: int = 0
    t_oc: int = 0
    n_spatial_tiles: int = 0
    n_oc_passes: int = 1
    load_bytes: int = 0        # external ifmap + eltwise side input traffic
    weight_bytes: int = 0      # weight traffic (incl. reloads)
    save_bytes: int = 0        # final ofmap traffic
    conv_cycles: int = 0       # CONV engine occupancy
    pool_cycles: int = 0       # POOL engine occupancy
    misc_cycles: int = 0       # MISC engine occupancy (eltwise/upsample/reorg)
    # per-tile on-chip footprints (memory/banks.py ping-pong planning):
    in_tile_bytes: int = 0     # one tile's ifmap + side-input slice in B_in
    out_tile_bytes: int = 0    # one tile's ofmap slice in B_out
    resident_bytes: int = 0    # full-channel intermediates pinned in B_out
    reason: str = ""

    @property
    def dram_bytes(self) -> int:
        return self.load_bytes + self.weight_bytes + self.save_bytes


def _rf(g: XGraph, name: str, w_out: int, h_out: int) -> tuple[int, int]:
    """Input tile extent needed by one op to produce a (w_out, h_out) tile."""
    node = g.nodes[name]
    a, op = node.attrs, node.op
    if op in ("conv", "dilated_conv", "depthwise_conv"):
        kh, kw = a["kernel"]
        dh, dw = a.get("dilation", (1, 1))
        sh, sw = a.get("stride", (1, 1))
        return ((w_out - 1) * sw + dw * (kw - 1) + 1,
                (h_out - 1) * sh + dh * (kh - 1) + 1)
    if op in ("maxpool", "avgpool"):
        kh, kw = a["kernel"]
        sh, sw = a.get("stride", a["kernel"])
        return ((w_out - 1) * sw + kw, (h_out - 1) * sh + kh)
    if op == "global_avgpool":
        ish = g.shape(node.inputs[0])
        return ish[2], ish[1]
    if op == "deconv":
        sh, sw = a.get("stride", (2, 2))
        return math.ceil(w_out / sw), math.ceil(h_out / sh)
    if op == "upsample":
        f = a.get("factor", 2)
        return math.ceil(w_out / f), math.ceil(h_out / f)
    if op == "reorg":
        s = a.get("stride", 2)
        return w_out * s, h_out * s
    if op == "fc":
        ish = g.shape(node.inputs[0])
        return ish[2], ish[1]
    return w_out, h_out  # eltwise / pointwise


def _conv_cycles(g: XGraph, name: str, dev: DeviceModel,
                 oc_override: int | None = None) -> int:
    node = g.nodes[name]
    n, oh, ow, oc = g.shape(name)
    if node.op not in ("conv", "dilated_conv", "depthwise_conv", "deconv", "fc"):
        return 0
    ic = g.shape(node.inputs[0])[3]
    if node.op == "fc":
        ish = g.shape(node.inputs[0])
        ic, oh, ow = ish[1] * ish[2] * ish[3], 1, 1
        kh = kw = 1
    else:
        kh, kw = node.attrs["kernel"]
    if node.op == "depthwise_conv":
        ic = 1
    oc_eff = oc_override if oc_override is not None else oc
    # padded MACs (ragged tiles round up to the array parallelism) retired at
    # the device's *effective* MAC rate (see DeviceModel.peak_ops_override)
    padded_macs = (n * math.ceil(oc_eff / dev.oc_p) * dev.oc_p
                   * math.ceil(ic / dev.ic_p) * dev.ic_p
                   * math.ceil(oh / dev.h_p) * dev.h_p * ow * kh * kw)
    return math.ceil(padded_macs / dev.macs_per_cycle_eff)


def solve(g: XGraph, group: list[str], dev: DeviceModel) -> GroupTiling:
    """Tile a fused chain ``group`` (topo-ordered node names) on ``dev``.

    Single-op groups use exactly the paper's Eq. 5/6: T_h/T_oc pinned to the
    array parallelism, T_w maximized under the buffer bounds.  Returns an
    infeasible tiling (with ``reason``) when even T_w = 1 violates a buffer
    bound — the path search then rejects the fusion (condition 1 fails).
    """
    return solve_shape(g, group, dev)


def solve_shape(g: XGraph, group: list[str], dev: DeviceModel,
                t_w: int | None = None, t_h: int | None = None,
                t_oc: int | None = None) -> GroupTiling:
    """Tile ``group`` with an explicit shape; ``None`` dims take the paper's
    Eq. 5/6 defaults (T_h = h_p, T_oc = oc_p, T_w maximized).  The returned
    tiling carries the full traffic/occupancy breakdown for the chosen shape,
    so ``enumerate_tilings`` candidates and the analytic default flow through
    one cost pipeline.
    """
    eb = dev.elem_bytes
    last = group[-1]
    n, H, W, OC = g.shape(last)
    first = group[0]
    ext_in = g.producers(first)[0] if g.producers(first) else None
    group_set = set(group)

    # Which boundaries are conv->conv (full-channel residents)?
    full_channel_after = {}
    for i, name in enumerate(group[:-1]):
        consumer = group[i + 1]
        full_channel_after[name] = g.nodes[consumer].op not in CHANNELWISE

    # side inputs (e.g. the second eltwise operand) loaded from DRAM per tile
    side_inputs = []
    for name in group:
        for inp in g.producers(name):
            if inp not in group_set and inp != ext_in:
                side_inputs.append(inp)

    t_h = min(dev.h_p, H) if t_h is None else max(1, min(int(t_h), H))
    t_oc = min(dev.oc_p, OC) if t_oc is None else max(1, min(int(t_oc), OC))

    total_weight_bytes = sum(g.param_bytes(nm, eb) for nm in group)
    weights_fit = total_weight_bytes <= dev.buf_weights_bytes

    def tile_footprint(t_w: int) -> tuple[int, int, int]:
        """(ifmap+side bytes in B_in, ofmap bytes in B_out, resident
        intermediates in B_out) for one tile of width ``t_w``."""
        # walk output -> input, tracking per-node tile extents
        w, h = t_w, t_h
        inter_bytes = 0
        for i in range(len(group) - 1, -1, -1):
            name = group[i]
            w, h = _rf(g, name, w, h)
            if i > 0:
                prod = group[i - 1]
                cdepth = (g.shape(prod)[3] if full_channel_after[prod] else t_oc)
                inter_bytes += w * h * min(cdepth, g.shape(prod)[3]) * eb
        ic_in = g.shape(ext_in)[3] if ext_in else 0
        in_tile = min(dev.ic_p, ic_in) * w * h * eb
        side_tile = sum(t_w * t_h * min(t_oc, g.shape(s)[3]) * eb
                        for s in side_inputs)
        out_tile = t_w * t_h * t_oc * eb
        return in_tile + side_tile, out_tile, inter_bytes

    def capacity_ok(t_w: int) -> bool:
        in_tile, out_tile, inter_bytes = tile_footprint(t_w)
        w_need = (total_weight_bytes if weights_fit else
                  sum(min(g.param_bytes(nm, eb),
                          dev.ic_p * dev.oc_p * _kk(g, nm) * eb) for nm in group))
        return (in_tile <= dev.buf_in_bytes
                and w_need <= dev.buf_weights_bytes
                and out_tile + inter_bytes <= dev.buf_out_bytes)

    if not capacity_ok(1):
        return GroupTiling(False, reason="working set exceeds on-chip buffers at T_w=1")

    if t_w is None:
        lo, hi = 1, W
        while lo < hi:  # binary search the largest feasible T_w
            mid = (lo + hi + 1) // 2
            if capacity_ok(mid):
                lo = mid
            else:
                hi = mid - 1
        t_w = lo
    else:
        t_w = max(1, min(int(t_w), W))
        if not capacity_ok(t_w):
            return GroupTiling(
                False, t_w=t_w, t_h=t_h, t_oc=t_oc,
                reason=f"tile ({t_w}, {t_h}, {t_oc}) exceeds on-chip buffers")

    n_w = math.ceil(W / t_w)
    n_h = math.ceil(H / t_h)
    n_spatial = n_w * n_h * max(1, n)
    n_oc_passes = math.ceil(OC / t_oc)

    # --- DRAM traffic ---------------------------------------------------------
    # per-tile input region (includes halo overlap between neighbouring tiles)
    w_in, h_in = t_w, t_h
    for i in range(len(group) - 1, -1, -1):
        w_in, h_in = _rf(g, group[i], w_in, h_in)
    ic_in = g.shape(ext_in)[3] if ext_in else 0
    in_bytes_full = g.fmap_bytes(ext_in, eb) if ext_in else 0
    per_tile_in = w_in * h_in * ic_in * eb
    input_resident = in_bytes_full <= dev.buf_in_bytes
    has_full_boundary = any(full_channel_after.values())
    in_sweep = min(per_tile_in * n_spatial, in_bytes_full * max(1, n_w * n_h))
    if input_resident and weights_fit:
        in_traffic, w_traffic = in_bytes_full, total_weight_bytes
    elif has_full_boundary:
        # conv->conv chain: upstream computes all channels once per spatial
        # tile, so input streams once; weights reload per tile unless resident
        in_traffic = in_sweep
        w_traffic = total_weight_bytes * (1 if weights_fit else n_spatial)
    else:
        # single conv / channel-wise chain: pick the cheaper loop order
        # (a) weight-stationary, oc outermost (paper Fig. 6): weights once,
        #     input re-streamed per oc pass
        ws = (in_sweep * (1 if input_resident else n_oc_passes),
              total_weight_bytes)
        # (b) input-stationary, spatial outermost: input once, weights
        #     re-streamed per spatial tile
        is_ = (in_sweep,
               total_weight_bytes * (1 if weights_fit else n_spatial))
        in_traffic, w_traffic = min((ws, is_), key=lambda t: t[0] + t[1])
    load_bytes = int(in_traffic) + sum(g.fmap_bytes(s, eb) for s in side_inputs)
    weight_traffic = int(w_traffic)
    save_bytes = g.fmap_bytes(last, eb)

    # --- engine occupancy ------------------------------------------------------
    conv_cycles = sum(_conv_cycles(g, nm, dev) for nm in group)
    pool_cycles = sum(math.ceil(g.misc_elems(nm) / dev.pool_elems_per_cycle)
                      for nm in group
                      if g.nodes[nm].op in ("maxpool", "avgpool", "global_avgpool"))
    misc_cycles = sum(math.ceil(g.misc_elems(nm) / dev.misc_elems_per_cycle)
                      for nm in group
                      if g.nodes[nm].op in ("eltwise_add", "upsample", "reorg"))

    in_tile_b, out_tile_b, resident_b = tile_footprint(t_w)
    return GroupTiling(
        True, t_w=t_w, t_h=t_h, t_oc=t_oc,
        n_spatial_tiles=n_spatial, n_oc_passes=n_oc_passes,
        load_bytes=int(load_bytes), weight_bytes=int(weight_traffic),
        save_bytes=int(save_bytes),
        conv_cycles=int(conv_cycles), pool_cycles=int(pool_cycles),
        misc_cycles=int(misc_cycles),
        in_tile_bytes=int(in_tile_b), out_tile_bytes=int(out_tile_b),
        resident_bytes=int(resident_b))


def _kk(g: XGraph, name: str) -> int:
    node = g.nodes[name]
    if "kernel" in node.attrs:
        kh, kw = node.attrs["kernel"]
        return kh * kw
    return 1


def unfused_tiling(g: XGraph, name: str, dev: DeviceModel) -> GroupTiling:
    return solve(g, [name], dev)


# ------------------------------------------------------- tile-shape search
def _shape_candidates_1d(p: int, extent: int) -> list[int]:
    """Multiples of the array parallelism ``p`` (1, 2, 4, ... times), capped
    by ``extent`` and always including the full extent."""
    out = []
    m = 1
    while p * m < extent:
        out.append(p * m)
        m *= 2
    out.append(extent)
    return sorted(set(out))


def _cells(t: GroupTiling) -> int:
    return max(1, t.n_spatial_tiles) * max(1, t.n_oc_passes)


def enumerate_tilings(g: XGraph, group: list[str], dev: DeviceModel, *,
                      pareto: bool = True, max_candidates: int = 32
                      ) -> list[GroupTiling]:
    """Enumerate feasible tile shapes for ``group`` on ``dev``.

    The paper pins (T_h, T_oc) to the array parallelism and maximizes T_w
    (Eq. 5/6) — one point of a larger feasible region.  This enumerates the
    grid of shapes whose T_h/T_oc are power-of-two multiples of the array
    parallelism (plus the full extents), with T_w the maximal feasible width
    for that (T_h, T_oc) and its halvings, every candidate capped by the
    Eq. 6 capacity check of :func:`solve_shape`.  T_oc candidates are kept to
    divisors of OC so a chosen shape is directly executable by the fused
    kernel's OC-tiled grid (ragged T_h/T_w are handled by the kernel's
    padded-coordinate masking; ragged T_oc would need weight padding).

    Returns the candidates with their full traffic/occupancy breakdowns,
    Pareto-pruned (unless ``pareto=False``) over (DRAM traffic, grid cells,
    on-chip footprint): a shape strictly worse on all three axes can never
    win under any cost model, so the search space handed to the tuner stays
    small without losing the optimum."""
    n, H, W, OC = g.shape(group[-1])
    cands: list[GroupTiling] = []
    seen: set[tuple] = set()
    for t_h in _shape_candidates_1d(dev.h_p, H):
        for t_oc in _shape_candidates_1d(dev.oc_p, OC):
            if OC % t_oc:
                continue            # kernel needs T_oc | OC (see docstring)
            best = solve_shape(g, group, dev, t_h=t_h, t_oc=t_oc)
            if not best.feasible:
                continue
            t_w = best.t_w
            widths = {t_w}
            while t_w > 1:
                t_w = (t_w + 1) // 2
                widths.add(t_w)
                if len(widths) >= 4:
                    break
            for w in sorted(widths, reverse=True):
                key = (w, t_h, t_oc)
                if key in seen:
                    continue
                seen.add(key)
                t = (best if w == best.t_w
                     else solve_shape(g, group, dev, t_w=w, t_h=t_h,
                                      t_oc=t_oc))
                if t.feasible:
                    cands.append(t)
    if pareto:
        cands = pareto_front(cands)
    cands.sort(key=lambda t: (_cells(t), t.dram_bytes,
                              -t.t_w, -t.t_h, -t.t_oc))
    return cands[:max_candidates]


def pareto_front(cands: list[GroupTiling]) -> list[GroupTiling]:
    """Drop candidates dominated on (DRAM bytes, grid cells, footprint)."""
    def axes(t: GroupTiling) -> tuple:
        return (t.dram_bytes, _cells(t),
                t.in_tile_bytes + t.out_tile_bytes + t.resident_bytes)

    out = []
    for t in cands:
        at = axes(t)
        dominated = any(
            all(b <= a for a, b in zip(at, axes(o)))
            and any(b < a for a, b in zip(at, axes(o)))
            for o in cands if o is not t)
        if not dominated:
            out.append(t)
    return out


def solve_horizontal(g: XGraph, siblings: list[str], dev: DeviceModel,
                     t_w: int | None = None, t_h: int | None = None,
                     t_oc: int | None = None) -> GroupTiling:
    """Horizontal fusion (paper §4.1.3 / §5.2): siblings share one input
    feature map, which is loaded once and reused by every member.

    Capacity: the shared input tile, the union of weight slices and every
    member's output tile must co-reside.  Traffic: input once, weights and
    outputs per member.  Engine time: members execute back-to-back on the
    CONV array (they contend for it) but share the LOAD stream.

    ``t_w``/``t_h``/``t_oc`` override the default shape (maximal co-resident
    T_w at T_h = h_p, T_oc = oc_p) — the tile-shape search serializes tuned
    shapes and the memory planner charges their true footprints.
    """
    eb = dev.elem_bytes
    parts = [solve(g, [s], dev) for s in siblings]
    if not all(p.feasible for p in parts):
        return GroupTiling(False, reason="a sibling is individually infeasible")
    src = g.producers(siblings[0])[0]
    in_bytes = g.fmap_bytes(src, eb)
    overridden = t_w is not None or t_h is not None or t_oc is not None
    t_h = dev.h_p if t_h is None else max(1, int(t_h))
    t_oc = dev.oc_p if t_oc is None else max(1, int(t_oc))
    w_need = sum(min(g.param_bytes(s, eb), dev.ic_p * dev.oc_p * _kk(g, s) * eb)
                 for s in siblings)

    def footprint(t_w: int) -> tuple[int, int]:
        """Co-resident (B_in, B_out) bytes for one t_w-wide tile of every
        member simultaneously — the shared input region plus each sibling's
        output slice."""
        in_tile = dev.ic_p * max(
            _rf(g, s, t_w, t_h)[0] * _rf(g, s, t_w, t_h)[1]
            for s in siblings) * eb
        out_tile = sum(t_w * t_h * min(t_oc, g.shape(s)[3]) * eb
                       for s in siblings)
        return in_tile, out_tile

    def fits(t_w: int) -> bool:
        in_tile, out_tile = footprint(t_w)
        return in_tile <= dev.buf_in_bytes and out_tile <= dev.buf_out_bytes

    if w_need > dev.buf_weights_bytes or not fits(1):
        return GroupTiling(False, reason="horizontal working set exceeds buffers")
    if t_w is None:
        # largest tile width at which all members co-reside (may be narrower
        # than each member's standalone t_w — the price of sharing buffers)
        lo, hi = 1, min(p.t_w for p in parts)
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if fits(mid):
                lo = mid
            else:
                hi = mid - 1
        t_w = lo
    else:
        t_w = max(1, min(int(t_w), min(p.t_w for p in parts)))
        if not fits(t_w):
            return GroupTiling(
                False, t_w=t_w, t_h=t_h, t_oc=t_oc,
                reason=f"horizontal tile ({t_w}, {t_h}, {t_oc}) exceeds buffers")
    in_tile, out_tile = footprint(t_w)
    n_spatial = max(
        math.ceil(g.shape(s)[2] / t_w) * math.ceil(g.shape(s)[1] / t_h)
        * max(1, g.shape(s)[0]) for s in siblings)
    if overridden:
        # explicit shape: the stream must carry the TRUE tile/pass counts of
        # what the kernel will run, not the default-shape sibling plans'
        n_oc_passes = max(math.ceil(g.shape(s)[3] / t_oc) for s in siblings)
        n_spatial_tiles = n_spatial
    else:
        n_oc_passes = max(p.n_oc_passes for p in parts)
        n_spatial_tiles = max(n_spatial, max(p.n_spatial_tiles for p in parts))
    # Input loaded once per shared pass (the fusion win).  The shared stream
    # must still be replayed as often as the *least demanding* member replays
    # it standalone: a member whose plan re-streams the input per oc pass
    # needs the bytes resident again on every pass.  Per-member reload factor
    # is an explicit ceil — flooring (the old ``// ... or 1``) undercounted
    # any member whose standalone plan re-streams a partially-resident input.
    reload = min(max(1, math.ceil(p.load_bytes / max(1, in_bytes)))
                 for p in parts)
    load = in_bytes * reload
    return GroupTiling(
        True,
        t_w=t_w, t_h=t_h, t_oc=t_oc,
        n_spatial_tiles=n_spatial_tiles,
        n_oc_passes=n_oc_passes,
        load_bytes=int(load),
        weight_bytes=sum(p.weight_bytes for p in parts),
        save_bytes=sum(p.save_bytes for p in parts),
        conv_cycles=sum(p.conv_cycles for p in parts),
        pool_cycles=sum(p.pool_cycles for p in parts),
        misc_cycles=sum(p.misc_cycles for p in parts),
        in_tile_bytes=int(in_tile),
        out_tile_bytes=int(out_tile),
        resident_bytes=0)
