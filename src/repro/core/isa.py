"""Custom ISA (paper §3.1): LOAD / SAVE / CONV / POOL / MISC coarse
instructions with dependency bits.

The assembler emits one instruction stream per execution group; instructions
are variable-grain (one CONV covers a whole tile's worth of MACs — the paper's
"coarse-grained nature of the ISA").  Dependencies are explicit instruction
ids, the hardware analogue of the dependency bits that let the Dispatcher
issue LOAD(t+1) while CONV(t) runs (double buffering).

With a :class:`repro.memory.MemoryPlan` the stream becomes *addressed*: every
LOAD/SAVE carries its DDR region and BRAM bank, and three extra families of
dependency bits appear —

* in-bank reuse:  LOAD(t) waits for the consumer of tile t-n_banks_in, since
  it overwrites that tile's ping/pong input bank;
* out-bank reuse: the first compute of tile t waits for SAVE(t-n_banks_out);
* DDR write-after-read: a group whose output buffer recycles the address
  range of an expired buffer waits for that buffer's last LOAD to retire.

Without a plan the streams are timing-only and byte-identical in schedule to
the pre-memory-planner assembler (addresses stay -1), so cost evaluation
inside the path search is unchanged.
"""
from __future__ import annotations

import dataclasses
import math

from repro.hw import DeviceModel
from repro.core.tiling import GroupTiling
from repro.core.xgraph import XGraph

# DDR_RD / DDR_WR: the AXI read and write channels are independent (the
# paper's Fig. 8/9 timelines show LOAD and SAVE overlapping), so LOAD and
# SAVE occupy separate bandwidth lanes; CONV / POOL / MISC mirror the
# accelerator's execution modules.
ENGINES = ("DDR_RD", "DDR_WR", "CONV", "POOL", "MISC")
COMPUTE_ENGINES = ("CONV", "POOL", "MISC")


@dataclasses.dataclass
class Instr:
    iid: int
    engine: str          # one of ENGINES
    opcode: str          # LOAD / SAVE / CONV / POOL / MISC / END
    cycles: int
    deps: tuple[int, ...] = ()
    tag: str = ""
    # memory-plan fields (memory/planner.py); -1 / 0 => unaddressed stream
    ddr_addr: int = -1   # DDR region this LOAD reads / SAVE writes
    ddr_len: int = 0
    bank: int = -1       # BRAM ping/pong bank (in-bank for LOAD, out for SAVE)
    group_id: int = -1   # execution-group index within the strategy
    tile: int = -1       # spatial tile index within the group


@dataclasses.dataclass(frozen=True)
class GroupMem:
    """Per-group slice of a MemoryPlan, as the emitter consumes it."""
    in_addr: int = -1
    in_len: int = 0
    out_addr: int = -1
    out_len: int = 0
    n_banks_in: int = 1
    n_banks_out: int = 1
    war_deps: tuple[int, ...] = ()   # last LOADs of recycled DDR buffers


def emit_group(g: XGraph, group: list[str], tiling: GroupTiling,
               dev: DeviceModel, base_id: int = 0,
               entry_deps: tuple[int, ...] = (),
               group_id: int = -1, mem: GroupMem | None = None) -> list[Instr]:
    """Assemble the tiled instruction stream for one fused group.

    One LOAD -> CONV -> POOL/MISC -> SAVE chain per spatial tile; oc passes
    are folded into per-tile durations (keeps streams compact for deep nets
    without changing the schedule the time wheel sees).  ``mem`` threads DDR
    addresses, bank ids and the bank/WAR dependency bits described in the
    module docstring.
    """
    instrs: list[Instr] = []
    nid = base_id
    n_t = max(1, tiling.n_spatial_tiles)
    bw_cyc = dev.dram_bw_bytes_per_s / dev.freq_hz  # DDR bytes per cycle

    def cyc_for_bytes(b: float) -> int:
        return max(1, math.ceil(b / bw_cyc))

    load_c = cyc_for_bytes((tiling.load_bytes + tiling.weight_bytes) / n_t)
    save_c = cyc_for_bytes(tiling.save_bytes / n_t)
    conv_c = max(0, math.ceil(tiling.conv_cycles / n_t))
    pool_c = max(0, math.ceil(tiling.pool_cycles / n_t))
    misc_c = max(0, math.ceil(tiling.misc_cycles / n_t))

    n_bi = mem.n_banks_in if mem else 1
    n_bo = mem.n_banks_out if mem else 1
    in_consumer: dict[int, int] = {}   # tile -> iid of last reader of its in-bank
    save_iid: dict[int, int] = {}      # tile -> iid of its SAVE

    for t in range(n_t):
        load_deps = list(entry_deps if t == 0 else ())
        if mem and t >= n_bi:
            # ping/pong: this LOAD overwrites the bank tile t-n_bi was read from
            load_deps.append(in_consumer[t - n_bi])
        li = Instr(nid, "DDR_RD", "LOAD", load_c, tuple(load_deps),
                   tag=f"{group[0]}@t{t}", group_id=group_id, tile=t)
        if mem:
            li.ddr_addr, li.ddr_len = mem.in_addr, mem.in_len
            li.bank = t % n_bi
        nid += 1
        last = li.iid
        instrs.append(li)
        first_compute = True
        for eng, cyc in (("CONV", conv_c), ("POOL", pool_c), ("MISC", misc_c)):
            if cyc:
                deps = [last]
                if first_compute and mem and t >= n_bo:
                    # out-bank reuse: don't overwrite tile t-n_bo before it is
                    # drained to DDR
                    deps.append(save_iid[t - n_bo])
                ins = Instr(nid, eng, eng, cyc, tuple(deps),
                            tag=f"{group[0]}@t{t}", group_id=group_id, tile=t)
                nid += 1
                last = ins.iid
                first_compute = False
                instrs.append(ins)
        save_deps = [last]
        if mem and t == 0 and mem.war_deps:
            save_deps.extend(mem.war_deps)   # DDR write-after-read
        if mem and first_compute and t >= n_bo:
            save_deps.append(save_iid[t - n_bo])  # compute-less pass-through
        si = Instr(nid, "DDR_WR", "SAVE", save_c, tuple(save_deps),
                   tag=f"{group[-1]}@t{t}", group_id=group_id, tile=t)
        if mem:
            si.ddr_addr, si.ddr_len = mem.out_addr, mem.out_len
            si.bank = t % n_bo
        nid += 1
        instrs.append(si)
        in_consumer[t] = last if not first_compute else si.iid
        save_iid[t] = si.iid
    return instrs


def emit_strategy(g: XGraph, groups: list[list[str]],
                  tilings: list[GroupTiling], dev: DeviceModel,
                  plan=None) -> list[Instr]:
    """Assemble the whole execution strategy with *dataflow* dependency bits:
    a group's first LOAD waits on the SAVEs of exactly the groups producing
    its external inputs.  Independent groups (e.g. Inception branches) then
    overlap across the CONV/POOL/MISC engines — the latency hiding of
    §4.1.3 ("different operations can be concurrently executed by different
    computation modules").

    ``plan`` (a :class:`repro.memory.MemoryPlan` over the same group order)
    threads DDR addresses, bank assignments and write-after-read bits into
    the stream; the result is checkable by ``simulator.memory_hazards``."""
    out: list[Instr] = []
    nid = 0
    save_of: dict[str, int] = {}       # producer node -> SAVE instr id
    last_load_of: dict[str, int] = {}  # DDR buffer name -> last LOAD iid
    for gi, (group, tiling) in enumerate(zip(groups, tilings)):
        gset = set(group)
        ext = [i for nm in group for i in g.nodes[nm].inputs if i not in gset]
        deps = tuple(sorted({save_of[i] for i in ext if i in save_of}))
        mem = None
        if plan is not None:
            # LOADs carry one DDR region, so multi-input groups (eltwise
            # residuals) advertise only their primary input to the hazard
            # oracle; reads of the remaining inputs are still protected,
            # because the WAR bookkeeping below records the group's last
            # LOAD against *every* external input buffer.
            primary = next((i for i in ext if i in plan.buf_of_node), None)
            in_addr, in_len = (plan.node_region(primary) if primary is not None
                               else (-1, 0))
            out_addr, out_len = plan.group_out_region(gi)
            bp = plan.banks[gi]
            war = tuple(sorted(last_load_of[b] for b in plan.war[gi]
                               if b in last_load_of))
            mem = GroupMem(in_addr=in_addr, in_len=in_len,
                           out_addr=out_addr, out_len=out_len,
                           n_banks_in=bp.n_banks_in, n_banks_out=bp.n_banks_out,
                           war_deps=war)
        instrs = emit_group(g, group, tiling, dev, base_id=nid,
                            entry_deps=deps, group_id=gi, mem=mem)
        nid += len(instrs)
        out.extend(instrs)
        if plan is not None:
            last_load = max((i.iid for i in instrs if i.opcode == "LOAD"),
                            default=None)
            if last_load is not None:
                for i in ext:
                    buf = plan.buf_of_node.get(i)
                    if buf is not None:
                        last_load_of[buf] = last_load
        saves = [i for i in instrs if i.opcode == "SAVE"]
        if saves:
            for nm in g.exposed_outputs(group):
                save_of[nm] = saves[-1].iid
    return out
