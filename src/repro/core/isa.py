"""Custom ISA (paper §3.1): LOAD / SAVE / CONV / POOL / MISC coarse
instructions with dependency bits.

The assembler emits one instruction stream per execution group; instructions
are variable-grain (one CONV covers a whole tile's worth of MACs — the paper's
"coarse-grained nature of the ISA").  Dependencies are explicit instruction
ids, the hardware analogue of the dependency bits that let the Dispatcher
issue LOAD(t+1) while CONV(t) runs (double buffering).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.hw import DeviceModel
from repro.core.tiling import GroupTiling
from repro.core.xgraph import XGraph

# DDR_RD / DDR_WR: the AXI read and write channels are independent (the
# paper's Fig. 8/9 timelines show LOAD and SAVE overlapping), so LOAD and
# SAVE occupy separate bandwidth lanes; CONV / POOL / MISC mirror the
# accelerator's execution modules.
ENGINES = ("DDR_RD", "DDR_WR", "CONV", "POOL", "MISC")


@dataclasses.dataclass
class Instr:
    iid: int
    engine: str          # one of ENGINES
    opcode: str          # LOAD / SAVE / CONV / POOL / MISC / END
    cycles: int
    deps: tuple[int, ...] = ()
    tag: str = ""


def emit_group(g: XGraph, group: list[str], tiling: GroupTiling,
               dev: DeviceModel, base_id: int = 0,
               entry_deps: tuple[int, ...] = ()) -> list[Instr]:
    """Assemble the tiled instruction stream for one fused group.

    One LOAD -> CONV -> POOL/MISC -> SAVE chain per spatial tile; oc passes
    are folded into per-tile durations (keeps streams compact for deep nets
    without changing the schedule the time wheel sees).
    """
    instrs: list[Instr] = []
    nid = base_id
    n_t = max(1, tiling.n_spatial_tiles)
    bw_cyc = dev.dram_bw_bytes_per_s / dev.freq_hz  # DDR bytes per cycle

    def cyc_for_bytes(b: float) -> int:
        return max(1, math.ceil(b / bw_cyc))

    load_c = cyc_for_bytes((tiling.load_bytes + tiling.weight_bytes) / n_t)
    save_c = cyc_for_bytes(tiling.save_bytes / n_t)
    conv_c = max(0, math.ceil(tiling.conv_cycles / n_t))
    pool_c = max(0, math.ceil(tiling.pool_cycles / n_t))
    misc_c = max(0, math.ceil(tiling.misc_cycles / n_t))

    for t in range(n_t):
        li = Instr(nid, "DDR_RD", "LOAD", load_c,
                   entry_deps if t == 0 else (), tag=f"{group[0]}@t{t}")
        nid += 1
        last = li.iid
        instrs.append(li)
        for eng, cyc in (("CONV", conv_c), ("POOL", pool_c), ("MISC", misc_c)):
            if cyc:
                ins = Instr(nid, eng, eng, cyc, (last,), tag=f"{group[0]}@t{t}")
                nid += 1
                last = ins.iid
                instrs.append(ins)
        si = Instr(nid, "DDR_WR", "SAVE", save_c, (last,), tag=f"{group[-1]}@t{t}")
        nid += 1
        instrs.append(si)
    return instrs


def emit_strategy(g: XGraph, groups: list[list[str]],
                  tilings: list[GroupTiling], dev: DeviceModel) -> list[Instr]:
    """Assemble the whole execution strategy with *dataflow* dependency bits:
    a group's first LOAD waits on the SAVEs of exactly the groups producing
    its external inputs.  Independent groups (e.g. Inception branches) then
    overlap across the CONV/POOL/MISC engines — the latency hiding of
    §4.1.3 ("different operations can be concurrently executed by different
    computation modules")."""
    out: list[Instr] = []
    nid = 0
    save_of: dict[str, int] = {}  # producer node -> SAVE instr id
    for group, tiling in zip(groups, tilings):
        gset = set(group)
        ext = [i for nm in group for i in g.nodes[nm].inputs if i not in gset]
        deps = tuple(sorted({save_of[i] for i in ext if i in save_of}))
        instrs = emit_group(g, group, tiling, dev, base_id=nid, entry_deps=deps)
        nid += len(instrs)
        out.extend(instrs)
        saves = [i for i in instrs if i.opcode == "SAVE"]
        if saves:
            # chain groups expose only their tail; horizontal groups expose
            # every member (each sibling's output lands in DDR)
            tails = [group[-1]] if _is_chain(g, group) else list(group)
            for nm in tails:
                save_of[nm] = saves[-1].iid
    return out


def _is_chain(g: XGraph, group: list[str]) -> bool:
    return all(group[i] in g.nodes[group[i + 1]].inputs
               for i in range(len(group) - 1)) or len(group) == 1
