"""Execution of a compiled strategy as JAX callables (runtime support, §3.2).

Three backends:

* ``float``      — float32 reference semantics (calibration + accuracy oracle);
* ``int8_ref``   — pure-jnp fixed-point semantics from ``int8_ops`` (the
  validation oracle; bit-exact by definition);
* ``int8_pallas``— dispatches the compile-time lowered ``GroupProgram``
  (``core.lower``): every ``FusedLaunch`` runs as ONE ``kernels.conv_fused``
  chain launch (LOAD->CONV->MISC->SAVE on-chip, the paper's fusion), every
  ``RefFallback`` runs its nodes through the ref ops.  The executor performs
  ZERO runtime graph pattern matching — lowering decided everything once.
  The contract — enforced by validate.py and the kernel tests — is
  bit-exactness with ``int8_ref``.

Mixed compilation (paper §2.3.5): nodes partitioned to the host execute as
plain float ops on dequantized inputs (softmax & friends) and appear in the
program as ``RefFallback("host_op")`` entries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import int8_ops
from repro.core.quantize import QuantizedModel
from repro.core.xgraph import XGraph, _padding


# ------------------------------------------------------------------ float ref
def _float_node(g: XGraph, node, env, params):
    a = node.attrs
    op = node.op
    xs = [env[i] for i in node.inputs]
    if op in ("conv", "dilated_conv", "depthwise_conv"):
        kh, kw = a["kernel"]
        dil = a.get("dilation", (1, 1))
        ph, pw = _padding(a.get("pad", "same"), dil[0] * (kh - 1) + 1,
                          dil[1] * (kw - 1) + 1)
        w = params[node.name]["w"]
        b = params[node.name].get("b", np.zeros(w.shape[-1], np.float32))
        groups = xs[0].shape[-1] if op == "depthwise_conv" else 1
        y = jax.lax.conv_general_dilated(
            xs[0], jnp.asarray(w), a.get("stride", (1, 1)),
            [(ph, ph), (pw, pw)], rhs_dilation=dil,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups) + jnp.asarray(b)
    elif op == "fc":
        w = params[node.name]["w"]
        b = params[node.name].get("b", np.zeros(w.shape[-1], np.float32))
        n = xs[0].shape[0]
        y = (xs[0].reshape(n, -1) @ jnp.asarray(w) + jnp.asarray(b)).reshape(
            n, 1, 1, -1)
    elif op == "maxpool":
        kh, kw = a["kernel"]
        sh, sw = a.get("stride", a["kernel"])
        ph, pw = _padding(a.get("pad", "valid"), kh, kw)
        oh = g.shape(node.name)[1]
        ow = g.shape(node.name)[2]
        h, w_ = xs[0].shape[1:3]
        eh = max(0, (oh - 1) * sh + kh - h - 2 * ph)
        ew = max(0, (ow - 1) * sw + kw - w_ - 2 * pw)
        y = jax.lax.reduce_window(
            xs[0], -jnp.inf, jax.lax.max, (1, kh, kw, 1), (1, sh, sw, 1),
            ((0, 0), (ph, ph + eh), (pw, pw + ew), (0, 0)))
    elif op == "avgpool":
        kh, kw = a["kernel"]
        sh, sw = a.get("stride", a["kernel"])
        ph, pw = _padding(a.get("pad", "valid"), kh, kw)
        oh, ow = g.shape(node.name)[1:3]
        h, w_ = xs[0].shape[1:3]
        eh = max(0, (oh - 1) * sh + kh - h - 2 * ph)
        ew = max(0, (ow - 1) * sw + kw - w_ - 2 * pw)
        y = jax.lax.reduce_window(
            xs[0], 0.0, jax.lax.add, (1, kh, kw, 1), (1, sh, sw, 1),
            ((0, 0), (ph, ph + eh), (pw, pw + ew), (0, 0))) / (kh * kw)
    elif op == "global_avgpool":
        y = jnp.mean(xs[0], axis=(1, 2), keepdims=True)
    elif op == "eltwise_add":
        y = sum(xs)
    elif op == "concat":
        y = jnp.concatenate(xs, axis=-1)
    elif op == "upsample":
        y = int8_ops.upsample(xs[0], a.get("factor", 2))
    elif op == "reorg":
        y = int8_ops.reorg(xs[0], a.get("stride", 2))
    elif op == "softmax":
        y = jax.nn.softmax(xs[0], axis=-1)
    elif op == "deconv":
        w = params[node.name]["w"]
        b = params[node.name].get("b", np.zeros(w.shape[-1], np.float32))
        y = jax.lax.conv_transpose(
            xs[0], jnp.asarray(w), a.get("stride", (2, 2)), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + jnp.asarray(b)
    else:
        raise ValueError(f"float executor: unknown op {op}")
    if a.get("relu"):
        y = jax.nn.relu(y)
    return y


def run_float(g: XGraph, params: dict, x: np.ndarray) -> dict:
    """All node activations in float32 (used by calibration)."""

    @jax.jit
    def go(x):
        env = {}
        for node in g:
            if node.op == "input":
                env[node.name] = x
            else:
                env[node.name] = _float_node(g, node, env, params)
        return env

    return {k: np.asarray(v) for k, v in go(jnp.asarray(x, jnp.float32)).items()}


def build_float_fn(g: XGraph, params: dict):
    outputs = [n.name for n in g if not g.consumers(n.name)]

    @jax.jit
    def fn(x):
        env = {}
        for node in g:
            env[node.name] = (x if node.op == "input"
                              else _float_node(g, node, env, params))
        return {o: env[o] for o in outputs}

    return fn


# -------------------------------------------------------------------- int8
def _int8_node(g: XGraph, node, env, qm: QuantizedModel):
    a, op = node.attrs, node.op
    xs = [env[i] for i in node.inputs]
    relu = bool(a.get("relu"))
    if op in ("conv", "dilated_conv"):
        kh, kw = a["kernel"]
        dil = a.get("dilation", (1, 1))
        ph, pw = _padding(a.get("pad", "same"), dil[0] * (kh - 1) + 1,
                          dil[1] * (kw - 1) + 1)
        return int8_ops.conv2d(xs[0], jnp.asarray(qm.weights[node.name]),
                               jnp.asarray(qm.biases[node.name]),
                               stride=a.get("stride", (1, 1)), pad=(ph, pw),
                               dilation=dil, shift=qm.shift_for(g, node.name),
                               relu=relu)
    if op == "depthwise_conv":
        kh, kw = a["kernel"]
        ph, pw = _padding(a.get("pad", "same"), kh, kw)
        return int8_ops.depthwise_conv2d(
            xs[0], jnp.asarray(qm.weights[node.name]),
            jnp.asarray(qm.biases[node.name]), stride=a.get("stride", (1, 1)),
            pad=(ph, pw), shift=qm.shift_for(g, node.name), relu=relu)
    if op == "fc":
        return int8_ops.fc(xs[0], jnp.asarray(qm.weights[node.name]),
                           jnp.asarray(qm.biases[node.name]),
                           shift=qm.shift_for(g, node.name), relu=relu)
    if op == "maxpool":
        kh, kw = a["kernel"]
        ph, pw = _padding(a.get("pad", "valid"), kh, kw)
        return int8_ops.maxpool(xs[0], kernel=a["kernel"],
                                stride=a.get("stride", a["kernel"]),
                                pad=(ph, pw), ceil_mode=a.get("ceil_mode", True))
    if op == "avgpool":
        kh, kw = a["kernel"]
        ph, pw = _padding(a.get("pad", "valid"), kh, kw)
        return int8_ops.avgpool(xs[0], kernel=a["kernel"],
                                stride=a.get("stride", a["kernel"]), pad=(ph, pw),
                                ceil_mode=a.get("ceil_mode", True))
    if op == "global_avgpool":
        return int8_ops.global_avgpool(xs[0])
    if op == "eltwise_add":
        fs = [qm.f_a[i] for i in node.inputs]
        return int8_ops.eltwise_add(xs, fs, qm.f_a[node.name], relu=relu)
    if op == "concat":
        fs = [qm.f_a[i] for i in node.inputs]
        return int8_ops.concat(xs, fs, qm.f_a[node.name])
    if op == "upsample":
        return int8_ops.upsample(xs[0], a.get("factor", 2))
    if op == "reorg":
        return int8_ops.reorg(xs[0], a.get("stride", 2))
    if op == "softmax":  # host op: dequantize, float softmax
        f_in = qm.f_a[node.inputs[0]]
        return jax.nn.softmax(xs[0].astype(jnp.float32) * 2.0 ** -f_in, axis=-1)
    raise ValueError(f"int8 executor: unknown op {op}")


class Int8Executor:
    """Executes a fusion strategy on int8 data.

    backend="ref"    : per-node jnp fixed-point ops (oracle).
    backend="pallas" : dispatches the lowered ``GroupProgram`` — one
                       ``kernels.conv_fused`` chain launch per FusedLaunch
                       (interpret mode on CPU), the ref path per RefFallback.
                       Bit-exact with "ref" by contract.
    """

    def __init__(self, g: XGraph, qm: QuantizedModel, strategy=None,
                 backend: str = "ref", interpret: bool = True):
        """``strategy`` is anything with ``.groups`` / ``.horizontal`` /
        ``.meta`` — a ``pathsearch.Strategy`` or a loaded
        ``asm.CompiledArtifact`` (the plan-cache serving path).  An artifact
        carrying a quantized ``.program`` section is dispatched as-is (no
        re-lowering); otherwise the strategy is lowered here, once, at
        construction time."""
        self.g, self.qm, self.backend = g, qm, backend
        self.groups = None
        self.program = None
        if backend == "pallas":
            prog = getattr(strategy, "program", None)
            if prog is None or not prog.meta.get("quantized"):
                from repro.core import lower
                prog = lower.lower_strategy(g, strategy, qm)
            self.program = prog
        elif strategy is not None:
            # ref path: horizontal (shared-input) groups execute per-member —
            # the sharing is a LOAD-time optimization, numerics are identical
            from repro.core.pathsearch import order_groups
            groups = [list(grp) for grp in strategy.groups]
            groups += [[m] for hg in strategy.horizontal for m in hg]
            groups += [[h] for h in strategy.meta.get("host_nodes", [])]
            self.groups = order_groups(g, groups)
        else:
            self.groups = [[n] for n in g.compute_nodes()]
        self.interpret = interpret
        self._fn = None
        self._fb_reasons = None
        self._in_shape = next((g.shape(n.name) for n in g if n.op == "input"),
                              None)

    def _validate_input(self, x) -> None:
        """Fail fast with a clear message instead of a deep Pallas/XLA shape
        error.  The graph's batch dimension is a planning default, not a
        constraint: any N >= 1 is accepted (dynamic batching stacks requests),
        while dtype, rank and the per-image extents must match the graph."""
        shape = getattr(x, "shape", None)
        dtype = getattr(x, "dtype", None)
        if dtype is None or jnp.dtype(dtype) != jnp.int8:
            raise ValueError(
                f"Int8Executor input must be int8 (quantize first, e.g. "
                f"quantize.quantize_to(x, qm.f_a[input])); got dtype {dtype}")
        if self._in_shape is None:
            return
        if shape is None or len(shape) != 4:
            raise ValueError(
                f"Int8Executor input must be rank-4 NHWC; got shape {shape}")
        if tuple(shape[1:]) != tuple(self._in_shape[1:]):
            raise ValueError(
                f"Int8Executor input spatial/channel extents {tuple(shape[1:])} "
                f"do not match the compiled graph's {tuple(self._in_shape[1:])} "
                f"(any batch size is accepted; H/W/C are fixed at compile time)")
        if shape[0] < 1:
            raise ValueError("Int8Executor input batch must be >= 1")

    def _build(self):
        g, qm = self.g, self.qm
        outputs = [n.name for n in g if not g.consumers(n.name)]

        if self.backend == "pallas":
            from repro.core.lower import FusedLaunch
            from repro.kernels.conv_fused import ops as fused_ops
            items = list(self.program.items)

            def fn(x):
                env = {}
                for node in g:
                    if node.op == "input":
                        env[node.name] = x
                for item in items:
                    if isinstance(item, FusedLaunch):
                        env.update(fused_ops.run_launch(
                            item, env, qm, interpret=self.interpret))
                    else:
                        for name in item.nodes:
                            env[name] = _int8_node(g, g.nodes[name], env, qm)
                return {o: env[o] for o in outputs}
        else:
            def fn(x):
                env = {}
                for node in g:
                    if node.op == "input":
                        env[node.name] = x
                for group in self.groups:
                    for name in group:
                        env[name] = _int8_node(g, g.nodes[name], env, qm)
                return {o: env[o] for o in outputs}

        return jax.jit(fn)

    def __call__(self, x: np.ndarray) -> dict:
        from repro.obs.metrics import REGISTRY

        self._validate_input(x)
        if self._fn is None:
            self._fn = self._build()
        out = self._fn(jnp.asarray(x))
        REGISTRY.counter("executor.calls").inc()
        if self.program is not None:
            # the jitted program dispatches every item per call; meta carries
            # the per-call split the lowering decided on
            REGISTRY.counter("executor.fused_launches").inc(
                self.program.meta.get("n_launches", 0))
            REGISTRY.counter("executor.fallback_launches").inc(
                self.program.meta.get("n_fallbacks", 0))
            # per-reason fallback counters: the lowering records a machine-
            # readable reason on every RefFallback (lower.FALLBACK_REASONS);
            # exporting it labelled makes a lowering-gap regression (a YOLO op
            # sliding back to the reference path) visible on /metrics instead
            # of only moving an aggregate
            for reason, n in self._fallback_reasons().items():
                REGISTRY.counter("executor.fallback",
                                 {"reason": reason}).inc(n)
        return {k: np.asarray(v) for k, v in out.items()}

    def _fallback_reasons(self) -> dict:
        """reason -> launches-per-call, computed once from the program."""
        if self._fb_reasons is None:
            from collections import Counter as _Counter
            self._fb_reasons = dict(_Counter(
                fb.reason for fb in self.program.fallbacks()))
        return self._fb_reasons


def build_group_callable(g: XGraph, group: list, params_or_qm):
    """One group as a standalone jitted callable with random inputs — the
    'on-board' evaluator's unit of measurement."""
    in_names = list(dict.fromkeys(
        i for nm in group for i in g.nodes[nm].inputs
        if i not in group))
    rng = np.random.default_rng(0)

    if isinstance(params_or_qm, QuantizedModel):
        qm = params_or_qm
        # full-range int8 activations: measuring on standard-normal data cast
        # to int truncates to {-2..2}, which makes on-board timings run on
        # near-all-zero tensors (and constant-folds away saturation work)
        ins = [jnp.asarray(rng.integers(-128, 128, g.shape(i)), jnp.int8)
               for i in in_names]

        @jax.jit
        def fn(*xs):
            env = dict(zip(in_names, xs))
            for nm in group:
                env[nm] = _int8_node(g, g.nodes[nm], env, qm)
            return env[group[-1]]
    else:
        params = params_or_qm
        ins = [jnp.asarray(rng.standard_normal(g.shape(i)), jnp.float32)
               for i in in_names]

        @jax.jit
        def fn(*xs):
            env = dict(zip(in_names, xs))
            for nm in group:
                env[nm] = _float_node(g, g.nodes[nm], env, params)
            return env[group[-1]]

    return fn, ins
