"""Front-end lowering: framework graphs -> coarse-grained XGraph.

Mirrors paper §3.2 / Fig. 4: different frameworks emit operations at different
granularities (Caffe: coarse conv+relu layers; TensorFlow: pad / conv2d /
biasadd / relu as separate fine-grained nodes).  The front-end normalizes all
of them into XGraph's coarse vocabulary via three passes:

  1. intrinsic fusion  — pad->conv folding, conv+BN+Scale / conv+bias_add
     parameter pre-computation (the fold itself happens at weight-prep time in
     ``quantize.prepare_params``; the graph pass records what was folded);
  2. point-wise fusion — relu-family after conv/fc/eltwise becomes an
     attribute bit (the CONV instruction's nonlinear bit, §4.1.2);
  3. layout pruning    — flatten is removed outright (NHWC flatten is a memory
     no-op for our layout, exactly the paper's Fig. 2c argument) and concat is
     marked ``folded`` so producers SAVE with strides instead of copying.

Each pass is also expressible through the generic template machinery; we keep
these three as direct passes because they are unconditional rewrites, whereas
kernel fusion (templates.py) is a *choice* costed by the path search.
"""
from __future__ import annotations

from repro.core.xgraph import XGraph, POINTWISE


def lower(g: XGraph) -> XGraph:
    from repro.obs.trace import TRACER

    with TRACER.span("frontend", cat="compile", track="compile",
                     graph=g.name):
        fold_pad(g)
        fold_intrinsics(g)
        fuse_pointwise(g)
        prune_flatten(g)
        fold_concat(g)
        g.validate()
    return g


def fold_pad(g: XGraph) -> None:
    """pad -> conv  becomes conv(pad=explicit)."""
    for name in list(g.nodes):
        node = g.nodes.get(name)
        if node is None or node.op != "pad":
            continue
        pads = tuple(node.attrs["pad"])
        ok = g.consumers(name) and all(
            g.nodes[c].op in ("conv", "dilated_conv", "depthwise_conv")
            for c in g.consumers(name))
        if not ok:
            continue
        for c in g.consumers(name):
            g.nodes[c].attrs["pad"] = pads
        g.remove(name)


def fold_intrinsics(g: XGraph) -> None:
    """bn / scale / bias_add after conv-like are folded into the conv.

    The numeric fold (w' = w*gamma/sqrt(var+eps), b' = ...) is performed by
    ``quantize.prepare_params``; here we record the chain on the conv node so
    weight preparation knows what to fold, and delete the graph nodes.
    """
    changed = True
    while changed:
        changed = False
        for name in list(g.nodes):
            node = g.nodes.get(name)
            if node is None or node.op not in ("bn", "scale", "bias_add"):
                continue
            (src,) = node.inputs
            prod = g.nodes[src]
            if prod.op in ("conv", "dilated_conv", "depthwise_conv", "deconv", "fc"):
                prod.attrs.setdefault("folded_intrinsics", []).append(
                    (node.op, dict(node.attrs)))
                g.remove(name)
                changed = True


def fuse_pointwise(g: XGraph) -> None:
    """relu-family after conv-like / eltwise becomes the nonlinear bit."""
    for name in list(g.nodes):
        node = g.nodes.get(name)
        if node is None or node.op not in POINTWISE:
            continue
        (src,) = node.inputs
        prod = g.nodes[src]
        if prod.op in ("conv", "dilated_conv", "depthwise_conv", "deconv",
                       "fc", "eltwise_add"):
            prod.attrs["relu"] = node.op
            g.remove(name)


def prune_flatten(g: XGraph) -> None:
    for name in list(g.nodes):
        node = g.nodes.get(name)
        if node is None or node.op != "flatten":
            continue
        # NHWC flatten is bit-identical in memory: prune (Fig. 2c).
        g.remove(name)


def fold_concat(g: XGraph) -> None:
    """Channel concat is folded into the producers' strided SAVE."""
    for name in list(g.nodes):
        node = g.nodes.get(name)
        if node is None or node.op != "concat":
            continue
        node.attrs["folded"] = True  # zero-cost in cost model & simulator


# ------------------------------------------------------------------ builders
def tf_style_conv(g: XGraph, name: str, bottom: str, *, oc: int, kernel,
                  stride=(1, 1), pad="same", relu: bool = True) -> str:
    """Emit the fine-grained TensorFlow-style op chain (pad, conv2d, biasadd,
    relu) that ``lower`` collapses into one XGraph conv — used by tests to
    demonstrate front-end decoupling (paper Fig. 4, path ②)."""
    kh, kw = kernel if isinstance(kernel, tuple) else (kernel, kernel)
    last = bottom
    if pad == "same" and (kh > 1 or kw > 1):
        g.add("pad", f"{name}/pad", (last,), pad=((kh - 1) // 2, (kw - 1) // 2))
        last = f"{name}/pad"
        pad = "valid"
    g.add("conv", name, (last,), oc=oc, kernel=(kh, kw), stride=stride, pad=pad)
    g.add("bias_add", f"{name}/bias", (name,))
    last = f"{name}/bias"
    if relu:
        g.add("relu", f"{name}/relu", (last,))
        last = f"{name}/relu"
    return last
