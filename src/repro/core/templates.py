"""Fusion templates (paper §4.1, Fig. 3 right column).

A template is a tiny typed pattern graph.  Kernel-fusion templates describe
*choices* that the path search weighs by cost; the ``injective`` vocabulary is
the paper's: convolution, pooling, nonlinear, deconvolution, depth-wise
convolution, upsample, reorganization.

Templates here are pairwise; longer fused chains are built by the path search
chaining compatible pairs (the paper: "more than two operations can be fused;
the number of operations to be fused is not the limitation"), subject to the
capacity condition checked by the tiling solver (fusion condition 1).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.xgraph import XGraph, CONV_LIKE, POOL_LIKE

CONVS = frozenset(CONV_LIKE - {"fc"})
POOLS = frozenset(POOL_LIKE)
ELTWISE = frozenset({"eltwise_add"})
MISC = frozenset({"upsample", "reorg"})
INJECTIVE = CONVS | POOLS | ELTWISE | MISC


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: used as dict key
class Template:
    name: str
    vertices: dict  # var -> frozenset of allowed op types
    edges: tuple    # ((producer_var, consumer_var), ...)
    # Extra semantic check on a complete embedding {var: node_name}.
    predicate: Optional[Callable[[XGraph, dict], bool]] = None

    def var_types(self, var: str) -> frozenset:
        return self.vertices[var]


def _no_stride_gap(g: XGraph, m: dict) -> bool:
    # A fused consumer must be able to stream the producer's output tile;
    # any injective pair qualifies on our engines (LOAD/CONV/POOL/MISC all
    # read NHWC row-major tiles), so no extra constraint today.
    return True


def _eltwise_two_inputs(g: XGraph, m: dict) -> bool:
    return len(g.nodes[m["b"]].inputs) == 2


def _distinct_siblings(g: XGraph, m: dict) -> bool:
    return m["a"] != m["b"]


# --- kernel fusion templates -------------------------------------------------
CONV_POOL = Template(
    "conv_pool",
    vertices={"a": CONVS, "b": POOLS},
    edges=(("a", "b"),),
    predicate=_no_stride_gap,
)

CONV_ELTWISE = Template(
    "conv_eltwise",
    vertices={"a": CONVS, "b": ELTWISE},
    edges=(("a", "b"),),
    predicate=_eltwise_two_inputs,
)

CONV_CONV = Template(  # longitudinal conv+conv (paper §4: "Conv + Conv")
    "conv_conv",
    vertices={"a": CONVS, "b": CONVS},
    edges=(("a", "b"),),
)

POOL_CONV = Template(
    "pool_conv",
    vertices={"a": POOLS, "b": CONVS},
    edges=(("a", "b"),),
)

ELTWISE_CONV = Template(
    "eltwise_conv",
    vertices={"a": ELTWISE, "b": CONVS},
    edges=(("a", "b"),),
)

MISC_ADJ = Template(  # upsample/reorg chained with conv (YOLO-style necks)
    "misc_adjacent",
    vertices={"a": MISC | CONVS, "b": MISC | CONVS},
    edges=(("a", "b"),),
)

HORIZONTAL = Template(  # siblings sharing one input (Inception, paper §5.2)
    "horizontal_share",
    vertices={"x": INJECTIVE | frozenset({"input"}), "a": CONVS, "b": CONVS},
    edges=(("x", "a"), ("x", "b")),
    predicate=_distinct_siblings,
)

KERNEL_TEMPLATES: tuple[Template, ...] = (
    CONV_POOL, CONV_ELTWISE, CONV_CONV, POOL_CONV, ELTWISE_CONV, MISC_ADJ,
)

ALL_TEMPLATES: tuple[Template, ...] = KERNEL_TEMPLATES + (HORIZONTAL,)


def pairwise_fusable(template_matches: dict) -> set:
    """Collapse pairwise template embeddings into a set of fusable (u, v)
    producer->consumer node pairs, consumed by the path search."""
    pairs: set[tuple[str, str]] = set()
    for tmpl, matches in template_matches.items():
        if tmpl.name == "horizontal_share":
            continue
        for m in matches:
            pairs.add((m["a"], m["b"]))
    return pairs
