"""LRU cache of compile-stage objects, keyed by stable content hashes.

One :class:`StageCache` holds all four stage tables (wrapped / lowered /
planned / compiled); each table is independently LRU-bounded so a many-model
server can keep dozens of cheap ``Wrapped`` stages resident while bounding
the artifact-bearing ``Compiled`` entries.  Hit/miss/eviction counts are
emitted into the shared metrics registry under ``stages.<stage>.*`` — the
zoo benchmark's "warm reopen compiles 0 stages" gate reads them.
"""
from __future__ import annotations

import threading
import time

STAGE_NAMES = ("wrapped", "lowered", "planned", "compiled")


class StageCache:
    """Thread-safe per-stage LRU memoization for the staged compile pipeline.

    ``get_or_build(stage, key, build)`` returns the cached stage object for
    ``key`` when present (LRU-refreshed) and otherwise calls ``build()``,
    stores the result, and returns it.  Keys are the stages' own content
    hashes, so equal inputs always share one stage object — the same
    contract ``PlanCache`` gives whole artifacts, pushed down to every
    intermediate stage.
    """

    def __init__(self, max_entries: int = 32, registry=None, events=None):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self._tables: dict[str, dict] = {s: {} for s in STAGE_NAMES}
        self._lock = threading.Lock()
        if registry is None:
            from repro.obs.metrics import REGISTRY
            registry = REGISTRY
        self._registry = registry
        self._events = events

    def _evt(self):
        if self._events is None:
            from repro.obs.events import EVENTS
            self._events = EVENTS
        return self._events

    def _count(self, stage: str, what: str) -> None:
        self._registry.counter(f"stages.{stage}.{what}").inc()

    def get_or_build(self, stage: str, key, build):
        """(stage object, cache hit?) — ``build`` runs outside the lock."""
        table = self._tables[stage]
        with self._lock:
            obj = table.get(key)
            if obj is not None:
                table[key] = table.pop(key)        # refresh LRU position
        if obj is not None:
            self._count(stage, "hits")
            return obj, True
        t0 = time.perf_counter()
        obj = build()
        self._count(stage, "misses")
        self._evt().emit("compile.stage", stage=stage, key=str(key)[:16],
                         seconds=time.perf_counter() - t0,
                         message=f"built {stage} stage in "
                                 f"{time.perf_counter() - t0:.3f}s")
        evicted = 0
        with self._lock:
            table.pop(key, None)
            table[key] = obj
            while len(table) > self.max_entries:
                table.pop(next(iter(table)))
                evicted += 1
        for _ in range(evicted):
            self._count(stage, "evictions")
        if evicted:
            self._evt().emit("cache.evict", stage=stage, n=evicted,
                             message=f"stage cache evicted {evicted} "
                                     f"{stage} entr"
                                     f"{'y' if evicted == 1 else 'ies'}")
        return obj, False

    def stats(self) -> dict:
        with self._lock:
            return {s: len(t) for s, t in self._tables.items()}

    def clear(self) -> None:
        with self._lock:
            for t in self._tables.values():
                t.clear()

    def __len__(self) -> int:
        with self._lock:
            return sum(len(t) for t in self._tables.values())


# Shared default cache: ``stages.compile_model`` and the zoo's warm-reopen
# path memoize here unless handed their own.
STAGE_CACHE = StageCache()


def _through(cache: StageCache | None, stage: str, key, build):
    """Run ``build`` through ``cache`` when one is given (None = pure
    compute: ``asm.compile_strategy``'s thin-wrapper path)."""
    if cache is None:
        return build(), False
    return cache.get_or_build(stage, key, build)
