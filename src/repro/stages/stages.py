"""Explicit compile stages: Wrapped -> Lowered -> Planned -> Compiled.

The monolithic ``asm.compile_strategy`` call becomes four first-class,
individually-cacheable objects (the JaCe ``Wrapped -> Lowered -> Compiled``
stage protocol, grown a ``Planned`` stage because DNNVM's memory planner is
a real phase with its own knobs):

* :class:`Wrapped`  — XGraph + quantized params + target device.  The
  immutable compilation *input*; its ``key`` hashes graph structure,
  quantization fingerprint, and device name.
* :class:`Lowered`  — a searched ``pathsearch.Strategy`` plus the lowered
  backend ``GroupProgram``.  Re-tuning tiles or swapping the device profile
  produces a new ``Lowered`` without touching ``Wrapped``.
* :class:`Planned`  — memory plan + addressed instruction stream for one
  (pin_input, DDR budget) choice.  Re-planning for a different budget reuses
  the search and the lowering.
* :class:`Compiled` — the ``CompiledArtifact`` object file, ready for a
  runtime ``Session`` or the on-disk model zoo.

Every stage has a stable content hash (``key``) chaining its upstream
stage's key with exactly the inputs that stage adds, so equal inputs reach
equal keys in any process — the zoo's content addresses and the stage
cache's identity both hang off these.  Stage transitions accept a
``StageCache`` (default: the shared ``STAGE_CACHE``; pass ``cache=None``
for pure recomputation, which is how ``asm.compile_strategy`` keeps its
original one-call semantics).
"""
from __future__ import annotations

import dataclasses

from repro.asm import artifact as _art
from repro.core import lower as _lower
from repro.core import pathsearch
from repro.core.quantize import QuantizedModel
from repro.core.xgraph import XGraph
from repro.hw import DeviceModel
from repro.stages.cache import STAGE_CACHE, StageCache, _through

# "use the cache this stage was built through" marker for stage methods
_INHERIT = object()


def _resolve_cache(cache, inherited):
    if cache is _INHERIT:
        return inherited
    return cache


def _resolve_profile(profile):
    """None | DeviceProfile | name/path -> DeviceProfile | None (lazy tune
    import, same contract as runtime.session)."""
    if profile is None:
        return None
    from repro.tune.profile import resolve_profile
    return resolve_profile(profile)


def _quant_signature_of(qm) -> str:
    return _art.quant_signature(qm)


# ------------------------------------------------------------------- wrapped
@dataclasses.dataclass
class Wrapped:
    """Stage 1: the compilation input — graph, quantized params, device."""
    graph: XGraph
    qm: QuantizedModel | None
    device: DeviceModel
    key: str
    _cache: object = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def graph_sig(self) -> str:
        return _art.graph_signature(self.graph)

    def lower(self, *, strategy=None, profile=None, profile_hash: str | None
              = None, evaluator=None, device_of=None,
              enable_horizontal: bool = True, cache=_INHERIT) -> "Lowered":
        """Search an execution strategy (or adopt a given one) and lower it
        to the backend ``GroupProgram``.

        ``profile`` resolves like everywhere else (DeviceProfile | name |
        path | None); when given and no ``evaluator`` is passed, the search
        runs under a ``tune.CalibratedEvaluator``.  ``profile_hash`` carries
        provenance when only the hash of the planning profile is known (a
        reloaded artifact).  ``device_of`` is the host/accelerator partition
        function (``core.partition.device_of``)."""
        cache = _resolve_cache(cache, self._cache)
        resolved = _resolve_profile(profile)
        ph = resolved.hash() if resolved is not None else profile_hash
        pname = resolved.name if resolved is not None else None

        if strategy is not None:
            ck = ("given", self.key, _art.strategy_signature(strategy),
                  ph or "analytic")
            return self._build_lowered(ck, strategy, resolved, ph, pname,
                                       cache)

        # deterministic fingerprint of the host/accelerator partition: the
        # set of host nodes is what the search actually consumes
        host = (sorted(n.name for n in self.graph
                       if n.op != "input" and device_of(n.name) != "acc")
                if device_of is not None else [])
        ck = ("search", self.key, ph or "analytic", _art._sha(host),
              bool(enable_horizontal))

        def build():
            ev = evaluator
            if ev is None and resolved is not None:
                from repro.tune import CalibratedEvaluator
                ev = CalibratedEvaluator(self.graph, self.device, resolved)
            strat = pathsearch.search(self.graph, self.device, evaluator=ev,
                                      device_of=device_of,
                                      enable_horizontal=enable_horizontal)
            return self._make_lowered(strat, resolved, ph, pname, cache)

        obj, _ = _through(cache, "lowered", ck, build)
        return obj

    def _build_lowered(self, ck, strategy, resolved, ph, pname, cache):
        obj, _ = _through(cache, "lowered", ck,
                          lambda: self._make_lowered(strategy, resolved, ph,
                                                     pname, cache))
        return obj

    def _make_lowered(self, strategy, resolved, ph, pname, cache):
        from repro.obs.trace import TRACER
        with TRACER.span("lower", cat="compile", track="compile"):
            program = _lower.lower_strategy(self.graph, strategy, self.qm)
        key = _art._sha([self.key, _art.strategy_signature(strategy),
                         ph or "analytic"])
        return Lowered(wrapped=self, strategy=strategy, program=program,
                       profile=resolved, profile_hash=ph, profile_name=pname,
                       key=key, _cache=cache)


def wrap(g: XGraph, qm: QuantizedModel | None, dev: DeviceModel, *,
         cache: StageCache | None = _INHERIT) -> Wrapped:
    """Open the staged pipeline on (graph, quantized params, device).

    The default cache is the shared ``STAGE_CACHE`` (so repeated wraps of
    identical inputs share one stage object); ``cache=None`` disables
    memoization for this pipeline walk."""
    if cache is _INHERIT:
        cache = STAGE_CACHE
    key = _art._sha([_art.graph_signature(g), _quant_signature_of(qm),
                     dev.name])
    obj, _ = _through(cache, "wrapped", key,
                      lambda: Wrapped(graph=g, qm=qm, device=dev, key=key,
                                      _cache=cache))
    return obj


# ------------------------------------------------------------------- lowered
@dataclasses.dataclass
class Lowered:
    """Stage 2: searched strategy + lowered backend program."""
    wrapped: Wrapped
    strategy: object                 # pathsearch.Strategy (or duck-typed)
    program: object                  # lower.GroupProgram
    profile: object                  # tune.DeviceProfile | None
    profile_hash: str | None
    profile_name: str | None
    key: str
    _cache: object = dataclasses.field(default=None, repr=False, compare=False)

    def plan(self, *, pin_input: bool = False,
             ddr_budget_bytes: int | None = None,
             cache=_INHERIT) -> "Planned":
        """Plan memory + emit the addressed instruction stream.  A different
        ``pin_input`` or DDR budget re-runs only this stage and later —
        the search and the lowering are reused as-is."""
        cache = _resolve_cache(cache, self._cache)
        budget = int(ddr_budget_bytes or 0)
        dev = self.wrapped.device
        if budget:
            dev = dev.replace(ddr_bytes=budget)
        ck = ("plan", self.key, bool(pin_input), budget)

        def build():
            planres = _art.plan_strategy(self.wrapped.graph, self.strategy,
                                         dev, pin_input=bool(pin_input))
            key = _art._sha([self.key, bool(pin_input), budget])
            return Planned(lowered=self, planres=planres,
                           ddr_budget_bytes=budget or None, key=key,
                           _cache=cache)

        obj, _ = _through(cache, "planned", ck, build)
        return obj

    def retune(self, *, profile=None, harness=None, cache=_INHERIT,
               **search_kw) -> "Lowered":
        """Re-run the measured tile-shape search over this lowering and
        return a new ``Lowered`` carrying the tuned shapes — pathsearch is
        NOT re-run (see ``tune.tiles.tune_lowered``)."""
        from repro.tune.tiles import tune_lowered
        return tune_lowered(self, profile=profile, harness=harness,
                            cache=_resolve_cache(cache, self._cache),
                            **search_kw)


# ------------------------------------------------------------------- planned
@dataclasses.dataclass
class Planned:
    """Stage 3: memory plan + addressed instructions for one budget."""
    lowered: Lowered
    planres: _art.PlanResult
    ddr_budget_bytes: int | None
    key: str
    _cache: object = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def mem_summary(self) -> dict:
        return self.planres.mem_summary

    @property
    def peak_ddr_bytes(self) -> int:
        return self.planres.mem_summary["peak_bytes"]

    def compile(self, cache=_INHERIT) -> "Compiled":
        """Assemble the final ``CompiledArtifact`` object file."""
        cache = _resolve_cache(cache, self._cache)
        lo, w = self.lowered, self.lowered.wrapped
        key = _art._sha([self.key, _art.FORMAT_VERSION])
        ck = ("compile", self.key)

        def build():
            art = _art.assemble_artifact(
                w.graph, lo.strategy, w.device, w.qm, self.planres,
                lo.program, profile_hash=lo.profile_hash,
                profile_name=lo.profile_name)
            return Compiled(artifact=art, key=key,
                            stage_keys={"wrapped": w.key, "lowered": lo.key,
                                        "planned": self.key,
                                        "compiled": key},
                            planned=self, _cache=cache)

        obj, _ = _through(cache, "compiled", ck, build)
        return obj


# ------------------------------------------------------------------ compiled
@dataclasses.dataclass
class Compiled:
    """Stage 4: the DNNVM object file, ready to serve or to shelve."""
    artifact: _art.CompiledArtifact
    key: str                         # content address (the zoo's key)
    stage_keys: dict                 # stage name -> content hash
    planned: Planned | None = None   # None when reopened from an object file
    _cache: object = dataclasses.field(default=None, repr=False, compare=False)

    def session(self, backend: str = "ref", **kw):
        """Open a runtime ``Session`` on the artifact (plan cache seeded,
        no recompilation)."""
        return self.artifact.session(backend=backend, **kw)

    def save(self, path: str) -> None:
        _art.save_artifact(self.artifact, path)

    @classmethod
    def from_artifact(cls, art: _art.CompiledArtifact) -> "Compiled":
        """Re-open an object file as a ``Compiled`` stage.  The stage-key
        chain is reconstructed from the artifact's own content, so a
        reloaded artifact content-addresses identically to the compilation
        that produced it (the zoo backcompat pin)."""
        keys = artifact_stage_keys(art)
        return cls(artifact=art, key=keys["compiled"], stage_keys=keys)


def artifact_stage_keys(art: _art.CompiledArtifact) -> dict:
    """Reconstruct the wrapped/lowered/planned/compiled content hashes of an
    artifact from its serialized content alone (no recompilation).  Loaded
    artifacts carry no DDR-budget record, so the planned key assumes the
    unbudgeted (device-default) plan — exactly what ``compile_strategy``
    produces."""
    if art.f_a or art.f_w or art.weights:
        qsig = _art.quant_signature(QuantizedModel(
            dict(art.weights), dict(art.biases), dict(art.f_w),
            dict(art.f_a)))
    else:
        qsig = _art.quant_signature(None)
    wrapped = _art._sha([art.graph_sig, qsig, art.device])
    lowered = _art._sha([wrapped, _art.strategy_signature(art),
                         art.profile_hash or "analytic"])
    planned = _art._sha([lowered, bool(art.pin_input), 0])
    compiled = _art._sha([planned, _art.FORMAT_VERSION])
    return {"wrapped": wrapped, "lowered": lowered, "planned": planned,
            "compiled": compiled}
