"""One-call front end over the staged pipeline, with zoo integration.

``compile_model`` walks Wrapped -> Lowered -> Planned -> Compiled through a
``StageCache`` (the shared ``STAGE_CACHE`` by default), so a warm recompile
of identical inputs hits all four stage caches and compiles nothing.  Given
a ``zoo.ModelZoo`` it also consults the on-disk store first — keyed by a
*source* fingerprint (wrapped key + profile + partition + plan knobs) that
is computable before any search runs — and shelves fresh compilations under
their content address.
"""
from __future__ import annotations

from repro.asm import artifact as _art
from repro.stages.cache import STAGE_CACHE, StageCache
from repro.stages.stages import Compiled, _INHERIT, _resolve_profile, wrap


def source_key(wrapped_key: str, profile_hash: str | None, host_sig: str,
               pin_input: bool, ddr_budget_bytes: int) -> str:
    """Fingerprint of compile-pipeline *inputs* (no search needed): what the
    zoo indexes so a reopen can find an artifact without recompiling."""
    return _art._sha(["source", wrapped_key, profile_hash or "analytic",
                      host_sig, bool(pin_input), int(ddr_budget_bytes),
                      _art.FORMAT_VERSION])


def compile_model(g, qm, dev, *, profile=None, device_of=None, strategy=None,
                  evaluator=None, enable_horizontal: bool = True,
                  pin_input: bool = False, ddr_budget_bytes: int | None = None,
                  cache: StageCache | None = _INHERIT, zoo=None,
                  name: str | None = None) -> Compiled:
    """Compile (or reopen) one model end to end through the staged pipeline.

    Returns the ``Compiled`` stage.  With ``zoo=`` the on-disk store is
    consulted before compiling (reopen = zero stages run) and fresh
    compilations are shelved into it under ``name``."""
    if cache is _INHERIT:
        cache = STAGE_CACHE
    resolved = _resolve_profile(profile)
    wrapped = wrap(g, qm, dev, cache=cache)

    host = (sorted(n.name for n in g
                   if n.op != "input" and device_of(n.name) != "acc")
            if device_of is not None else [])
    skey = source_key(wrapped.key,
                      resolved.hash() if resolved is not None else None,
                      _art._sha(host), pin_input,
                      int(ddr_budget_bytes or 0))

    from repro.obs.events import EVENTS

    if zoo is not None and strategy is None:
        art = zoo.find_source(skey)
        if art is not None:
            EVENTS.emit("compile.model", model=name, source_key=skey[:16],
                        reopened=True,
                        message=f"model {name or skey[:16]} reopened from "
                                "zoo (0 stages run)")
            return Compiled.from_artifact(art)

    lowered = wrapped.lower(strategy=strategy, profile=resolved,
                            evaluator=evaluator, device_of=device_of,
                            enable_horizontal=enable_horizontal, cache=cache)
    compiled = lowered.plan(pin_input=pin_input,
                            ddr_budget_bytes=ddr_budget_bytes,
                            cache=cache).compile(cache=cache)
    if zoo is not None:
        zoo.put(compiled.artifact, name=name, source_key=skey)
    EVENTS.emit("compile.model", model=name, source_key=skey[:16],
                reopened=False,
                message=f"model {name or skey[:16]} compiled through the "
                        "staged pipeline")
    return compiled
