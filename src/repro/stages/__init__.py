"""Staged compile pipeline: Wrapped -> Lowered -> Planned -> Compiled.

Each compiler phase is a first-class, content-hashed, individually-cacheable
object (the JaCe stage protocol adapted to DNNVM's phases), so partial
recompiles — re-tune tiles without re-running pathsearch, re-plan memory
for a different DDR budget without re-searching — reuse upstream stages,
and the on-disk model zoo (``repro.zoo``) can content-address object files.

    from repro.stages import wrap, compile_model

    co = compile_model(g, qm, ZU2, profile=prof)     # all four stages
    sess = co.session(backend="pallas")

    w  = wrap(g, qm, ZU2)                            # or stage by stage
    lo = w.lower(profile=prof)                       # search + lower
    pl = lo.plan(pin_input=True)                     # re-plan only
    co = pl.compile()
"""
from repro.stages.cache import STAGE_CACHE, STAGE_NAMES, StageCache
from repro.stages.pipeline import compile_model, source_key
from repro.stages.stages import (Compiled, Lowered, Planned, Wrapped,
                                 artifact_stage_keys, wrap)

__all__ = [
    "Compiled", "Lowered", "Planned", "STAGE_CACHE", "STAGE_NAMES",
    "StageCache", "Wrapped", "artifact_stage_keys", "compile_model",
    "source_key", "wrap",
]
