"""Training step factory + CLI driver.

``make_train_step(cfg, ...)`` returns a pure (state, batch) -> (state,
metrics) function:

* gradient accumulation over ``grad_accum`` microbatches via lax.scan — the
  logits tensor (the memory peak at 128k-vocab) only ever materializes per
  microbatch;
* grads accumulated in ``grad_dtype`` (bf16 at 405B scale, fp32 below);
* AdamW with ZeRO-1-sharded moments (shard.moment_specs);
* optional int8 gradient compression with error feedback (optim.compress).

CLI:  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
          --steps 100 --batch 8 --seq 256   (runs on whatever devices exist)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get as get_cfg
from repro.configs.base import ArchConfig
from repro.models import api
from repro.optim.adamw import AdamWConfig, adamw_update, init_moments


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    grad_accum: int = 1, grad_dtype: str = "float32",
                    grad_sync: str = "auto", mesh=None):
    """grad_sync:
    "auto" — GSPMD decides; XLA all-reduces weight grads once per MICROBATCH
             inside the accumulation scan (measured §Perf).
    "late" — the microbatch loop runs inside shard_map over the data axes
             (model axis stays auto/GSPMD): grads accumulate locally and are
             psum'd ONCE per step — grad-sync collective bytes / grad_accum.
             Requires ``mesh`` and a JAX with native ``jax.shard_map``
             (partial-auto shard_map crashes XLA on 0.4.x meshes with a
             model axis — there "late" degrades to the numerically identical
             per-microbatch path).
    """
    gdt = jnp.dtype(grad_dtype)

    def loss(params, mb):
        return api.loss_fn(cfg, params, mb)

    def accum_grads(params, micro):
        def body(acc, mb):
            l, g = jax.value_and_grad(loss)(params, mb)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(
                lambda a, b: (a + b.astype(gdt)).astype(gdt), acc_g, g)
            return (acc_g, acc_l + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
        (grads, lsum), _ = jax.lax.scan(body, (g0, 0.0), micro)
        return (jax.tree.map(lambda g: g / grad_accum, grads),
                lsum / grad_accum)

    def split_batch(x):
        from repro.nn.layers import constrain

        y = x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:])
        # keep every microbatch batch-sharded over dp: without this XLA
        # factors the dp axis across the microbatch-index dim and the scan
        # gathers each slice (§Perf iteration 3)
        return constrain(y, None, "dp", *([None] * (y.ndim - 2)))

    if grad_sync == "late":
        if mesh is None:
            raise ValueError("grad_sync='late' needs the mesh")
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import data_axes

        dp = data_axes(mesh)
        if (not hasattr(jax, "shard_map")
                and set(mesh.axis_names) - set(dp)):
            grad_sync = "auto"   # see docstring: 0.4.x partial-auto crash
        # each microbatch must still split across the data axes
        if grad_accum > 1 and dp:
            pass  # divisibility asserted by shard_map at trace time

        def grad_fn(params, micro_local):
            g, l = accum_grads(params, micro_local)
            # THE one grad sync per step (vs one per microbatch under GSPMD)
            g = jax.tree.map(lambda x: jax.lax.pmean(x, dp), g)
            return g, jax.lax.pmean(l, dp)

        def late_grads(params, batch):
            micro = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            in_specs = (jax.tree.map(lambda _: P(), params),
                        jax.tree.map(lambda x: P(None, dp), micro))
            out_specs = (jax.tree.map(lambda _: P(), params), P())
            if hasattr(jax, "shard_map"):
                fn = jax.shard_map(grad_fn, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, axis_names=set(dp),
                                   check_vma=False)
            else:  # JAX 0.4.x: non-mapped mesh axes go through ``auto``
                from jax.experimental.shard_map import shard_map
                fn = shard_map(grad_fn, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False,
                               auto=frozenset(mesh.axis_names) - set(dp))
            return fn(params, micro)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        if grad_accum > 1 and grad_sync == "late":
            grads, lval = late_grads(params, batch)
        elif grad_accum > 1:
            micro = jax.tree.map(split_batch, batch)
            grads, lval = accum_grads(params, micro)
        else:
            lval, grads = jax.value_and_grad(loss)(params, batch)
        new_params, new_opt = adamw_update(params, grads, opt, opt_cfg)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return ({"params": new_params, "opt": new_opt},
                {"loss": lval, "grad_norm": gnorm, "step": new_opt["step"]})

    return train_step


def _dp_size(mesh) -> int:
    from repro.launch.mesh import data_axes, mesh_dims

    md = mesh_dims(mesh)
    n = 1
    for a in data_axes(mesh):
        n *= md[a]
    return n


def init_state(cfg: ArchConfig, opt_cfg: AdamWConfig = AdamWConfig(), rng=None):
    params = api.init_params(cfg, rng)
    return {"params": params, "opt": init_moments(params, opt_cfg)}


def abstract_state(cfg: ArchConfig, opt_cfg: AdamWConfig = AdamWConfig()):
    return jax.eval_shape(lambda: init_state(cfg, opt_cfg))


def state_specs(state_abstract, mesh):
    """Sharding specs for the full train state (params TP, moments ZeRO-1)."""
    from repro.launch import shard

    return {
        "params": shard.param_specs(state_abstract["params"], mesh),
        "opt": {
            "m": shard.moment_specs(state_abstract["opt"]["m"], mesh),
            "v": shard.moment_specs(state_abstract["opt"]["v"], mesh),
            "step": jax.sharding.PartitionSpec(),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_cfg(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    from repro.data.pipeline import SyntheticLM

    data = SyntheticLM(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                       family=cfg.family, d_model=cfg.d_model,
                       n_patches=cfg.n_patches)
    state = init_state(cfg)
    step_fn = jax.jit(make_train_step(cfg, grad_accum=args.grad_accum),
                      donate_argnums=(0,))
    ckpt = None
    if args.checkpoint_dir:
        from repro.checkpoint.store import CheckpointStore

        ckpt = CheckpointStore(args.checkpoint_dir)
        restored = ckpt.restore_latest(jax.eval_shape(lambda: state))
        if restored is not None:
            state, start = restored
            data.seek(start)
            print(f"restored checkpoint at step {start}")
    t0 = time.perf_counter()
    for i in range(args.steps):
        state, metrics = step_fn(state, data.next())
        if (i + 1) % 10 == 0:
            l = float(metrics["loss"])
            dt = (time.perf_counter() - t0) / (i + 1)
            print(f"step {i+1:5d} loss {l:.4f}  {dt*1e3:.1f} ms/step")
        if ckpt and (i + 1) % args.checkpoint_every == 0:
            ckpt.save(state, step=i + 1, async_write=True)
    if ckpt:
        ckpt.save(state, step=args.steps)
        ckpt.wait()
    print(f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
