"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run forces 512 host devices; smoke tests and
benches must keep seeing 1).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=`` kwarg when this JAX has it (>= 0.5), else nothing —
    0.4.x meshes are implicitly Auto, which is what we ask for anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod ("data" x "model"); two pods add a "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def mesh_context(mesh):
    """Portable ``with <mesh active>`` context: ``jax.set_mesh`` on newer
    JAX, the ``Mesh`` object's own context manager on 0.4.x."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def mesh_dims(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
