"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run forces 512 host devices; smoke tests and
benches must keep seeing 1).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod ("data" x "model"); two pods add a "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def mesh_dims(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
