"""Post-SPMD HLO text analysis: per-device collective traffic with loop
trip-count accounting.

XLA emits each ``while`` body once; collectives inside a scanned layer stack
execute trip-count times.  We rebuild the computation graph from the HLO
text: computations are split on their header lines, ``while`` ops link a
parent computation to body/condition computations, and the trip count is
recovered from the loop-condition's compare constant.  Collective bytes are
then summed as result-shape bytes x ring-traffic factor x loop multiplier.

Ring-traffic factors (per-device bytes moved / result bytes):
  all-reduce       2 (N-1)/N   ~ 2
  all-gather         (N-1)/N   ~ 1
  reduce-scatter     (N-1)     (result is the shard; input = result x N)
  all-to-all         (N-1)/N   ~ 1
  collective-permute 1
"""
from __future__ import annotations

import re
from collections import Counter, defaultdict

COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE = re.compile(r"while\(.*?\).*condition=(%[\w.\-]+).*body=(%[\w.\-]+)|"
                    r"while\(.*?\).*body=(%[\w.\-]+).*condition=(%[\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=(%[\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")
_SHAPE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|"
                    r"c64|c128)\[([\d,]*)\]")
_RG_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_RG_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
          "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
          "pred": 1, "c64": 8, "c128": 16}


def _split_computations(text: str) -> dict:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _result_bytes(line: str) -> int:
    """Bytes of the op's result type (text between '=' and the op name)."""
    eq = line.find("=")
    if eq < 0:
        return 0
    rest = line[eq + 1:]
    for op in COLL_OPS:
        k = rest.find(op + "(")
        if k < 0:
            k = rest.find(op + "-start(")
        if k >= 0:
            rest = rest[:k]
            break
    total = 0
    for dt, dims in _SHAPE.findall(rest):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _RG_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _RG_LIST.search(line)
    if m:
        return m.group(1).count(",") + 1
    return 1


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count = the constant operand of the condition's compare op (NOT
    the max constant in the computation — loop bodies hoist unrelated
    constants like cache lengths into the condition)."""
    consts: dict[str, int] = {}
    for l in cond_lines:
        m = re.search(r"(%[\w.\-]+)\s*=\s*s\d+\[\]\s*constant\((\d+)\)", l)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for l in cond_lines:
        if "compare(" not in l:
            continue
        m = re.search(r"compare\(([^)]*)\)", l)
        if not m:
            continue
        for ref in re.findall(r"%[\w.\-]+", m.group(1)):
            if ref in consts:
                return consts[ref]
    # fallback: any single constant
    allc = [int(c) for l in cond_lines for c in _CONST.findall(l)]
    return min(allc) if allc else 1


def collective_stats(text: str) -> dict:
    comps = _split_computations(text)
    # multiplier per computation: product of enclosing while trip counts
    mult: dict[str, float] = defaultdict(lambda: 1.0)
    # BFS from every computation: propagate to called computations
    children: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line or "while(" in line.lstrip("%"):
                refs = dict()
                mcond = re.search(r"condition=(%[\w.\-]+)", line)
                mbody = re.search(r"body=(%[\w.\-]+)", line)
                if mcond and mbody:
                    trips = _trip_count(comps.get(mcond.group(1), []))
                    children[cname].append((mbody.group(1), float(trips)))
                    continue
            for ref in _CALLS.findall(line):
                if ref in comps:
                    children[cname].append((ref, 1.0))
    # roots: computations never referenced
    referenced = {c for lst in children.values() for c, _ in lst}
    roots = [c for c in comps if c not in referenced]
    # propagate along the call DAG in topological order; a computation called
    # from k sites executes the SUM of its callers' (multiplier x trips)
    indeg: Counter = Counter()
    for lst in children.values():
        for child, _ in lst:
            indeg[child] += 1
    from collections import deque

    mult = {c: 0.0 for c in comps}
    for r in roots:
        mult[r] = 1.0
    dq = deque(roots)
    while dq:
        c = dq.popleft()
        for child, f in children.get(c, ()):
            mult[child] += mult[c] * f
            indeg[child] -= 1
            if indeg[child] == 0:
                dq.append(child)

    totals: Counter = Counter()
    counts: Counter = Counter()
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0)
        for line in lines:
            for op in COLL_OPS:
                if f" {op}(" in line or f" {op}-start(" in line:
                    nbytes = _result_bytes(line)
                    gsz = _group_size(line)
                    factor = {"all-reduce": 2.0 * (gsz - 1) / max(gsz, 1),
                              "all-gather": (gsz - 1) / max(gsz, 1),
                              "reduce-scatter": float(max(1, gsz - 1)),
                              "all-to-all": (gsz - 1) / max(gsz, 1),
                              "collective-permute": 1.0}[op]
                    totals[op] += int(nbytes * factor * m)
                    counts[op] += 1
                    break
    return {"bytes_by_op": dict(totals), "counts": dict(counts),
            "total_bytes": int(sum(totals.values()))}
