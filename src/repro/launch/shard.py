"""Sharding rules: PartitionSpecs for params, optimizer state, caches and
batches on the ("pod",) "data" x "model" mesh.

Strategy (DESIGN.md §4): DP over ("pod","data"); TP over "model" — each
parameter shards its largest model-divisible dimension (preferring trailing
dims, the contraction-friendly choice); norms and other small vectors
replicate.  ZeRO-1: optimizer moments additionally shard one remaining
dimension over "data".  Non-divisible cases (smollm's 15 heads, mixtral's 8
experts) fall back to replication of that dim — recorded per-arch in
DESIGN.md §5.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes, mesh_dims

_REPLICATED_HINTS = ("ln", "bias", "a_log", "b_gates")


def _is_replicated(path: str) -> bool:
    leaf = path.split("/")[-1]
    return any(leaf.startswith(h) or leaf == h for h in _REPLICATED_HINTS)


def _stacked_dims(path: str) -> int:
    """Leading stacking axes (layer stacks, LoRA application stacks) that we
    keep unsharded for scan slicing."""
    top = path.split("/")[0]
    return 1 if top in ("layers", "mlstm", "slstm", "mamba", "enc", "dec",
                        "lora") else 0


# Megatron row-parallel weights: shard the CONTRACTION (input) dim so the
# matmul reduces with one small activation all-reduce — sharding their output
# dim instead makes XLA all-gather the whole weight per use (a 3.5 GB/layer
# gather for llama w2; §Perf iteration 5).
_ROW_PARALLEL = {"w2", "wo", "w_down", "w_out", "xwo"}


def param_spec(path: str, shape: tuple, model_size: int) -> P:
    if _is_replicated(path) or len(shape) <= 1:
        return P()
    leaf = path.split("/")[-1]
    if leaf in ("embed", "unembed") and shape[0] % model_size == 0:
        # vocab-parallel (Megatron-style): logits reduce over shards instead
        # of gathering the table
        return P("model", *([None] * (len(shape) - 1)))
    lead = min(_stacked_dims(path), len(shape) - 1)
    dims = list(range(len(shape)))[lead:]
    order = list(reversed(dims))
    if leaf in _ROW_PARALLEL and len(dims) >= 2:
        order = [dims[-2], dims[-1]] + list(reversed(dims[:-2]))
    for d in order:
        if shape[d] % model_size == 0 and shape[d] >= model_size:
            spec = [None] * len(shape)
            spec[d] = "model"
            return P(*spec)
    return P()


def zero1_spec(pspec: P, shape: tuple, data_size: int, path: str = "") -> P:
    """Optimizer-moment spec: param spec + shard one more dim over "data"."""
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    for d in reversed(range(len(shape))):
        if spec[d] is None and shape[d] % data_size == 0 and shape[d] >= data_size:
            spec[d] = "data"
            return P(*spec)
    return P(*spec)


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def param_specs(params_abstract, mesh) -> object:
    msize = mesh_dims(mesh).get("model", 1)
    paths, leaves, treedef = _tree_paths(params_abstract)
    specs = [param_spec(p, l.shape, msize) for p, l in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(treedef, specs)


def moment_specs(params_abstract, mesh) -> object:
    md = mesh_dims(mesh)
    msize, dsize = md.get("model", 1), md.get("data", 1)
    paths, leaves, treedef = _tree_paths(params_abstract)
    specs = [zero1_spec(param_spec(p, l.shape, msize), l.shape, dsize, p)
             for p, l in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch_abstract, mesh) -> object:
    dp = data_axes(mesh)
    md = mesh_dims(mesh)
    dp_size = int(np.prod([md[a] for a in dp])) if dp else 1

    def spec(l):
        if l.ndim == 0 or l.shape[0] % dp_size or l.shape[0] < dp_size:
            return P(*([None] * l.ndim))
        return P(dp, *([None] * (l.ndim - 1)))

    return jax.tree.map(spec, batch_abstract)


def cache_specs(cache_abstract, cfg, mesh) -> object:
    """KV caches (L,B,S,KV,D) / SSM states (L,B,H,K,V): batch over data axes;
    the kv-head dim over "model" when divisible, otherwise the sequence /
    state dim — sequence-sharded KV decodes flash-decode style (partial
    softmax + small all-reduce), which XLA SPMD materializes from these
    constraints (DESIGN.md §4)."""
    md = mesh_dims(mesh)
    msize = md.get("model", 1)
    dp = data_axes(mesh)
    dp_size = int(np.prod([md[a] for a in dp])) if dp else 1

    def spec(l):
        s = [None] * l.ndim
        batch_sharded = (l.ndim >= 2 and l.shape[1] % dp_size == 0
                         and l.shape[1] >= dp_size)
        if batch_sharded:
            s[1] = dp          # (L, B, ...)
        if l.ndim >= 4 and l.shape[3] % msize == 0 and l.shape[3] >= msize:
            s[3] = "model"     # kv heads / ssm K dim
            if not batch_sharded and dp and l.shape[2] % dp_size == 0 \
                    and l.shape[2] >= dp_size:
                # batch too small (long_500k decode): shard the sequence over
                # the idle data axes — flash-decode partial softmax + small
                # all-reduce (§Perf iteration: zamba2 long_500k)
                s[2] = dp
        elif l.ndim >= 4 and l.shape[2] % msize == 0 and l.shape[2] >= msize:
            s[2] = "model"     # sequence (KV cache) / head state dim
        return P(*s)

    return jax.tree.map(spec, cache_abstract)


def named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
