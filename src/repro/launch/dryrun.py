import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on init.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective receipts.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh pod

Artifacts land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json and
feed the §Roofline analysis (benchmarks/roofline.py).
"""
import argparse
import dataclasses
import gc
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES
from repro.launch import shard
from repro.launch.hlo_analysis import collective_stats
from repro.launch.mesh import (data_axes, make_production_mesh, mesh_context,
                               mesh_dims)
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.launch.train import abstract_state, make_train_step, state_specs
from repro.models import api
from repro.nn import flags as nn_flags

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def grad_accum_for(cfg, shape) -> int:
    """Microbatch count: the §Perf memory-feasibility boundary (with the
    dots remat policy, activations per live microbatch must keep temp bytes
    under the 16 GB v5e HBM — granite@ga=16 measures 13.5 GiB/device)."""
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 8192:
        return 64
    if cfg.d_model >= 3072:
        return 16
    return 8


# ------------------------------------------------------- layer-group secant
def _group_unit(cfg) -> int:
    """Layers per repeating structural unit."""
    if cfg.family == "ssm":
        return cfg.slstm_every or 1
    if cfg.family == "hybrid":
        return cfg.shared_attn_every or 1
    return 1


def _with_units(cfg, n_units: int):
    g = _group_unit(cfg)
    L = n_units * g
    if cfg.family == "audio":
        return dataclasses.replace(cfg, n_layers=L, enc_layers=L)
    return dataclasses.replace(cfg, n_layers=L)


def _units_full(cfg) -> float:
    return cfg.n_layers / _group_unit(cfg)


def _compile_cell(cfg, shape, mesh, ga: int):
    """Lower + compile one step for (cfg, shape) on mesh.  Returns compiled."""
    specs_in = api.input_specs(cfg, shape)
    bspecs = shard.named(shard.batch_specs(specs_in, mesh), mesh)
    if shape.kind == "train":
        st_abs = abstract_state(cfg)
        st_specs = shard.named(state_specs(st_abs, mesh), mesh)
        gs = os.environ.get("REPRO_GRAD_SYNC", "auto")
        step = make_train_step(cfg, grad_accum=ga,
                               grad_dtype=("bfloat16" if cfg.d_model >= 8192
                                           else "float32"),
                               grad_sync=gs, mesh=mesh if gs == "late" else None)
        jitted = jax.jit(step, in_shardings=(st_specs, bspecs),
                         donate_argnums=(0,))
        return jitted.lower(st_abs, specs_in)
    if shape.kind == "prefill":
        p_abs = api.abstract_params(cfg)
        p_specs = shard.named(shard.param_specs(p_abs, mesh), mesh)
        jitted = jax.jit(make_prefill_step(cfg), in_shardings=(p_specs, bspecs))
        return jitted.lower(p_abs, specs_in)
    # decode
    p_abs = api.abstract_params(cfg)
    p_specs = shard.named(shard.param_specs(p_abs, mesh), mesh)
    c_abs = api.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    c_specs = shard.named(shard.cache_specs(c_abs, cfg, mesh), mesh)
    tok_spec = shard.named(
        shard.batch_specs(specs_in["tokens"], mesh), mesh)
    pos_spec = shard.named(jax.sharding.PartitionSpec(), mesh)
    jitted = jax.jit(make_serve_step(cfg),
                     in_shardings=(p_specs, c_specs, tok_spec, pos_spec),
                     donate_argnums=(1,))
    return jitted.lower(p_abs, c_abs, specs_in["tokens"], specs_in["pos"])


def _cost_of(compiled) -> tuple[float, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             measure: bool = True) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "n_devices": int(mesh.devices.size), "kind": shape.kind,
           "status": "ok"}
    ga = grad_accum_for(cfg, shape)
    rec["grad_accum"] = ga
    with mesh_context(mesh):
        # ---- production compile: memory receipts + loop-aware collectives
        t0 = time.time()
        lowered = _compile_cell(cfg, shape, mesh, ga)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                rec[k] = int(v)
        rec["hlo_flops_body"], rec["hlo_bytes_body"] = _cost_of(compiled)
        rec["collectives"] = collective_stats(compiled.as_text())
        del compiled, lowered

        # ---- measurement compiles: L=1/L=2-unit secant => loop-aware totals
        if measure:
            nn_flags.MEASURE = True
            try:
                f, b = {}, {}
                for n_units in (1, 2):
                    c = _with_units(cfg, n_units)
                    lw = _compile_cell(c, shape, mesh, ga=1)
                    comp = lw.compile()
                    f[n_units], b[n_units] = _cost_of(comp)
                    del comp, lw
                u = _units_full(cfg)
                rec["hlo_flops"] = f[1] + (f[2] - f[1]) * (u - 1)
                rec["hlo_bytes"] = b[1] + (b[2] - b[1]) * (u - 1)
                rec["secant"] = {"f1": f[1], "f2": f[2], "b1": b[1],
                                 "b2": b[2], "units": u}
            finally:
                nn_flags.MEASURE = False
    return rec


def save(rec: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(
        RESULTS_DIR, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for name, cfg in configs.ARCHS.items():
            for sh in configs.shapes_for(cfg):
                meshes = (("pod", "multipod") if args.mesh == "both"
                          else (args.mesh,))
                for mk in meshes:
                    cells.append((name, sh, mk))
    else:
        meshes = (("pod", "multipod") if args.mesh == "both" else (args.mesh,))
        for mk in meshes:
            cells.append((args.arch, args.shape, mk))

    ok = fail = skipped = 0
    for arch, sh, mk in cells:
        path = os.path.join(RESULTS_DIR, f"{arch}__{sh}__{mk}.json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") == "ok":
                    skipped += 1
                    continue
        try:
            rec = run_cell(arch, sh, mk)
            ok += 1
        except Exception as e:
            rec = {"arch": arch, "shape": sh, "mesh": mk, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            fail += 1
        save(rec)
        print(f"[{ok+fail+skipped}/{len(cells)}] {arch:24s} {sh:12s} {mk:8s} "
              f"{rec['status']}"
              + (f"  compile={rec.get('compile_s')}s "
                 f"flops={rec.get('hlo_flops', 0):.3g} "
                 f"coll={rec.get('collectives', {}).get('total_bytes', 0):.3g}B"
                 if rec["status"] == "ok" else f"  {rec.get('error', '')[:120]}"))
        gc.collect()
    print(f"done: {ok} ok, {fail} failed, {skipped} skipped")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
