"""Serving steps: prefill (logits over a full prompt batch) and decode
(one token against the KV/SSM state), plus a small batched-request driver.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get as get_cfg
from repro.configs.base import ArchConfig
from repro.models import api


def make_prefill_step(cfg: ArchConfig):
    from repro.nn import encdec, model, xlstm, zamba

    def prefill(params, batch):
        if cfg.family == "audio":
            enc_out = encdec.encode(cfg, params, batch["frames"])
            return encdec.decode_train(cfg, params, enc_out, batch["tokens"])
        if cfg.family == "ssm":
            return xlstm.forward(cfg, params, batch["tokens"])[0]
        if cfg.family == "hybrid":
            return zamba.forward(cfg, params, batch["tokens"])[0]
        return model.forward(cfg, params, batch["tokens"],
                             batch.get("patch_embeds"))[0]

    return prefill


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, tokens, pos):
        logits, cache = api.decode_step(cfg, params, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_cfg(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = api.init_params(cfg)
    B = args.batch
    max_len = args.prompt_len + args.gen_len
    cache = api.init_cache(cfg, B, max_len)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    import numpy as np

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (B, args.prompt_len)).astype("int32")
    # prefill via repeated decode (teacher-forced) — exercises the cache path
    tok = jnp.asarray(prompt[:, 0])
    t0 = time.perf_counter()
    for p in range(args.prompt_len - 1):
        _, cache = serve(params, cache, jnp.asarray(prompt[:, p]),
                         jnp.int32(p))
    out = []
    tok = jnp.asarray(prompt[:, -1])
    for p in range(args.prompt_len - 1, max_len - 1):
        tok, cache = serve(params, cache, tok, jnp.int32(p))
        out.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    toks = (max_len - 1) * B
    print(f"generated {len(out)} steps x {B} seqs "
          f"({toks / dt:.1f} tok/s incl. prefill-by-decode)")
    print("sample:", np.stack(out, 1)[0][:16])


if __name__ == "__main__":
    main()
