"""Elastic re-meshing: rebuild the mesh from the devices that remain and
re-place a checkpointed state onto it.

Policy: keep the "model" axis fixed (TP degree is baked into layouts and
kernel block shapes) and shrink the data-parallel axes to the largest
multiple that still divides the surviving device count — the standard
elastic-DP design.  Re-placement itself is just jax.device_put with the new
NamedShardings (the checkpoint format is topology-free, see
checkpoint.store).
"""
from __future__ import annotations

import jax

from repro.launch.mesh import make_mesh


def plan_mesh(n_devices: int, model_size: int = 16,
              prefer_pods: bool = True) -> tuple[tuple, tuple]:
    """Largest (pod, data, model) grid with the fixed model axis."""
    if n_devices < model_size:
        raise ValueError(
            f"cannot keep TP={model_size} with only {n_devices} devices")
    dp = n_devices // model_size
    if prefer_pods and dp % 2 == 0 and dp >= 32:
        return (2, dp // 2, model_size), ("pod", "data", "model")
    return (dp, model_size), ("data", "model")


def remesh(available_devices=None, model_size: int = 16):
    devs = available_devices if available_devices is not None else jax.devices()
    shape, axes = plan_mesh(len(devs), model_size)
    import numpy as np

    grid = np.asarray(devs)[:int(np.prod(shape))].reshape(shape)
    return jax.sharding.Mesh(grid, axes)


def reshard_state(state, specs, new_mesh):
    from repro.launch.shard import named

    shardings = named(specs, new_mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
