"""Fault-tolerance plumbing: heartbeats, straggler detection, retry driver.

At thousand-node scale the failure model is: (a) hosts vanish (heartbeat
timeout -> elastic re-mesh + checkpoint restore), (b) hosts straggle
(step-time outliers -> flagged for replacement before they stall the
collectives).  Both detectors are deterministic pure-python so they unit-test
on this container; the launcher (``run_with_retries``) is the driver loop a
cluster scheduler would call per-host.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict


@dataclasses.dataclass
class HostState:
    last_beat: float
    step_ema: float = 0.0
    beats: int = 0


class HeartbeatMonitor:
    def __init__(self, timeout_s: float = 60.0, clock=time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.hosts: dict[str, HostState] = {}

    def beat(self, host: str, step_time_s: float | None = None) -> None:
        now = self.clock()
        st = self.hosts.setdefault(host, HostState(last_beat=now))
        st.last_beat = now
        st.beats += 1
        if step_time_s is not None:
            a = 0.2 if st.step_ema else 1.0
            st.step_ema = (1 - a) * st.step_ema + a * step_time_s

    def forget(self, host: str) -> None:
        """Drop a host's state entirely.  An evicted replica must leave the
        fleet's statistics — its stale EWMA would otherwise skew the straggler
        median and its stale beat would keep re-reporting it dead."""
        self.hosts.pop(host, None)

    def dead(self) -> list[str]:
        now = self.clock()
        return [h for h, s in self.hosts.items()
                if now - s.last_beat > self.timeout]

    def stragglers(self, factor: float = 1.5) -> list[str]:
        """Hosts whose step-time EWMA exceeds factor x the fleet median."""
        emas = sorted(s.step_ema for s in self.hosts.values() if s.step_ema)
        if len(emas) < 3:
            return []
        median = emas[len(emas) // 2]
        return [h for h, s in self.hosts.items()
                if s.step_ema > factor * median]


class RetryPolicy:
    def __init__(self, max_restarts: int = 10, window_s: float = 3600.0,
                 clock=time.monotonic):
        self.max_restarts, self.window = max_restarts, window_s
        self.clock = clock
        self.restarts: list[float] = []

    def should_retry(self) -> bool:
        now = self.clock()
        self.restarts = [t for t in self.restarts if now - t < self.window]
        return len(self.restarts) < self.max_restarts

    def record(self) -> None:
        self.restarts.append(self.clock())


def run_with_retries(make_state, run_fn, ckpt_store, policy: RetryPolicy,
                     abstract_state, shardings=None):
    """Launcher loop: run -> on failure restore latest checkpoint -> retry.

    ``run_fn(state, start_step) -> (state, completed)`` raises on failure.
    """
    restored = ckpt_store.restore_latest(abstract_state, shardings)
    state, start = restored if restored is not None else (make_state(), 0)
    while True:
        try:
            return run_fn(state, start)
        except Exception:
            if not policy.should_retry():
                raise
            policy.record()
            restored = ckpt_store.restore_latest(abstract_state, shardings)
            state, start = (restored if restored is not None
                            else (make_state(), 0))
