"""Compile-decision provenance: explainable reports and plan diffs.

DNNVM's value proposition is *search* — fusion templates enumerated by
subgraph isomorphism, strategies picked by shortest path, tile shapes picked
by measured search, DDR regions packed by liveness — and this package makes
every one of those decisions inspectable after the fact:

- :func:`build_report` assembles the structured ``CompileReport`` at the
  ``Compiled`` stage (called by ``asm.assemble_artifact``; embedded in every
  v5 artifact);
- :func:`report_of` returns an artifact's embedded report, or a degraded
  reconstruction for pre-v5 artifacts (never crashes on old files);
- :func:`diff` / :func:`diff_artifacts` compute the structural + cost diff of
  two plans — the audit record the continuous-autotuning hot-swap loop emits;
- :func:`render_report` / :func:`render_diff` are the deterministic text
  renderers behind ``python -m repro.explain``.

Runtime surfaces: ``Session.explain()`` joins the static report with live
drift samples; ``ObsHTTPServer`` serves ``/explain/<model>``; the event log
carries ``explain.report`` / ``plan.diff`` events.
"""
from repro.explain.diff import diff, diff_artifacts, diff_reports, negate
from repro.explain.render import render_diff, render_report
from repro.explain.report import (REPORT_VERSION, build_report, report_of,
                                  validate_report)

__all__ = [
    "REPORT_VERSION", "build_report", "report_of", "validate_report",
    "diff", "diff_artifacts", "diff_reports", "negate",
    "render_report", "render_diff",
]
