"""Deterministic text rendering of CompileReports and plan diffs.

Formatting is fixed-precision and sorted everywhere (no dict-order or
locale dependence): the golden-output test in tests/test_explain.py diffs
this renderer's output byte-for-byte against a committed expectation, so
cosmetic changes here are schema changes — update the golden with intent.
"""
from __future__ import annotations


def _us(x) -> str:
    """Seconds -> fixed-precision microseconds ('-' for unknown)."""
    return "-" if x is None else f"{float(x) * 1e6:.2f}us"


def _kb(n) -> str:
    return f"{int(n) / 1024.0:.1f}KiB"


def _shape(t) -> str:
    return "default" if not t else "x".join(str(int(v)) for v in t)


def _pct(x) -> str:
    return "-" if x is None else f"{float(x) * 100.0:.0f}%"


def render_report(rep: dict, *, drift: list | None = None,
                  max_rows: int = 64) -> str:
    """Render one CompileReport as an aligned text document.

    ``drift`` (optional) is the live measured-vs-predicted join produced by
    ``Session.explain()`` — rendered as an extra section when present."""
    L: list[str] = []
    prof = rep.get("profile_name") or rep.get("profile_hash") or "analytic"
    L.append(f"== compile report: {rep['model']} on {rep['device']} "
             f"[{rep.get('evaluator') or 'unknown'} / {prof}]"
             f"{' (degraded: pre-v5 artifact)' if rep.get('degraded') else ''}"
             " ==")
    if rep.get("total_cost_s") is not None:
        L.append(f"predicted e2e cost: {_us(rep['total_cost_s'])}")

    fu = rep["fusion"]
    L.append("")
    L.append(f"-- fusion: {fu['n_groups']} chain + {fu['n_horizontal']} "
             f"horizontal groups, coverage {_pct(fu.get('coverage'))}, "
             f"{len(fu['fallbacks'])} fallbacks")
    for grp in fu["groups"][:max_rows]:
        tag = "horiz" if grp["kind"] == "horizontal" else "chain"
        cost = _us(grp.get("cost_s"))
        ana = grp.get("analytic_cost_s")
        vs = ("" if ana is None or grp.get("cost_s") is None
              or ana == grp.get("cost_s")
              else f" (analytic {_us(ana)})")
        L.append(f"  [{tag}] {grp['key']}  cost {cost}{vs}  "
                 f"tile {_shape(grp.get('tile'))}")
    if len(fu["groups"]) > max_rows:
        L.append(f"  ... {len(fu['groups']) - max_rows} more groups")
    for fb in fu["fallbacks"][:max_rows]:
        L.append(f"  [fallback] {'|'.join(fb['nodes'])}  "
                 f"reason={fb['reason']}")

    search = fu.get("search")
    if search:
        L.append("")
        rejected: dict[str, int] = {}
        for ch in search.get("chains", []):
            for why, n in (ch.get("n_rejected") or {}).items():
                rejected[why] = rejected.get(why, 0) + n
        rej = (", ".join(f"{k}={v}" for k, v in sorted(rejected.items()))
               or "none")
        L.append(f"-- search: {search.get('n_chains', 0)} chains, "
                 f"{search.get('n_fusable_pairs', 0)} fusable pairs, "
                 f"rejected: {rej}")
        tmpl = ", ".join(f"{k}={v}" for k, v in
                         sorted((search.get("templates") or {}).items()))
        if tmpl:
            L.append(f"  templates: {tmpl}")
        rows = 0
        for ch in search.get("chains", []):
            for alt in ch.get("alternatives", []):
                if rows >= max_rows:
                    break
                L.append(f"  [not chosen] {'|'.join(alt['nodes'])}  "
                         f"cost {_us(alt.get('cost_s'))}")
                rows += 1
        for ch in search.get("chains", []):
            for ex in ch.get("rejected_examples", [])[:2]:
                if rows >= max_rows:
                    break
                L.append(f"  [rejected] {'|'.join(ex['nodes'])}  "
                         f"reason={ex['reason']}")
                rows += 1
        for ew in search.get("eltwise_absorb", []):
            word = (f"absorbed into {ew['into']}" if ew.get("absorbed")
                    else "kept standalone")
            L.append(f"  [eltwise] {ew['eltwise']}: {word} "
                     f"(delta {_us(ew.get('delta_s'))})")
        for hz in search.get("horizontal", []):
            word = "fused" if hz.get("fused") else "split"
            detail = (f" ({_us(hz.get('with_tails_cost_s'))} vs split "
                      f"{_us(hz.get('split_cost_s'))})"
                      if hz.get("split_cost_s") is not None else
                      f" ({hz.get('reason', '')})")
            L.append(f"  [horizontal] {'+'.join(hz['heads'])}: "
                     f"{word}{detail}")

    ti = rep["tiles"]
    L.append("")
    L.append(f"-- tiles: source={ti.get('source') or 'default'}, "
             f"{ti['n_tuned']}/{ti['n_units']} units tuned")
    for unit in ti["leaderboard"][:max_rows]:
        key = unit.get("key") or "|".join(unit.get("nodes", []))
        chosen = unit.get("chosen")
        L.append(f"  {key}  chosen={_shape(chosen)} "
                 f"(default {_shape(unit.get('default'))})")
        for cand in unit.get("candidates", []):
            mark = "*" if (cand.get("shape") == chosen
                           or (chosen is None and cand.get("default"))) \
                else " "
            meas = _us(cand.get("measured"))
            pred = _us(cand.get("predicted"))
            L.append(f"   {mark} {_shape(cand.get('shape'))}"
                     f"{' [default]' if cand.get('default') else ''}  "
                     f"measured {meas}  predicted {pred}")

    me = rep["memory"]
    L.append("")
    L.append(f"-- memory: peak {_kb(me['peak_bytes'])} "
             f"(no-reuse {_kb(me['no_reuse_bytes'])}, "
             f"reuse x{float(me['reuse_factor']):.2f}"
             f"{', pinned input' if me.get('pin_input') else ''})")
    for reg in me["regions"][:max_rows]:
        reuse = (f"  reuses {','.join(reg['reuses'])}" if reg.get("reuses")
                 else "")
        L.append(f"  0x{int(reg['offset']):08x}  {_kb(reg['bytes']):>10}  "
                 f"{reg['buffer']}{reuse}")
    if me["n_regions"] > max_rows:
        L.append(f"  ... {me['n_regions'] - max_rows} more regions")
    elif not me["regions"]:
        L.append("  (DDR map not serialized in this artifact version)")
    pp = sum(1 for b in me["banks"] if b.get("n_in", 1) == 2)
    L.append(f"  banks: {pp}/{len(me['banks'])} groups ping/pong "
             f"double-buffered")

    sc = rep["schedule"]
    L.append("")
    engines = ", ".join(f"{k}={v}" for k, v in sorted(sc["engines"].items()))
    L.append(f"-- schedule: {sc['n_instrs']} instrs "
             f"({engines}), {sc['sim_total_cycles']} simulated cycles")

    if drift is not None:
        L.append("")
        L.append(f"-- live drift: {len(drift)} units sampled")
        for u in drift[:max_rows]:
            L.append(f"  {u['key']}  predicted {_us(u.get('predicted'))}  "
                     f"measured {_us(u.get('measured'))}  "
                     f"deviation {_pct(u.get('deviation'))} "
                     f"(n={u.get('n_samples', 0)})")
    return "\n".join(L) + "\n"


def render_diff(d: dict, *, max_rows: int = 64) -> str:
    L: list[str] = []
    L.append(f"== plan diff: {d['models']['a']} (a) vs "
             f"{d['models']['b']} (b) ==")
    if d["identical"]:
        L.append("plans are identical")
        return "\n".join(L) + "\n"

    fu = d["fusion"]
    if fu["only_a"] or fu["only_b"]:
        L.append("")
        L.append(f"-- fusion changed: {len(fu['only_a'])} groups only in a, "
                 f"{len(fu['only_b'])} only in b")
        for key in fu["only_a"][:max_rows]:
            L.append(f"  - {key}")
        for key in fu["only_b"][:max_rows]:
            L.append(f"  + {key}")

    ti = d["tiles"]
    if ti["changed"]:
        L.append("")
        L.append(f"-- tiles changed: {ti['n_changed']} units")
        for c in ti["changed"][:max_rows]:
            delta = c.get("predicted_delta_s")
            word = ("" if delta is None else
                    f"  predicted {_us(c.get('predicted_a_s'))} -> "
                    f"{_us(c.get('predicted_b_s'))} "
                    f"({'+' if delta >= 0 else ''}{delta * 1e6:.2f}us)")
            L.append(f"  {c['key']}  {_shape(c.get('a'))} -> "
                     f"{_shape(c.get('b'))}{word}")

    L.append("")
    me, sc, co = d["memory"], d["schedule"], d["cost"]
    L.append(f"-- memory: peak {_kb(me['peak_bytes']['a'])} -> "
             f"{_kb(me['peak_bytes']['b'])}")
    L.append(f"-- schedule: {sc['sim_total_cycles']['a']} -> "
             f"{sc['sim_total_cycles']['b']} simulated cycles, "
             f"{sc['n_instrs']['a']} -> {sc['n_instrs']['b']} instrs")
    total = co["total_cost_s"]
    if total["a"] or total["b"]:
        L.append(f"-- predicted e2e: {_us(total['a'])} -> "
                 f"{_us(total['b'])}")
    return "\n".join(L) + "\n"
