"""CLI: render a compiled artifact's decision provenance.

    python -m repro.explain artifact.npz                 # text report
    python -m repro.explain artifact.npz --format json   # machine-readable
    python -m repro.explain a.npz --diff b.npz           # what changed a->b

Works on any loadable artifact version: pre-v5 object files render a
degraded report (structure + schedule, no search trace or DDR map) instead
of failing.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.explain",
        description="Explain a compiled artifact's decisions "
                    "(fusion, tiles, memory, schedule) or diff two plans.")
    ap.add_argument("artifact", help="path to a compiled .npz artifact")
    ap.add_argument("--diff", metavar="OTHER",
                    help="second artifact: report what changed "
                         "artifact -> OTHER instead of rendering the report")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    args = ap.parse_args(argv)

    from repro.asm import load_artifact
    from repro.explain import diff as plan_diff
    from repro.explain import render_diff, render_report, report_of
    from repro.obs.events import EVENTS

    art = load_artifact(args.artifact)
    if args.diff:
        other = load_artifact(args.diff)
        d = plan_diff(art, other)
        out = (json.dumps(d, indent=2, sort_keys=True)
               if args.format == "json" else render_diff(d))
    else:
        rep = report_of(art)
        EVENTS.emit("explain.report",
                    message=f"explain {rep['model']} ({args.artifact})",
                    model=rep["model"], device=rep["device"],
                    degraded=rep.get("degraded", False))
        out = (json.dumps(rep, indent=2, sort_keys=True)
               if args.format == "json" else render_report(rep))
    sys.stdout.write(out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
