"""The CompileReport: one structured record of every compile decision.

Assembled by :func:`build_report` at the point where graph, strategy, memory
plan, and lowered program are all simultaneously in hand (``asm.
assemble_artifact``), embedded into the artifact meta, and read back by
:func:`report_of` — which also reconstructs a *degraded* report for pre-v5
artifacts so ``explain`` never crashes on an old object file.

The report is plain JSON-native data (dicts / lists / scalars, no NaN/Inf):
it must survive the artifact's strict ``json.dumps`` round trip and the
``/explain/<model>`` HTTP route unchanged.  Schema stability is a contract
(``validate_report`` + tests/test_explain.py); grow it by adding keys, not by
renaming them, and bump :data:`REPORT_VERSION` when you do.
"""
from __future__ import annotations

from collections import Counter

REPORT_VERSION = 1

# (key, type(s)) pairs every report must carry — the stable schema surface.
_TOP_SCHEMA = {
    "report_version": int,
    "model": str,
    "device": str,
    "evaluator": (str, type(None)),
    "profile_hash": (str, type(None)),
    "profile_name": (str, type(None)),
    "degraded": bool,
    "fusion": dict,
    "tiles": dict,
    "memory": dict,
    "schedule": dict,
}
_FUSION_SCHEMA = {
    "n_groups": int,
    "n_horizontal": int,
    "coverage": (float, int, type(None)),
    "groups": list,
    "fallbacks": list,
    "search": (dict, type(None)),
}
_TILES_SCHEMA = {
    "source": (str, type(None)),
    "n_units": int,
    "n_tuned": int,
    "leaderboard": list,
}
_MEMORY_SCHEMA = {
    "peak_bytes": int,
    "no_reuse_bytes": int,
    "reuse_factor": (float, int),
    "pin_input": bool,
    "regions": list,
    "n_regions": int,
    "banks": list,
}
_SCHEDULE_SCHEMA = {
    "sim_total_cycles": int,
    "n_instrs": int,
    "engines": dict,
}

# DDR allocation map entries embedded per report (a 224px GoogLeNet plan has
# ~100 buffers; deeper synthetic graphs get the head of the map + a count).
MAX_REGIONS = 128


def _group_key(nodes) -> str:
    from repro.core.lower import tile_key
    return tile_key(list(nodes))


def build_report(g, strategy, dev, planres, program, *,
                 profile_hash: str | None = None,
                 profile_name: str | None = None) -> dict:
    """Assemble the CompileReport for one finished compilation."""
    trace = strategy.meta.get("search_trace")
    group_costs = (trace or {}).get("group_costs", {})
    tile_shapes = dict(strategy.meta.get("tile_shapes") or {})
    hset = {tuple(h) for h in strategy.horizontal}

    groups = []
    for grp in planres.items:
        key = _group_key(grp)
        costs = group_costs.get(key, {})
        groups.append({
            "key": key,
            "nodes": list(grp),
            "ops": [g.nodes[n].op for n in grp if n in g.nodes],
            "kind": "horizontal" if tuple(grp) in hset else "chain",
            "cost_s": costs.get("cost_s"),
            "analytic_cost_s": costs.get("analytic_cost_s"),
            "tile": tile_shapes.get(key),
        })

    fallbacks = []
    coverage = None
    if program is not None:
        coverage = program.meta.get("coverage")
        for fb in program.fallbacks():
            fallbacks.append({"nodes": list(fb.nodes), "reason": fb.reason,
                              "detail": fb.detail})

    provenance = (_bounded_provenance(strategy.meta.get("tile_provenance"))
                  or [])
    tiles = {
        "source": strategy.meta.get("tile_source"),
        "n_units": len(planres.items),
        "n_tuned": len(tile_shapes),
        "leaderboard": provenance,
    }

    plan = planres.plan
    regions = sorted(
        ({"buffer": name,
          "offset": int(pl.offset),
          "bytes": int(pl.interval.nbytes),
          "reserved_bytes": int(pl.size),
          "reuses": list(plan.ddr.reuses.get(name, []))}
         for name, pl in plan.ddr.placements.items()),
        key=lambda r: (r["offset"], r["buffer"]))
    banks = [{"key": _group_key(grp), "n_in": b.n_banks_in,
              "n_out": b.n_banks_out}
             for grp, b in zip(planres.items, plan.banks)]
    memory = {
        "peak_bytes": int(plan.peak_bytes),
        "no_reuse_bytes": int(plan.no_reuse_bytes),
        "reuse_factor": float(plan.reuse_factor),
        "pin_input": bool(planres.pin_input),
        "regions": regions[:MAX_REGIONS],
        "n_regions": len(regions),
        "banks": banks,
    }

    schedule = {
        "sim_total_cycles": int(planres.sim_total_cycles),
        "n_instrs": len(planres.instrs),
        "engines": dict(Counter(ins.engine for ins in planres.instrs)),
    }

    return {
        "report_version": REPORT_VERSION,
        "model": g.name,
        "device": dev.name,
        "evaluator": strategy.meta.get("evaluator") or (trace or {}).get(
            "evaluator"),
        "profile_hash": profile_hash,
        "profile_name": profile_name,
        "total_cost_s": getattr(strategy, "cost", None),
        "degraded": False,
        "fusion": {
            "n_groups": len(strategy.groups),
            "n_horizontal": len(strategy.horizontal),
            "coverage": coverage,
            "groups": groups,
            "fallbacks": fallbacks,
            "search": trace,
        },
        "tiles": tiles,
        "memory": memory,
        "schedule": schedule,
    }


def _bounded_provenance(prov):
    from repro.asm.artifact import bounded_tile_provenance
    return bounded_tile_provenance(prov)


def report_of(art) -> dict:
    """An artifact's CompileReport.

    v5 artifacts carry it verbatim; older artifacts (or plans compiled with
    reporting stripped) get a *degraded* reconstruction from what the object
    file alone can say — fusion structure, tile shapes, memory summary, and
    instruction schedule, but no search trace, no runner-up costs, and no DDR
    region map (the placements are not serialized pre-v5)."""
    rep = art.meta.get("compile_report")
    if rep:
        return rep

    tile_shapes = dict(art.meta.get("tile_shapes") or {})
    hset = {tuple(h) for h in art.horizontal}
    groups = [{
        "key": _group_key(grp),
        "nodes": list(grp),
        "ops": [],
        "kind": "horizontal" if tuple(grp) in hset else "chain",
        "cost_s": None,
        "analytic_cost_s": None,
        "tile": tile_shapes.get(_group_key(grp)),
    } for grp in art.exec_items]
    fallbacks = []
    coverage = None
    if art.program is not None:
        coverage = art.program.meta.get("coverage")
        fallbacks = [{"nodes": list(fb.nodes), "reason": fb.reason,
                      "detail": fb.detail} for fb in art.program.fallbacks()]
    ms = dict(art.mem_summary)
    banks = [{"key": _group_key(grp), "n_in": b.get("n_in", 1),
              "n_out": b.get("n_out", 1)}
             for grp, b in zip(art.exec_items, ms.get("banks") or [])]
    return {
        "report_version": REPORT_VERSION,
        "model": art.meta.get("graph_name") or "artifact",
        "device": art.device,
        "evaluator": art.meta.get("evaluator"),
        "profile_hash": art.meta.get("profile_hash"),
        "profile_name": art.meta.get("profile_name"),
        "total_cost_s": None,
        "degraded": True,
        "fusion": {
            "n_groups": len(art.groups),
            "n_horizontal": len(art.horizontal),
            "coverage": coverage,
            "groups": groups,
            "fallbacks": fallbacks,
            "search": art.meta.get("search_trace"),
        },
        "tiles": {
            "source": art.meta.get("tile_source"),
            "n_units": len(art.exec_items),
            "n_tuned": len(tile_shapes),
            "leaderboard": list(art.meta.get("tile_provenance") or []),
        },
        "memory": {
            "peak_bytes": int(ms.get("peak_bytes", 0)),
            "no_reuse_bytes": int(ms.get("no_reuse_bytes", 0)),
            "reuse_factor": float(ms.get("reuse_factor", 1.0)),
            "pin_input": bool(ms.get("pin_input", False)),
            "regions": [],
            "n_regions": 0,
            "banks": banks,
        },
        "schedule": {
            "sim_total_cycles": int(art.sim_total_cycles),
            "n_instrs": len(art.instrs),
            "engines": dict(Counter(ins.engine for ins in art.instrs)),
        },
    }


def validate_report(rep: dict) -> dict:
    """Assert the stable schema surface; returns ``rep`` for chaining.

    Raises ``ValueError`` naming the first offending key — used by the tests
    and the explain-smoke gate so accidental schema drift fails loudly."""
    def check(d, schema, where):
        if not isinstance(d, dict):
            raise ValueError(f"{where}: expected dict, got {type(d).__name__}")
        for key, types in schema.items():
            if key not in d:
                raise ValueError(f"{where}.{key}: missing")
            if not isinstance(d[key], types):
                raise ValueError(
                    f"{where}.{key}: expected {types}, got "
                    f"{type(d[key]).__name__}")

    check(rep, _TOP_SCHEMA, "report")
    if rep["report_version"] != REPORT_VERSION:
        raise ValueError(f"report.report_version: {rep['report_version']} != "
                         f"{REPORT_VERSION}")
    check(rep["fusion"], _FUSION_SCHEMA, "report.fusion")
    check(rep["tiles"], _TILES_SCHEMA, "report.tiles")
    check(rep["memory"], _MEMORY_SCHEMA, "report.memory")
    check(rep["schedule"], _SCHEDULE_SCHEMA, "report.schedule")
    for i, grp in enumerate(rep["fusion"]["groups"]):
        for key in ("key", "nodes", "kind"):
            if key not in grp:
                raise ValueError(f"report.fusion.groups[{i}].{key}: missing")
    for i, reg in enumerate(rep["memory"]["regions"]):
        for key in ("buffer", "offset", "bytes", "reuses"):
            if key not in reg:
                raise ValueError(f"report.memory.regions[{i}].{key}: missing")
    return rep
