"""Structural + cost diff of two compiled plans.

``diff(a, b)`` answers the hot-swap loop's audit question — *what changed
between the plan we were serving and the plan we just re-tuned?* — in
machine-readable form: fusion groups present on only one side, shared units
whose tile shape changed (with each side's own predicted seconds and the
predicted delta), and the memory / schedule / cost scalar deltas.

Contract (enforced by tests/test_explain.py):

- ``diff(a, a)`` is *empty*: ``identical`` is True and every changed-list is
  ``[]``, every scalar delta 0;
- antisymmetry: ``diff(a, b) == negate(diff(b, a))`` — the diff carries no
  information that depends on argument order beyond the a/b labelling.

Every computed diff is also emitted as a ``plan.diff`` event so the re-tune
loop leaves an audit trail in the event log.
"""
from __future__ import annotations

from repro.explain.report import report_of


def diff(a, b) -> dict:
    """Diff two artifacts or two reports (duck-typed: anything with ``.meta``
    is treated as an artifact and run through :func:`report_of`)."""
    ra = report_of(a) if hasattr(a, "meta") else a
    rb = report_of(b) if hasattr(b, "meta") else b
    return diff_reports(ra, rb)


def diff_artifacts(a, b) -> dict:
    return diff(a, b)


def _scalar(va, vb) -> dict:
    va = 0 if va is None else va
    vb = 0 if vb is None else vb
    return {"a": va, "b": vb, "delta": vb - va}


def _chosen_shape(grp: dict) -> tuple | None:
    t = grp.get("tile")
    return tuple(int(v) for v in t) if t else None


def _predicted_unit_seconds(rep: dict, key: str, shape: tuple | None):
    """This side's own predicted seconds for unit ``key`` at ``shape``: the
    matching tile-leaderboard candidate's measured (preferred) or predicted
    seconds; falls back to the search trace's group cost when the unit never
    entered the tile search."""
    for unit in rep["tiles"]["leaderboard"]:
        ukey = unit.get("key") or "|".join(unit.get("nodes", []))
        if ukey != key:
            continue
        default = tuple(int(v) for v in unit.get("default") or ()) or None
        want = shape if shape is not None else default
        for cand in unit.get("candidates", []):
            cshape = tuple(int(v) for v in cand.get("shape") or ()) or None
            if cshape == want or (want is None and cand.get("default")):
                for k in ("measured", "predicted"):
                    if cand.get(k) is not None:
                        return float(cand[k])
    for grp in rep["fusion"]["groups"]:
        if grp["key"] == key:
            return grp.get("cost_s")
    return None


def diff_reports(ra: dict, rb: dict) -> dict:
    keys_a = {grp["key"]: grp for grp in ra["fusion"]["groups"]}
    keys_b = {grp["key"]: grp for grp in rb["fusion"]["groups"]}
    only_a = sorted(set(keys_a) - set(keys_b))
    only_b = sorted(set(keys_b) - set(keys_a))

    changed = []
    for key in sorted(set(keys_a) & set(keys_b)):
        sa = _chosen_shape(keys_a[key])
        sb = _chosen_shape(keys_b[key])
        if sa == sb:
            continue
        pa = _predicted_unit_seconds(ra, key, sa)
        pb = _predicted_unit_seconds(rb, key, sb)
        changed.append({
            "key": key,
            "a": list(sa) if sa else None,
            "b": list(sb) if sb else None,
            "predicted_a_s": pa,
            "predicted_b_s": pb,
            "predicted_delta_s": (pb - pa
                                  if pa is not None and pb is not None
                                  else None),
        })

    out = {
        "models": {"a": ra["model"], "b": rb["model"]},
        "fusion": {
            "only_a": only_a,
            "only_b": only_b,
            "n_groups": _scalar(ra["fusion"]["n_groups"],
                                rb["fusion"]["n_groups"]),
            "n_horizontal": _scalar(ra["fusion"]["n_horizontal"],
                                    rb["fusion"]["n_horizontal"]),
        },
        "tiles": {"changed": changed, "n_changed": len(changed)},
        "memory": {
            "peak_bytes": _scalar(ra["memory"]["peak_bytes"],
                                  rb["memory"]["peak_bytes"]),
            "reuse_factor": _scalar(ra["memory"]["reuse_factor"],
                                    rb["memory"]["reuse_factor"]),
        },
        "schedule": {
            "sim_total_cycles": _scalar(ra["schedule"]["sim_total_cycles"],
                                        rb["schedule"]["sim_total_cycles"]),
            "n_instrs": _scalar(ra["schedule"]["n_instrs"],
                                rb["schedule"]["n_instrs"]),
        },
        "cost": {
            "total_cost_s": _scalar(ra.get("total_cost_s"),
                                    rb.get("total_cost_s")),
        },
    }
    out["identical"] = (not only_a and not only_b and not changed
                        and all(s["delta"] == 0 for s in (
                            out["memory"]["peak_bytes"],
                            out["schedule"]["sim_total_cycles"],
                            out["schedule"]["n_instrs"],
                            out["cost"]["total_cost_s"])))
    _emit(out)
    return out


def _emit(d: dict) -> None:
    from repro.obs.events import EVENTS
    EVENTS.emit(
        "plan.diff",
        message=(f"plan diff {d['models']['a']} vs {d['models']['b']}: "
                 f"{'identical' if d['identical'] else 'changed'} "
                 f"({d['tiles']['n_changed']} tiles, "
                 f"{len(d['fusion']['only_a']) + len(d['fusion']['only_b'])}"
                 f" fusion groups)"),
        severity="info",
        identical=d["identical"],
        n_tiles_changed=d["tiles"]["n_changed"],
        n_fusion_changed=(len(d["fusion"]["only_a"])
                          + len(d["fusion"]["only_b"])),
        cost_delta_s=d["cost"]["total_cost_s"]["delta"],
    )


def negate(d: dict) -> dict:
    """Mirror a diff: swap the a/b roles and negate every delta, such that
    ``negate(diff(b, a)) == diff(a, b)``."""
    def neg_scalar(s):
        return {"a": s["b"], "b": s["a"], "delta": -s["delta"]
                if s["delta"] != 0 else 0}

    changed = [{
        "key": c["key"],
        "a": c["b"], "b": c["a"],
        "predicted_a_s": c["predicted_b_s"],
        "predicted_b_s": c["predicted_a_s"],
        "predicted_delta_s": (-c["predicted_delta_s"]
                              if c["predicted_delta_s"] else
                              c["predicted_delta_s"]),
    } for c in d["tiles"]["changed"]]
    return {
        "models": {"a": d["models"]["b"], "b": d["models"]["a"]},
        "fusion": {
            "only_a": list(d["fusion"]["only_b"]),
            "only_b": list(d["fusion"]["only_a"]),
            "n_groups": neg_scalar(d["fusion"]["n_groups"]),
            "n_horizontal": neg_scalar(d["fusion"]["n_horizontal"]),
        },
        "tiles": {"changed": changed, "n_changed": len(changed)},
        "memory": {k: neg_scalar(v) for k, v in d["memory"].items()},
        "schedule": {k: neg_scalar(v) for k, v in d["schedule"].items()},
        "cost": {k: neg_scalar(v) for k, v in d["cost"].items()},
        "identical": d["identical"],
    }
