"""Assembler artifact layer (paper §3.2: "an assembler, a runtime supporter").

``compile_strategy`` lowers a path-searched execution strategy into an
addressed instruction stream (memory planner + ``core.isa``), audits it with
the simulator's hazard oracle, lowers the backend ``GroupProgram``
(``core.lower``: fused-launch descriptors + reasoned fallbacks), and packages
everything a runtime needs — instructions, program, execution groups,
quantization metadata, memory-plan summary — into a single serializable
:class:`CompiledArtifact` ("DNNVM object file", an npz, format v3).
``PLAN_CACHE`` memoizes compilation by (graph, device, strategy, quant) so
repeated serving requests reload plans instead of recompiling.
"""
from repro.asm.artifact import (
    ArtifactError,
    CompiledArtifact,
    PlanCache,
    PlanResult,
    PLAN_CACHE,
    assemble_artifact,
    compile_strategy,
    device_of_artifact,
    graph_signature,
    load_artifact,
    plan_strategy,
    quant_signature,
    save_artifact,
    strategy_signature,
)

__all__ = [
    "ArtifactError", "CompiledArtifact", "PlanCache", "PlanResult",
    "PLAN_CACHE",
    "assemble_artifact", "compile_strategy", "device_of_artifact",
    "graph_signature", "load_artifact", "plan_strategy", "quant_signature",
    "save_artifact", "strategy_signature",
]
