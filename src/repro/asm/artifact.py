"""The DNNVM object file: addressed instructions + plan + quantization.

A :class:`CompiledArtifact` is the end product of the compiler: the ordered
execution groups, the lowered :class:`~repro.core.lower.GroupProgram` (the
backend's executable: fused-launch descriptors + reasoned fallbacks), the
address-bearing instruction stream (DDR offsets, BRAM banks, dependency
bits), the memory-plan summary, and — when compiled from a quantized model —
the int8 weights/biases and radix positions.  It duck-types
``pathsearch.Strategy`` (``.groups`` / ``.horizontal`` / ``.meta``) so the
executor and validator consume it directly, and it serializes to a single
``.npz`` with :func:`save_artifact` / :func:`load_artifact` — the graph and
program ride along as JSON, so a loaded artifact is self-contained (no
recompilation, no re-quantization, no re-lowering).

``PlanCache`` keys compilations by (graph signature, device, strategy
signature, quantization fingerprint): the production-serving path compiles a
model once and every later request is a dictionary hit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import zipfile

import numpy as np

from repro.core import lower, simulator, tiling
from repro.core.cost import AnalyticEvaluator
from repro.core.isa import Instr, ENGINES, emit_strategy
from repro.core.pathsearch import order_groups
from repro.core.quantize import QuantizedModel
from repro.core.xgraph import XGraph
from repro.hw import DeviceModel, get_device
from repro.memory import MemoryPlanError, plan_memory

# v2: adds the lowered GroupProgram section (launch descriptors + reasoned
# fallbacks) — v1 artifacts predate compile-time lowering and cannot be
# dispatched without re-pattern-matching, so loading them is refused.
# v3: "avgpool_ceil" left the fallback vocabulary (ceil-extended avgpool now
# lowers to a fused launch) — a v2 program may carry that reason, which
# RefFallback would reject on deserialization, so v2 loads are refused too.
# v4: launches carry the searched tile shape (``FusedLaunch.tile``) and the
# artifact meta records ``tile_shapes``.  v3 artifacts load fine — a missing
# tile record means the kernel-heuristic shapes, exactly what v3 ran.
# v5: the artifact embeds its compile-decision provenance — meta carries the
# bounded pathsearch ``search_trace``, the tile-search ``tile_provenance``
# leaderboard (top-K candidates per unit), and the assembled
# ``compile_report`` (see ``repro.explain``).  v3/v4 artifacts load fine — a
# missing report just means ``explain`` degrades to what the plan alone says.
FORMAT_VERSION = 5
_LOADABLE_VERSIONS = (3, 4, FORMAT_VERSION)
_OPCODES = ("LOAD", "SAVE", "CONV", "POOL", "MISC", "END")
# attrs whose JSON lists must come back as tuples (XGraph convention)
_TUPLE_ATTRS = {"shape", "kernel", "stride", "dilation", "pad"}


# ------------------------------------------------------------------ signatures
def graph_signature(g: XGraph) -> str:
    """Stable content hash of the graph's structure, attrs and shapes."""
    payload = [(n.name, n.op, list(n.inputs), _safe_attrs(n.attrs),
                list(g.shape(n.name))) for n in g]
    return _sha(payload)


def strategy_signature(strategy) -> str:
    # tile_shapes are part of the identity: the same group partition with
    # different searched tile shapes compiles to a different program (and a
    # different bank plan), so it must not hit the same cache entry.
    tiles = strategy.meta.get("tile_shapes") or {}
    return _sha({"groups": list(strategy.groups),
                 "horizontal": list(strategy.horizontal),
                 "host": sorted(strategy.meta.get("host_nodes", [])),
                 "tiles": {k: list(v) for k, v in sorted(tiles.items())}})


def quant_signature(qm: QuantizedModel | None) -> str:
    if qm is None:
        return "noquant"
    # Radix positions plus a strided per-tensor digest: radix positions alone
    # are not injective over weights (a fine-tune can keep every f_w), and
    # hashing full hundred-MB weight sets on every cache lookup is too slow —
    # shape + int sum + ~1K sampled elements per tensor distinguishes any
    # realistic weight update at microsecond cost.
    digests = {}
    for name in sorted(qm.weights):
        w = np.asarray(qm.weights[name])
        flat = w.ravel()
        sample = flat[::max(1, flat.size // 1024)]
        digests[name] = [list(w.shape), str(w.dtype), int(flat.sum(dtype=np.int64)),
                         hashlib.sha256(sample.tobytes()).hexdigest()[:12]]
    return _sha({"f_a": dict(sorted(qm.f_a.items())),
                 "f_w": dict(sorted(qm.f_w.items())),
                 "w": digests})


def _sha(obj) -> str:
    return hashlib.sha256(
        json.dumps(obj, sort_keys=True, default=str).encode()).hexdigest()[:16]


def _profile_hash(profile) -> str | None:
    """DeviceProfile | raw hash string | None -> hash string | None (duck-
    typed so asm never imports tune)."""
    if profile is None or isinstance(profile, str):
        return profile
    return profile.hash()


def _resolve_provenance(strategy, profile_hash, pin_input) -> tuple:
    """Normalize (profile hash, pin_input) for cache keys and compilation.

    Explicit arguments win; otherwise they are inherited from the strategy
    itself — a searched ``Strategy`` carries ``meta['profile_hash']`` when a
    profile-guided evaluator picked it, and a ``CompiledArtifact`` (which
    duck-types Strategy) carries both from its own compilation — so loaded
    artifacts re-key identically to the compilations that produced them."""
    if profile_hash is None:
        meta = getattr(strategy, "meta", None)
        if isinstance(meta, dict):
            profile_hash = meta.get("profile_hash")
    if pin_input is None:
        ms = getattr(strategy, "mem_summary", None)
        pin_input = bool(ms.get("pin_input")) if isinstance(ms, dict) else False
    return profile_hash, bool(pin_input)


def _safe_attrs(attrs: dict) -> dict:
    """JSON-serializable attr subset; folded-intrinsic parameter blobs are
    dropped (their numeric effect already lives in the quantized weights)."""
    out = {}
    for k, v in attrs.items():
        if k == "folded_intrinsics":
            continue
        if isinstance(v, (list, tuple)):
            v = [int(x) if isinstance(x, (int, np.integer)) else x for x in v]
        elif isinstance(v, (np.integer,)):
            v = int(v)
        elif isinstance(v, (np.floating,)):
            v = float(v)
        elif not isinstance(v, (str, int, float, bool, type(None))):
            continue
        out[k] = v
    return out


def _untuple(k, v):
    if isinstance(v, list) and (k in _TUPLE_ATTRS or
                                all(isinstance(x, int) for x in v)):
        return tuple(v)
    return v


# --------------------------------------------------------------- provenance
# Bounds on the tile-search leaderboard persisted into the artifact: the full
# provenance can carry every enumerated candidate of every unit; the artifact
# keeps the default plus the best few per unit (enough to explain the choice)
# for a bounded number of units.
TILE_PROVENANCE_MAX_UNITS = 128
TILE_PROVENANCE_MAX_CANDIDATES = 8


def json_sanitize(v):
    """Recursive coercion to JSON-native types (numpy scalars to Python,
    tuples to lists, non-finite floats to None) so ``save_artifact``'s strict
    ``json.dumps`` round trip never chokes on provenance payloads."""
    import math

    if isinstance(v, dict):
        return {str(k): json_sanitize(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [json_sanitize(x) for x in v]
    if isinstance(v, (bool, str, type(None))):
        return v
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        f = float(v)
        return f if math.isfinite(f) else None
    return str(v)


def bounded_tile_provenance(prov, *,
                            max_units: int = TILE_PROVENANCE_MAX_UNITS,
                            max_candidates: int = TILE_PROVENANCE_MAX_CANDIDATES
                            ) -> list | None:
    """Bound the tune.tiles leaderboard for artifact embedding: per unit keep
    the kernel default plus the best ``max_candidates - 1`` others (measured
    seconds when available, else predicted), recording how many candidates
    the search actually scored."""
    if not prov:
        return None

    def rank(c):
        for k in ("measured", "predicted"):
            if c.get(k) is not None:
                return float(c[k])
        return float("inf")

    out = []
    for entry in prov[:max_units]:
        e = dict(entry)
        cands = list(e.get("candidates") or [])
        defaults = [c for c in cands if c.get("default")]
        rest = sorted((c for c in cands if not c.get("default")), key=rank)
        e["candidates"] = defaults + rest[:max(0, max_candidates - len(defaults))]
        e["n_candidates"] = len(cands)
        out.append(e)
    return json_sanitize(out)


# -------------------------------------------------------------------- artifact
class ArtifactError(ValueError):
    """A persisted artifact could not be loaded: missing or truncated npz,
    garbage bytes, tampered/incomplete metadata, or an unloadable format
    version.  Subclasses :class:`ValueError` so pre-existing callers that
    guarded version mismatches with ``except ValueError`` keep working;
    new callers (the zoo, the fleet) catch this to distinguish a corrupt
    store entry from a programming error."""


@dataclasses.dataclass
class CompiledArtifact:
    graph_sig: str
    device: str
    groups: list                    # chain groups (Strategy duck-typing)
    horizontal: list
    meta: dict                      # incl. host_nodes
    exec_items: list                # ordered groups the instrs were emitted for
    instrs: list                    # list[Instr], addressed
    mem_summary: dict               # peak/no-reuse/reuse-factor/banks
    graph_nodes: list               # JSON-safe node records for rebuild
    f_a: dict
    f_w: dict
    weights: dict                   # node -> int8 ndarray ({} if planned w/o qm)
    biases: dict                    # node -> int32 ndarray
    sim_total_cycles: int = 0
    program: lower.GroupProgram | None = None   # lowered backend program

    @property
    def fused_coverage(self) -> float:
        return self.program.meta["coverage"] if self.program else 0.0

    @property
    def profile_hash(self) -> str | None:
        """Hash of the device profile this plan was searched/compiled under
        (None: the hand-written analytic model)."""
        return self.meta.get("profile_hash")

    @property
    def pin_input(self) -> bool:
        return bool(self.mem_summary.get("pin_input"))

    @property
    def tile_shapes(self) -> dict:
        """Searched per-launch tile shapes this plan was compiled with
        (tile_key -> (t_h, t_w, t_oc); {} = kernel-heuristic shapes)."""
        return dict(self.meta.get("tile_shapes") or {})

    @property
    def tile_provenance(self) -> list:
        """Bounded tile-search leaderboard (per-unit candidates with predicted
        / measured seconds); [] for pre-v5 artifacts or untuned plans."""
        return list(self.meta.get("tile_provenance") or [])

    @property
    def search_trace(self) -> dict | None:
        """Bounded pathsearch decision trace; None for pre-v5 artifacts."""
        return self.meta.get("search_trace")

    @property
    def report(self) -> dict | None:
        """The embedded CompileReport (see ``repro.explain``); None for
        pre-v5 artifacts — use ``repro.explain.report_of`` to get a degraded
        reconstruction instead of None."""
        return self.meta.get("compile_report")

    @property
    def peak_ddr_bytes(self) -> int:
        return self.mem_summary["peak_bytes"]

    @property
    def reuse_factor(self) -> float:
        return self.mem_summary["reuse_factor"]

    def quantized_model(self) -> QuantizedModel:
        needs_weights = any(nd["op"] in ("conv", "dilated_conv", "deconv",
                                         "depthwise_conv", "fc")
                            for nd in self.graph_nodes)
        if not self.weights and needs_weights:
            raise ValueError(
                "artifact was compiled without a QuantizedModel (plan-only); "
                "recompile with qm= to execute it")
        return QuantizedModel(dict(self.weights), dict(self.biases),
                              dict(self.f_w), dict(self.f_a))

    def rebuild_graph(self) -> XGraph:
        g = XGraph(self.meta.get("graph_name", "artifact"))
        for nd in self.graph_nodes:
            attrs = {k: _untuple(k, v) for k, v in nd["attrs"].items()}
            g.add(nd["op"], nd["name"], tuple(nd["inputs"]), **attrs)
        return g

    def executor(self, g: XGraph | None = None, backend: str = "ref"):
        from repro.core.executor import Int8Executor
        return Int8Executor(g if g is not None else self.rebuild_graph(),
                            self.quantized_model(), strategy=self,
                            backend=backend)

    def session(self, backend: str = "ref", **kw):
        """Open a runtime-supporter :class:`~repro.runtime.session.Session`
        on this artifact (seeds the plan cache; no recompilation)."""
        from repro.runtime import Session
        return Session.from_artifact(self, backend=backend, **kw)

    # ------------------------------------------------------------ round trip
    def save(self, path: str) -> None:
        """Persist as one DNNVM object file (see :func:`save_artifact`)."""
        save_artifact(self, path)

    @classmethod
    def load(cls, path: str) -> "CompiledArtifact":
        """Load a DNNVM object file; raises :class:`ArtifactError` on any
        corrupt/truncated/tampered input (see :func:`load_artifact`)."""
        return load_artifact(path)


# ----------------------------------------------------------------- compilation
@dataclasses.dataclass
class PlanResult:
    """Payload of the ``Planned`` compile stage (see ``repro.stages``): the
    ordered execution items, their solved tilings, the memory plan, the
    addressed instruction stream, and the simulator's hazard audit."""
    items: list                     # ordered groups the instrs were emitted for
    tilings: list                   # one GroupTiling per item
    plan: object                    # memory.MemoryPlan
    instrs: list                    # list[Instr], addressed
    mem_summary: dict               # peak/no-reuse/reuse-factor/banks
    sim_total_cycles: int
    pin_input: bool


def plan_strategy(g: XGraph, strategy, dev: DeviceModel, *,
                  pin_input: bool = False) -> PlanResult:
    """The memory-planning half of compilation: solve every group's tiling
    (searched shapes win over the analytic Eq. 5/6 defaults), plan DDR +
    bank layout, emit the addressed instruction stream, and hard-error on
    any memory hazard the simulator finds."""
    from repro.obs.trace import TRACER

    items = order_groups(g, [list(grp) for grp in strategy.groups] +
                         [list(h) for h in strategy.horizontal])
    hset = {tuple(h) for h in strategy.horizontal}
    ana = AnalyticEvaluator(g, dev)
    tile_shapes = dict(strategy.meta.get("tile_shapes") or {})
    tilings = []
    with TRACER.span("tiling", cat="compile", track="compile",
                     n_groups=len(items)):
        for grp in items:
            # A searched tile shape replaces the analytic Eq. 5/6 default, so
            # the bank planner charges the TRUE per-tile footprints of what
            # the kernel will actually execute (and the instruction stream
            # carries the true tile count).  A shape that does not fit the
            # device's buffers is a compile error, not a silent fallback.  A
            # horizontal unit's shapes are recorded per lowered LAUNCH; when
            # the unit's members split across several launches (mixed kernel
            # classes) the unit-level plan takes the stacked launch's shape
            # if there is exactly one — otherwise it keeps the analytic
            # default (one unit, one bank plan: there is no single true shape
            # to charge).
            shape = tile_shapes.get(lower.tile_key(grp))
            subset_shape = None
            if shape is None and tuple(grp) in hset:
                stacked = [it for it in
                           lower.lower_horizontal(g, None, list(grp))
                           if isinstance(it, lower.FusedLaunch)
                           and it.kind == "horizontal"]
                if len(stacked) == 1:
                    subset_shape = tile_shapes.get(
                        lower.tile_key(stacked[0].nodes))
            th, tw, toc = ((int(s) for s in (shape or subset_shape))
                           if (shape or subset_shape) else (None,) * 3)
            if tuple(grp) in hset:
                t = tiling.solve_horizontal(g, grp, dev, t_w=tw, t_h=th,
                                            t_oc=toc)
                if not t.feasible and subset_shape is not None:
                    # the subset shape was only proven feasible for the
                    # stacked launch's members — over the full unit it is
                    # best-effort, not a contract; fall back to the analytic
                    # unit plan
                    t = tiling.solve_horizontal(g, grp, dev)
            elif shape:
                t = tiling.solve_shape(g, grp, dev, t_w=tw, t_h=th, t_oc=toc)
            else:
                t = ana.cost(grp).tiling
            if not t.feasible:
                raise MemoryPlanError(f"group {grp} infeasible: {t.reason}")
            tilings.append(t)

    with TRACER.span("memory_plan", cat="compile", track="compile"):
        plan = plan_memory(g, items, tilings, dev, pin_input=pin_input)
    with TRACER.span("assemble", cat="compile", track="compile") as sp:
        instrs = emit_strategy(g, items, tilings, dev, plan=plan)
        sp.set(n_instrs=len(instrs))
    rep = simulator.check(instrs)   # hard-errors on any memory hazard
    mem_summary = plan.summary()
    mem_summary["banks"] = [
        {"n_in": b.n_banks_in, "n_out": b.n_banks_out} for b in plan.banks]
    return PlanResult(items=items, tilings=tilings, plan=plan, instrs=instrs,
                      mem_summary=mem_summary,
                      sim_total_cycles=rep.total_cycles,
                      pin_input=bool(pin_input))


def assemble_artifact(g: XGraph, strategy, dev: DeviceModel,
                      qm: QuantizedModel | None, planres: PlanResult,
                      program: lower.GroupProgram | None, *,
                      profile_hash: str | None = None,
                      profile_name: str | None = None) -> CompiledArtifact:
    """Package a planned + lowered compilation into the DNNVM object file."""
    tile_shapes = dict(strategy.meta.get("tile_shapes") or {})
    art = CompiledArtifact(
        graph_sig=graph_signature(g),
        device=dev.name,
        groups=[list(grp) for grp in strategy.groups],
        horizontal=[list(h) for h in strategy.horizontal],
        meta={"host_nodes": list(strategy.meta.get("host_nodes", [])),
              "graph_name": g.name,
              "profile_hash": profile_hash,
              "profile_name": (profile_name
                               or strategy.meta.get("profile_name")),
              # tile provenance: the artifact re-keys identically to the
              # strategy that produced it (strategy_signature hashes these)
              "tile_shapes": {k: list(v) for k, v in tile_shapes.items()},
              "tile_source": strategy.meta.get("tile_source"),
              # decision provenance (v5): the search's audit trace and the
              # tile-search leaderboard survive the npz round trip, so a
              # reopened artifact can still explain its own choices
              "search_trace": json_sanitize(
                  strategy.meta.get("search_trace")),
              "tile_provenance": bounded_tile_provenance(
                  strategy.meta.get("tile_provenance"))},
        exec_items=[list(grp) for grp in planres.items],
        instrs=planres.instrs,
        mem_summary=planres.mem_summary,
        graph_nodes=[{"name": n.name, "op": n.op, "inputs": list(n.inputs),
                      "attrs": _safe_attrs(n.attrs)} for n in g],
        f_a=dict(qm.f_a) if qm else {},
        f_w=dict(qm.f_w) if qm else {},
        weights={k: np.asarray(v) for k, v in qm.weights.items()} if qm else {},
        biases={k: np.asarray(v) for k, v in qm.biases.items()} if qm else {},
        sim_total_cycles=planres.sim_total_cycles,
        program=program)
    # The CompileReport (repro.explain) is assembled here — the one point
    # where graph, strategy, plan, and lowered program are all in hand — and
    # embedded so every artifact ships its own explanation.  Lazy import:
    # explain consumes asm types, not the other way around.
    from repro.explain.report import build_report

    art.meta["compile_report"] = json_sanitize(build_report(
        g, strategy, dev, planres, program,
        profile_hash=profile_hash,
        profile_name=profile_name or strategy.meta.get("profile_name")))
    return art


def compile_strategy(g: XGraph, strategy, dev: DeviceModel,
                     qm: QuantizedModel | None = None, *,
                     profile=None, pin_input: bool | None = None
                     ) -> CompiledArtifact:
    """Lower ``strategy`` to an addressed, hazard-checked artifact.

    Thin wrapper over the staged compile pipeline (``repro.stages``): the
    strategy is wrapped, lowered, planned, and compiled in explicit stages —
    callers that want partial recompiles or stage-level caching should use
    ``repro.stages`` directly; this entry point preserves the original
    one-call contract (no stage cache, identical output).

    ``profile`` (a ``tune.DeviceProfile``, its hash string, or None) is
    provenance: the artifact records which calibrated cost model planned it.
    ``pin_input`` keeps the network input's DDR region out of the planner's
    reuse pool (see ``memory.plan_memory``)."""
    from repro.stages import wrap

    profile_hash, pin_input = _resolve_provenance(strategy, _profile_hash(
        profile), pin_input)
    wrapped = wrap(g, qm, dev, cache=None)
    lowered = wrapped.lower(
        strategy=strategy,
        profile=profile if not isinstance(profile, str) else None,
        profile_hash=profile_hash, cache=None)
    return lowered.plan(pin_input=pin_input, cache=None) \
                  .compile(cache=None).artifact


# -------------------------------------------------------------- serialization
def save_artifact(art: CompiledArtifact, path: str) -> None:
    """One npz: instruction arrays + weight tensors + a JSON metadata block."""
    n = len(art.instrs)
    fields = np.zeros((n, 9), dtype=np.int64)
    deps_flat, deps_off = [], [0]
    tags = []
    for i, ins in enumerate(art.instrs):
        fields[i] = (ins.iid, ENGINES.index(ins.engine),
                     _OPCODES.index(ins.opcode), ins.cycles, ins.ddr_addr,
                     ins.ddr_len, ins.bank, ins.group_id, ins.tile)
        deps_flat.extend(ins.deps)
        deps_off.append(len(deps_flat))
        tags.append(ins.tag)
    meta = {
        "format_version": FORMAT_VERSION,
        "graph_sig": art.graph_sig,
        "device": art.device,
        "groups": art.groups,
        "horizontal": art.horizontal,
        "meta": art.meta,
        "exec_items": art.exec_items,
        "mem_summary": art.mem_summary,
        "graph_nodes": art.graph_nodes,
        "f_a": art.f_a,
        "f_w": art.f_w,
        "tags": tags,
        "sim_total_cycles": art.sim_total_cycles,
        "weight_nodes": sorted(art.weights),
        "bias_nodes": sorted(art.biases),
        "program": (lower.program_to_json(art.program)
                    if art.program is not None else None),
    }
    arrays = {
        "instr_fields": fields,
        "deps_flat": np.asarray(deps_flat, dtype=np.int64),
        "deps_off": np.asarray(deps_off, dtype=np.int64),
        "meta_json": np.asarray(json.dumps(meta)),
    }
    for k, w in art.weights.items():
        arrays[f"w::{k}"] = w
    for k, b in art.biases.items():
        arrays[f"b::{k}"] = b
    with open(path, "wb") as f:
        np.savez_compressed(f, **arrays)


def load_artifact(path: str) -> CompiledArtifact:
    """Load one DNNVM object file.

    Any way the file can be bad — not an npz at all, truncated mid-archive,
    a missing/garbled ``meta_json`` block, metadata referencing arrays that
    are not in the archive, or an unloadable format version — raises
    :class:`ArtifactError` naming the path and the cause, never a raw
    ``zipfile``/``KeyError``/decoder exception from the guts of the reader.
    ``FileNotFoundError`` stays ``FileNotFoundError`` (a missing file is an
    addressing mistake, not corruption)."""
    try:
        return _load_artifact(path)
    except (ArtifactError, FileNotFoundError, IsADirectoryError):
        raise
    except (zipfile.BadZipFile, KeyError, IndexError, TypeError, ValueError,
            EOFError, OSError, json.JSONDecodeError) as e:
        raise ArtifactError(
            f"corrupt artifact {path!r}: {type(e).__name__}: {e}") from e


def _load_artifact(path: str) -> CompiledArtifact:
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta_json"]))
        if not isinstance(meta, dict) or "format_version" not in meta:
            raise ArtifactError(
                f"corrupt artifact {path!r}: metadata block is not an "
                f"artifact header")
        if meta["format_version"] not in _LOADABLE_VERSIONS:
            raise ArtifactError(
                f"artifact {path!r}: format {meta['format_version']} not "
                f"in {_LOADABLE_VERSIONS}")
        fields = z["instr_fields"]
        deps_flat = z["deps_flat"]
        deps_off = z["deps_off"]
        instrs = []
        for i in range(fields.shape[0]):
            iid, eng, opc, cyc, addr, ln, bank, gid, tile = (
                int(x) for x in fields[i])
            deps = tuple(int(d) for d in
                         deps_flat[deps_off[i]:deps_off[i + 1]])
            instrs.append(Instr(iid, ENGINES[eng], _OPCODES[opc], cyc,
                                deps, tag=meta["tags"][i], ddr_addr=addr,
                                ddr_len=ln, bank=bank, group_id=gid,
                                tile=tile))
        weights = {k: z[f"w::{k}"] for k in meta["weight_nodes"]}
        # biases keyed independently: a weight node without a bias (or a
        # bias-only correction) must survive the round trip
        biases = {k: z[f"b::{k}"] for k in meta.get("bias_nodes",
                                                    meta["weight_nodes"])}
    program = (lower.program_from_json(meta["program"])
               if meta.get("program") is not None else None)
    return CompiledArtifact(
        graph_sig=meta["graph_sig"], device=meta["device"],
        groups=meta["groups"], horizontal=meta["horizontal"],
        meta=meta["meta"], exec_items=meta["exec_items"], instrs=instrs,
        mem_summary=meta["mem_summary"], graph_nodes=meta["graph_nodes"],
        f_a=meta["f_a"], f_w=meta["f_w"], weights=weights, biases=biases,
        sim_total_cycles=meta["sim_total_cycles"], program=program)


# ---------------------------------------------------------------- plan cache
class PlanCache:
    """In-process memoization of compiled artifacts.

    Keyed by (graph signature, device, strategy signature, quantization
    fingerprint) — the serving path's "have we compiled this before?".
    LRU-bounded: cached artifacts can pin large weight tensors, so a
    long-running server evicts the least-recently-used plan past
    ``maxsize`` instead of growing without bound."""

    def __init__(self, maxsize: int = 64, *, max_entries: int | None = None):
        self._store: dict[tuple, CompiledArtifact] = {}
        self.maxsize = max_entries if max_entries is not None else maxsize
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def max_entries(self) -> int:
        """Bound on resident plans (alias of ``maxsize``; a many-model server
        sets this through ``Session``/``MultiServer``)."""
        return self.maxsize

    @max_entries.setter
    def max_entries(self, n: int) -> None:
        if n < 1:
            raise ValueError("max_entries must be >= 1")
        self.maxsize = n
        self._shrink()

    def key(self, g: XGraph, strategy, dev: DeviceModel,
            qm: QuantizedModel | None = None, *, profile=None,
            pin_input: bool | None = None) -> tuple:
        ph, pi = _resolve_provenance(strategy, _profile_hash(profile),
                                     pin_input)
        return (graph_signature(g), dev.name, strategy_signature(strategy),
                quant_signature(qm), ph or "analytic", pi)

    def get_or_compile(self, g: XGraph, strategy, dev: DeviceModel,
                       qm: QuantizedModel | None = None, *, profile=None,
                       pin_input: bool | None = None
                       ) -> tuple[CompiledArtifact, bool]:
        ph, pi = _resolve_provenance(strategy, _profile_hash(profile),
                                     pin_input)
        k = self.key(g, strategy, dev, qm, profile=ph, pin_input=pi)
        from repro.obs.metrics import REGISTRY

        art = self._store.get(k)
        if art is not None:
            self._store[k] = self._store.pop(k)   # refresh LRU position
            self.hits += 1
            REGISTRY.counter("plan_cache.hits").inc()
            return art, True
        art = compile_strategy(g, strategy, dev, qm=qm,
                               profile=profile if profile is not None else ph,
                               pin_input=pi)
        self.misses += 1
        REGISTRY.counter("plan_cache.misses").inc()
        self._put(k, art)
        return art, False

    def put(self, g: XGraph, strategy, dev: DeviceModel, art: CompiledArtifact,
            qm: QuantizedModel | None = None, *, profile=None,
            pin_input: bool | None = None) -> None:
        """Seed a precompiled artifact (e.g. loaded from an object file) so
        later ``get_or_compile`` calls hit instead of recompiling."""
        self._put(self.key(g, strategy, dev, qm, profile=profile,
                           pin_input=pin_input), art)

    def _put(self, k: tuple, art: CompiledArtifact) -> None:
        self._store.pop(k, None)
        self._store[k] = art
        self._shrink()

    def _shrink(self) -> None:
        from repro.obs.events import EVENTS
        from repro.obs.metrics import REGISTRY

        dropped = 0
        while len(self._store) > self.maxsize:
            self._store.pop(next(iter(self._store)))
            self.evictions += 1
            dropped += 1
            REGISTRY.counter("plan_cache.evictions").inc()
        if dropped:
            EVENTS.emit("cache.evict", cache="plan_cache", n=dropped,
                        resident=len(self._store), bound=self.maxsize,
                        message=f"plan cache evicted {dropped} artifact"
                                f"{'' if dropped == 1 else 's'} "
                                f"(bound {self.maxsize})")

    def clear(self) -> None:
        self._store.clear()
        self.hits = self.misses = 0

    def __len__(self) -> int:
        return len(self._store)


PLAN_CACHE = PlanCache()


def device_of_artifact(art: CompiledArtifact) -> DeviceModel:
    return get_device(art.device)
