"""Deterministic, seekable synthetic data pipeline.

Every batch is a pure function of (seed, step) — so restarts resume
bit-identically from a checkpointed cursor, and each host slices its own
rows (per-host sharding for multi-host launches).  Token streams follow a
Zipfian-ish distribution with local n-gram structure so losses actually
decrease during the example runs (pure uniform noise would not train).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


class SyntheticLM:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 17,
                 family: str = "dense", d_model: int = 0, n_patches: int = 0,
                 host_index: int = 0, host_count: int = 1):
        assert batch % host_count == 0
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed, self.step = seed, 0
        self.family, self.d_model, self.n_patches = family, d_model, n_patches
        self.host_index, self.host_count = host_index, host_count

    # ------------------------------------------------------------- cursor
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def seek(self, step: int) -> None:
        self.step = int(step)

    # -------------------------------------------------------------- batches
    def _tokens(self, rng, rows: int, length: int) -> np.ndarray:
        # zipf-flavored marginals + shifted-copy structure => learnable
        z = rng.zipf(1.3, size=(rows, length)).astype(np.int64)
        t = z % self.vocab
        t[:, 1::2] = t[:, 0:-1:2]  # every odd position copies its neighbor
        return t.astype(np.int32)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index]))
        rows = self.batch // self.host_count
        if self.family == "audio":
            se = max(8, self.seq // 2)
            sd = self.seq - se
            toks = self._tokens(rng, rows, sd + 1)
            return {
                "frames": jnp.asarray(
                    rng.standard_normal((rows, se, self.d_model)), jnp.float32),
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
            }
        if self.family == "vlm" and self.n_patches:
            npat = min(self.n_patches, self.seq // 2)
            st = self.seq - npat
            toks = self._tokens(rng, rows, st + 1)
            return {
                "patch_embeds": jnp.asarray(
                    rng.standard_normal((rows, npat, self.d_model)), jnp.float32),
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
            }
        toks = self._tokens(rng, rows, self.seq + 1)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def next(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b
