"""Int8 gradient compression with error feedback (distributed-optimization
trick; paper-adjacent: the same 8-bit quantization philosophy applied to the
gradient all-reduce).

Two forms:

* ``quantize_ef`` — the pure transform: int8-quantize (per-leaf scale) with
  an error-feedback accumulator so the quantization error is re-injected
  next step (provably convergent for SGD-family under standard assumptions).
* ``compressed_psum`` — the shard_map building block: quantize local grads,
  all-reduce the int8 payload in int32, dequantize.  8x less ICI traffic
  than fp32 psum, 4x less than bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def quantize_ef(grads, err):
    """(grads, err) -> (dequantized grads, new err).  err pytree like grads."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)) / 127.0, 1e-12)
        q = _q(g32, scale)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, err)
    leaves, treedef = jax.tree_util.tree_flatten(
        out, is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree_util.tree_unflatten(treedef, [t[0] for t in leaves])
    new_err = jax.tree_util.tree_unflatten(treedef, [t[1] for t in leaves])
    return deq, new_err


def init_error(grads_abstract):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_abstract)


def compressed_psum(g, axis_name: str, err):
    """Inside shard_map: int8 all-reduce of one gradient leaf with error
    feedback.  Returns (mean gradient, new error).

    All shards must quantize against a SHARED scale or the int8 sum is
    meaningless; one scalar pmax fixes the codebook, then the int8 payload
    reduces in int32.  A real TPU lowering packs int8 on the wire: 4x less
    ICI traffic than fp32, 2x less than bf16 (plus one scalar)."""
    g32 = g.astype(jnp.float32) + err
    scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(g32)) / 127.0, 1e-12),
                         axis_name)
    q = _q(g32, scale)
    deq_local = q.astype(jnp.float32) * scale
    new_err = g32 - deq_local
    total = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = total * scale / n
    return mean.astype(g.dtype), new_err