"""AdamW with ZeRO-1-shardable moments and configurable storage dtypes.

Moments are stored in ``moment_dtype`` (bf16 by default at 100B+ scale —
the memory receipt that lets llama3-405b train on one v5e pod, see
EXPERIMENTS.md §Dry-run) and promoted to fp32 for the update math.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "bfloat16"
    warmup_steps: int = 100


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()):
    return init_moments(params, cfg)


def init_moments(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mh, vh = m32 / bc1, v32 / bc2
        step_ = mh * jax.lax.rsqrt(vh + cfg.eps * cfg.eps)  # ~m/(sqrt(v)+eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step_ + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    leaves, treedef = jax.tree_util.tree_flatten(
        out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree_util.tree_unflatten(treedef, [t[0] for t in leaves])
    new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in leaves])
    new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in leaves])
    return new_p, {"m": new_m, "v": new_v, "step": step}
