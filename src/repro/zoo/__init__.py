"""Content-addressed on-disk store of compiled model artifacts."""
from repro.zoo.store import ModelZoo

__all__ = ["ModelZoo"]
