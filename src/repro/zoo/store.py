"""Content-addressed on-disk model zoo for multi-tenant serving.

A production server holds MANY compiled nets (vgg16 + resnet50 + googlenet
at several resolutions); the zoo is where their object files live between
processes.  It generalizes the two persistence idioms the repo already has —
the artifact npz (``asm.save_artifact``) and the on-disk ``tune.ProfileCache``
— into one store:

* **content-addressed**: every artifact is keyed by its ``Compiled`` stage
  hash (graph + quantization + device + strategy signature + profile hash +
  pin_input + artifact format version), so identical compilations share one
  file and a key can never name stale bytes;
* **source-indexed**: each entry also records the *source* fingerprint of
  the pipeline inputs that produced it (``stages.source_key``), so a reopen
  finds the artifact before any search runs;
* **atomic**: npz + sidecar JSON are written to a temp name and
  ``os.replace``d — a crashed writer leaves no half-entry visible;
* **cross-process safe**: writers (put / evict / remove, and get's index
  refresh) serialize on an advisory ``flock`` over ``<root>/.lock``, so
  concurrent processes shelving into one zoo cannot interleave a
  read-modify-write of the sidecar index or evict an entry mid-put;
* **corruption-hardened**: a truncated or garbage npz, or a sidecar whose
  recorded key disagrees with its filename, raises a clear
  :class:`~repro.asm.artifact.ArtifactError` naming the entry — never a raw
  ``zipfile``/``KeyError`` from the reader's guts;
* **bounded**: ``evict`` trims least-recently-*used* entries past
  ``max_entries`` / ``max_bytes`` (both optional), mirroring ``PlanCache``'s
  LRU discipline on disk.

Layout: ``<root>/<key>.npz`` (the object file) + ``<root>/<key>.json`` (the
index record).  Default root: ``$DNNVM_ZOO`` or ``~/.cache/dnnvm/zoo``.
"""
from __future__ import annotations

import contextlib
import json
import os
import time

from repro import asm

try:                                    # POSIX advisory locking; the zoo
    import fcntl                        # degrades to in-process-only safety
except ImportError:                     # where it's unavailable
    fcntl = None


def _registry():
    from repro.obs.metrics import REGISTRY
    return REGISTRY


def _events():
    from repro.obs.events import EVENTS
    return EVENTS


class ModelZoo:
    def __init__(self, root: str | None = None, *,
                 max_entries: int | None = None,
                 max_bytes: int | None = None):
        self.root = root or os.environ.get("DNNVM_ZOO") or \
            os.path.join(os.path.expanduser("~"), ".cache", "dnnvm", "zoo")
        self.max_entries = max_entries
        self.max_bytes = max_bytes

    # ------------------------------------------------------------- identity
    @staticmethod
    def key_for(art) -> str:
        """Content address of an artifact (its ``Compiled`` stage hash)."""
        from repro.stages import artifact_stage_keys
        return artifact_stage_keys(art)["compiled"]

    def _npz(self, key: str) -> str:
        return os.path.join(self.root, key + ".npz")

    def _meta(self, key: str) -> str:
        return os.path.join(self.root, key + ".json")

    # --------------------------------------------------------------- locking
    @contextlib.contextmanager
    def _locked(self):
        """Advisory cross-process writer lock over the whole store
        (``flock`` on ``<root>/.lock``).  NOT re-entrant — internal callers
        already under the lock use the ``_evict``/``_remove`` forms; a second
        ``flock`` on a fresh fd of the same file would deadlock the
        process against itself."""
        os.makedirs(self.root, exist_ok=True)
        if fcntl is None:
            yield
            return
        with open(os.path.join(self.root, ".lock"), "a+") as fh:
            fcntl.flock(fh.fileno(), fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(fh.fileno(), fcntl.LOCK_UN)

    # ---------------------------------------------------------------- write
    def put(self, art, *, name: str | None = None,
            source_key: str | None = None) -> str:
        """Shelve an artifact under its content address (atomic; idempotent —
        re-putting existing content only refreshes the index record;
        concurrent writers serialize on the store lock)."""
        key = self.key_for(art)
        with self._locked():
            npz = self._npz(key)
            fresh = not os.path.exists(npz)
            if fresh:
                tmp = npz + f".tmp-{os.getpid()}"
                try:
                    asm.save_artifact(art, tmp)
                    os.replace(tmp, npz)
                finally:
                    if os.path.exists(tmp):
                        os.unlink(tmp)
            rec = self._read_meta(key) or {
                "key": key, "created": time.time(), "n_opens": 0}
            rec.update({
                "name": name or rec.get("name") or art.meta.get("graph_name"),
                "graph_name": art.meta.get("graph_name"),
                "device": art.device,
                "format_version": asm.artifact.FORMAT_VERSION,
                "profile_hash": art.profile_hash,
                "pin_input": art.pin_input,
                "fused_coverage": art.fused_coverage,
                "peak_ddr_bytes": art.peak_ddr_bytes,
                "size_bytes": os.path.getsize(npz),
                "last_used": time.time(),
            })
            if source_key:
                sources = set(rec.get("source_keys") or [])
                sources.add(source_key)
                rec["source_keys"] = sorted(sources)
            self._write_meta(key, rec)
            _registry().counter("zoo.puts").inc()
            if fresh:
                _events().emit("zoo.put", key=key[:16], model=name,
                               size_bytes=rec["size_bytes"],
                               message=f"shelved {name or key[:16]} "
                                       f"({rec['size_bytes']} B)")
                self._evict()
        return key

    # ----------------------------------------------------------------- read
    def get(self, key: str):
        """Load one artifact by content address (None on a miss; a resident
        but corrupt/tampered entry raises
        :class:`~repro.asm.artifact.ArtifactError` naming the entry)."""
        npz = self._npz(key)
        if not os.path.exists(npz):
            _registry().counter("zoo.misses").inc()
            return None
        rec = self._read_meta(key)
        if rec is not None and rec.get("key") not in (None, key):
            _registry().counter("zoo.corrupt").inc()
            raise asm.ArtifactError(
                f"zoo entry {key!r} under {self.root!r}: sidecar records key "
                f"{rec.get('key')!r} — tampered or misplaced index record")
        try:
            art = asm.load_artifact(npz)
        except FileNotFoundError:        # concurrently evicted between the
            _registry().counter("zoo.misses").inc()   # exists check + read
            return None
        except asm.ArtifactError as e:
            _registry().counter("zoo.corrupt").inc()
            _events().emit("zoo.corrupt", severity="error", key=key[:16],
                           message=f"zoo entry {key[:16]} is corrupt: {e}")
            raise asm.ArtifactError(
                f"zoo entry {key!r} under {self.root!r} is corrupt "
                f"(remove it with ModelZoo.remove): {e}") from e
        with self._locked():
            rec = self._read_meta(key)
            if rec is not None:
                rec["last_used"] = time.time()
                rec["n_opens"] = int(rec.get("n_opens", 0)) + 1
                self._write_meta(key, rec)
        _registry().counter("zoo.hits").inc()
        return art

    def open(self, key: str):
        """Reopen an entry as a ``stages.Compiled`` stage (no recompilation;
        the stage-key chain is rebuilt from the artifact content)."""
        from repro.stages import Compiled
        art = self.get(key)
        if art is None:
            raise KeyError(f"no zoo entry {key!r} under {self.root!r}")
        return Compiled.from_artifact(art)

    def find_source(self, source_key: str):
        """Artifact whose recorded pipeline-input fingerprint matches (None
        when absent) — the reopen-before-search path of
        ``stages.compile_model``."""
        for rec in self.list():
            if source_key in (rec.get("source_keys") or []):
                return self.get(rec["key"])
        _registry().counter("zoo.misses").inc()
        return None

    def list(self) -> list[dict]:
        """Index records of every resident entry, most recently used last."""
        if not os.path.isdir(self.root):
            return []
        out = []
        for fn in sorted(os.listdir(self.root)):
            if not fn.endswith(".json"):
                continue
            key = fn[:-5]
            if not os.path.exists(self._npz(key)):
                continue               # half-evicted: npz gone, sidecar late
            rec = self._read_meta(key)
            if rec is not None:
                out.append(rec)
        return sorted(out, key=lambda r: r.get("last_used", 0.0))

    # ---------------------------------------------------------------- evict
    def remove(self, key: str) -> bool:
        with self._locked():
            return self._remove(key)

    def _remove(self, key: str) -> bool:
        found = False
        for path in (self._npz(key), self._meta(key)):
            if os.path.exists(path):
                os.unlink(path)
                found = True
        return found

    def evict(self, max_entries: int | None = None,
              max_bytes: int | None = None) -> list[str]:
        """Trim least-recently-used entries past the given (or configured)
        bounds; returns the evicted keys."""
        with self._locked():
            return self._evict(max_entries, max_bytes)

    def _evict(self, max_entries: int | None = None,
               max_bytes: int | None = None) -> list[str]:
        max_entries = max_entries if max_entries is not None else \
            self.max_entries
        max_bytes = max_bytes if max_bytes is not None else self.max_bytes
        if max_entries is None and max_bytes is None:
            return []
        recs = self.list()             # LRU first
        total = sum(int(r.get("size_bytes", 0)) for r in recs)
        evicted = []
        while recs and (
                (max_entries is not None and len(recs) > max_entries) or
                (max_bytes is not None and total > max_bytes)):
            victim = recs.pop(0)
            total -= int(victim.get("size_bytes", 0))
            self._remove(victim["key"])
            evicted.append(victim["key"])
            _registry().counter("zoo.evictions").inc()
        if evicted:
            _events().emit("zoo.evict", n=len(evicted),
                           keys=[k[:16] for k in evicted],
                           message=f"zoo evicted {len(evicted)} "
                                   "least-recently-used entr"
                                   f"{'y' if len(evicted) == 1 else 'ies'}")
        return evicted

    # ------------------------------------------------------------ pipelines
    def get_or_compile(self, g, qm, dev, **kw):
        """``stages.compile_model`` against this zoo: reopen when the source
        fingerprint is shelved, compile-and-put otherwise."""
        from repro.stages import compile_model
        return compile_model(g, qm, dev, zoo=self, **kw)

    # ------------------------------------------------------------- sidecars
    def _read_meta(self, key: str) -> dict | None:
        path = self._meta(key)
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                return json.load(f)
        except (json.JSONDecodeError, OSError):
            return None

    def _write_meta(self, key: str, rec: dict) -> None:
        tmp = self._meta(key) + f".tmp-{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        os.replace(tmp, self._meta(key))

    def __len__(self) -> int:
        return len(self.list())
