"""Sharded, async, elastic checkpointing.

Format (designed for multi-host, exercised single-host here):

    <dir>/step_<N>/
        index.json            tree structure, leaf shapes/dtypes, step, and
                              the writing topology (n_hosts, mesh shape)
        leaf_<i>_host<h>.npy  per-host shard of leaf i (this process writes
                              its addressable shards; single-host = full leaf)
        COMMITTED             written last — a checkpoint without it is
                              ignored on restore (crash-safe)

Restore is *elastic*: arrays are rebuilt from the saved bytes and re-placed
with ``jax.device_put`` against whatever mesh/sharding the restoring job
uses — a different device count than the writer is fine (DESIGN.md §4).
Async: ``save(..., async_write=True)`` snapshots to host RAM synchronously
(jax.device_get) and writes on a background thread, so training resumes
immediately — the standard large-run pattern.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np


def _encode(arr: np.ndarray) -> np.ndarray:
    """npy can't represent ml_dtypes (bfloat16 etc.); store a same-width
    integer view and record the true dtype in the index."""
    if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
        return arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
    return arr


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if arr.dtype.name != dtype_name:
        return arr.view(jnp.dtype(dtype_name))
    return arr


class CheckpointStore:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None
        # serializes every directory mutation (write + gc): a synchronous
        # save must not gc step dirs while a background write is in flight
        self._io_lock = threading.Lock()
        # guards the _thread handle so concurrent wait()s are idempotent
        self._state_lock = threading.Lock()

    # ------------------------------------------------------------------ save
    def save(self, state, step: int, async_write: bool = False,
             extra: dict | None = None) -> str:
        leaves, treedef = jax.tree_util.tree_flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        path = os.path.join(self.root, f"step_{step:08d}")

        def write():
            # one writer at a time: a sync save overlapping an async one
            # must not interleave directory mutations (or gc — below)
            with self._io_lock:
                tmp = path + ".tmp"
                os.makedirs(tmp, exist_ok=True)
                for i, arr in enumerate(host_leaves):
                    np.save(os.path.join(tmp, f"leaf_{i}_host0.npy"),
                            _encode(arr))
                index = {
                    "step": step,
                    "n_leaves": len(host_leaves),
                    "treedef": str(treedef),
                    "shapes": [list(a.shape) for a in host_leaves],
                    "dtypes": [str(a.dtype) for a in host_leaves],
                    "n_hosts": 1,
                    "extra": extra or {},
                }
                with open(os.path.join(tmp, "index.json"), "w") as f:
                    json.dump(index, f)
                with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                    f.write("ok")
                if os.path.exists(path):
                    shutil.rmtree(path)
                os.replace(tmp, path)
                self._gc()

        if async_write:
            self.wait()
            with self._state_lock:
                self._thread = threading.Thread(target=write, daemon=True)
                self._thread.start()
        else:
            write()
        return path

    def wait(self) -> None:
        """Block until the outstanding background write (if any) finishes.
        Idempotent and safe under concurrent callers: the thread handle is
        claimed under a lock, so every waiter joins (or finds nothing) and
        a double wait is a no-op."""
        with self._state_lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join()

    def _gc(self) -> None:
        # only ever called from write(), under _io_lock: gc never races an
        # in-flight background write's tmp dir or commit rename
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.root, d, "COMMITTED")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, abstract_state, shardings=None):
        """Rebuild the state pytree; re-place onto ``shardings`` if given
        (elastic: the target mesh may differ from the writer's)."""
        path = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
        leaves_abs, treedef = jax.tree_util.tree_flatten(abstract_state)
        assert index["n_leaves"] == len(leaves_abs), \
            f"leaf count mismatch: ckpt {index['n_leaves']} vs {len(leaves_abs)}"
        leaves = []
        for i, ab in enumerate(leaves_abs):
            arr = np.load(os.path.join(path, f"leaf_{i}_host0.npy"))
            arr = _decode(arr, index["dtypes"][i])
            assert tuple(arr.shape) == tuple(ab.shape), \
                f"leaf {i} shape {arr.shape} != {ab.shape}"
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(lambda x, s: jax.device_put(x, s),
                                 state, shardings)
        else:
            state = jax.tree.map(jax.numpy.asarray, state)
        return state, index["step"]

    def restore_latest(self, abstract_state, shardings=None):
        steps = self.steps()
        if not steps:
            return None
        return self.restore(steps[-1], abstract_state, shardings)
