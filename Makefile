PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test ci test-multidevice dev-deps bench-table3 serve-smoke \
        tune-smoke bench-tune tile-smoke bench-tile obs-smoke bench-obs \
        zoo-smoke bench-zoo explain-smoke bench-explain examples-smoke \
        fleet-smoke bench-fleet

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt

# Tier-1 verification (ROADMAP.md): install dev deps, run the full suite.
verify: dev-deps test

test:
	$(PY) -m pytest -x -q

# CI gate: the full suite except the multi-device subprocess tests.  The
# jax.sharding/mesh API drift that broke the LM/training-layer tests on JAX
# 0.4.37 (test_models / test_multidevice / test_train_infra /
# test_kernels_flash::test_flash_in_model_path) is fixed by version-portable
# guards — test_models and test_train_infra are back in the gate.
# test_multidevice forces 8 host devices in subprocesses, which needs real
# cores; on throttled 2-core CI boxes it can exceed any sane wall budget, so
# it gates separately (make test-multidevice).
ci: dev-deps serve-smoke tune-smoke tile-smoke obs-smoke zoo-smoke \
    explain-smoke fleet-smoke examples-smoke
	$(PY) -m pytest -q --ignore=tests/test_multidevice.py

test-multidevice:
	$(PY) -m pytest -q tests/test_multidevice.py

bench-table3:
	$(PY) benchmarks/table3.py

# Serving acceptance (ISSUE 3): tiny-resolution serve_bench run asserting
# batched > sequential throughput, bit-exact served outputs, and a
# hazard-free cross-request pipeline schedule.  Benchmark JSON lands under
# the gitignored benchmarks/out/ (uploaded as a CI build artifact).
serve-smoke:
	$(PY) benchmarks/serve_bench.py --model vgg16 --img 32 --requests 16 \
	    --smoke --json serve_bench.json

# Autotuner acceptance (ISSUE 4): calibrate a device profile on a small op
# set, assert the fit deviation is within the accept band and that the
# profile-guided strategy is measured no slower end-to-end than the analytic
# one.  Writes benchmarks/out/tune_bench.json (CI build artifact).
tune-smoke:
	$(PY) benchmarks/tune_bench.py --model vgg16 --img 32 --smoke \
	    --json tune_bench.json

# Full tune benchmark: all three nets, saved profiles.
bench-tune:
	$(PY) benchmarks/tune_bench.py --save-profiles --json tune_bench.json

# Autotuned-tiling acceptance (ISSUE 5): search per-launch tile shapes on
# vgg16@32, assert tuned shapes are never measured-slower than the analytic
# Eq. 5/6 shapes, the e2e delta is within the gate, every searched strategy
# still lowers with 1.00 fused coverage, and the tuned program is bit-exact.
# Writes benchmarks/out/tile_bench.json (CI build artifact).
tile-smoke:
	$(PY) benchmarks/tile_bench.py --model vgg16 --img 32 --smoke \
	    --json tile_bench.json

# Full tiling benchmark: all three nets (the BENCH_tiling.json trajectory).
bench-tile:
	$(PY) benchmarks/tile_bench.py --json tile_bench.json

# Observability acceptance (ISSUE 6 + 8): serve vgg16@32 with the span
# tracer + sampling drift profiler on; assert the exported trace is valid
# Perfetto JSON carrying compile/serve/modeled tracks, the metrics snapshot
# is complete, the drift band is finite, and traced throughput is within 10%
# of untraced.  Then serve the same model through the full production plane
# (OpenMetrics endpoint scraped mid-run and strict-parsed, flight recorder,
# event log, per-tenant burn-rate trackers, drift gauges) within 5% of
# traced throughput, and induce one gold-SLO violation — asserting the
# burn-rate alert fires and a slo_violation flight dump lands on disk.
# Trace, bench JSON, forensic flight dumps, and the events JSONL all land
# in benchmarks/out/ (CI build artifacts).
obs-smoke:
	$(PY) benchmarks/obs_bench.py --model vgg16 --img 32 --requests 24 \
	    --smoke --trace obs_trace.json --json obs_bench.json

# Full observability benchmark: more requests, default knobs.
bench-obs:
	$(PY) benchmarks/obs_bench.py --json obs_bench.json

# Staged-pipeline / model-zoo acceptance (ISSUE 7): compile three nets into
# a content-addressed zoo, serve a skewed mixed stream co-resident vs
# swap-per-model, and assert cross-model bit-exactness, co-resident >
# swapped throughput, and that warm recompiles/zoo reopens build 0 stages
# (verified via the stage-cache metrics counters).
zoo-smoke:
	$(PY) benchmarks/zoo_bench.py --img 32 --requests 24 --smoke \
	    --json zoo_bench.json

# Full zoo benchmark: more traffic, default knobs.
bench-zoo:
	$(PY) benchmarks/zoo_bench.py --requests 96 --json zoo_bench.json

# Compile-provenance acceptance (ISSUE 9): compile vgg16@32, strict-parse
# and render the embedded CompileReport (fusion decisions with recorded
# not-chosen alternatives, tile leaderboard, DDR map), retune the tiles and
# assert the plan diff names exactly the changed units, scrape the
# /explain/<model> route mid-serve, and gate search-tracing overhead <= 5%.
# Writes benchmarks/out/explain_bench.json (CI build artifact).
explain-smoke:
	$(PY) benchmarks/explain_bench.py --model vgg16 --img 32 --smoke \
	    --json explain_bench.json

# Full explain benchmark: all three nets.
bench-explain:
	$(PY) benchmarks/explain_bench.py --model vgg16 --model resnet50 \
	    --model googlenet --json explain_bench.json

# Fault-tolerant fleet acceptance (ISSUE 10): serve googlenet@32 through a
# replicated Fleet on forced-host devices and gate the chaos harness —
# 2 replicas >= 1.7x one replica under a uniform injected launch cost,
# kill-a-replica mid-stream completes every request bit-exact (ZERO drops)
# with the eviction, retries, frozen flight dump, and post-heal re-admission
# all observable on the obs plane, and a tiny queue bound sheds load via
# AdmissionError instead of wedging.  Bench JSON + flight dumps land in
# benchmarks/out/ (CI build artifacts).
fleet-smoke:
	$(PY) benchmarks/fleet_bench.py --model googlenet --img 32 \
	    --requests 32 --replicas 2 --smoke --json fleet_bench.json

# Full fleet benchmark: more traffic, best-of-3 scaling trials.
bench-fleet:
	$(PY) benchmarks/fleet_bench.py --requests 64 --repeats 3 \
	    --json fleet_bench.json

# The README quickstarts must keep running: both examples at small
# resolution (documentation that executes is documentation that's true).
examples-smoke:
	$(PY) examples/quickstart.py
	$(PY) examples/serve_cnn.py --model vgg16 --img 32 --requests 4 \
	    --max-batch 2
