PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test ci dev-deps bench-table3

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt

# Tier-1 verification (ROADMAP.md): install dev deps, run the full suite.
verify: dev-deps test

test:
	$(PY) -m pytest -x -q

# CI gate: the compiler-pipeline suites.  The seed ships with known-failing
# LM/training-layer tests (test_models / test_multidevice / test_train_infra,
# plus one jax.sharding API drift in nn/layers.py reached via
# test_flash_in_model_path — see CHANGES.md); excluding them keeps the gate
# green-able and meaningful until those layers are repaired.
ci: dev-deps
	$(PY) -m pytest -q \
		--ignore=tests/test_models.py \
		--ignore=tests/test_multidevice.py \
		--ignore=tests/test_train_infra.py \
		--deselect tests/test_kernels_flash.py::test_flash_in_model_path

bench-table3:
	$(PY) benchmarks/table3.py
