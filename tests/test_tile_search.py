"""Autotuned tiling: enumerate/solve_shape, width-tiled kernels, tile-shape
serialization (artifact v4 + v3 backcompat), the tile search itself, the
stacked-launch calibration rows, and the profile-guided ddr_slots pick."""
import dataclasses
import json
import math
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor, int8_ops, lower, pathsearch, quantize, tiling
from repro.core.xgraph import XGraph
from repro.hw import TPU_V5E, ZU2
from tests.conftest import make_toy_resnet_graph, toy_params


def _quantized_toy():
    g = make_toy_resnet_graph()
    params = toy_params(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    xq = quantize.quantize_to(x, qm.f_a["data"])
    return g, qm, xq


def _kernel_profile(cell_s=1e-4, launch_s=0.0):
    """Synthetic kernel-domain profile dominated by per-cell overhead — under
    it fewer, larger tiles always predict faster (the interpret-mode truth)."""
    from repro.tune.profile import COEF_NAMES, DeviceProfile

    coef = [0.0] * len(COEF_NAMES)
    coef[COEF_NAMES.index("rd")] = 1e-12
    coef[COEF_NAMES.index("conv")] = 1e-12
    coef[COEF_NAMES.index("cells")] = cell_s
    coef[COEF_NAMES.index("launch")] = launch_s
    return DeviceProfile(name="cells", device="tpu_v5e", backend="pallas",
                         jax_version="test", features="kernel", combine="sum",
                         coef=tuple(coef), deviation=0.0, n_samples=3)


# ------------------------------------------------------- enumerate / solve
def test_solve_unchanged_and_enumerate_caps_capacity():
    g = make_toy_resnet_graph()
    t0 = tiling.solve(g, ["c1"], ZU2)
    # Eq. 5 pins: solve() keeps the paper's shape exactly
    assert (t0.t_h, t0.t_oc) == (min(ZU2.h_p, 16), min(ZU2.oc_p, 16))
    cands = tiling.enumerate_tilings(g, ["c1"], ZU2)
    assert cands, "a feasible group must enumerate at least one shape"
    for t in cands:
        assert t.feasible
        # every candidate respects the Eq. 6 capacity check of solve_shape
        again = tiling.solve_shape(g, ["c1"], ZU2, t_w=t.t_w, t_h=t.t_h,
                                   t_oc=t.t_oc)
        assert again.feasible and (again.t_w, again.t_h, again.t_oc) == \
            (t.t_w, t.t_h, t.t_oc)
        # kernel-executable OC axis
        assert 16 % t.t_oc == 0


def test_enumerate_pareto_no_dominated():
    g = make_toy_resnet_graph()
    cands = tiling.enumerate_tilings(g, ["c2b", "add1"], TPU_V5E)

    def axes(t):
        return (t.dram_bytes, tiling._cells(t),
                t.in_tile_bytes + t.out_tile_bytes + t.resident_bytes)

    for a in cands:
        for b in cands:
            if a is b:
                continue
            assert not (all(x <= y for x, y in zip(axes(b), axes(a)))
                        and any(x < y for x, y in zip(axes(b), axes(a)))), \
                f"{axes(b)} dominates {axes(a)} but both survived"


def test_solve_shape_rejects_over_capacity():
    g = XGraph()
    g.input("x", (1, 64, 64, 64))
    g.add("conv", "c", ("x",), oc=64, kernel=(3, 3), pad="same")
    t = tiling.solve_shape(g, ["c"], ZU2, t_w=64, t_h=64, t_oc=64)
    assert not t.feasible and "exceeds on-chip buffers" in t.reason


# ------------------------------------------------------ width-tiled kernels
def _conv_data(rng, h, w, ic, oc, k):
    x = jnp.asarray(rng.integers(-128, 128, (1, h, w, ic)).astype(np.int8))
    wt = jnp.asarray(rng.integers(-128, 128, (k, k, ic, oc)).astype(np.int8))
    b = jnp.asarray(rng.integers(-2000, 2000, oc).astype(np.int32))
    return x, wt, b


@pytest.mark.parametrize("h,k,s,d,tile", [
    (13, 3, 1, 1, (4, 5, 4)),    # ragged right edge (13 % 5 != 0)
    (12, 3, 2, 1, (3, 2, 8)),    # stride-2 halo between width tiles
    (12, 3, 1, 2, (5, 3, 2)),    # dilated halo
    (11, 5, 2, 1, (2, 3, 8)),    # 5x5 stride-2, everything ragged
])
def test_width_tiled_conv_bit_exact(h, k, s, d, tile):
    from repro.kernels.conv_fused.ops import _run_chain

    rng = np.random.default_rng(h * k + s)
    x, wt, b = _conv_data(rng, h, h, 4, 8, k)
    p = d * (k - 1) // 2
    oh = (h + 2 * p - (d * (k - 1) + 1)) // s + 1
    want = int8_ops.conv2d(x, wt, b, stride=(s, s), pad=(p, p),
                           dilation=(d, d), shift=6, relu=True)
    chain = (("conv", "c", k, k, s, s, p, p, d, d, 6, True, oh, oh),)
    got = _run_chain(x, (wt,), (b,), (), chain=chain, oh=oh, ow=oh, oc=8,
                     interpret=True, tile=tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_width_tiled_pool_tail_chain_bit_exact():
    """conv -> ceil-mode maxpool across width tiles: the padded-coordinate
    masking must hold at interior tile boundaries, not just the right edge."""
    from repro.kernels.conv_fused.ops import _run_chain
    from repro.kernels.conv_fused.ref import fused_conv_ref

    rng = np.random.default_rng(5)
    x, wt, b = _conv_data(rng, 13, 13, 4, 8, 3)
    y_c = fused_conv_ref(x, wt, b, stride=(1, 1), pad=(1, 1), shift=6,
                         relu=True)
    for kp, sp, pp in [(3, 2, 0), (3, 2, 1), (2, 2, 1)]:
        want = int8_ops.maxpool(y_c, kernel=(kp, kp), stride=(sp, sp),
                                pad=(pp, pp), ceil_mode=True)
        oh = math.ceil((13 + 2 * pp - kp) / sp) + 1
        chain = (("conv", "c", 3, 3, 1, 1, 1, 1, 1, 1, 6, True, 13, 13),
                 ("pool", "p", "max", kp, kp, sp, sp, pp, pp, oh, oh, kp * kp))
        for tile in [(2, 3, 4), (3, 2, 2), (oh, oh, 8)]:
            got = _run_chain(x, (wt,), (b,), (), chain=chain, oh=oh, ow=oh,
                             oc=8, interpret=True, tile=tile)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_width_tiled_eltwise_chain_bit_exact():
    """conv -> eltwise_add: the side input rides the same width tiling."""
    from repro.kernels.conv_fused.ops import _run_chain

    rng = np.random.default_rng(7)
    x, wt, b = _conv_data(rng, 10, 10, 4, 8, 3)
    side = jnp.asarray(rng.integers(-128, 128, (1, 10, 10, 8)).astype(np.int8))
    y_c = int8_ops.conv2d(x, wt, b, stride=(1, 1), pad=(1, 1), shift=6)
    want = int8_ops.eltwise_add([y_c, side], [1, 2], 0, relu=True)
    chain = (("conv", "c", 3, 3, 1, 1, 1, 1, 1, 1, 6, False, 10, 10),
             ("elt", "e", 1, 2, True, 10, 10))
    for tile in [(4, 3, 8), (3, 4, 4), (10, 7, 2)]:
        got = _run_chain(x, (wt,), (b,), (side,), chain=chain, oh=10, ow=10,
                         oc=8, interpret=True, tile=tile)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_width_tiled_horizontal_bit_exact():
    from repro.kernels.conv_fused.ops import _run_horizontal

    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(-128, 128, (1, 11, 11, 4)).astype(np.int8))
    wa = jnp.asarray(rng.integers(-128, 128, (3, 3, 4, 8)).astype(np.int8))
    wb = jnp.asarray(rng.integers(-128, 128, (3, 3, 4, 12)).astype(np.int8))
    ba = jnp.asarray(rng.integers(-2000, 2000, 8).astype(np.int32))
    bb = jnp.asarray(rng.integers(-2000, 2000, 12).astype(np.int32))
    ya = int8_ops.conv2d(x, wa, ba, stride=(1, 1), pad=(1, 1), shift=5,
                         relu=True)
    yb = int8_ops.conv2d(x, wb, bb, stride=(1, 1), pad=(1, 1), shift=7)
    for tile in [(3, 4, 20), (4, 7, 10), (11, 11, 4)]:   # 11 % 4, 11 % 7 != 0
        y = _run_horizontal(
            x, jnp.concatenate([wa, wb], axis=-1), jnp.concatenate([ba, bb]),
            jnp.asarray(np.repeat([5, 7], [8, 12]).astype(np.int32)),
            jnp.asarray(np.repeat([1, 0], [8, 12]).astype(np.int32)),
            stride=(1, 1), pad=(1, 1), oh=11, ow=11, interpret=True,
            tile=tile)
        np.testing.assert_array_equal(np.asarray(y[..., :8]), np.asarray(ya))
        np.testing.assert_array_equal(np.asarray(y[..., 8:]), np.asarray(yb))


# ----------------------------------------------------- lowering + execution
def test_lower_strategy_applies_tile_map_and_stays_bit_exact():
    g, qm, xq = _quantized_toy()
    s = pathsearch.search(g, TPU_V5E)
    s.meta["tile_shapes"] = {
        lower.tile_key(grp): [16, 7, int(g.shape(grp[-1])[3])]
        for grp in s.groups
        if isinstance(lower.lower_group(g, qm, list(grp)), lower.FusedLaunch)
        and g.shape(grp[-1])[3] > 1}
    assert s.meta["tile_shapes"], "toy strategy must have tunable launches"
    prog = lower.lower_strategy(g, s, qm)
    tiled = [it for it in prog.launches() if it.tile]
    assert len(tiled) == len(s.meta["tile_shapes"])
    assert prog.meta["n_tiled_launches"] == len(tiled)
    ref = executor.Int8Executor(g, qm, strategy=s, backend="ref")(xq)
    got = executor.Int8Executor(g, qm, strategy=s, backend="pallas")(xq)
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k])


def test_artifact_v4_tile_round_trip(tmp_path):
    from repro import asm

    g, qm, xq = _quantized_toy()
    s = pathsearch.search(g, TPU_V5E)
    s.meta["tile_shapes"] = {lower.tile_key(s.groups[0]):
                             [16, 8, int(g.shape(s.groups[0][-1])[3])]}
    art = asm.compile_strategy(g, s, TPU_V5E, qm=qm)
    assert art.tile_shapes == s.meta["tile_shapes"]
    p = os.path.join(tmp_path, "a.npz")
    asm.save_artifact(art, p)
    art2 = asm.load_artifact(p)
    assert art2.tile_shapes == art.tile_shapes
    got = {it.nodes: it.tile for it in art2.program.launches() if it.tile}
    assert got == {tuple(s.groups[0]):
                   tuple(s.meta["tile_shapes"][lower.tile_key(s.groups[0])])}
    # the loaded artifact re-keys identically (tile shapes are identity)
    assert asm.strategy_signature(art2) == asm.strategy_signature(s)


def test_artifact_v3_backward_compat(tmp_path):
    """A v3 artifact (no tile records) must still load — missing tiles mean
    the kernel-heuristic shapes, exactly what v3 executed."""
    from repro import asm

    g, qm, xq = _quantized_toy()
    s = pathsearch.search(g, TPU_V5E)
    art = asm.compile_strategy(g, s, TPU_V5E, qm=qm)
    p = os.path.join(tmp_path, "v4.npz")
    asm.save_artifact(art, p)
    # rewrite as a v3 object file: drop every v4-only field
    with np.load(p, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(str(arrays["meta_json"]))
    meta["format_version"] = 3
    meta["meta"].pop("tile_shapes", None)
    meta["meta"].pop("tile_source", None)
    for item in meta["program"]["items"]:
        item.pop("tile", None)
    meta["program"]["meta"].pop("n_tiled_launches", None)
    arrays["meta_json"] = np.asarray(json.dumps(meta))
    p3 = os.path.join(tmp_path, "v3.npz")
    with open(p3, "wb") as f:
        np.savez_compressed(f, **arrays)
    art3 = asm.load_artifact(p3)
    assert art3.tile_shapes == {}
    assert all(it.tile == () for it in art3.program.launches())
    out = art3.executor(backend="pallas")(xq)
    ref = executor.Int8Executor(g, qm, strategy=s, backend="ref")(xq)
    for k in ref:
        np.testing.assert_array_equal(ref[k], out[k])


def test_plan_cache_distinguishes_tile_shapes():
    from repro import asm

    g, qm, _ = _quantized_toy()
    s = pathsearch.search(g, TPU_V5E)
    sig0 = asm.strategy_signature(s)
    s.meta["tile_shapes"] = {lower.tile_key(s.groups[0]): [16, 8, 16]}
    assert asm.strategy_signature(s) != sig0, \
        "same partition + different tiles must not collide in the plan cache"


# ------------------------------------------------------------- tile search
def test_profile_predicted_tiles_recorded_by_search():
    from repro.tune import CalibratedEvaluator

    g, qm, xq = _quantized_toy()
    profile = _kernel_profile()
    ev = CalibratedEvaluator(g, TPU_V5E, profile)
    s = pathsearch.search(g, TPU_V5E, evaluator=ev)
    # under a per-cell-dominated profile, bigger tiles always predict faster
    # than the row/oc heuristics, so the search must record shapes
    assert s.meta.get("tile_shapes"), "profile-guided search must record tiles"
    assert s.meta["tile_source"] == "profile"
    # and the program they produce still matches the oracle bit for bit
    ref = executor.Int8Executor(g, qm, strategy=s, backend="ref")(xq)
    got = executor.Int8Executor(g, qm, strategy=s, backend="pallas")(xq)
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k])


def test_search_tile_shapes_measured_winner():
    from repro.tune import MeasurementHarness, search_tile_shapes

    g, qm, xq = _quantized_toy()
    s = pathsearch.search(g, TPU_V5E)
    h = MeasurementHarness(g, qm, TPU_V5E, repeats=3)
    rep = search_tile_shapes(g, qm, TPU_V5E, s, harness=h, top_k=2)
    assert rep.n_units >= 4
    assert rep.source == "measured"
    assert s.meta.get("tile_provenance")
    for unit in rep.provenance:
        default = next(c for c in unit["candidates"] if c["default"])
        if unit["chosen"] is not None:
            win = min(unit["candidates"], key=lambda c: c["measured"])
            assert win["measured"] <= default["measured"]
    # chosen shapes compile hazard-free and stay bit-exact
    from repro import asm
    art = asm.compile_strategy(g, s, TPU_V5E, qm=qm)
    ref = executor.Int8Executor(g, qm, strategy=s, backend="ref")(xq)
    got = executor.Int8Executor(g, qm, strategy=art, backend="pallas")(xq)
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k])


# ------------------------------------------------- stacked calibration rows
def _quantized_fork():
    """Tiny inception-style fork with two STACKABLE siblings (same 3x3
    class), so lower_horizontal emits one OC-stacked launch."""
    from repro.core import frontend

    g = XGraph("fork")
    g.input("data", (1, 16, 16, 8))
    g.add("conv", "c0", ("data",), oc=8, kernel=(3, 3), pad="same")
    g.add("conv", "ba", ("c0",), oc=16, kernel=(3, 3), pad="same", relu=True)
    g.add("conv", "bb", ("c0",), oc=8, kernel=(3, 3), pad="same")
    g.add("concat", "cat", ("ba", "bb"))
    frontend.lower(g)
    from repro.cnn import init_params
    params = init_params(g)
    rng = np.random.default_rng(1)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    return g, qm


def test_default_horizontal_candidates_compatibility():
    from repro.tune.calibrate import default_horizontal_candidates

    g, _ = _quantized_fork()
    assert ["ba", "bb"] in default_horizontal_candidates(g)
    # the toy resnet fork (3x3 vs 1x1 siblings) is NOT stackable
    assert default_horizontal_candidates(make_toy_resnet_graph()) == []


def test_calibrate_measures_stacked_launches_directly():
    from repro.tune import calibrate

    g, qm = _quantized_fork()
    res = calibrate(g, qm, ZU2, repeats=2, warmup=1, min_measurable_s=0.0)
    stk = res.report["stacked"]
    assert stk["n_samples"] >= 1
    assert stk["deviation"] is not None and np.isfinite(stk["deviation"])
    stacked_rows = [m for m in res.measurements if len(m.nodes) > 1
                    and m.kind == "horizontal"]
    assert stacked_rows, "stacked measurement must enter the fit set"


def test_calibrate_injected_ground_truth_skips_stacked():
    """Simulator-ground-truth calibration (injected measure_fn) measures
    chain groups only — the stacked section must not break it."""
    from repro.core.cost import SimulatorEvaluator
    from repro.tune import calibrate

    g, qm, _ = _quantized_toy()
    sim = SimulatorEvaluator(g, ZU2)
    res = calibrate(g, qm, ZU2, measure_fn=lambda grp: sim(grp),
                    features="analytic")
    assert res.report["stacked"]["n_samples"] == 0
    assert res.report["deviation"] < 0.5


# ------------------------------------------------------ solve_horizontal fix
def test_horizontal_reload_counts_re_streams():
    """3-sibling inception-style branch whose members re-stream the shared
    input: the reload factor must ceil per member, not floor to 1."""
    from repro.core import frontend

    g = XGraph()
    g.input("x", (1, 64, 64, 256))
    g.add("conv", "b1", ("x",), oc=96, kernel=(3, 3), pad="same")
    g.add("conv", "b3", ("x",), oc=128, kernel=(3, 3), pad="same")
    g.add("conv", "b5", ("x",), oc=64, kernel=(5, 5), pad="same")
    g.add("concat", "cat", ("b1", "b3", "b5"))
    frontend.lower(g)
    sibs = ["b1", "b3", "b5"]
    in_bytes = g.fmap_bytes("x", ZU2.elem_bytes)
    parts = [tiling.solve(g, [s], ZU2) for s in sibs]
    # the fixture must actually exercise re-streaming (input not resident)
    assert all(p.load_bytes > in_bytes for p in parts)
    expected = in_bytes * min(
        max(1, math.ceil(p.load_bytes / in_bytes)) for p in parts)
    t = tiling.solve_horizontal(g, sibs, ZU2)
    assert t.feasible
    assert t.load_bytes == expected
    # the old floor formula undercounted for this branch
    old = in_bytes * max(1, min(p.load_bytes // in_bytes or 1 for p in parts))
    assert expected > old


def test_solve_horizontal_shape_override():
    g = make_toy_resnet_graph()
    t = tiling.solve_horizontal(g, ["c2a", "c2s"], ZU2, t_w=4, t_h=8, t_oc=16)
    assert t.feasible and (t.t_w, t.t_h, t.t_oc) == (4, 8, 16)
    bad = tiling.solve_horizontal(g, ["c2a", "c2s"], ZU2, t_w=10 ** 6,
                                  t_h=10 ** 6, t_oc=10 ** 6)
    assert not bad.feasible or bad.t_w <= 16


# ------------------------------------------------------- ddr_slots selection
def _toy_artifact(dev=ZU2):
    from repro import asm

    g, qm, xq = _quantized_toy()
    s = pathsearch.search(g, dev)
    return asm.compile_strategy(g, s, dev, qm=qm), g, qm


def test_choose_ddr_slots_profile_guided():
    from repro.runtime.schedule import choose_ddr_slots, pipeline_report
    from repro.tune.profile import COEF_NAMES, DeviceProfile

    art, g, qm = _toy_artifact()

    def prof(bw):
        coef = [0.0] * len(COEF_NAMES)
        coef[COEF_NAMES.index("rd")] = 1.0 / bw
        return DeviceProfile(name=f"bw{bw:g}", device="zu2",
                             backend="pallas", jax_version="t",
                             features="kernel", combine="sum",
                             coef=tuple(coef), deviation=0.0, n_samples=3)

    # measured bandwidth far above the model: DDR time shrinks -> default
    fast = choose_ddr_slots(art, prof(ZU2.dram_bw_bytes_per_s * 1e3))
    assert fast == 2
    # measured bandwidth far below: DDR-bound stream -> deeper buffering
    slow = choose_ddr_slots(art, prof(ZU2.dram_bw_bytes_per_s / 1e3))
    assert slow > 2
    assert choose_ddr_slots(art, None) >= 2
    rep = pipeline_report(art, 4, ddr_slots=None)
    assert rep.ddr_slots_source == "auto" and rep.ddr_slots >= 2
    repp = pipeline_report(art, 4, ddr_slots=None,
                           profile=prof(ZU2.dram_bw_bytes_per_s / 1e3))
    assert repp.ddr_slots_source == "profile" and repp.ddr_slots == slow
    repe = pipeline_report(art, 4, ddr_slots=3)
    assert repe.ddr_slots_source == "explicit" and repe.ddr_slots == 3
