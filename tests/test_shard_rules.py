"""Sharding-rule unit tests (pure logic, no mesh needed) + HLO analysis."""
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import collective_stats
from repro.launch.shard import param_spec, zero1_spec
from repro.core import lm_bridge
from repro import configs


def test_param_spec_tp_rules():
    # llama wq (L, D, H*hd): H*hd = 16384 divisible by 16 -> last dim
    assert param_spec("layers/wq", (126, 16384, 16384), 16) == \
        P(None, None, "model")
    # embed (V, D)
    assert param_spec("embed", (128256, 16384), 16) == P("model", None)
    # norms replicate
    assert param_spec("layers/ln1", (126, 16384), 16) == P()
    # smollm attention: 15*64=960 and d=960 are divisible by 16 -> sharded
    assert param_spec("layers/wq", (32, 960, 960), 16) == P(None, None, "model")
    # row-parallel weights shard the CONTRACTION dim (Megatron):
    assert param_spec("layers/w2", (126, 53248, 16384), 16) == \
        P(None, "model", None)
    assert param_spec("layers/wo", (126, 16384, 16384), 16) == \
        P(None, "model", None)
    assert param_spec("mlstm/w_down", (42, 4096, 2048), 16) == \
        P(None, "model", None)
    # a truly non-divisible trailing dim falls back to an earlier dim
    assert param_spec("layers/w_qkg", (42, 4096, 8200), 16) == \
        P(None, "model", None)
    # nothing divisible -> replicate
    assert param_spec("layers/odd", (3, 7, 11), 16) == P()


def test_zero1_adds_data_axis():
    ps = param_spec("layers/w1", (126, 16384, 53248), 16)
    zs = zero1_spec(ps, (126, 16384, 53248), 16)
    assert zs == P(None, "data", "model")
    # already fully sharded dims are left alone
    zs2 = zero1_spec(P("model", None), (128256, 16384), 16)
    assert zs2 == P("model", "data")


HLO_FIXTURE = """\
HloModule test

%body.1 (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %ar = f32[128]{0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%sum
  ROOT %t = tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[128])) -> pred[] {
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(32)
  ROOT %cmp = pred[] compare(%iv, %c), direction=LT
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %ag = f32[256,64]{1,0} all-gather(%a), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
  %w = (s32[], f32[128]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[128] get-tuple-element(%w), index=1
}
"""


def test_collective_stats_loop_trip_counts():
    st = collective_stats(HLO_FIXTURE)
    # all-gather outside the loop: 256*64*4 bytes * (15/16)
    ag = int(256 * 64 * 4 * 15 / 16)
    # all-reduce inside a 32-trip while: 128*4 * 2*(15/16) * 32
    ar = int(128 * 4 * 2 * 15 / 16) * 32
    assert st["bytes_by_op"]["all-gather"] == ag
    assert abs(st["bytes_by_op"]["all-reduce"] - ar) <= 32
    assert st["counts"] == {"all-gather": 1, "all-reduce": 1}


def test_lm_bridge_planner_decisions():
    """The DNNVM planner (condition 1 + cost) must pick the fused flash
    kernel at long sequence for attention archs and a VMEM-feasible chunk
    for SSM archs."""
    g8 = configs.get("granite-8b")
    plan = lm_bridge.plan_attention(g8, seq_len=32768, batch_per_device=1)
    assert plan.fused and plan.blk_q >= 8
    assert plan.fused_cost_s < plan.unfused_cost_s
    # short sequences: the score matrix is small, either choice is
    # admissible but cost ordering must be consistent
    short = lm_bridge.plan_attention(g8, seq_len=128, batch_per_device=1)
    assert short.fused_cost_s <= short.unfused_cost_s

    x = configs.get("xlstm-1.3b")
    L = lm_bridge.plan_ssm_chunk(x, 4096)
    assert 16 <= L <= 512 and 4096 % L == 0
