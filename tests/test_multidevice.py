"""Multi-device behaviors that need >1 device: run in subprocesses with a
forced 8-device host platform (the parent test process keeps its 1-device
view, so these never pollute other tests)."""
import subprocess
import sys
import textwrap

import pytest


def _run(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    # generous budget: forcing 8 host devices onto a small / cgroup-throttled
    # CI box makes XLA partition-compile at a crawl (observed >7 min for the
    # sharded train step on 2 throttled cores)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=1800,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = _run("""
        import dataclasses
        from repro import configs
        from repro.launch.mesh import make_mesh, mesh_context
        from repro.launch import shard
        from repro.launch.train import init_state, make_train_step, state_specs
        from repro.data.pipeline import SyntheticLM

        cfg = dataclasses.replace(configs.get("smollm-360m").smoke(), n_layers=2)
        data = SyntheticLM(vocab=cfg.vocab, batch=8, seq=32)
        batch = data.next()
        state = init_state(cfg)
        step = make_train_step(cfg)

        # single-device reference
        s1, m1 = jax.jit(step)(state, batch)

        mesh = make_mesh((4, 2), ("data", "model"))
        with mesh_context(mesh):
            st_specs = shard.named(state_specs(jax.eval_shape(lambda: state), mesh), mesh)
            b_specs = shard.named(shard.batch_specs(batch, mesh), mesh)
            state_sh = jax.tree.map(jax.device_put, state,
                                    jax.tree.map(lambda s: s, st_specs))
            batch_sh = jax.tree.map(jax.device_put, batch, b_specs)
            s2, m2 = jax.jit(step, in_shardings=(st_specs, b_specs))(state_sh, batch_sh)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) < 5e-3, (l1, l2)
        print("OK", l1, l2)
    """)
    assert "OK" in out


def test_late_grad_sync_matches_gspmd():
    """grad_sync='late' (one psum per step) == the GSPMD per-microbatch path."""
    out = _run("""
        import dataclasses
        from repro import configs
        from repro.launch.mesh import make_mesh, mesh_context
        from repro.launch import shard
        from repro.launch.train import init_state, make_train_step, state_specs
        from repro.data.pipeline import SyntheticLM

        cfg = dataclasses.replace(configs.get("smollm-360m").smoke(), n_layers=2)
        batch = SyntheticLM(vocab=cfg.vocab, batch=16, seq=32).next()
        state = init_state(cfg)
        mesh = make_mesh((4, 2), ("data", "model"))
        with mesh_context(mesh):
            st = shard.named(state_specs(jax.eval_shape(lambda: state), mesh), mesh)
            bs = shard.named(shard.batch_specs(batch, mesh), mesh)
            a = jax.jit(make_train_step(cfg, grad_accum=2),
                        in_shardings=(st, bs))(state, batch)
            b = jax.jit(make_train_step(cfg, grad_accum=2, grad_sync="late",
                                        mesh=mesh),
                        in_shardings=(st, bs))(state, batch)
        assert abs(float(a[1]["loss"]) - float(b[1]["loss"])) < 5e-3
        for x, y in zip(jax.tree.leaves(a[0]["params"]),
                        jax.tree.leaves(b[0]["params"])):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       rtol=5e-3, atol=5e-4)
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_shardmap():
    out = _run("""
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.compress import compressed_psum

        mesh = make_mesh((8,), ("data",))
        g = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 100.0
        err = jnp.zeros((8, 16), jnp.float32)

        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data")))
        def sync(gl, el):
            m, e = compressed_psum(gl[0], "data", el[0])
            return m[None], e[None]

        mean, new_err = sync(g, err)
        want = jnp.mean(g, axis=0)
        got = mean[0]
        rel = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
        assert rel < 0.05, rel   # int8 quantization error bound
        print("OK", rel)
    """)
    assert "OK" in out


def test_elastic_remesh_and_restore():
    out = _run("""
        import dataclasses, tempfile
        from repro import configs
        from repro.checkpoint.store import CheckpointStore
        from repro.distributed.elastic import plan_mesh, remesh, reshard_state
        from repro.launch.train import init_state, state_specs

        # plan: keep TP fixed, shrink DP
        assert plan_mesh(8, model_size=2) == ((4, 2), ("data", "model"))
        assert plan_mesh(6, model_size=2) == ((3, 2), ("data", "model"))
        try:
            plan_mesh(1, model_size=2)
            raise SystemExit("expected failure")
        except ValueError:
            pass

        cfg = dataclasses.replace(configs.get("smollm-360m").smoke(), n_layers=2)
        state = init_state(cfg)
        store = CheckpointStore(tempfile.mkdtemp())
        store.save(state, step=5)

        # "lose" 2 devices: restore onto a 6-device (3,2) mesh
        mesh = remesh(jax.devices()[:6], model_size=2)
        specs = state_specs(jax.eval_shape(lambda: state), mesh)
        restored, step = store.restore_latest(jax.eval_shape(lambda: state))
        resharded = reshard_state(restored, specs, mesh)
        leaf = jax.tree.leaves(resharded)[0]
        assert step == 5 and len(leaf.sharding.mesh.devices.ravel()) == 6
        print("OK", step)
    """)
    assert "OK" in out


def test_pipeline_stage_overlap_collective_permute():
    """GPipe-style microbatch pipeline over a 2-stage axis (the optional
    'pod-as-pipeline' mode) — correctness of the collective_permute chain."""
    out = _run("""
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("stage", "data"))
        # two "layers", one per stage; stage i applies W_i
        W = jnp.stack([jnp.eye(8) * 2.0, jnp.eye(8) * 3.0])  # (2, 8, 8)
        x = jnp.ones((4, 8))

        @partial(shard_map, mesh=mesh, in_specs=(P("stage"), P("data")),
                 out_specs=P("data"))
        def pipe(w, xb):
            h = xb @ w[0]
            # send stage0 output to stage1 (ring permute along "stage")
            h = jax.lax.ppermute(h, "stage", [(0, 1), (1, 0)])
            h = h @ w[0]
            # only stage1's result is the pipeline output; bring it home
            idx = jax.lax.axis_index("stage")
            h = jnp.where(idx == 1, h, 0.0)
            return jax.lax.psum(h, "stage")

        y = pipe(W, x)
        np.testing.assert_allclose(np.asarray(y), np.ones((4, 8)) @ np.eye(8) * 6.0)
        print("OK")
    """)
    assert "OK" in out
