"""Compile-time group lowering (ISSUE 2 tentpole): every strategy group
either lowers to a FusedLaunch or carries an allow-listed machine-readable
fallback reason (no silent fallback), each lowered kind is bit-exact with the
int8 oracle, and the GroupProgram survives the artifact round trip."""
import numpy as np
import pytest

from repro import asm
from repro.cnn import build, init_params
from repro.core import (executor, frontend, lower, partition, pathsearch,
                        quantize, validate)
from repro.core.lower import FALLBACK_REASONS, FusedLaunch, RefFallback
from repro.core.pathsearch import Strategy
from repro.core.xgraph import XGraph
from repro.hw import ZU2
from tests.conftest import make_toy_resnet_graph, toy_params


def _calibrated(g, rng):
    params = init_params(g)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    xq = quantize.quantize_to(x, qm.f_a["data"])
    return qm, xq


def _assert_bit_exact(g, strategy, rng):
    qm, xq = _calibrated(g, rng)
    rep = validate.bit_exact(g, qm, xq, strategy=strategy, backend="pallas")
    assert rep.bit_exact, rep.max_abs_diff
    return lower.lower_strategy(g, strategy, qm)


# ------------------------------------------------- no silent fallback
@pytest.mark.parametrize("model", ["vgg16", "resnet50", "googlenet"])
def test_benchmark_strategies_lower_fully(model):
    """At the paper's 224 benchmark resolution, search() strategies for the
    acceptance models must execute >= 90% fused, and every fallback must
    carry an allow-listed reason."""
    g = build(model)
    dv = partition.device_of(g, "paper")
    s = pathsearch.search(g, ZU2, device_of=dv)
    prog = lower.lower_strategy(g, s)
    for item in prog.items:
        if isinstance(item, RefFallback):
            assert item.reason in FALLBACK_REASONS, item
    rep = validate.fused_coverage(g, s)
    assert rep.ratio >= 0.9, (rep.ratio, rep.fallback_reasons)


@pytest.mark.parametrize("model,img", [("vgg16", 32), ("resnet50", 32),
                                       ("googlenet", 64), ("yolo_lite", 64)])
def test_small_strategies_never_fall_back_silently(model, img):
    """Small resolutions produce the deepest fused chains (buffers fit);
    whatever the search emits, lowering must classify every group."""
    g = build(model, img=img, num_classes=10) if model != "yolo_lite" \
        else build(model, img=img)
    s = pathsearch.search(g, ZU2)
    prog = lower.lower_strategy(g, s)
    covered = set()
    for item in prog.items:
        if isinstance(item, RefFallback):
            assert item.reason in FALLBACK_REASONS, item
        covered |= set(item.nodes)
    assert covered == set(g.compute_nodes())


# ------------------------------------------------- bit-exactness per kind
def test_conv_eltwise_maxpool_chain_bit_exact(rng):
    g = XGraph("cep")
    g.input("data", (1, 13, 13, 4))
    g.add("conv", "side", ("data",), oc=8, kernel=(1, 1), pad="same")
    g.add("conv", "main", ("data",), oc=8, kernel=(3, 3), pad="same")
    g.add("eltwise_add", "add", ("main", "side"))
    g.add("relu", "r", ("add",))
    g.add("maxpool", "pool", ("r",), kernel=(2, 2), stride=(2, 2))  # ceil: 13->7
    frontend.lower(g)
    s = Strategy(groups=[["side"], ["main", "add", "pool"]], horizontal=[],
                 cost=0.0)
    prog = _assert_bit_exact(g, s, rng)
    (launch,) = [i for i in prog.items if len(i.nodes) == 3]
    assert isinstance(launch, FusedLaunch)
    assert [st[0] for st in launch.stages] == ["conv", "elt", "pool"]


def test_conv_maxpool_ceil_and_padding_bit_exact(rng):
    g = XGraph("cp")
    g.input("data", (1, 13, 13, 3))
    g.add("conv", "c", ("data",), oc=8, kernel=(3, 3), pad="same", relu="relu")
    g.add("maxpool", "p", ("c",), kernel=(3, 3), stride=(2, 2), pad=(1, 1))
    s = Strategy(groups=[["c", "p"]], horizontal=[], cost=0.0)
    prog = _assert_bit_exact(g, s, rng)
    assert all(isinstance(i, FusedLaunch) for i in prog.items)


def test_conv_avgpool_bit_exact(rng):
    g = XGraph("ca")
    g.input("data", (1, 12, 12, 3))
    g.add("conv", "c", ("data",), oc=8, kernel=(3, 3), pad="same", relu="relu")
    g.add("avgpool", "p", ("c",), kernel=(2, 2), stride=(2, 2))
    s = Strategy(groups=[["c", "p"]], horizontal=[], cost=0.0)
    prog = _assert_bit_exact(g, s, rng)
    assert all(isinstance(i, FusedLaunch) for i in prog.items)


def test_conv_avgpool_ceil_extended_bit_exact(rng):
    """Ceil-mode avgpool (Caffe count-include-pad: extended windows read
    zeros, divisor stays kh*kw) lowers to a fused launch — no fallback."""
    assert "avgpool_ceil" not in FALLBACK_REASONS
    g = XGraph("cac")
    g.input("data", (1, 12, 12, 3))
    g.add("conv", "c", ("data",), oc=8, kernel=(3, 3), pad="same", relu="relu")
    g.add("avgpool", "p", ("c",), kernel=(3, 3), stride=(2, 2))  # ceil: 12->6
    assert g.shape("p")[1:3] == (6, 6)      # floor semantics would give 5x5
    s = Strategy(groups=[["c", "p"]], horizontal=[], cost=0.0)
    prog = _assert_bit_exact(g, s, rng)
    assert all(isinstance(i, FusedLaunch) for i in prog.items)


def test_avgpool_ceil_matches_zero_padded_reference(rng):
    """int8_ops.avgpool ceil semantics: the bottom/right extension behaves
    exactly like zero padding with an unchanged kh*kw divisor."""
    import jax.numpy as jnp
    from repro.core import int8_ops
    x = jnp.asarray(rng.integers(-128, 128, (1, 5, 5, 2)), jnp.int8)
    got = int8_ops.avgpool(x, kernel=(2, 2), stride=(2, 2))       # ceil: 5->3
    xp = jnp.pad(x, ((0, 0), (0, 1), (0, 1), (0, 0)))
    want = int8_ops.avgpool(xp, kernel=(2, 2), stride=(2, 2),
                            ceil_mode=False)
    assert got.shape == (1, 3, 3, 2)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_multi_conv_chain_and_gap_bit_exact(rng):
    g = XGraph("mc")
    g.input("data", (1, 12, 12, 3))
    g.add("conv", "c1", ("data",), oc=8, kernel=(3, 3), pad="same", relu="relu")
    g.add("conv", "c2", ("c1",), oc=16, kernel=(3, 3), pad="same", relu="relu")
    g.add("maxpool", "p", ("c2",), kernel=(2, 2), stride=(2, 2))
    g.add("conv", "c3", ("p",), oc=8, kernel=(1, 1), pad="same")
    g.add("global_avgpool", "gap", ("c3",))
    s = Strategy(groups=[["c1", "c2", "p"], ["c3", "gap"]], horizontal=[],
                 cost=0.0)
    prog = _assert_bit_exact(g, s, rng)
    assert all(isinstance(i, FusedLaunch) for i in prog.items)
    chains = [[st[0] for st in i.stages] for i in prog.items]
    assert ["conv", "conv", "pool"] in chains
    assert ["conv", "pool"] in chains


def test_fc_lowers_as_1x1_conv_bit_exact(rng):
    g = XGraph("fc")
    g.input("data", (1, 8, 8, 4))
    g.add("conv", "c", ("data",), oc=8, kernel=(3, 3), pad="same", relu="relu")
    g.add("fc", "fc1", ("c",), oc=10, relu="relu")
    g.add("fc", "fc2", ("fc1",), oc=5)
    s = Strategy(groups=[["c"], ["fc1"], ["fc2"]], horizontal=[], cost=0.0)
    prog = _assert_bit_exact(g, s, rng)
    fc_launches = [i for i in prog.items
                   if isinstance(i, FusedLaunch) and i.fc_reshape]
    assert len(fc_launches) == 2


def test_horizontal_group_batches_stacked_weights(rng):
    g = XGraph("hz")
    g.input("data", (1, 12, 12, 4))
    g.add("conv", "ca", ("data",), oc=8, kernel=(3, 3), pad="same", relu="relu")
    g.add("conv", "cb", ("data",), oc=12, kernel=(3, 3), pad="same")
    g.add("conv", "cc", ("data",), oc=8, kernel=(1, 1), pad="same")
    s = Strategy(groups=[], horizontal=[["ca", "cb", "cc"]], cost=0.0)
    prog = _assert_bit_exact(g, s, rng)
    (hz,) = [i for i in prog.items
             if isinstance(i, FusedLaunch) and i.kind == "horizontal"]
    # ca/cb share (3,3)/stride/pad -> one batched launch; cc launches alone
    assert {m[0] for m in hz.members} == {"ca", "cb"}
    assert sum(isinstance(i, FusedLaunch) for i in prog.items) == 2


# ------------------------------------------------- fallback classification
def test_fallback_reasons_are_explicit():
    g = make_toy_resnet_graph()
    dv = partition.device_of(g, "paper")   # fc1 -> host
    s = pathsearch.search(g, ZU2, device_of=dv)
    prog = lower.lower_strategy(g, s)
    reasons = prog.meta["fallback_reasons"]
    assert reasons.get("host_op", 0) >= 1            # fc1 on the host
    assert set(reasons) <= FALLBACK_REASONS
    with pytest.raises(ValueError):
        RefFallback(("x",), "because")               # not machine-readable


def test_unquantized_conv_falls_back_with_reason(rng):
    g = make_toy_resnet_graph()
    qm, _ = _calibrated(g, rng)
    del qm.weights["c1"]
    prog = lower.lower_strategy(g, pathsearch.naive(g, ZU2), qm)
    fb = {i.nodes[0]: i.reason for i in prog.fallbacks()}
    assert fb.get("c1") == "unquantized"


def test_executor_dispatch_is_precompiled(rng):
    """Zero runtime pattern matching: the pallas executor dispatches from a
    GroupProgram resolved at construction/compile time."""
    from repro.kernels.conv_fused import ops as fused_ops
    assert not hasattr(fused_ops, "group_descriptor")
    g = make_toy_resnet_graph()
    qm, xq = _calibrated(g, rng)
    s = pathsearch.search(g, ZU2)
    ex = executor.Int8Executor(g, qm, strategy=s, backend="pallas")
    assert ex.program is not None and ex.program.meta["quantized"]
    assert all(isinstance(i, (FusedLaunch, RefFallback))
               for i in ex.program.items)


# ------------------------------------------------- artifact round trip
def test_artifact_carries_program_and_round_trips(rng, tmp_path):
    g = make_toy_resnet_graph()
    qm, xq = _calibrated(g, rng)
    s = pathsearch.search(g, ZU2)
    art = asm.compile_strategy(g, s, ZU2, qm=qm)
    assert art.program is not None and art.program.meta["quantized"]
    assert art.fused_coverage > 0.0

    path = str(tmp_path / "prog.npz")
    asm.save_artifact(art, path)
    loaded = asm.load_artifact(path)
    assert lower.program_to_json(loaded.program) == \
        lower.program_to_json(art.program)
    # the loaded artifact's executor dispatches the STORED program (no
    # re-lowering, no graph inspection: the artifact is self-contained)
    ex = loaded.executor(backend="pallas")
    assert ex.program is loaded.program

    rep = validate.artifact_round_trip(g, qm, xq, s, ZU2,
                                       str(tmp_path / "rt.npz"),
                                       backend="pallas")
    assert rep.bit_exact, rep.max_abs_diff


def test_structural_program_without_qm_reports_coverage():
    g = make_toy_resnet_graph()
    s = pathsearch.search(g, ZU2)
    art = asm.compile_strategy(g, s, ZU2)          # plan-only, no weights
    assert art.program is not None
    assert not art.program.meta["quantized"]
    assert 0.0 < art.fused_coverage <= 1.0


# ------------------------------------------------- satellite regressions
def test_group_callable_uses_full_range_int8(rng):
    import jax.numpy as jnp
    g = make_toy_resnet_graph()
    qm, _ = _calibrated(g, rng)
    fn, ins = executor.build_group_callable(g, ["c1"], qm)
    assert all(i.dtype == jnp.int8 for i in ins)
    a = np.asarray(ins[0])
    assert a.min() < -100 and a.max() > 100    # not near-all-zero activations
    fn(*ins)
