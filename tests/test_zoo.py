"""Content-addressed model zoo: round trips, source-indexed reopen through
``compile_model``, LRU eviction, and sidecar robustness."""
import os

import numpy as np
import pytest

from repro import asm
from repro.core import executor, pathsearch, quantize
from repro.hw import ZU2
from repro.obs.metrics import MetricsRegistry
from repro.stages import StageCache, compile_model
from repro.zoo import ModelZoo
from tests.conftest import make_toy_resnet_graph, toy_params


@pytest.fixture(scope="module")
def toy():
    g = make_toy_resnet_graph()
    params = toy_params(g)
    x = np.random.default_rng(0).standard_normal(
        g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    return g, qm


@pytest.fixture(scope="module")
def toy_artifacts(toy):
    """Three distinct artifacts of the same net (different strategies)."""
    g, qm = toy
    return g, qm, [asm.compile_strategy(g, s, ZU2, qm=qm)
                   for s in (pathsearch.search(g, ZU2),
                             pathsearch.greedy(g, ZU2),
                             pathsearch.naive(g, ZU2))]


def test_put_get_open_round_trip(toy_artifacts, tmp_path):
    g, qm, (art, *_) = toy_artifacts
    zoo = ModelZoo(str(tmp_path / "zoo"))
    key = zoo.put(art, name="toy")
    assert zoo.key_for(art) == key
    art2 = zoo.get(key)
    assert asm.strategy_signature(art2) == asm.strategy_signature(art)
    assert art2.instrs == art.instrs
    co = zoo.open(key)
    assert co.key == key
    [rec] = zoo.list()
    assert rec["name"] == "toy" and rec["key"] == key
    assert rec["size_bytes"] == os.path.getsize(
        os.path.join(zoo.root, key + ".npz"))
    # idempotent re-put: same key, still one entry
    assert zoo.put(art) == key and len(zoo) == 1


def test_compile_model_reopens_from_zoo_without_compiling(toy, tmp_path):
    """Cold call compiles and shelves; a fresh process-equivalent (empty
    stage cache) reopens from the zoo and builds ZERO stages past wrap."""
    g, qm = toy
    zoo = ModelZoo(str(tmp_path / "zoo"))
    co1 = compile_model(g, qm, ZU2, zoo=zoo, name="toy",
                        cache=StageCache(registry=MetricsRegistry()))
    assert len(zoo) == 1
    reg = MetricsRegistry()
    co2 = compile_model(g, qm, ZU2, zoo=zoo,
                        cache=StageCache(registry=reg))
    assert co2.key == co1.key
    assert co2.stage_keys == co1.stage_keys
    for stage in ("lowered", "planned", "compiled"):
        assert reg.get(f"stages.{stage}.misses") is None   # never built
    # bit-exact across the reopen
    x = np.random.default_rng(2).integers(-128, 127,
                                          g.shape("data"), np.int8)
    got, want = co2.session().run(x), co1.session().run(x)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


def test_zoo_lru_eviction_and_counters(toy_artifacts, tmp_path):
    g, qm, arts = toy_artifacts
    from repro.obs.metrics import REGISTRY
    zoo = ModelZoo(str(tmp_path / "zoo"), max_entries=2)
    keys = [zoo.put(a) for a in arts[:2]]
    zoo.get(keys[0])                     # refresh: keys[1] becomes LRU
    before = (REGISTRY.get("zoo.evictions").value
              if REGISTRY.get("zoo.evictions") else 0.0)
    k3 = zoo.put(arts[2])                # over capacity: evicts keys[1]
    assert len(zoo) == 2
    assert zoo.get(keys[1]) is None
    assert zoo.get(keys[0]) is not None and zoo.get(k3) is not None
    assert REGISTRY.get("zoo.evictions").value == before + 1


def test_zoo_max_bytes_bound(toy_artifacts, tmp_path):
    g, qm, arts = toy_artifacts
    zoo = ModelZoo(str(tmp_path / "zoo"))
    k1 = zoo.put(arts[0])
    size = zoo.list()[0]["size_bytes"]
    zoo.max_bytes = size + size // 2     # room for one entry only
    zoo.put(arts[1])
    assert len(zoo) == 1 and zoo.get(k1) is None


def test_zoo_tolerates_corrupt_sidecar(toy_artifacts, tmp_path):
    g, qm, (art, *_) = toy_artifacts
    zoo = ModelZoo(str(tmp_path / "zoo"))
    key = zoo.put(art)
    with open(os.path.join(zoo.root, key + ".json"), "w") as f:
        f.write("{not json")
    assert zoo.list() == []              # skipped, not crashed
    assert zoo.get(key) is not None      # the npz itself is still readable


# ------------------------------------------------- corruption (ISSUE 10)
def test_load_artifact_truncated_npz_raises_artifact_error(toy_artifacts,
                                                           tmp_path):
    g, qm, (art, *_) = toy_artifacts
    path = str(tmp_path / "art.npz")
    asm.save_artifact(art, path)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[:len(blob) // 2])   # truncated mid-archive
    with pytest.raises(asm.ArtifactError, match="corrupt artifact"):
        asm.load_artifact(path)
    # still a ValueError subclass: pre-existing guards keep working
    with pytest.raises(ValueError):
        asm.CompiledArtifact.load(path)


def test_load_artifact_garbage_bytes_raise_artifact_error(tmp_path):
    path = str(tmp_path / "garbage.npz")
    with open(path, "wb") as f:
        f.write(b"this is not an npz archive at all")
    with pytest.raises(asm.ArtifactError, match="corrupt artifact"):
        asm.load_artifact(path)


def test_load_artifact_missing_file_stays_file_not_found(tmp_path):
    with pytest.raises(FileNotFoundError):
        asm.load_artifact(str(tmp_path / "never-saved.npz"))


def test_load_artifact_tampered_metadata_raises_artifact_error(toy_artifacts,
                                                               tmp_path):
    import zipfile as zf
    g, qm, (art, *_) = toy_artifacts
    path = str(tmp_path / "art.npz")
    asm.save_artifact(art, path)
    # npz archives are zips: rewrite the metadata member with non-JSON bytes
    tampered = str(tmp_path / "tampered.npz")
    with zf.ZipFile(path) as zin, zf.ZipFile(tampered, "w") as zout:
        for item in zin.infolist():
            data = zin.read(item.filename)
            if item.filename == "meta_json.npy":
                data = data[:len(data) // 2]
            zout.writestr(item, data)
    with pytest.raises(asm.ArtifactError, match="corrupt artifact"):
        asm.load_artifact(tampered)


def test_zoo_get_corrupt_npz_raises_artifact_error_with_key(toy_artifacts,
                                                            tmp_path):
    g, qm, (art, *_) = toy_artifacts
    zoo = ModelZoo(str(tmp_path / "zoo"))
    key = zoo.put(art)
    with open(os.path.join(zoo.root, key + ".npz"), "wb") as f:
        f.write(b"\x00" * 64)
    with pytest.raises(asm.ArtifactError, match=key[:16]):
        zoo.get(key)
    assert zoo.remove(key)              # the advertised cleanup works
    assert zoo.get(key) is None


def test_zoo_get_tampered_sidecar_key_raises_artifact_error(toy_artifacts,
                                                            tmp_path):
    import json as jsonlib
    g, qm, (art, *_) = toy_artifacts
    zoo = ModelZoo(str(tmp_path / "zoo"))
    key = zoo.put(art)
    side = os.path.join(zoo.root, key + ".json")
    rec = jsonlib.load(open(side))
    rec["key"] = "someone-elses-key"
    with open(side, "w") as f:
        jsonlib.dump(rec, f)
    with pytest.raises(asm.ArtifactError, match="tampered"):
        zoo.get(key)


# ---------------------------------------------- concurrent writers (lock)
def test_zoo_concurrent_writers_keep_index_consistent(toy_artifacts,
                                                      tmp_path):
    """Hammer one store from many threads (flock serializes per open fd, so
    in-process threads exercise the same lock path as processes): every
    put/evict interleaving must leave readable sidecars and npz/json pairs."""
    import threading

    g, qm, arts = toy_artifacts
    zoo = ModelZoo(str(tmp_path / "zoo"), max_entries=2)
    errs = []

    def writer(art, n=6):
        try:
            for _ in range(n):
                key = zoo.put(art, name="hammer")
                zoo.get(key)             # may be None if another evicted it
                zoo.evict()
        except Exception as e:           # pragma: no cover - the failure path
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(a,)) for a in arts
               for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errs == []
    # the index is consistent: every listed record reloads bit-true (the
    # sidecar's recorded key is validated against the filename by get)
    recs = zoo.list()
    assert len(recs) <= 2                # the bound held under concurrency
    for rec in recs:
        art = zoo.get(rec["key"])
        assert art is None or art.graph_sig == arts[0].graph_sig
