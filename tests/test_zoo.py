"""Content-addressed model zoo: round trips, source-indexed reopen through
``compile_model``, LRU eviction, and sidecar robustness."""
import os

import numpy as np
import pytest

from repro import asm
from repro.core import executor, pathsearch, quantize
from repro.hw import ZU2
from repro.obs.metrics import MetricsRegistry
from repro.stages import StageCache, compile_model
from repro.zoo import ModelZoo
from tests.conftest import make_toy_resnet_graph, toy_params


@pytest.fixture(scope="module")
def toy():
    g = make_toy_resnet_graph()
    params = toy_params(g)
    x = np.random.default_rng(0).standard_normal(
        g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    return g, qm


@pytest.fixture(scope="module")
def toy_artifacts(toy):
    """Three distinct artifacts of the same net (different strategies)."""
    g, qm = toy
    return g, qm, [asm.compile_strategy(g, s, ZU2, qm=qm)
                   for s in (pathsearch.search(g, ZU2),
                             pathsearch.greedy(g, ZU2),
                             pathsearch.naive(g, ZU2))]


def test_put_get_open_round_trip(toy_artifacts, tmp_path):
    g, qm, (art, *_) = toy_artifacts
    zoo = ModelZoo(str(tmp_path / "zoo"))
    key = zoo.put(art, name="toy")
    assert zoo.key_for(art) == key
    art2 = zoo.get(key)
    assert asm.strategy_signature(art2) == asm.strategy_signature(art)
    assert art2.instrs == art.instrs
    co = zoo.open(key)
    assert co.key == key
    [rec] = zoo.list()
    assert rec["name"] == "toy" and rec["key"] == key
    assert rec["size_bytes"] == os.path.getsize(
        os.path.join(zoo.root, key + ".npz"))
    # idempotent re-put: same key, still one entry
    assert zoo.put(art) == key and len(zoo) == 1


def test_compile_model_reopens_from_zoo_without_compiling(toy, tmp_path):
    """Cold call compiles and shelves; a fresh process-equivalent (empty
    stage cache) reopens from the zoo and builds ZERO stages past wrap."""
    g, qm = toy
    zoo = ModelZoo(str(tmp_path / "zoo"))
    co1 = compile_model(g, qm, ZU2, zoo=zoo, name="toy",
                        cache=StageCache(registry=MetricsRegistry()))
    assert len(zoo) == 1
    reg = MetricsRegistry()
    co2 = compile_model(g, qm, ZU2, zoo=zoo,
                        cache=StageCache(registry=reg))
    assert co2.key == co1.key
    assert co2.stage_keys == co1.stage_keys
    for stage in ("lowered", "planned", "compiled"):
        assert reg.get(f"stages.{stage}.misses") is None   # never built
    # bit-exact across the reopen
    x = np.random.default_rng(2).integers(-128, 127,
                                          g.shape("data"), np.int8)
    got, want = co2.session().run(x), co1.session().run(x)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


def test_zoo_lru_eviction_and_counters(toy_artifacts, tmp_path):
    g, qm, arts = toy_artifacts
    from repro.obs.metrics import REGISTRY
    zoo = ModelZoo(str(tmp_path / "zoo"), max_entries=2)
    keys = [zoo.put(a) for a in arts[:2]]
    zoo.get(keys[0])                     # refresh: keys[1] becomes LRU
    before = (REGISTRY.get("zoo.evictions").value
              if REGISTRY.get("zoo.evictions") else 0.0)
    k3 = zoo.put(arts[2])                # over capacity: evicts keys[1]
    assert len(zoo) == 2
    assert zoo.get(keys[1]) is None
    assert zoo.get(keys[0]) is not None and zoo.get(k3) is not None
    assert REGISTRY.get("zoo.evictions").value == before + 1


def test_zoo_max_bytes_bound(toy_artifacts, tmp_path):
    g, qm, arts = toy_artifacts
    zoo = ModelZoo(str(tmp_path / "zoo"))
    k1 = zoo.put(arts[0])
    size = zoo.list()[0]["size_bytes"]
    zoo.max_bytes = size + size // 2     # room for one entry only
    zoo.put(arts[1])
    assert len(zoo) == 1 and zoo.get(k1) is None


def test_zoo_tolerates_corrupt_sidecar(toy_artifacts, tmp_path):
    g, qm, (art, *_) = toy_artifacts
    zoo = ModelZoo(str(tmp_path / "zoo"))
    key = zoo.put(art)
    with open(os.path.join(zoo.root, key + ".json"), "w") as f:
        f.write("{not json")
    assert zoo.list() == []              # skipped, not crashed
    assert zoo.get(key) is not None      # the npz itself is still readable
