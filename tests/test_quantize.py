"""Int8 fixed-point semantics + calibration (paper C7)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import int8_ops, quantize


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(-2**20, 2**20), min_size=1, max_size=16),
       st.integers(0, 12))
def test_round_shift_half_away(vals, s):
    x = jnp.asarray(vals, jnp.int32)
    got = np.asarray(int8_ops.round_shift(x, s))
    want = np.sign(vals) * ((np.abs(vals) + (1 << max(s - 1, 0)) * (s > 0)) >> s) \
        if s > 0 else np.asarray(vals)
    np.testing.assert_array_equal(got, want.astype(np.int32))


def test_round_shift_negative_is_left_shift():
    x = jnp.asarray([1, -3, 7], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(int8_ops.round_shift(x, -2)), [4, -12, 28])


@settings(max_examples=30, deadline=None)
@given(st.floats(0.01, 1000.0))
def test_best_fraction_brackets_range(amax):
    data = np.array([amax, -amax / 3, amax / 7], np.float32)
    f = quantize.best_fraction(data)
    q = quantize.quantize_to(data, f)
    # max magnitude uses a healthy part of the int8 range, never overflows
    assert 32 <= abs(int(q[0])) <= 127, (amax, f, q)
    # reconstruction error bounded by one quantization step
    assert abs(q[0] * 2.0 ** -f - amax) <= 2.0 ** -f


def test_fold_bn_matches_float():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((3, 3, 4, 8)).astype(np.float32)
    b = rng.standard_normal(8).astype(np.float32)
    bn = dict(gamma=rng.uniform(0.5, 2, 8), beta=rng.standard_normal(8),
              mean=rng.standard_normal(8), var=rng.uniform(0.5, 2, 8), eps=1e-5)
    wf, bf = quantize.fold_conv_intrinsics(w, b, [("bn", bn)])
    x = rng.standard_normal((1, 8, 8, 4)).astype(np.float32)
    import jax

    y_ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
    y_ref = (y_ref - bn["mean"]) / np.sqrt(bn["var"] + 1e-5) * bn["gamma"] + bn["beta"]
    y_fold = jax.lax.conv_general_dilated(
        x, wf, (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + bf
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_fold),
                               rtol=1e-4, atol=1e-4)


def test_eltwise_rescale_alignment():
    a = jnp.asarray([[100]], jnp.int8)   # f=4 => 6.25
    b = jnp.asarray([[40]], jnp.int8)    # f=2 => 10.0
    out = int8_ops.eltwise_add([a, b], [4, 2], 2)
    # 6.25 + 10.0 = 16.25 at f=2 => 65
    assert int(out[0, 0]) == 65


def test_int8_conv_vs_numpy():
    rng = np.random.default_rng(1)
    x = rng.integers(-128, 128, (1, 6, 6, 3)).astype(np.int8)
    w = rng.integers(-128, 128, (3, 3, 3, 4)).astype(np.int8)
    b = rng.integers(-1000, 1000, 4).astype(np.int32)
    y = np.asarray(int8_ops.conv2d(jnp.asarray(x), jnp.asarray(w),
                                   jnp.asarray(b), pad=(1, 1), shift=5,
                                   relu=True))
    # manual accumulation at one position
    xp = np.pad(x.astype(np.int32), ((0, 0), (1, 1), (1, 1), (0, 0)))
    acc = (xp[0, 2:5, 3:6, :, None] * w[:, :, :, :].astype(np.int32)).sum((0, 1, 2)) + b
    want = np.clip(np.maximum(np.sign(acc) * ((np.abs(acc) + 16) >> 5), 0),
                   -128, 127).astype(np.int8)
    np.testing.assert_array_equal(y[0, 2, 3], want)
