"""Regenerate tests/data/explain_golden.txt — the committed expectation the
golden-output renderer test diffs against byte-for-byte.

Run from the repo root after an *intentional* renderer or report change:

    PYTHONPATH=src:tests python tests/data/gen_explain_golden.py

It compiles exactly the fixture tests/test_explain.py uses (seeded toy
resnet, analytic search on zu2) and renders its embedded CompileReport.
"""
import os

import numpy as np


def main():
    from conftest import make_toy_resnet_graph, toy_params
    from repro import asm, hw
    from repro.core import executor, pathsearch, quantize
    from repro.explain import render_report

    g = make_toy_resnet_graph()
    params = toy_params(g)
    x = np.random.default_rng(0).standard_normal(
        g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    dev = hw.get_device("zu2")
    s = pathsearch.search(g, dev)
    art = asm.compile_strategy(g, s, dev, qm)

    out = os.path.join(os.path.dirname(__file__), "explain_golden.txt")
    with open(out, "w") as f:
        f.write(render_report(art.report))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
