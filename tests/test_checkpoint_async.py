"""CheckpointStore async-write lifecycle regressions (ISSUE 7 satellite):
gc must never run concurrently with an in-flight background write, and
``wait()`` must be idempotent and safe under concurrent callers."""
import threading

import jax
import numpy as np

import repro.checkpoint.store as store_mod
from repro.checkpoint.store import CheckpointStore

STATE = {"w": np.arange(16, dtype=np.float32), "b": np.ones(4, np.float32)}


def test_wait_is_idempotent_and_concurrent_safe(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(STATE, step=1, async_write=True)
    errors = []

    def waiter():
        try:
            store.wait()
        except Exception as e:          # pragma: no cover - the regression
            errors.append(e)

    threads = [threading.Thread(target=waiter) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    store.wait()                         # double wait: no-op, no error
    store.wait()
    assert store.steps() == [1]


def test_sync_save_and_gc_serialized_behind_inflight_async_write(
        tmp_path, monkeypatch):
    """While a background write is mid-flight, a synchronous save (whose
    ``_gc`` deletes old step dirs) must block until the async write commits
    — interleaving used to let gc race the writer's tmp dir."""
    store = CheckpointStore(str(tmp_path), keep=1)
    gate = threading.Event()
    entered = threading.Event()
    orig = store_mod._encode
    state = {"calls": 0}

    def gated_encode(arr):
        # stall only the FIRST leaf of the first (async) write
        state["calls"] += 1
        if state["calls"] == 1:
            entered.set()
            assert gate.wait(timeout=10)
        return orig(arr)

    monkeypatch.setattr(store_mod, "_encode", gated_encode)
    store.save(STATE, step=1, async_write=True)
    assert entered.wait(timeout=10)      # async writer is now mid-write

    done = threading.Event()

    def sync_save():
        store.save(STATE, step=2)        # runs write()+_gc() inline
        done.set()

    t = threading.Thread(target=sync_save)
    t.start()
    # the sync save must NOT complete while the async write holds the lock
    assert not done.wait(timeout=0.3)
    gate.set()
    assert done.wait(timeout=10)
    t.join()
    store.wait()
    # both writes landed in order; gc (keep=1) then kept only the newest
    assert store.steps() == [2]
    restored, step = store.restore_latest(
        jax.eval_shape(lambda: STATE))
    assert step == 2
    np.testing.assert_array_equal(restored["w"], STATE["w"])


def test_async_writes_back_to_back_commit_all(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=3)
    for s in (1, 2, 3, 4):
        store.save(STATE, step=s, async_write=True)
    store.wait()
    assert store.steps() == [2, 3, 4]
