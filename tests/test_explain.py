"""Compile-decision provenance (ISSUE 9): search trace, CompileReport,
artifact plan-diff, renderer goldens, and the runtime explain surfaces."""
import copy
import json
import os
import urllib.request

import numpy as np
import pytest

from conftest import make_toy_resnet_graph, toy_params

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "explain_golden.txt")


def _quantized_toy():
    from repro.core import executor, quantize

    g = make_toy_resnet_graph()
    params = toy_params(g)
    x = np.random.default_rng(0).standard_normal(
        g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    xq = quantize.quantize_to(x, qm.f_a["data"])
    return g, qm, xq


@pytest.fixture(scope="module")
def compiled():
    from repro import asm, hw
    from repro.core import pathsearch

    g, qm, xq = _quantized_toy()
    dev = hw.get_device("zu2")
    s = pathsearch.search(g, dev)
    art = asm.compile_strategy(g, s, dev, qm)
    return g, s, dev, qm, xq, art


def _retiled_artifact(compiled):
    """A second compilation of the same strategy with one group's tile shape
    moved to a different feasible candidate — the minimal 'retune' pair."""
    from repro import asm
    from repro.core import lower, tiling

    g, s, dev, qm, _, art = compiled
    for grp in s.groups:
        cands = tiling.enumerate_tilings(g, list(grp), dev)
        current = s.meta.get("tile_shapes", {}).get(lower.tile_key(grp))
        alts = [(t.t_h, t.t_w, t.t_oc) for t in cands
                if list((t.t_h, t.t_w, t.t_oc)) != current]
        if alts:
            key, alt = lower.tile_key(grp), alts[0]
            break
    else:
        pytest.skip("no alternative feasible tiling on the toy net")
    s2 = copy.copy(s)
    s2.meta = dict(s.meta)
    shapes = dict(s2.meta.get("tile_shapes") or {})
    shapes[key] = [int(v) for v in alt]
    s2.meta["tile_shapes"] = shapes
    s2.meta["tile_source"] = "measured"
    return key, asm.compile_strategy(g, s2, dev, qm)


# ------------------------------------------------------------- search trace
def test_search_trace_records_decisions(compiled):
    g, s, dev, *_ = compiled
    tr = s.meta["search_trace"]
    json.dumps(tr)                                   # JSON-native throughout
    assert tr["n_chains"] == len(tr["chains"]) == tr["n_chains_recorded"]
    assert tr["templates"] and tr["n_fusable_pairs"] > 0
    # at least one scored-but-not-chosen alternative with its cost...
    alts = [a for ch in tr["chains"] for a in ch["alternatives"]]
    assert alts and all(a["cost_s"] > 0 for a in alts)
    # ...and at least one rejection with a machine-readable reason
    from repro.core.pathsearch import REJECT_REASONS
    rejects = [ex for ch in tr["chains"] for ex in ch["rejected_examples"]]
    assert rejects and all(ex["reason"] in REJECT_REASONS for ex in rejects)
    assert all(ch["frontier"] >= len(ch["chosen"]) for ch in tr["chains"])
    # every final group has a direct cost on record
    from repro.core.lower import tile_key
    for grp in s.groups:
        assert tile_key(grp) in tr["group_costs"]
    assert tr["total_cost_s"] == pytest.approx(s.cost)
    # the toy net exercises both barrier heuristics
    assert any(e["absorbed"] for e in tr["eltwise_absorb"])
    assert any(h["fused"] for h in tr["horizontal"])


def test_search_trace_optional():
    from repro.core import pathsearch
    from repro.hw import get_device

    g = make_toy_resnet_graph()
    dev = get_device("zu2")
    s_on = pathsearch.search(g, dev)
    s_off = pathsearch.search(g, dev, trace=False)
    assert "search_trace" not in s_off.meta
    # tracing must not change the strategy itself
    assert [list(grp) for grp in s_off.groups] == \
        [list(grp) for grp in s_on.groups]
    assert s_off.cost == pytest.approx(s_on.cost)


# ------------------------------------------------------------ CompileReport
def test_report_embedded_and_schema_stable(compiled):
    from repro.explain import validate_report

    *_, art = compiled
    rep = art.report
    validate_report(rep)
    # strict JSON round trip (what the npz serialization and the HTTP route
    # both do) must preserve the report exactly
    assert json.loads(json.dumps(rep)) == rep
    assert rep["fusion"]["n_groups"] == len(art.groups)
    assert rep["memory"]["regions"], "DDR allocation map must be present"
    offsets = [r["offset"] for r in rep["memory"]["regions"]]
    assert offsets == sorted(offsets)
    assert rep["schedule"]["n_instrs"] == len(art.instrs)
    assert sum(rep["schedule"]["engines"].values()) == len(art.instrs)


def test_report_survives_npz_roundtrip(compiled, tmp_path):
    from repro import asm
    from repro.explain import report_of, validate_report

    *_, art = compiled
    p = os.path.join(tmp_path, "a.npz")
    asm.save_artifact(art, p)
    art2 = asm.load_artifact(p)
    assert art2.report == art.report
    validate_report(report_of(art2))
    assert art2.search_trace == art.search_trace


def test_golden_text_render(compiled):
    from repro.explain import render_report

    *_, art = compiled
    got = render_report(art.report)
    with open(GOLDEN) as f:
        want = f.read()
    assert got == want, (
        "text renderer output drifted from tests/data/explain_golden.txt — "
        "if the change is intentional, regenerate the golden:\n"
        "PYTHONPATH=src:tests python tests/data/gen_explain_golden.py")


def test_report_of_v4_artifact_degrades(compiled, tmp_path):
    """v4 object files (no embedded report) must still load and explain."""
    from repro import asm
    from repro.explain import render_report, report_of, validate_report

    *_, art = compiled
    p = os.path.join(tmp_path, "v5.npz")
    asm.save_artifact(art, p)
    with np.load(p, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(str(arrays["meta_json"]))
    meta["format_version"] = 4
    for key in ("compile_report", "search_trace", "tile_provenance"):
        meta["meta"].pop(key, None)
    arrays["meta_json"] = np.asarray(json.dumps(meta))
    p4 = os.path.join(tmp_path, "v4.npz")
    with open(p4, "wb") as f:
        np.savez_compressed(f, **arrays)

    art4 = asm.load_artifact(p4)
    assert art4.report is None
    rep = report_of(art4)                            # degraded, no crash
    validate_report(rep)
    assert rep["degraded"] is True
    assert rep["fusion"]["n_groups"] == len(art4.groups)
    assert rep["memory"]["regions"] == []            # map not serialized pre-v5
    assert "degraded" in render_report(rep)


def test_tile_provenance_roundtrip_bounded(compiled, tmp_path):
    """Satellite: tile_provenance used to be dropped at serialization; it
    must survive the npz round trip, bounded to top-K candidates per unit."""
    from repro import asm
    from repro.asm.artifact import TILE_PROVENANCE_MAX_CANDIDATES

    g, s, dev, qm, _, _ = compiled
    s2 = copy.copy(s)
    s2.meta = dict(s.meta)
    # synthesize a deep leaderboard (more candidates than the bound keeps)
    s2.meta["tile_provenance"] = [{
        "key": "c1", "nodes": ["c1"], "kind": "chain", "default": [16, 16, 16],
        "chosen": [8, 16, 16], "source": "measured",
        "candidates": [{"shape": [16, 16, 16], "default": True,
                        "predicted": 1e-3, "measured": 2e-3, "spread": 0.01}]
        + [{"shape": [8, 16, 16 + i], "default": False,
            "predicted": 1e-3 + i * 1e-5, "measured": float("nan") if i == 0
            else 2e-3 + i * 1e-5, "spread": 0.01}
           for i in range(20)],
    }]
    art = asm.compile_strategy(g, s2, dev, qm)
    p = os.path.join(tmp_path, "prov.npz")
    asm.save_artifact(art, p)
    art2 = asm.load_artifact(p)

    prov = art2.tile_provenance
    assert len(prov) == 1
    unit = prov[0]
    assert unit["key"] == "c1" and unit["chosen"] == [8, 16, 16]
    assert len(unit["candidates"]) <= TILE_PROVENANCE_MAX_CANDIDATES
    assert unit["n_candidates"] == 21                # full count recorded
    assert unit["candidates"][0]["default"] is True  # default always kept
    # kept non-default candidates are the best-ranked ones, NaN sanitized
    assert unit["candidates"][1]["measured"] is None
    assert art2.report["tiles"]["leaderboard"] == prov


def test_measured_search_provenance_reaches_artifact(compiled):
    """The real tune.tiles leaderboard (not a synthetic one) lands in the
    compiled artifact and names each unit by its tile_key."""
    from repro import asm
    from repro.core import lower
    from repro.tune import MeasurementHarness
    from repro.tune.tiles import search_tile_shapes

    g, s, dev, qm, _, _ = compiled
    s2 = copy.copy(s)
    s2.meta = dict(s.meta)
    harness = MeasurementHarness(g, qm, dev, repeats=1)
    rep = search_tile_shapes(g, qm, dev, s2, harness=harness, top_k=1,
                             min_measurable_s=0.0)
    assert rep.provenance
    assert all(u["key"] == lower.tile_key(u["nodes"]) for u in rep.provenance)
    art = asm.compile_strategy(g, s2, dev, qm)
    assert art.tile_provenance
    keys = {u["key"] for u in art.tile_provenance}
    assert all(k in keys for k in art.tile_shapes)


# ---------------------------------------------------------------------- diff
def test_diff_self_is_empty(compiled):
    from repro.explain import diff

    *_, art = compiled
    d = diff(art, art)
    assert d["identical"] is True
    assert d["fusion"]["only_a"] == d["fusion"]["only_b"] == []
    assert d["tiles"]["changed"] == [] and d["tiles"]["n_changed"] == 0
    assert d["cost"]["total_cost_s"]["delta"] == 0


def test_diff_names_exactly_the_changed_tiles(compiled):
    from repro.explain import diff, negate, render_diff

    *_, art_a = compiled
    key, art_b = _retiled_artifact(compiled)
    d = diff(art_a, art_b)
    assert d["identical"] is False
    assert [c["key"] for c in d["tiles"]["changed"]] == [key]
    (change,) = d["tiles"]["changed"]
    assert change["a"] != change["b"] and change["b"] is not None
    # fusion did not change, only the tile
    assert d["fusion"]["only_a"] == d["fusion"]["only_b"] == []
    # antisymmetry: the diff carries no argument-order information beyond
    # the a/b labelling
    assert diff(art_a, art_b) == negate(diff(art_b, art_a))
    assert diff(art_b, art_a) == negate(diff(art_a, art_b))
    text = render_diff(d)
    assert key in text


def test_diff_emits_plan_diff_event(compiled):
    from repro.explain import diff
    from repro.obs.events import EVENTS

    *_, art = compiled
    seen = []
    sub = seen.append
    EVENTS.subscribe(sub)
    try:
        diff(art, art)
    finally:
        EVENTS.unsubscribe(sub)
    kinds = [e.kind for e in seen]
    assert "plan.diff" in kinds
    ev = next(e for e in seen if e.kind == "plan.diff")
    assert ev.fields["identical"] is True
    assert ev.fields["n_tiles_changed"] == 0


# ------------------------------------------------------------------ runtime
def test_fallback_reason_counters(compiled):
    """Satellite: RefFallback launches export per-reason labelled counters
    (``executor.fallback{reason=...}``), not just the aggregate."""
    from repro.core import partition, pathsearch
    from repro.core.executor import Int8Executor
    from repro.obs.metrics import REGISTRY

    g, _, dev, qm, xq, _ = compiled
    dv = partition.device_of(g, "paper")           # fc1 -> host: a fallback
    s = pathsearch.search(g, dev, device_of=dv)
    run = Int8Executor(g, qm, strategy=s, backend="pallas")
    reasons = {fb.reason for fb in run.program.fallbacks()}
    assert "host_op" in reasons

    def snapshot():
        by = REGISTRY.labelled("executor.fallback", label="reason")
        return {r: (by[r].value if r in by else 0.0) for r in reasons}

    before = snapshot()
    run(xq)
    after = snapshot()
    for r in reasons:
        n = sum(1 for fb in run.program.fallbacks() if fb.reason == r)
        assert after[r] == before[r] + n


def test_session_explain_joins_drift(compiled):
    from repro import asm
    from repro.core.cost import SimulatorEvaluator
    from repro.explain import validate_report
    from repro.obs.drift import DriftProfiler
    from repro.obs.events import EVENTS
    from repro.obs.metrics import MetricsRegistry
    from repro.runtime import Session
    from repro.tune import calibrate
    from repro.tune.evaluator import predict_item_seconds

    g, s, dev, qm, xq, _ = compiled
    sim = SimulatorEvaluator(g, dev)
    prof = calibrate(g, qm, dev, measure_fn=lambda grp: sim(grp),
                     features="analytic").profile
    sess = Session(g, s, dev, qm, backend="pallas", cache=asm.PlanCache(),
                   profile=prof)
    rep = sess.explain()
    validate_report(rep)
    assert "drift" not in rep                       # no profiler attached

    # an undrifted world: measurements ARE the profile's own predictions
    dp = DriftProfiler.from_session(
        sess, every=1, registry=MetricsRegistry(),
        measure_fn=lambda item: predict_item_seconds(prof, g, dev, item))
    sess.attach_drift(dp)
    dp.sample()
    seen = []
    sub = seen.append
    EVENTS.subscribe(sub)
    try:
        rep = sess.explain()
    finally:
        EVENTS.unsubscribe(sub)
    assert rep["drift"]["units"]
    planned = {n for grp in rep["fusion"]["groups"] for n in grp["nodes"]}
    for u in rep["drift"]["units"]:
        assert u["measured"] == pytest.approx(u["predicted"])
        # report-style keys ("|"-joined), every node from the compiled plan
        assert "+" not in u["key"]
        assert set(u["key"].split("|")) <= planned
    assert rep["drift"]["drifted"] is False
    assert rep["drift"]["profile_match"] is True
    assert any(e.kind == "explain.report" for e in seen)
    text = sess.explain(render=True)
    assert "live drift" in text


def test_http_explain_route(compiled):
    from repro.explain import validate_report
    from repro.obs import MetricsRegistry
    from repro.obs.export import ObsHTTPServer

    *_, art = compiled
    rep = art.report
    reg = MetricsRegistry()
    with ObsHTTPServer(reg, port=0) as srv:
        srv.add_explain("toy", lambda: rep)
        with urllib.request.urlopen(srv.url("/explain")) as r:
            assert json.load(r)["models"] == ["toy"]
        with urllib.request.urlopen(srv.url("/explain/toy")) as r:
            got = json.load(r)
        validate_report(got)
        assert got == json.loads(json.dumps(rep))
        try:
            urllib.request.urlopen(srv.url("/explain/nope"))
            assert False, "unknown model must 404"
        except urllib.error.HTTPError as e:
            assert e.code == 404
        assert reg.counter("obs.explain_scrapes",
                           {"model": "toy"}).value == 1
