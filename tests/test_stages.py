"""Staged compile pipeline (ISSUE 7 tentpole): stage-key stability, partial
recompiles, cache-hit accounting, and the artifact-format backcompat pin."""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro import asm
from repro.core import executor, pathsearch, quantize
from repro.hw import ZU2
from repro.obs.metrics import MetricsRegistry
from repro.stages import (Compiled, StageCache, artifact_stage_keys,
                          compile_model, wrap)
from tests.conftest import make_toy_resnet_graph, toy_params

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def toy():
    g = make_toy_resnet_graph()
    params = toy_params(g)
    x = np.random.default_rng(0).standard_normal(
        g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    return g, qm


def _counts(reg, what):
    return {s: (reg.get(f"stages.{s}.{what}").value
                if reg.get(f"stages.{s}.{what}") else 0.0)
            for s in ("wrapped", "lowered", "planned", "compiled")}


# ------------------------------------------------------ pipeline == monolith
def test_pipeline_matches_compile_strategy(toy):
    """The staged walk must produce the same object file as the one-call
    ``compile_strategy`` it refactors (same strategy search, same plan)."""
    g, qm = toy
    s = pathsearch.search(g, ZU2)
    art = asm.compile_strategy(g, s, ZU2, qm=qm)
    co = compile_model(g, qm, ZU2, cache=StageCache(
        registry=MetricsRegistry()))
    assert co.artifact.graph_sig == art.graph_sig
    assert asm.strategy_signature(co.artifact) == asm.strategy_signature(art)
    assert co.artifact.instrs == art.instrs
    assert co.artifact.sim_total_cycles == art.sim_total_cycles
    assert artifact_stage_keys(co.artifact) == artifact_stage_keys(art)
    assert co.stage_keys == artifact_stage_keys(art)


def test_warm_recompile_hits_all_four_stage_caches(toy):
    g, qm = toy
    reg = MetricsRegistry()
    sc = StageCache(registry=reg)
    co1 = compile_model(g, qm, ZU2, cache=sc)
    assert _counts(reg, "misses") == {s: 1.0 for s in _counts(reg, "misses")}
    co2 = compile_model(g, qm, ZU2, cache=sc)
    assert co2 is co1                    # the same stage object, not a copy
    assert _counts(reg, "hits") == {s: 1.0 for s in _counts(reg, "hits")}
    assert _counts(reg, "misses") == {s: 1.0 for s in _counts(reg, "misses")}


# --------------------------------------------------------- partial recompile
def test_pin_input_replans_without_researching(toy):
    """Changing a planner knob must re-run plan+compile only: Wrapped and
    Lowered are reused (one search total)."""
    g, qm = toy
    reg = MetricsRegistry()
    sc = StageCache(registry=reg)
    w = wrap(g, qm, ZU2, cache=sc)
    lo = w.lower()
    p0 = lo.plan()
    p1 = lo.plan(pin_input=True)
    assert p0.key != p1.key
    assert p1.compile().artifact.pin_input
    assert not p0.compile().artifact.pin_input
    assert reg.get("stages.lowered.misses").value == 1.0
    assert reg.get("stages.planned.misses").value == 2.0


def test_ddr_budget_replans_and_enforces_capacity(toy):
    g, qm = toy
    sc = StageCache(registry=MetricsRegistry())
    lo = wrap(g, qm, ZU2, cache=sc).lower()
    p0 = lo.plan()
    # a roomy budget replans fine (new stage key, same upstream search) ...
    p1 = lo.plan(ddr_budget_bytes=p0.peak_ddr_bytes * 2)
    assert p1.key != p0.key
    assert p1.peak_ddr_bytes == p0.peak_ddr_bytes
    # ... and a budget below the plan's peak is refused by the planner
    with pytest.raises(Exception, match="(?i)ddr|capacity|exceed"):
        lo.plan(ddr_budget_bytes=max(1, p0.peak_ddr_bytes // 2))


def test_profile_perturbation_invalidates_lowered_not_wrapped(toy):
    """A different device profile must invalidate Lowered-and-later only:
    the Wrapped stage (graph + quant + device) is untouched."""
    from repro.tune.profile import COEF_NAMES, DeviceProfile

    def prof(scale):
        return DeviceProfile(name=f"p{scale:g}", device="zu2",
                             backend="pallas", jax_version="t",
                             features="kernel", combine="sum",
                             coef=tuple(scale * (i + 1) * 1e-9
                                        for i in range(len(COEF_NAMES))),
                             deviation=0.0, n_samples=3)

    g, qm = toy
    reg = MetricsRegistry()
    sc = StageCache(registry=reg)
    co_a = compile_model(g, qm, ZU2, profile=prof(1.0), cache=sc)
    co_b = compile_model(g, qm, ZU2, profile=prof(4.0), cache=sc)
    assert reg.get("stages.wrapped.hits").value == 1.0      # reused
    assert reg.get("stages.lowered.misses").value == 2.0    # re-searched
    assert co_a.stage_keys["wrapped"] == co_b.stage_keys["wrapped"]
    assert co_a.stage_keys["lowered"] != co_b.stage_keys["lowered"]
    assert co_a.stage_keys["planned"] != co_b.stage_keys["planned"]
    assert co_a.artifact.profile_hash == prof(1.0).hash()
    assert co_b.artifact.profile_hash == prof(4.0).hash()


def test_retune_copies_strategy_and_reuses_search(toy):
    """``Lowered.retune`` re-runs only the tile search: the input stage's
    strategy is never mutated and pathsearch is not re-run."""
    from repro.tune.profile import COEF_NAMES, DeviceProfile

    prof = DeviceProfile(name="t", device="zu2", backend="pallas",
                         jax_version="t", features="kernel", combine="sum",
                         coef=tuple((i + 1) * 1e-9
                                    for i in range(len(COEF_NAMES))),
                         deviation=0.0, n_samples=3)
    g, qm = toy
    lo = wrap(g, qm, ZU2, cache=None).lower()
    before = dict(lo.strategy.meta)
    lo2 = lo.retune(profile=prof)
    assert lo.strategy.meta == before            # input stage untouched
    assert lo2.wrapped is lo.wrapped
    assert lo2.strategy.meta.get("tile_source") == "profile"
    assert lo2.strategy.groups == lo.strategy.groups   # same partition
    co = lo2.plan().compile()
    assert co.artifact.profile_hash == prof.hash()


# ------------------------------------------------------- cross-process keys
def test_stage_keys_stable_across_processes(toy):
    """Same net + params must reach identical stage hashes in a different
    interpreter — the property the on-disk zoo's addressing relies on."""
    g, qm = toy
    co = wrap(g, qm, ZU2, cache=None).lower().plan().compile()
    code = (
        "import sys, json\n"
        "sys.path.insert(0, 'src'); sys.path.insert(0, '.')\n"
        "import numpy as np\n"
        "from repro.core import executor, quantize\n"
        "from repro.hw import ZU2\n"
        "from repro.stages import wrap\n"
        "from tests.conftest import make_toy_resnet_graph, toy_params\n"
        "g = make_toy_resnet_graph()\n"
        "params = toy_params(g)\n"
        "x = np.random.default_rng(0).standard_normal("
        "g.shape('data')).astype(np.float32)\n"
        "qm = quantize.calibrate(g, params, x, executor.run_float)\n"
        "co = wrap(g, qm, ZU2, cache=None).lower(cache=None)"
        ".plan(cache=None).compile(cache=None)\n"
        "print(json.dumps(co.stage_keys))\n")
    out = subprocess.run([sys.executable, "-c", code], cwd=str(REPO),
                         capture_output=True, text=True, check=True)
    assert json.loads(out.stdout.strip().splitlines()[-1]) == co.stage_keys


# ------------------------------------------------------------ backcompat pin
def test_saved_artifact_reopens_with_identical_stage_keys(toy, tmp_path):
    """A format-v4 npz written by the compile path must reopen as a
    ``Compiled`` stage with the SAME content address — otherwise every zoo
    entry would orphan on upgrade."""
    g, qm = toy
    co = compile_model(g, qm, ZU2, cache=StageCache(
        registry=MetricsRegistry()))
    path = str(tmp_path / "m.npz")
    co.save(path)
    re = Compiled.from_artifact(asm.load_artifact(path))
    assert re.key == co.key
    assert re.stage_keys == co.stage_keys
    # and it still serves, bit-exactly
    x = np.random.default_rng(1).integers(-128, 127,
                                          g.shape("data"), np.int8)
    got = re.session().run(x)
    want = co.session().run(x)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


def test_compile_strategy_is_a_thin_stage_wrapper(toy):
    """The legacy one-call API keeps byte-identical behavior: no global
    stage-cache participation (pure recompute), same artifact content."""
    g, qm = toy
    from repro.stages import STAGE_CACHE
    s = pathsearch.search(g, ZU2)
    before = len(STAGE_CACHE)
    a1 = asm.compile_strategy(g, s, ZU2, qm=qm)
    a2 = asm.compile_strategy(g, s, ZU2, qm=qm)
    assert len(STAGE_CACHE) == before          # no pollution of the global
    assert a1 is not a2                        # pure recompute, as before
    assert a1.instrs == a2.instrs
