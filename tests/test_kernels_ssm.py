"""ssm_scan Pallas kernel + the chunked algorithm itself vs the sequential
recurrence oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import chunked_ref, sequential_ref
from repro.nn.recurrent import chunked_linear_scan, linear_step


def _inputs(rng, b, s, h, dk, dv):
    q = rng.standard_normal((b, s, h, dk)).astype(np.float32) * 0.3
    k = rng.standard_normal((b, s, h, dk)).astype(np.float32) * 0.3
    v = rng.standard_normal((b, s, h, dv)).astype(np.float32)
    la = -np.abs(rng.standard_normal((b, s, h))).astype(np.float32) * 0.2
    return map(jnp.asarray, (q, k, v, la))


@pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
    (1, 32, 2, 8, 8, 8),
    (2, 64, 2, 4, 16, 16),      # K != V (mamba2-style)
    (1, 64, 4, 16, 16, 32),
    (1, 48, 1, 8, 8, 16),       # chunk not power-of-two-aligned count
])
def test_chunked_matches_sequential(b, s, h, dk, dv, chunk):
    rng = np.random.default_rng(s + dk)
    q, k, v, la = _inputs(rng, b, s, h, dk, dv)
    got = chunked_ref(q, k, v, la, chunk=chunk)
    want = sequential_ref(q, k, v, la)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
    (1, 32, 2, 8, 8, 8),
    (2, 64, 2, 4, 16, 16),
    (1, 64, 1, 16, 32, 32),
])
def test_pallas_matches_chunked(b, s, h, dk, dv, chunk):
    rng = np.random.default_rng(3 * s + dv)
    q, k, v, la = _inputs(rng, b, s, h, dk, dv)
    got = ssm_scan(q, k, v, la, chunk=chunk)
    want = chunked_ref(q, k, v, la, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_step_matches_scan_tail():
    """Running the per-token linear_step over a sequence reproduces the
    chunked scan (prefill/decode consistency for SSM caches)."""
    rng = np.random.default_rng(11)
    b, s, h, dk, dv = 1, 16, 2, 4, 8
    q, k, v, la = _inputs(rng, b, s, h, dk, dv)
    y_scan, S_final = chunked_linear_scan(q, k, v, la, chunk=8)
    S = jnp.zeros((b, h, dk, dv), jnp.float32)
    ys = []
    for t in range(s):
        y, S = linear_step(q[:, t], k[:, t], v[:, t], la[:, t], S)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_scan),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_final),
                               rtol=2e-4, atol=2e-4)
