"""Fault-tolerance plumbing (ISSUE 10 satellite): the previously idle
heartbeat/straggler detectors, the retry-budget policy and driver loop, and
the elastic re-mesh shrink policy — all on injectable clocks, no sleeps."""
import pytest

from repro.distributed.elastic import plan_mesh, remesh
from repro.distributed.health import (HeartbeatMonitor, RetryPolicy,
                                      run_with_retries)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ------------------------------------------------------------- heartbeats
def test_heartbeat_timeout_and_revival():
    clk = FakeClock()
    mon = HeartbeatMonitor(timeout_s=10.0, clock=clk)
    mon.beat("a")
    mon.beat("b")
    clk.advance(9.0)
    mon.beat("b")
    assert mon.dead() == []
    clk.advance(2.0)                    # a: 11s silent; b: 2s
    assert mon.dead() == ["a"]
    mon.beat("a")                       # a revives on its next beat
    assert mon.dead() == []


def test_heartbeat_forget_drops_all_state():
    clk = FakeClock()
    mon = HeartbeatMonitor(timeout_s=1.0, clock=clk)
    mon.beat("a", step_time_s=5.0)
    clk.advance(100.0)
    mon.forget("a")
    assert mon.dead() == []             # no stale "still dead" re-reports
    assert "a" not in mon.hosts
    mon.forget("a")                     # idempotent on unknown hosts


def test_step_ewma_first_beat_seeds_then_blends():
    mon = HeartbeatMonitor(clock=FakeClock())
    mon.beat("a", step_time_s=1.0)
    assert mon.hosts["a"].step_ema == pytest.approx(1.0)   # a=1.0 seed
    mon.beat("a", step_time_s=2.0)                          # 0.8*1 + 0.2*2
    assert mon.hosts["a"].step_ema == pytest.approx(1.2)


def test_straggler_needs_three_samples_and_beats_median():
    mon = HeartbeatMonitor(clock=FakeClock())
    mon.beat("a", step_time_s=1.0)
    mon.beat("b", step_time_s=10.0)
    assert mon.stragglers(1.5) == []    # < 3 EWMAs: not enough signal
    mon.beat("c", step_time_s=1.0)
    assert mon.stragglers(1.5) == ["b"]  # 10 > 1.5 x median(1, 1, 10)
    assert mon.stragglers(20.0) == []    # factor is respected
    # hosts that never reported a step time don't dilute the median
    mon.beat("d")
    assert mon.stragglers(1.5) == ["b"]


# ----------------------------------------------------------- retry budget
def test_retry_policy_window_prunes_old_restarts():
    clk = FakeClock()
    pol = RetryPolicy(max_restarts=2, window_s=100.0, clock=clk)
    assert pol.should_retry()
    pol.record()
    pol.record()
    assert not pol.should_retry()       # budget spent
    clk.advance(101.0)                  # both restarts age out of the window
    assert pol.should_retry()


def test_run_with_retries_restores_latest_checkpoint():
    clk = FakeClock()

    class Store:
        def __init__(self):
            self.saved = None

        def restore_latest(self, abstract_state, shardings=None):
            return self.saved

    store = Store()
    attempts = []

    def run_fn(state, start):
        attempts.append((state, start))
        if len(attempts) < 3:
            store.saved = ({"w": len(attempts)}, 10 * len(attempts))
            raise RuntimeError("host lost")
        return state, True

    pol = RetryPolicy(max_restarts=5, clock=clk)
    state, done = run_with_retries(lambda: {"w": 0}, run_fn, store, pol,
                                   abstract_state=None)
    assert done and state == {"w": 2}
    # cold start from scratch, then each retry resumes the latest checkpoint
    assert attempts == [({"w": 0}, 0), ({"w": 1}, 10), ({"w": 2}, 20)]
    assert len(pol.restarts) == 2


def test_run_with_retries_exhausted_budget_raises():
    class Store:
        def restore_latest(self, abstract_state, shardings=None):
            return None

    def run_fn(state, start):
        raise RuntimeError("always fails")

    pol = RetryPolicy(max_restarts=1, clock=FakeClock())
    with pytest.raises(RuntimeError, match="always fails"):
        run_with_retries(lambda: {}, run_fn, Store(), pol,
                         abstract_state=None)


# ------------------------------------------------------------- elastic DP
def test_plan_mesh_shrinks_data_axis_keeps_model_axis():
    assert plan_mesh(64, model_size=16) == ((4, 16), ("data", "model"))
    # a lost host shrinks DP to the largest multiple that still divides
    assert plan_mesh(63, model_size=16) == ((3, 16), ("data", "model"))
    assert plan_mesh(16, model_size=16) == ((1, 16), ("data", "model"))


def test_plan_mesh_pod_split_only_when_wide_and_even():
    shape, axes = plan_mesh(1024, model_size=16)
    assert shape == (2, 32, 16) and axes == ("pod", "data", "model")
    # prefer_pods off, or a DP degree below the pod threshold, stays flat
    assert plan_mesh(1024, model_size=16, prefer_pods=False)[0] == (64, 16)
    assert plan_mesh(256, model_size=16)[0] == (16, 16)


def test_plan_mesh_refuses_to_break_tensor_parallel():
    with pytest.raises(ValueError, match="cannot keep TP=16"):
        plan_mesh(8, model_size=16)


def test_remesh_on_local_devices():
    import jax
    devs = jax.devices()[:1]            # one survivor: the smallest re-mesh
    mesh = remesh(devs, model_size=1)
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (1, 1)
