"""Serving fleet (ISSUE 10 tentpole): replicated Sessions, health-driven
failover under injected chaos (kill / poison / hang / straggle), bounded
retries with duplicate suppression, load shedding, and elastic re-admission
after the warmup probe."""
import threading
import time

import numpy as np
import pytest

from repro import asm
from repro.core import executor, pathsearch, quantize
from repro.hw import ZU2
from repro.obs.events import EventLog
from repro.obs.metrics import MetricsRegistry
from repro.runtime import (AdmissionError, ChaosError, ChaosInjector,
                           DeadlineExceeded, Fleet, Session)
from tests.conftest import make_toy_resnet_graph, toy_params


@pytest.fixture(scope="module")
def toy_artifact():
    g = make_toy_resnet_graph()
    params = toy_params(g)
    x = np.random.default_rng(0).standard_normal(
        g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    s = pathsearch.search(g, ZU2)
    return asm.compile_strategy(g, s, ZU2, qm=qm)


@pytest.fixture(scope="module")
def oracle(toy_artifact):
    """Single-session bit-exactness reference + the request inputs."""
    sess = Session.from_artifact(toy_artifact)
    g = sess.graph
    rng = np.random.default_rng(7)
    xs = [rng.integers(-128, 128, g.shape("data")[1:],
                       np.int64).astype(np.int8) for _ in range(24)]
    return xs, [sess.run(x) for x in xs]


def make_fleet(art, n=2, **kw):
    """A fleet with test-speed knobs (fresh registry/event log per test so
    counter asserts don't see other tests' traffic)."""
    kw.setdefault("n_replicas", n)
    kw.setdefault("check_interval_s", 0.01)
    kw.setdefault("heartbeat_timeout_s", 0.5)
    kw.setdefault("retry_backoff_s", 0.005)
    kw.setdefault("attempt_timeout_s", 1.0)
    kw.setdefault("probe_interval_s", 0.03)
    kw.setdefault("probe_timeout_s", 2.0)
    kw.setdefault("registry", MetricsRegistry())
    kw.setdefault("events", EventLog())
    kw.setdefault("server_kw", {"max_batch": 4, "max_latency_s": 1e-3})
    return Fleet(art, **kw)


def assert_bit_exact(got, want):
    assert got is not None
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


def wait_until(pred, timeout_s=8.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        if pred():
            return True
        time.sleep(0.01)
    return False


# ----------------------------------------------------------------- healthy
def test_fleet_serves_bit_exact_across_replicas(toy_artifact, oracle):
    xs, wants = oracle
    with make_fleet(toy_artifact, n=2) as fleet:
        futs = [fleet.submit(x) for x in xs]
        for fut, want in zip(futs, wants):
            assert_bit_exact(fut.result(timeout=30), want)
        st = fleet.stats()
        assert st["completed"] == len(xs)
        assert sorted(st["active"]) == ["r0", "r1"]
        assert sum(r["n_served"] for r in st["replicas"].values()) >= len(xs)


def test_fleet_single_replica_matches_session(toy_artifact, oracle):
    xs, wants = oracle
    with make_fleet(toy_artifact, n=1) as fleet:
        for x, want in zip(xs[:6], wants[:6]):
            assert_bit_exact(fleet.submit(x).result(timeout=30), want)


# -------------------------------------------------------------------- chaos
def test_kill_replica_failover_and_readmission(toy_artifact, oracle):
    """The chaos gate in miniature: kill r1, every request still completes
    bit-exact (retried on r0), r1 is evicted with an event + flight dump,
    then healed and re-admitted after the warmup probe."""
    xs, wants = oracle
    fleet = make_fleet(toy_artifact, n=2)
    chaos = ChaosInjector().attach(fleet)
    try:
        chaos.kill("r1")
        futs = [fleet.submit(x) for x in xs]
        for fut, want in zip(futs, wants):
            assert_bit_exact(fut.result(timeout=30), want)
        assert wait_until(lambda: "r1" not in fleet.active_replicas())
        st = fleet.stats()
        assert st["replicas"]["r1"]["state"] == "evicted"
        assert st["retries"] >= 1 and chaos.fired("kill") >= 1
        assert [e for e in fleet._events.records(kind="replica.evict")
                if e.fields["replica"] == "r1"]
        assert [e for e in fleet._events.records(kind="request.retry")]
        assert fleet.flight.dumps(), "eviction must freeze a flight dump"
        # heal -> warmup probe passes -> elastically re-admitted
        chaos.heal("r1")
        assert fleet.wait_active("r1", timeout_s=10)
        assert fleet.stats()["replicas"]["r1"]["admissions"] >= 1
        admits = [e for e in fleet._events.records(kind="replica.admit")
                  if e.fields["replica"] == "r1"
                  and not e.fields.get("initial")]
        assert admits
        # and traffic flows back through it bit-exactly
        for x, want in zip(xs[:8], wants[:8]):
            assert_bit_exact(fleet.submit(x).result(timeout=30), want)
    finally:
        chaos.heal_all()
        fleet.close()


def test_poison_one_launch_is_retried_transparently(toy_artifact, oracle):
    """A single poisoned launch strikes the replica but stays below the
    eviction threshold; its requests are retried and complete bit-exact."""
    xs, wants = oracle
    fleet = make_fleet(toy_artifact, n=2, max_consecutive_errors=3)
    chaos = ChaosInjector().attach(fleet)
    try:
        chaos.poison("r0", n_launches=1)
        chaos.poison("r1", n_launches=1)
        futs = [fleet.submit(x) for x in xs]
        for fut, want in zip(futs, wants):
            assert_bit_exact(fut.result(timeout=30), want)
        st = fleet.stats()
        assert st["retries"] >= 1
        assert chaos.fired("poison") == 2
        assert sorted(st["active"]) == ["r0", "r1"]   # transient: no eviction
    finally:
        chaos.heal_all()
        fleet.close()


def test_hang_replica_attempt_timeout_drains_elsewhere(toy_artifact, oracle):
    """A wedged replica answers nothing: its in-flight requests must time
    out, drain to the survivor, and the late result (after heal) must be
    duplicate-suppressed, not double-delivered."""
    xs, wants = oracle
    fleet = make_fleet(toy_artifact, n=2, attempt_timeout_s=0.3)
    chaos = ChaosInjector().attach(fleet)
    try:
        chaos.hang("r1")
        futs = [fleet.submit(x) for x in xs]
        for fut, want in zip(futs, wants):
            assert_bit_exact(fut.result(timeout=30), want)
        st = fleet.stats()
        assert st["completed"] == len(xs)
        assert st["retries"] >= 1
        # the hung replica eventually leaves the fleet one way or another
        assert wait_until(lambda: "r1" not in fleet.active_replicas())
    finally:
        chaos.heal_all()
        assert fleet.wait_active("r1", timeout_s=10)
        fleet.close()


def test_straggler_is_evicted(toy_artifact):
    """Step-time EWMAs far beyond the fleet median trip the straggler
    detector (driven directly through the monitor for determinism)."""
    fleet = make_fleet(toy_artifact, n=3)
    try:
        for _ in range(4):
            fleet.monitor.beat("r0", step_time_s=0.01)
            fleet.monitor.beat("r1", step_time_s=0.01)
            fleet.monitor.beat("r2", step_time_s=5.0)
        # evictions is monotone (the healthy replica may be probed back in
        # almost immediately, so don't race on the current state)
        assert wait_until(lambda: fleet.replicas()["r2"].evictions >= 1)
        evs = [e for e in fleet._events.records(kind="replica.evict")
               if e.fields["replica"] == "r2"]
        assert evs and evs[0].fields["reason"] == "straggler"
    finally:
        fleet.close()


def test_deadline_exceeded_when_fleet_is_wedged(toy_artifact, oracle):
    xs, _ = oracle
    fleet = make_fleet(toy_artifact, n=1, request_deadline_s=0.3,
                       attempt_timeout_s=10.0, max_retries=100)
    chaos = ChaosInjector().attach(fleet)
    try:
        chaos.hang("r0")
        fut = fleet.submit(xs[0])
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=30)
        assert fleet.stats()["deadline_exceeded"] >= 1
    finally:
        chaos.heal_all()
        fleet.close()


# ----------------------------------------------------------- load shedding
def test_fleet_sheds_load_past_queue_bound(toy_artifact, oracle):
    xs, wants = oracle
    fleet = make_fleet(toy_artifact, n=1, max_queue_per_replica=2)
    chaos = ChaosInjector().attach(fleet)
    try:
        chaos.slow("r0", 0.05)
        accepted, shed = [], 0
        for x in xs:
            try:
                accepted.append((fleet.submit(x), x))
            except AdmissionError:
                shed += 1
        assert shed >= 1, "queue bound must shed some of the burst"
        assert accepted, "the bound must not shed everything"
        by_x = {i: w for i, (x, w) in enumerate(zip(xs, wants))}
        for fut, x in accepted:
            want = next(w for i, w in by_x.items()
                        if np.array_equal(xs[i], x))
            assert_bit_exact(fut.result(timeout=30), want)
        assert fleet.stats()["rejected"] == shed
    finally:
        chaos.heal_all()
        fleet.close()


def test_no_active_replicas_rejects_not_hangs(toy_artifact, oracle):
    xs, _ = oracle
    fleet = make_fleet(toy_artifact, n=2, request_deadline_s=2.0)
    chaos = ChaosInjector().attach(fleet)
    try:
        chaos.kill("r0")
        chaos.kill("r1")
        futs = []
        try:
            for x in xs[:8]:
                futs.append(fleet.submit(x))
        except AdmissionError:
            pass
        assert wait_until(lambda: not fleet.active_replicas())
        with pytest.raises(AdmissionError):
            fleet.submit(xs[0])
        for fut in futs:                 # accepted ones fail bounded, no hang
            with pytest.raises(Exception):
                fut.result(timeout=30)
    finally:
        chaos.heal_all()
        fleet.close()


# ---------------------------------------------------------------- plumbing
def test_fleet_metrics_and_stats_shape(toy_artifact, oracle):
    xs, wants = oracle
    reg = MetricsRegistry()
    with make_fleet(toy_artifact, n=2, registry=reg) as fleet:
        for x, want in zip(xs[:4], wants[:4]):
            assert_bit_exact(fleet.submit(x).result(timeout=30), want)
        st = fleet.stats()
        assert st["submitted"] == 4 and st["completed"] == 4
        assert reg.get("fleet.submitted").value == 4
        assert reg.get("fleet.active_replicas").value == 2
        for rid in ("r0", "r1"):
            rs = st["replicas"][rid]
            assert rs["state"] == "active" and rs["strikes"] == 0
        # per-batch completions heartbeat the monitor with step times
        assert any(h.step_ema > 0 for h in fleet.monitor.hosts.values())


def test_chaos_log_is_deterministic(toy_artifact):
    fleet = make_fleet(toy_artifact, n=1)
    chaos = ChaosInjector().attach(fleet)
    try:
        chaos.poison("r0", n_launches=2, after_launches=1)
        sess = fleet.replicas()["r0"].session
        x = np.zeros((1,) + tuple(sess.graph.shape("data"))[1:], np.int8)
        sess._launch(x)                          # healthy (after_launches=1)
        with pytest.raises(ChaosError):
            sess._launch(x)
        with pytest.raises(ChaosError):
            sess._launch(x)
        sess._launch(x)                          # poison exhausted
        assert [e["kind"] for e in chaos.log] == ["poison", "poison"]
        assert [e["launch"] for e in chaos.log] == [2, 3]
    finally:
        chaos.heal_all()
        fleet.close()
