"""End-to-end: quantize -> plan -> execute; bit-exact validation (paper C7)
and mixed compilation (C8)."""
import numpy as np
import pytest

from repro.cnn import build, init_params
from repro.core import executor, partition, pathsearch, quantize, validate
from repro.hw import ZU2
from tests.conftest import make_toy_resnet_graph, toy_params


def _calibrated(g, params, rng, size, c):
    x = rng.standard_normal((1, size, size, c)).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    xq = quantize.quantize_to(x, qm.f_a["data"])
    return qm, x, xq


def test_toy_bit_exact_all_strategies(rng):
    g = make_toy_resnet_graph()
    params = toy_params(g)
    qm, x, xq = _calibrated(g, params, rng, 16, 8)
    for strat_fn in (pathsearch.naive, pathsearch.greedy, pathsearch.search):
        s = strat_fn(g, ZU2)
        rep = validate.bit_exact(g, qm, xq, strategy=s, backend="pallas",
                                 float_params=params)
        assert rep.bit_exact, (strat_fn.__name__, rep.max_abs_diff)


def test_fusion_never_changes_numerics(rng):
    """Any strategy == naive bit-for-bit (fusion is execution-only)."""
    g = make_toy_resnet_graph()
    params = toy_params(g)
    qm, _, xq = _calibrated(g, params, rng, 16, 8)
    s = pathsearch.search(g, ZU2)
    from repro.core.executor import Int8Executor

    ref = Int8Executor(g, qm, strategy=None, backend="ref")(xq)
    fused = Int8Executor(g, qm, strategy=s, backend="ref")(xq)
    for k in ref:
        np.testing.assert_array_equal(ref[k], fused[k])


@pytest.mark.parametrize("model,img", [("vgg16", 32), ("resnet50", 32),
                                       ("googlenet", 64), ("yolo_lite", 64)])
def test_small_cnn_bit_exact(model, img, rng):
    g = build(model, img=img, num_classes=10) if model != "yolo_lite" \
        else build(model, img=img)
    params = init_params(g)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    xq = quantize.quantize_to(x, qm.f_a["data"])
    s = pathsearch.search(g, ZU2)
    rep = validate.bit_exact(g, qm, xq, strategy=s, backend="pallas")
    assert rep.bit_exact, rep.max_abs_diff


def test_quantization_sqnr_reasonable(rng):
    """Int8 path should track the float model (random weights, so the bar is
    qualitative: positive SQNR on the pre-softmax output)."""
    g = make_toy_resnet_graph()
    params = toy_params(g)
    qm, x, xq = _calibrated(g, params, rng, 16, 8)
    rep = validate.bit_exact(g, qm, xq, strategy=None, backend="ref",
                             float_params=params)
    assert all(v > 0 for v in rep.sqnr_db.values()), rep.sqnr_db


def test_partition_paper_policy():
    g = make_toy_resnet_graph()
    table = partition.assign(g, "paper")
    assert table["fc1"] == "cpu"
    assert table["c1"] == "acc"
    table2 = partition.assign(g, "all_acc")
    assert table2["fc1"] == "acc"


def test_planner_respects_partition():
    g = make_toy_resnet_graph()
    dv = partition.device_of(g, "paper")
    s = pathsearch.search(g, ZU2, device_of=dv)
    assert ["fc1"] not in s.groups
    assert "fc1" in s.meta["host_nodes"]
