"""Tiling solver (Eq. 5/6) + cost model + CTC (Eq. 1/2) properties."""
import math

import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import frontend, tiling
from repro.core.cost import AnalyticEvaluator, SimulatorEvaluator
from repro.core.xgraph import XGraph
from repro.hw import ZU2, ZU9, TPU_V5E
from tests.conftest import make_toy_resnet_graph


def _single_conv(h, w, ic, oc, k):
    g = XGraph()
    g.input("x", (1, h, w, ic))
    g.add("conv", "c", ("x",), oc=oc, kernel=(k, k), pad="same")
    return g


@settings(max_examples=40, deadline=None)
@given(st.integers(8, 64), st.integers(8, 64), st.sampled_from([3, 16, 64]),
       st.sampled_from([8, 32, 128]), st.sampled_from([1, 3, 5]))
def test_tile_respects_buffers(h, w, ic, oc, k):
    g = _single_conv(h, w, ic, oc, k)
    for dev in (ZU2, ZU9):
        t = tiling.solve(g, ["c"], dev)
        assert t.feasible
        # Eq. 5: pinned tile dims
        assert t.t_h == min(dev.h_p, h) and t.t_oc == min(dev.oc_p, oc)
        # Eq. 6: the chosen T_w working set fits every buffer
        in_w = (t.t_w - 1) + k
        in_h = (t.t_h - 1) + k
        assert min(dev.ic_p, ic) * in_w * in_h <= dev.buf_in_bytes
        assert t.t_w * t.t_h * t.t_oc <= dev.buf_out_bytes
        # maximality: T_w is as large as possible
        assert t.t_w == w or not _fits(g, dev, t.t_w + 1, t.t_h, t.t_oc, k, ic)


def _fits(g, dev, tw, th, toc, k, ic):
    in_tile = min(dev.ic_p, ic) * ((tw - 1) + k) * ((th - 1) + k)
    out_tile = tw * th * toc
    return (in_tile <= dev.buf_in_bytes and out_tile <= dev.buf_out_bytes)


def test_fusion_reduces_traffic_and_ctc_increases():
    """Eq. 1 -> Eq. 2: fusing removes intermediate DRAM traffic."""
    g = XGraph()
    g.input("x", (1, 28, 28, 32))
    g.add("conv", "c", ("x",), oc=64, kernel=(3, 3), pad="same")
    g.add("maxpool", "p", ("c",), kernel=(2, 2), stride=(2, 2))
    frontend.lower(g)
    ev = AnalyticEvaluator(g, ZU2)
    sep = (ev.cost(["c"]).tiling.dram_bytes + ev.cost(["p"]).tiling.dram_bytes)
    fused = ev.cost(["c", "p"]).tiling.dram_bytes
    assert fused < sep
    assert ev.ctc(["c", "p"]) > (
        sum(g.ops(n) for n in ("c", "p")) / sep)


def test_infeasible_giant_group_rejected():
    """Condition 1: a fused chain whose working set cannot fit even at
    T_w = 1 must be rejected."""
    g = XGraph()
    g.input("x", (1, 224, 224, 512))
    g.add("conv", "a", ("x",), oc=32768, kernel=(3, 3), pad="same")
    g.add("conv", "b", ("a",), oc=2048, kernel=(3, 3), pad="same")
    t = tiling.solve(g, ["a", "b"], ZU2)
    # conv->conv forces a full-channel resident intermediate: even at
    # T_w = 1 the 3x6x32768 tile exceeds ZU2's output BRAM
    assert not t.feasible
    # ...but a moderate conv->conv line-buffer schedule IS feasible
    g2 = XGraph()
    g2.input("x", (1, 56, 56, 64))
    g2.add("conv", "a", ("x",), oc=64, kernel=(3, 3), pad="same")
    g2.add("conv", "b", ("a",), oc=64, kernel=(3, 3), pad="same")
    assert tiling.solve(g2, ["a", "b"], ZU2).feasible


def test_sim_close_to_analytic():
    g = make_toy_resnet_graph()
    ana = AnalyticEvaluator(g, ZU2)
    sim = SimulatorEvaluator(g, ZU2)
    for grp in ([["c1"], ["c2a"], ["p1"], ["c2b", "add1"]]):
        a, s = ana(grp), sim(grp)
        assert math.isfinite(a) and math.isfinite(s)
        assert 0.5 < a / s < 2.0, (grp, a, s)


def test_horizontal_saves_input_load():
    g = make_toy_resnet_graph()
    t = tiling.solve_horizontal(g, ["c2a", "c2s"], ZU2)
    assert t.feasible
    parts = [tiling.solve(g, [s], ZU2) for s in ("c2a", "c2s")]
    assert t.load_bytes < sum(p.load_bytes for p in parts)


def test_tpu_device_model_scales():
    """The same machinery runs against the TPU v5e model with VMEM-scale
    buffers (the hardware-adaptation claim)."""
    g = _single_conv(56, 56, 256, 256, 3)
    t = tiling.solve(g, ["c"], TPU_V5E)
    assert t.feasible and t.t_oc == 128 and t.t_w == 56
